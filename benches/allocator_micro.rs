//! Microbenchmarks of the caching-allocator simulator — the L3 hot path.
//! Used by EXPERIMENTS.md §Perf (replay throughput target: >= 10 M ops/s).
//!
//! The `large-pool churn` workload is the indexed-allocator acceptance
//! benchmark: thousands of partially-used segments pin cached blocks
//! while a hot alloc/free/`empty_cache` loop runs on top. The seed
//! allocator re-scanned every pooled block (and every driver segment
//! slot) per `empty_cache`; the fully-free-segment index visits only the
//! segment actually released — ≥2× allocator-op throughput here.

use rlhf_mem::alloc::CachingAllocator;
use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::bench::workloads::{large_pool_churn, large_pool_churn_ops};
use rlhf_mem::bench::{bench, throughput};
use rlhf_mem::util::bytes::{GIB, KIB, MIB};
use rlhf_mem::util::prng::Rng;

fn main() {
    let mut entries: Vec<LocalEntry> = Vec::new();

    // 1. alloc/free ping-pong (cache hits).
    let r = bench("alloc/free cache-hit pairs (x100k)", 1, 10, || {
        let mut a = CachingAllocator::with_default_config(GIB);
        for _ in 0..100_000 {
            let h = a.alloc(64 * KIB).unwrap();
            a.free(h);
        }
    });
    println!("{}  -> {:.1} M ops/s", r.report(), throughput(&r, 200_000.0) / 1e6);
    entries.push(LocalEntry::timed(&r, Some(200_000.0)));

    // 2. mixed-size steady state.
    let r = bench("mixed sizes steady-state (x100k)", 1, 5, || {
        let mut rng = Rng::seeded(7);
        let mut a = CachingAllocator::with_default_config(8 * GIB);
        let mut live = Vec::new();
        for _ in 0..100_000 {
            if live.is_empty() || rng.bernoulli(0.55) {
                let sz = match rng.gen_range(4) {
                    0 => rng.gen_range(4 * KIB) + 1,
                    1 => rng.gen_range(900 * KIB) + KIB,
                    2 => rng.gen_range(8 * MIB) + MIB,
                    _ => rng.gen_range(64 * MIB) + 10 * MIB,
                };
                if let Ok(h) = a.alloc(sz) {
                    live.push(h);
                }
            } else {
                let i = rng.range_usize(0, live.len());
                a.free(live.swap_remove(i));
            }
        }
        for h in live.drain(..) {
            a.free(h);
        }
    });
    println!("{}  -> {:.1} M ops/s", r.report(), throughput(&r, 200_000.0) / 1e6);
    entries.push(LocalEntry::timed(&r, Some(200_000.0)));

    // 3. empty_cache on a populated cache.
    let r = bench("empty_cache (200 cached segments)", 1, 20, || {
        let mut a = CachingAllocator::with_default_config(64 * GIB);
        let hs: Vec<_> = (0..200).map(|_| a.alloc(32 * MIB).unwrap()).collect();
        for h in hs {
            a.free(h);
        }
        a.empty_cache();
    });
    println!("{}", r.report());
    entries.push(LocalEntry::timed(&r, None));

    // 4. large-pool churn — the fully-free-segment index's acceptance
    // workload (shared with `rlhf-mem bench`'s alloc_churn).
    let churn_ops = large_pool_churn_ops() as f64;
    let r = bench("large-pool churn (6k pinned segs)", 1, 5, || {
        let a = large_pool_churn();
        assert_eq!(a.reserved(), 0);
    });
    println!(
        "{}  -> {:.2} M alloc-ops/s",
        r.report(),
        throughput(&r, churn_ops) / 1e6
    );
    entries.push(LocalEntry::timed(&r, Some(churn_ops)));

    // 5. end-to-end scenario replay (the Table-1 inner loop).
    use rlhf_mem::experiment::{run_trace, RTX3090_HBM};
    use rlhf_mem::policy::EmptyCachePolicy;
    use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
    use rlhf_mem::strategies::StrategyConfig;
    let scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
    let trace = build_trace(&scn);
    let ops = trace.len() as f64;
    let r = bench("replay DS/OPT all-enabled (3 steps)", 1, 5, || {
        let _ = run_trace(&trace, RTX3090_HBM);
    });
    println!("{}  -> {:.1} M trace-ops/s", r.report(), throughput(&r, ops) / 1e6);
    entries.push(LocalEntry::timed(&r, Some(ops)));

    emit_local("allocator_micro", &entries);
}
