//! Bench for §3.3's placement ablation (E7): never / after-both /
//! after-inference / after-training.

use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::json::Json;

fn main() {
    let mut results = Vec::new();
    let mut entries: Vec<LocalEntry> = Vec::new();
    for policy in EmptyCachePolicy::ALL {
        let mut scn = SimScenario::colossal_gpt2(StrategyConfig::zero3(), policy);
        scn.steps = 3;
        let res = run_scenario(&scn, RTX3090_HBM);
        println!(
            "{:<16} reserved {:>6} GiB  frag {:>6} GiB  (empty_cache calls: {})",
            policy.name(),
            fmt_gib_paper(res.summary.peak_reserved),
            fmt_gib_paper(res.summary.frag),
            res.summary.empty_cache_calls
        );
        entries.push(LocalEntry::counters(
            policy.name(),
            Json::obj(vec![
                ("peak_reserved", Json::from(res.summary.peak_reserved)),
                ("frag", Json::from(res.summary.frag)),
                (
                    "empty_cache_calls",
                    Json::from(res.summary.empty_cache_calls),
                ),
            ]),
        ));
        results.push((policy, res.summary));
    }
    let get = |p: EmptyCachePolicy| results.iter().find(|(q, _)| *q == p).unwrap().1.clone();
    let never = get(EmptyCachePolicy::Never);
    let both = get(EmptyCachePolicy::AfterBoth);
    let inf = get(EmptyCachePolicy::AfterInference);
    // §3.3: after-inference ≈ after-both, both better than never.
    assert!(both.peak_reserved <= never.peak_reserved);
    assert!(inf.peak_reserved <= never.peak_reserved);
    let gap = (inf.peak_reserved as f64 - both.peak_reserved as f64).abs()
        / both.peak_reserved as f64;
    assert!(gap < 0.15, "after_inference should be within 15% of after_both, gap {gap:.2}");
    println!("empty_cache_ablation bench complete (orderings hold)");
    emit_local("empty_cache_ablation", &entries);
}
