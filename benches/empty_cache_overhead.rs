//! Bench for §3.3's cost/benefit claim (E8): memory saved vs end-to-end
//! simulated time overhead of empty_cache across representative rows.

use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::measure_row_full;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::json::Json;

fn main() {
    let rows: Vec<(&str, SimScenario)> = vec![
        ("DS/OPT ZeRO-3", SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never)),
        ("DS/OPT All", SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never)),
        ("CC/OPT None", SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never)),
        ("CC/GPT2 None", SimScenario::colossal_gpt2(StrategyConfig::none(), EmptyCachePolicy::Never)),
        ("CC/GPT2 ZeRO-3", SimScenario::colossal_gpt2(StrategyConfig::zero3(), EmptyCachePolicy::Never)),
    ];
    let mut worst_overhead: f64 = 0.0;
    let mut entries: Vec<LocalEntry> = Vec::new();
    for (label, scn) in rows {
        let (row, orig, ec) = measure_row_full(label, &scn, RTX3090_HBM);
        let saved = 1.0 - row.with_empty_cache.peak_reserved as f64 / row.original.peak_reserved as f64;
        let overhead = ec.summary.total_time_us / orig.summary.total_time_us - 1.0;
        worst_overhead = worst_overhead.max(overhead);
        println!(
            "{label:<18} mem saved {:>5.1}%   time overhead {:>5.2}%   (frag {:.1} -> {:.1} GiB)",
            saved * 100.0,
            overhead * 100.0,
            row.original.frag as f64 / (1u64 << 30) as f64,
            row.with_empty_cache.frag as f64 / (1u64 << 30) as f64,
        );
        entries.push(LocalEntry::counters(
            label,
            Json::obj(vec![
                ("peak_reserved", Json::from(row.original.peak_reserved)),
                (
                    "peak_reserved_with_empty_cache",
                    Json::from(row.with_empty_cache.peak_reserved),
                ),
                ("overhead_pct", Json::from(overhead * 100.0)),
            ]),
        ));
    }
    // Paper: ~2% average overhead. Assert the order of magnitude: well
    // under 10% on every row.
    assert!(worst_overhead < 0.10, "time overhead too high: {worst_overhead:.3}");
    println!("empty_cache_overhead bench complete (overhead < 10% everywhere)");
    emit_local("empty_cache_overhead", &entries);
}
