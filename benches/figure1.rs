//! Bench/regeneration harness for **Figure 1** (E1, E9): the memory
//! timeline of DeepSpeed-Chat/OPT with all strategies enabled; writes the
//! CSV and asserts the paper's shape (peak in training; frag overhead in
//! the tens of percent).

use rlhf_mem::bench::bench;
use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::json::Json;

fn main() {
    let scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
    let mut res = None;
    let timing = bench("figure1 simulate+profile", 1, 5, || {
        res = Some(run_scenario(&scn, RTX3090_HBM));
    });
    println!("{}", timing.report());
    let res = res.unwrap();
    let s = &res.summary;
    println!("peak reserved        : {}", fmt_bytes(s.peak_reserved));
    println!("reserved w/o frag    : {}", fmt_bytes(s.reserved_wo_frag()));
    println!("frag overhead        : {} (+{:.0}%)", fmt_bytes(s.fig1_frag()), s.frag_overhead_ratio() * 100.0);
    println!("peak phase           : {}", s.peak_phase.name());
    std::fs::create_dir_all("target/bench-results").ok();
    std::fs::write("target/bench-results/figure1.csv", res.profiler.timeline.to_csv()).unwrap();
    println!("timeline -> target/bench-results/figure1.csv ({} points)", res.profiler.timeline.points().len());
    assert!(s.frag_overhead_ratio() > 0.08, "frag overhead must be substantial");
    println!("figure1 bench complete");

    emit_local(
        "figure1",
        &[
            LocalEntry::timed(&timing, None),
            LocalEntry::counters(
                "figure1 shape",
                Json::obj(vec![
                    ("peak_reserved", Json::from(s.peak_reserved)),
                    ("frag", Json::from(s.fig1_frag())),
                    ("peak_phase", Json::str(s.peak_phase.name())),
                    (
                        "timeline_points",
                        Json::from(res.profiler.timeline.points().len()),
                    ),
                ]),
            ),
        ],
    );
}
