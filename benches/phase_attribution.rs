//! Bench for §3.1's three-scenario comparison (E6): full pipeline vs
//! training-only scenarios — fragmentation must come from the inferences.

use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::{ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::json::Json;

fn main() {
    let mut out = Vec::new();
    let mut entries: Vec<LocalEntry> = Vec::new();
    for (label, mode) in [
        ("full pipeline", ScenarioMode::Full),
        ("train both (pre-collected)", ScenarioMode::TrainBothPrecollected),
        ("train actor only", ScenarioMode::TrainActorOnly),
    ] {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
        scn.mode = mode;
        let res = run_scenario(&scn, RTX3090_HBM);
        println!(
            "{label:<32} reserved {:>6} GiB  frag {:>6} GiB  allocated {:>6} GiB",
            fmt_gib_paper(res.summary.peak_reserved),
            fmt_gib_paper(res.summary.frag),
            fmt_gib_paper(res.summary.peak_allocated),
        );
        entries.push(LocalEntry::counters(
            label,
            Json::obj(vec![
                ("peak_reserved", Json::from(res.summary.peak_reserved)),
                ("frag", Json::from(res.summary.frag)),
                ("peak_allocated", Json::from(res.summary.peak_allocated)),
            ]),
        ));
        out.push(res.summary);
    }
    // Paper §3.1: the full pipeline shows more fragmentation and reserved
    // memory than the training-only scenarios.
    assert!(out[0].frag >= out[1].frag, "inference must drive fragmentation");
    assert!(out[0].peak_reserved >= out[1].peak_reserved);
    assert!(out[1].peak_reserved >= out[2].peak_reserved, "actor-only is smallest");
    println!("phase_attribution bench complete (orderings hold)");
    emit_local("phase_attribution", &entries);
}
