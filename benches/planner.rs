//! Bench harness for the memory planner: one full `advise` search over
//! the paper's RTX-3090 budget (every strategy × `empty_cache` placement
//! × allocator-knob candidate), timed serially and on the worker pool —
//! same shape as `benches/table1.rs`. Asserts the recommendation output
//! is byte-identical whatever the job count (the planner's determinism
//! contract).

use rlhf_mem::bench::bench;
use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::bench::workloads::hash_text;
use rlhf_mem::planner::{plan, Budget};
use rlhf_mem::sweep::SweepRunner;
use rlhf_mem::util::json::Json;

fn main() {
    let budget = Budget::from_json_text(include_str!("../examples/budget_rtx3090.json"))
        .expect("example budget parses");
    let candidates = rlhf_mem::planner::space::enumerate(&budget)
        .expect("space enumerates")
        .len();
    let jobs = SweepRunner::default_jobs().min(8);
    println!("advise search: {candidates} candidates, pool of {jobs} workers\n");

    let mut serial = None;
    let t1 = bench("advise --jobs 1", 0, 2, || {
        serial = Some(plan(&budget, 1).expect("plan"));
    });
    println!("{}", t1.report());

    let mut pooled = None;
    let tn = bench(&format!("advise --jobs {jobs}"), 0, 2, || {
        pooled = Some(plan(&budget, jobs).expect("plan"));
    });
    println!("{}", tn.report());
    let speedup = t1.summary.median / tn.summary.median;
    println!("parallel speedup: {speedup:.2}x on {jobs} workers\n");

    let (serial, pooled) = (serial.unwrap(), pooled.unwrap());
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "recommendations must be byte-identical whatever the job count"
    );
    assert_eq!(
        serial.best().map(|o| o.candidate.key()),
        pooled.best().map(|o| o.candidate.key()),
    );

    println!("{}", pooled.to_table(10).render());
    println!("== frontier ==\n{}", pooled.frontier_table().render());
    if let Some(pct) = pooled.empty_cache_frontier_overhead() {
        println!("empty_cache (stock allocator) on frontier at {pct:+.2}% overhead (paper: ~2%)");
    } else if let Some(pct) = pooled.any_empty_cache_frontier_overhead() {
        println!("cheapest empty_cache placement on frontier at {pct:+.2}% overhead");
    }
    println!(
        "planner bench complete: {candidates} candidates, speedup {speedup:.2}x"
    );

    emit_local(
        "planner",
        &[
            LocalEntry::timed(&t1, Some(candidates as f64)),
            LocalEntry::timed(&tn, Some(candidates as f64)),
            LocalEntry::counters(
                "advise results",
                Json::obj(vec![
                    ("candidates", Json::from(candidates)),
                    ("jsonl_fingerprint", Json::str(hash_text(&pooled.jsonl()))),
                ]),
            ),
        ],
    );
}
