//! Trace-generation throughput across the algorithm axis — guards the
//! PhaseProgram interpreter's hot path. `build_trace` is pure CPU (no
//! allocator replay), so this measures exactly what the compile +
//! interpret refactor touched: ops emitted per second per algorithm.

use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::bench::workloads::fmt_fingerprint;
use rlhf_mem::bench::{bench, throughput};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::{Algo, PhaseProgram};
use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::json::Json;

fn main() {
    println!("trace-generation throughput (DeepSpeed-Chat/OPT, ZeRO-3, 2 steps)\n");
    let mut entries: Vec<LocalEntry> = Vec::new();
    let mut total_mops = 0.0;
    for algo in Algo::ALL {
        let mut scn =
            SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        scn.steps = 2;
        scn.algo = algo;
        let trace = build_trace(&scn);
        let ops = trace.len();
        let r = bench(&format!("build_trace {} ({} ops)", algo.name(), ops), 1, 5, || {
            let t = build_trace(&scn);
            assert!(!t.is_empty());
        });
        println!("{}", r.report());
        let mops = throughput(&r, ops as f64) / 1e6;
        println!("    {:>8.2} Mops/s", mops);
        total_mops += mops;
        entries.push(LocalEntry::timed(&r, Some(ops as f64)));
        entries.push(LocalEntry::counters(
            format!("trace {}", algo.name()),
            Json::obj(vec![
                ("trace_ops", Json::from(ops)),
                (
                    "trace_fingerprint",
                    Json::str(fmt_fingerprint(trace.fingerprint())),
                ),
            ]),
        ));
    }

    // Compilation alone should be vanishingly cheap next to emission.
    let scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
    let r = bench("PhaseProgram::compile x1000", 1, 5, || {
        for _ in 0..1000 {
            let p = PhaseProgram::compile(&scn);
            assert!(!p.nodes.is_empty());
        }
    });
    println!("{}", r.report());
    entries.push(LocalEntry::timed(&r, Some(1000.0)));
    println!(
        "\nsim_trace bench complete: {:.2} Mops/s summed across {} algorithms",
        total_mops,
        Algo::ALL.len()
    );
    emit_local("sim_trace", &entries);
}
