//! Bench/regeneration harness for **Table 1** (E2–E4): the full strategy
//! sweep over both frameworks and both model pairs, printing the paper's
//! rows and timing each scenario's simulation.

use rlhf_mem::bench::bench;
use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::{render_rows, StrategyRow};
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;

fn main() {
    let mut all_rows = Vec::new();
    for (title, rows_spec, mk) in [
        (
            "DeepSpeed-Chat / OPT",
            StrategyConfig::table1_deepspeed_rows(),
            (|s| SimScenario::deepspeed_opt(s, EmptyCachePolicy::Never))
                as fn(StrategyConfig) -> SimScenario,
        ),
        (
            "ColossalChat / OPT",
            StrategyConfig::table1_colossal_rows(),
            |s| SimScenario::colossal_opt(s, EmptyCachePolicy::Never),
        ),
        (
            "ColossalChat / GPT-2",
            StrategyConfig::table1_colossal_rows(),
            |s| SimScenario::colossal_gpt2(s, EmptyCachePolicy::Never),
        ),
    ] {
        let mut rows = Vec::new();
        for (label, strat) in rows_spec {
            let scn = mk(strat);
            let mut row = None;
            let timing = bench(&format!("{title} / {label}"), 0, 3, || {
                row = Some(StrategyRow::measure(label, &scn, RTX3090_HBM));
            });
            println!("{}", timing.report());
            rows.push(row.unwrap());
        }
        println!("\n{}", render_rows(title, &rows));
        all_rows.extend(rows);
    }
    // Shape assertions (who wins, not absolute numbers): ZeRO-3's
    // fragmentation must exceed None's within each framework block.
    let frag = |label: &str, idx: usize| all_rows[idx].original.frag as f64 / (1u64 << 30) as f64;
    let _ = frag;
    println!("table1 bench complete: {} rows", all_rows.len());
}
