//! Bench/regeneration harness for **Table 1** (E2–E4) on the sweep
//! engine: the full strategy sweep over both frameworks and both model
//! pairs (grid from `rlhf_mem::sweep::presets`, shared with the CLI),
//! timed serially (`jobs=1`) and on the worker pool, printing the
//! paper's rows plus the parallel speedup.

use rlhf_mem::bench::bench;
use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::bench::workloads::hash_text;
use rlhf_mem::report::paper::render_rows;
use rlhf_mem::sweep::{presets, SweepRunner};
use rlhf_mem::util::json::Json;

fn main() {
    let cells = presets::table1_cells(3).expect("table1 grid");
    let n = cells.len();
    let jobs = SweepRunner::default_jobs().min(8);
    println!("table1 sweep: {n} cells, pool of {jobs} workers\n");

    let mut serial = None;
    let t1 = bench("table1 sweep --jobs 1", 0, 2, || {
        serial = Some(SweepRunner::new(1).run(cells.clone()));
    });
    println!("{}", t1.report());

    let mut pooled = None;
    let tn = bench(&format!("table1 sweep --jobs {jobs}"), 0, 2, || {
        pooled = Some(SweepRunner::new(jobs).run(cells.clone()));
    });
    println!("{}", tn.report());
    let speedup = t1.summary.median / tn.summary.median;
    println!("parallel speedup: {speedup:.2}x on {jobs} workers\n");

    let (serial, pooled) = (serial.unwrap(), pooled.unwrap());
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "per-cell results must be byte-identical whatever the job count"
    );

    for (fw, model, rows) in pooled.strategy_rows() {
        println!("{}", render_rows(&format!("{fw} / {model}"), &rows));
    }
    println!("table1 bench complete: {n} cells, speedup {speedup:.2}x");

    emit_local(
        "table1",
        &[
            LocalEntry::timed(&t1, Some(n as f64)),
            LocalEntry::timed(&tn, Some(n as f64)),
            LocalEntry::counters(
                "table1 results",
                Json::obj(vec![
                    ("cells", Json::from(n)),
                    ("jsonl_fingerprint", Json::str(hash_text(&pooled.jsonl()))),
                ]),
            ),
        ],
    );
}
