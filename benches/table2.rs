//! Bench/regeneration harness for **Table 2** (E5) on the sweep engine:
//! None vs ZeRO-3 on a 4xA100-80G node for OPT-1.3b / OPT-6.7b /
//! Llama-2-7b (grid from `rlhf_mem::sweep::presets`, shared with the
//! CLI), timed serially and on the worker pool.

use rlhf_mem::bench::bench;
use rlhf_mem::bench::report::{emit_local, LocalEntry};
use rlhf_mem::bench::workloads::hash_text;
use rlhf_mem::report::paper::{paper_table2, render_rows};
use rlhf_mem::sweep::{presets, SweepRunner};
use rlhf_mem::util::json::Json;

fn main() {
    let cells = presets::table2_cells(3).expect("table2 grid");
    let jobs = SweepRunner::default_jobs().min(8);
    println!("table2 sweep: {} cells, pool of {jobs} workers\n", cells.len());

    let t1 = bench("table2 sweep --jobs 1", 0, 2, || {
        SweepRunner::new(1).run(cells.clone());
    });
    println!("{}", t1.report());
    let mut pooled = None;
    let tn = bench(&format!("table2 sweep --jobs {jobs}"), 0, 2, || {
        pooled = Some(SweepRunner::new(jobs).run(cells.clone()));
    });
    println!("{}", tn.report());
    println!(
        "parallel speedup: {:.2}x on {jobs} workers\n",
        t1.summary.median / tn.summary.median
    );

    let pooled = pooled.unwrap();
    for (_fw, model, rows) in pooled.strategy_rows() {
        println!("{}", render_rows(&format!("{model} (4xA100-80G)"), &rows));
    }
    println!("paper reference:");
    for (model, strat, v) in paper_table2() {
        println!(
            "  {model:<12} {strat:<8} {:>5.1} {:>5.1} {:>5.1} | {:>5.1} {:>5.1}",
            v[0], v[1], v[2], v[3], v[4]
        );
    }

    let n = pooled.cells.len();
    emit_local(
        "table2",
        &[
            LocalEntry::timed(&t1, Some(n as f64)),
            LocalEntry::timed(&tn, Some(n as f64)),
            LocalEntry::counters(
                "table2 results",
                Json::obj(vec![
                    ("cells", Json::from(n)),
                    ("jsonl_fingerprint", Json::str(hash_text(&pooled.jsonl()))),
                ]),
            ),
        ],
    );
}
