//! Bench/regeneration harness for **Table 2** (E5): None vs ZeRO-3 on a
//! 4xA100-80G node for OPT-1.3b / OPT-6.7b / Llama-2-7b (full fine-tune).

use rlhf_mem::bench::bench;
use rlhf_mem::experiment::A100_HBM;
use rlhf_mem::mem::ModelArch;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::{paper_table2, render_rows, StrategyRow};
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::models::RlhfModelSet;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;

fn main() {
    for arch_name in ["opt-1.3b", "opt-6.7b", "llama-2-7b"] {
        let arch = ModelArch::by_name(arch_name).unwrap();
        let mut rows = Vec::new();
        for (label, strat) in [
            ("None", StrategyConfig::none()),
            ("ZeRO-3", StrategyConfig::zero3()),
        ] {
            let mut scn = SimScenario::colossal_opt(strat, EmptyCachePolicy::Never);
            scn.models = RlhfModelSet {
                policy_arch: arch.clone(),
                value_arch: ModelArch::opt_350m(),
            };
            scn.framework.prompt_len = 256;
            scn.framework.gen_len = 256;
            scn.framework.rollout_batch = 64;
            scn.framework.infer_micro_batch = 8;
            scn.framework.train_micro_batch = 4;
            scn.gpu = GpuSpec::a100_80g();
            let mut row = None;
            let timing = bench(&format!("table2 {arch_name}/{label}"), 0, 2, || {
                row = Some(StrategyRow::measure(label, &scn, A100_HBM));
            });
            println!("{}", timing.report());
            rows.push(row.unwrap());
        }
        println!("\n{}", render_rows(&format!("{arch_name} (4xA100-80G)"), &rows));
    }
    println!("paper reference:");
    for (model, strat, v) in paper_table2() {
        println!("  {model:<12} {strat:<8} {:>5.1} {:>5.1} {:>5.1} | {:>5.1} {:>5.1}", v[0], v[1], v[2], v[3], v[4]);
    }
}
