//! Figure-1 regeneration (E1/E9): profile DeepSpeed-Chat/OPT with all
//! strategies enabled, dump the timeline CSV, and verify the paper's two
//! headline observations — the peak is in a training phase, and the
//! fragmentation overhead at the peak is tens of percent.
//!
//! Run: `cargo run --release --example fragmentation_study`

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_bytes;

fn main() {
    let scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
    let res = run_scenario(&scn, RTX3090_HBM);
    let s = &res.summary;

    println!("{}", res.profiler.timeline.ascii_chart(110, 16));
    println!();
    println!("red cross    (peak reserved)    : {}", fmt_bytes(s.peak_reserved));
    println!("yellow cross (w/o fragmentation): {}", fmt_bytes(s.reserved_wo_frag()));
    println!("fragmentation overhead          : {} (+{:.0}%)", fmt_bytes(s.fig1_frag()), s.frag_overhead_ratio() * 100.0);
    println!("phase of the peak               : {}", s.peak_phase.name());
    println!("frag samples at cudaMalloc      : {}", res.profiler.frag_samples.len());

    std::fs::write("fragmentation_timeline.csv", res.profiler.timeline.to_csv()).unwrap();
    println!("timeline -> fragmentation_timeline.csv");

    assert!(
        s.peak_phase.is_training() || s.peak_phase.is_inference(),
        "peak must land in a PPO work phase"
    );
    assert!(s.frag_overhead_ratio() > 0.08, "fragmentation must be substantial");
    println!("OK: paper's Figure-1 shape reproduced");
}
