//! Quickstart: simulate one PPO step of DeepSpeed-Chat/OPT on a 24 GiB
//! GPU, print the memory summary and the Figure-1-style timeline, then
//! show the effect of the paper's `empty_cache()` mitigation.
//!
//! Run: `cargo run --release --example quickstart`

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_bytes;

fn main() {
    for policy in [EmptyCachePolicy::Never, EmptyCachePolicy::AfterInference] {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), policy);
        scn.steps = 2;
        let res = run_scenario(&scn, RTX3090_HBM);
        let s = &res.summary;
        println!("== policy: {} ==", policy.name());
        println!("  peak reserved : {}", fmt_bytes(s.peak_reserved));
        println!("  fragmentation : {}", fmt_bytes(s.frag));
        println!("  peak allocated: {}", fmt_bytes(s.peak_allocated));
        println!("  peak phase    : {}\n", s.peak_phase.name());
    }
    let scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
    let res = run_scenario(&scn, RTX3090_HBM);
    println!("{}", res.profiler.timeline.ascii_chart(100, 12));
}
