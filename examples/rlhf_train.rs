//! **End-to-end driver (E10)**: real PPO training of a small transformer
//! through the full three-layer stack — Rust coordinator -> PJRT -> AOT
//! HLO from JAX (L2) with the Pallas attention kernel variant available
//! (L1). Generation, scoring, synthetic preference reward, GAE and the
//! fused train step all run from Rust; Python is never on this path.
//!
//! Run: `make artifacts && cargo run --release --example rlhf_train -- [iters]`
//! Writes `rlhf_train_curve.csv`; the run is recorded in EXPERIMENTS.md.

use rlhf_mem::rlhf::real::{PpoConfig, RealPpoTrainer};
use rlhf_mem::runtime::{KernelVariant, RlhfEngine};

fn main() {
    let iters: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let engine = RlhfEngine::load("artifacts", "opt-nano", KernelVariant::Jnp)
        .expect("run `make artifacts` first");
    println!(
        "opt-nano: {} params, batch {}, seq {} ({} prompt)",
        engine.manifest.num_params, engine.manifest.batch, engine.manifest.max_seq,
        engine.manifest.prompt
    );
    let mut trainer = RealPpoTrainer::new(engine, PpoConfig::default());
    for _ in 0..iters {
        let s = trainer.step().expect("ppo step");
        if s.iter % 5 == 0 || s.iter <= 3 {
            println!(
                "iter {:>4}  reward {:>7.3}  kl {:>7.4}  pg {:>8.4}  vf {:>8.4}  ent {:>6.3}",
                s.iter, s.mean_reward, s.mean_kl, s.policy_loss, s.value_loss, s.entropy
            );
        }
    }
    std::fs::write("rlhf_train_curve.csv", trainer.history_csv()).unwrap();
    let k = trainer.history.len().min(10);
    let first: f32 = trainer.history[..k].iter().map(|h| h.mean_reward).sum::<f32>() / k as f32;
    let last: f32 = trainer.history[trainer.history.len() - k..].iter().map(|h| h.mean_reward).sum::<f32>() / k as f32;
    println!("\nmean reward first-{k}: {first:.3}   last-{k}: {last:.3}");
    println!("curve -> rlhf_train_curve.csv");
    if last > first {
        println!("OK: reward improved (policy aligned to the synthetic preference)");
    }
}
