//! Table-1 regeneration (E2–E4) as a library-API walkthrough, now on the
//! sweep engine: define the DeepSpeed-Chat/OPT strategy grid, run it on a
//! worker pool, print the paper-style table, and check the paper's §3.2
//! insights hold.
//!
//! Run: `cargo run --release --example strategy_sweep`

use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SweepGrid, SweepRunner};

fn main() {
    let cells = SweepGrid::new() // defaults: DeepSpeed-Chat / OPT / 24 GiB
        .strategies(StrategyConfig::table1_deepspeed_rows())
        .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
        .build()
        .expect("grid");
    println!("grid: {} cells", cells.len());

    let report = SweepRunner::new(SweepRunner::default_jobs()).run(cells);
    let blocks = report.strategy_rows();
    let (_, _, rows) = &blocks[0];
    println!(
        "{}",
        rlhf_mem::report::paper::render_rows("DeepSpeed-Chat / OPT (simulated 4x24 GiB)", rows)
    );
    println!("({})", report.summary_line());

    let by = |name: &str| rows.iter().find(|r| r.strategy == name).unwrap();
    let none = by("None");
    let z1 = by("ZeRO-1");
    let z3 = by("ZeRO-3");
    // §3.2 insights:
    assert!(z1.original.peak_reserved < none.original.peak_reserved, "ZeRO-1 stably reduces memory");
    assert!(z3.original.frag > none.original.frag, "ZeRO-3 increases fragmentation");
    assert!(z3.original.peak_allocated < z1.original.peak_allocated, "ZeRO-3 allocates least");
    for r in rows {
        assert!(
            r.with_empty_cache.peak_reserved <= r.original.peak_reserved + (1 << 28),
            "empty_cache must not blow up reserved ({})", r.strategy
        );
    }
    println!("OK: §3.2 orderings hold");
}
