//! Table-1 regeneration (E2–E4) as a library-API walkthrough: sweep every
//! memory-management strategy on DeepSpeed-Chat/OPT, print the paper-style
//! table, and check the paper's §3.2 insights hold.
//!
//! Run: `cargo run --release --example strategy_sweep`

use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::{render_rows, StrategyRow};
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;

fn main() {
    let mut rows = Vec::new();
    for (label, strat) in StrategyConfig::table1_deepspeed_rows() {
        let scn = SimScenario::deepspeed_opt(strat, EmptyCachePolicy::Never);
        rows.push(StrategyRow::measure(label, &scn, RTX3090_HBM));
    }
    println!("{}", render_rows("DeepSpeed-Chat / OPT (simulated 4x24 GiB)", &rows));

    let by = |name: &str| rows.iter().find(|r| r.strategy == name).unwrap();
    let none = by("None");
    let z1 = by("ZeRO-1");
    let z3 = by("ZeRO-3");
    // §3.2 insights:
    assert!(z1.original.peak_reserved < none.original.peak_reserved, "ZeRO-1 stably reduces memory");
    assert!(z3.original.frag > none.original.frag, "ZeRO-3 increases fragmentation");
    assert!(z3.original.peak_allocated < z1.original.peak_allocated, "ZeRO-3 allocates least");
    for r in &rows {
        assert!(
            r.with_empty_cache.peak_reserved <= r.original.peak_reserved + (1 << 28),
            "empty_cache must not blow up reserved ({})", r.strategy
        );
    }
    println!("OK: §3.2 orderings hold");
}
