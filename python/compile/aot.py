"""AOT lowering: JAX functions -> HLO *text* artifacts + a JSON manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts (per architecture, default `opt-nano`):
  {arch}.score.jnp.hlo.txt      scoring pass (logprobs + values)
  {arch}.score.pallas.hlo.txt   same, attention via the Pallas kernel
  {arch}.decode.jnp.hlo.txt     one KV-cache generation step
  {arch}.train.jnp.hlo.txt      one fused PPO train step (grads + Adam)
  {arch}.init.npz               initial parameter/optimizer values
  {arch}.manifest.json          shapes/arg-order contract for the runtime

The Pallas variant exists for the forward paths only: `pallas_call` has no
automatic VJP, so the train step (which differentiates through attention)
always uses the jnp oracle path — the tests assert the two forwards are
numerically identical, so the trained model is the same model.

Usage: python -m compile.aot --out-dir ../artifacts [--arch opt-nano]
       [--batch 4] [--prompt 32]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def build_artifacts(arch: str, batch: int, prompt: int, out_dir: str,
                    with_pallas: bool = True, seed: int = 0, lr: float = 1e-3):
    cfg = M.config_by_name(arch)
    seq = cfg.max_seq
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    leaves = M.params_to_list(cfg, params)
    zeros = [jnp.zeros_like(x) for x in leaves]
    n_leaves = len(leaves)

    os.makedirs(out_dir, exist_ok=True)
    written = {}

    def emit(name, fn, example_args):
        lowered = jax.jit(fn).lower(*[spec_of(a) for a in example_args])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{arch}.{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = os.path.basename(path)
        print(f"  wrote {path} ({len(text)} chars)")

    tokens = jnp.zeros((batch, seq), dtype=jnp.int32)
    maskf = jnp.zeros((batch, seq), dtype=jnp.float32)
    scored = jnp.zeros((batch, seq - 1), dtype=jnp.float32)
    values = jnp.zeros((batch, seq), dtype=jnp.float32)
    step = jnp.zeros((), dtype=jnp.float32)
    token1 = jnp.zeros((batch,), dtype=jnp.int32)
    pos = jnp.zeros((), dtype=jnp.int32)
    kv = M.init_kv(cfg, batch)

    # --- score ---
    def score_jnp(*args):
        lv = list(args[:n_leaves])
        t = args[n_leaves]
        p = M.list_to_params(cfg, lv)
        return M.score_fn(cfg, p, t, use_pallas=False)

    emit("score.jnp", score_jnp, leaves + [tokens])
    if with_pallas:
        def score_pallas(*args):
            lv = list(args[:n_leaves])
            t = args[n_leaves]
            p = M.list_to_params(cfg, lv)
            return M.score_fn(cfg, p, t, use_pallas=True)

        emit("score.pallas", score_pallas, leaves + [tokens])

    # --- decode ---
    def decode(*args):
        lv = list(args[:n_leaves])
        kv_, tok_, pos_ = args[n_leaves], args[n_leaves + 1], args[n_leaves + 2]
        p = M.list_to_params(cfg, lv)
        return M.decode_step(cfg, p, kv_, tok_, pos_)

    emit("decode.jnp", decode, leaves + [kv, token1, pos])

    # --- train ---
    def train(*args):
        lv = list(args[:n_leaves])
        m = list(args[n_leaves:2 * n_leaves])
        v = list(args[2 * n_leaves:3 * n_leaves])
        (step_, tokens_, mask_, olp_, ov_, adv_, ret_) = args[3 * n_leaves:]
        out = M.train_step(cfg, lv, m, v, step_, tokens_, mask_, olp_, ov_,
                           adv_, ret_, use_pallas=False, lr=lr)
        new_leaves, new_m, new_v, pg, vf, ent = out
        return tuple(new_leaves) + tuple(new_m) + tuple(new_v) + (pg, vf, ent)

    emit(
        "train.jnp",
        train,
        leaves + zeros + zeros + [step, tokens, maskf, scored, values, scored, scored],
    )

    # --- initial values ---
    order = M.param_order(cfg)
    np.savez(
        os.path.join(out_dir, f"{arch}.init.npz"),
        **{n: np.asarray(x) for n, x in zip(order, leaves)},
    )
    print(f"  wrote {arch}.init.npz")

    # --- manifest ---
    manifest = {
        "arch": arch,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "ffn": cfg.ffn,
            "max_seq": cfg.max_seq,
        },
        "batch": batch,
        "prompt": prompt,
        "num_params": int(sum(int(np.prod(x.shape)) for x in leaves)),
        "leaves": [
            {"name": n, "shape": list(x.shape), "dtype": str(x.dtype)}
            for n, x in zip(order, leaves)
        ],
        "kv_shape": list(kv.shape),
        "artifacts": written,
        "signatures": {
            "score": {"args": f"{n_leaves} leaves + tokens[i32 {batch}x{seq}]",
                      "outs": ["logprobs", "values"]},
            "decode": {"args": f"{n_leaves} leaves + kv + token[i32 {batch}] + pos[i32]",
                       "outs": ["logits", "kv"]},
            "train": {"args": f"3x{n_leaves} leaves + step + tokens + mask + "
                              "old_logprobs + old_values + advantages + returns",
                      "outs": f"3x{n_leaves} leaves + pg + vf + ent"},
        },
    }
    with open(os.path.join(out_dir, f"{arch}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {arch}.manifest.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--arch", default="opt-nano")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the (slow-to-trace) pallas score variant")
    ap.add_argument("--lr", type=float, default=1e-3,
                    help="Adam learning rate baked into the train artifact")
    args = ap.parse_args()
    print(f"AOT-lowering {args.arch} (batch={args.batch}, lr={args.lr})...")
    build_artifacts(args.arch, args.batch, args.prompt, args.out_dir,
                    with_pallas=not args.no_pallas, lr=args.lr)


if __name__ == "__main__":
    main()
