"""L1 Pallas kernel: fused causal attention with online softmax.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot spot
runs on CUDA GPUs; on TPU the same insight (never materialize the [s, s]
score matrix in main memory) becomes VMEM tiling: Q is blocked `block_q`
rows at a time, K/V stream through VMEM in `block_k` columns, and a running
(max, sum, acc) triple implements the online softmax. BlockSpec index maps
express the HBM->VMEM schedule a CUDA kernel would express with
threadblocks. MXU-friendly shapes (multiples of 8/128) are chosen by
`pick_blocks`.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (same numerics, same
blocking structure). Real-TPU performance is estimated in DESIGN.md from
the VMEM footprint + MXU utilization of these block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def pick_blocks(seq: int, head_dim: int):
    """Choose (block_q, block_k) for a sequence length.

    Aim: both tiles + accumulator fit comfortably in ~16 MiB VMEM while
    keeping the MXU busy (≥8 rows, ideally 128-multiples).
    """
    def pick(n):
        for cand in (128, 64, 32, 16, 8):
            if n % cand == 0:
                return cand
        return n
    bq = pick(seq)
    bk = pick(seq)
    # VMEM estimate: q (bq*d) + k,v (bk*d each) + acc (bq*d) + scores (bq*bk),
    # all fp32 in the worst case.
    vmem = 4 * (2 * bq * head_dim + 2 * bk * head_dim + bq * bk)
    assert vmem < 16 * 2**20, f"block choice exceeds VMEM: {vmem}"
    return bq, bk


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, seq):
    """One (head, q-block) program: stream K/V blocks, online softmax."""
    q_block = q_ref[...].astype(jnp.float32) * scale  # [bq, d]
    bq, d = q_block.shape
    q_index = pl.program_id(1)  # which q block
    q_positions = q_index * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(start, carry):
        acc, m_prev, l_prev = carry
        k_block = pl.load(k_ref, (pl.dslice(start * block_k, block_k), slice(None)))
        v_block = pl.load(v_ref, (pl.dslice(start * block_k, block_k), slice(None)))
        s = q_block @ k_block.astype(jnp.float32).T  # [bq, bk]
        k_positions = start * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        causal = q_positions >= k_positions
        s = jnp.where(causal, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v_block.astype(jnp.float32)
        return acc, m_cur, l_cur

    n_k_blocks = seq // block_k
    acc = jnp.zeros((bq, d), dtype=jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_k_blocks, body, (acc, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def causal_attention(q, k, v, block_q=None, block_k=None):
    """Fused causal attention. q, k, v: [heads, seq, head_dim]."""
    h, s, d = q.shape
    bq_auto, bk_auto = pick_blocks(s, d)
    bq = block_q or bq_auto
    bk = block_k or bk_auto
    assert s % bq == 0 and s % bk == 0, f"{s} % ({bq},{bk})"
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, scale=scale, block_k=bk, seq=s)
    return pl.pallas_call(
        kernel,
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


def vmem_bytes(seq: int, head_dim: int, block_q=None, block_k=None) -> int:
    """VMEM footprint estimate for DESIGN.md's §Perf table."""
    bq_a, bk_a = pick_blocks(seq, head_dim)
    bq = block_q or bq_a
    bk = block_k or bk_a
    return 4 * (2 * bq * head_dim + 2 * bk * head_dim + bq * bk)


def mxu_utilization_estimate(seq: int, head_dim: int, block_q=None, block_k=None) -> float:
    """Fraction of each MXU pass doing useful work (128x128 systolic array):
    product of dimension fill ratios for the two matmuls of one block step.
    """
    bq_a, bk_a = pick_blocks(seq, head_dim)
    bq = block_q or bq_a
    bk = block_k or bk_a

    def fill(n):
        return min(n, 128) / 128.0

    # QK^T: [bq, d] @ [d, bk]; PV: [bq, bk] @ [bk, d].
    qk = fill(bq) * fill(head_dim) * fill(bk)
    pv = fill(bq) * fill(bk) * fill(head_dim)
    return (qk + pv) / 2.0
