"""L1 Pallas kernel: fused PPO clipped-surrogate + value losses.

Fuses exp/ratio/clip/max and the masked reduction into one VMEM pass per
[batch, seq] tile instead of materializing five intermediate [b, s] arrays
(ratio, unclipped, clipped, per-token, masked) in HBM — the same
"don't round-trip intermediates" insight the attention kernel applies,
relevant here because the PPO loss runs on every micro-batch of every PPO
epoch. interpret=True (see attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ppo_kernel(lp_ref, old_ref, adv_ref, mask_ref, num_ref, den_ref, *, clip):
    lp = lp_ref[...].astype(jnp.float32)
    old = old_ref[...].astype(jnp.float32)
    adv = adv_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    ratio = jnp.exp(lp - old)
    unclipped = -adv * ratio
    clipped = -adv * jnp.clip(ratio, 1.0 - clip, 1.0 + clip)
    per_token = jnp.maximum(unclipped, clipped) * mask
    num_ref[0, 0] = per_token.sum()
    den_ref[0, 0] = mask.sum()


@functools.partial(jax.jit, static_argnames=("clip",))
def ppo_loss(logprobs, old_logprobs, advantages, mask, clip=0.2):
    """Fused PPO policy loss. Inputs [b, s] -> scalar masked mean."""
    b, s = logprobs.shape
    kernel = functools.partial(_ppo_kernel, clip=clip)
    num, den = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((b, s), lambda i: (0, 0))] * 4,
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(logprobs, old_logprobs, advantages, mask)
    return (num / jnp.maximum(den, 1.0))[0, 0]


def _value_kernel(v_ref, ov_ref, ret_ref, mask_ref, num_ref, den_ref, *, clip):
    v = v_ref[...].astype(jnp.float32)
    ov = ov_ref[...].astype(jnp.float32)
    ret = ret_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    vc = ov + jnp.clip(v - ov, -clip, clip)
    per_token = jnp.maximum((v - ret) ** 2, (vc - ret) ** 2) * mask
    num_ref[0, 0] = per_token.sum()
    den_ref[0, 0] = mask.sum()


@functools.partial(jax.jit, static_argnames=("clip",))
def value_loss(values, old_values, returns, mask, clip=0.2):
    """Fused clipped value loss. Inputs [b, s] -> scalar."""
    b, s = values.shape
    kernel = functools.partial(_value_kernel, clip=clip)
    num, den = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((b, s), lambda i: (0, 0))] * 4,
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(values, old_values, returns, mask)
    return 0.5 * (num / jnp.maximum(den, 1.0))[0, 0]
