"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel in this package must
match its oracle to float tolerance under pytest + hypothesis sweeps
(python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def causal_attention_ref(q, k, v, scale=None):
    """Reference causal attention.

    q, k, v: [heads, seq, head_dim] (single example; vmap for batch).
    Returns [heads, seq, head_dim].
    """
    _, s, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum(
        "hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ppo_loss_ref(logprobs, old_logprobs, advantages, mask, clip=0.2):
    """Reference PPO clipped-surrogate policy loss (per-token, masked mean).

    All inputs [batch, seq] float32; mask selects response tokens.
    Returns scalar loss.
    """
    ratio = jnp.exp(logprobs - old_logprobs)
    unclipped = -advantages * ratio
    clipped = -advantages * jnp.clip(ratio, 1.0 - clip, 1.0 + clip)
    per_token = jnp.maximum(unclipped, clipped)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_token * mask).sum() / denom


def value_loss_ref(values, old_values, returns, mask, clip=0.2):
    """Reference clipped value loss (DeepSpeed-Chat style)."""
    clipped_values = old_values + jnp.clip(values - old_values, -clip, clip)
    l1 = (values - returns) ** 2
    l2 = (clipped_values - returns) ** 2
    per_token = jnp.maximum(l1, l2)
    denom = jnp.maximum(mask.sum(), 1.0)
    return 0.5 * (per_token * mask).sum() / denom
