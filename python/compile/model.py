"""L2: the RLHF model in JAX — an OPT-style pre-LN transformer with a tied
LM head and a scalar value head (shared actor-critic backbone), its PPO
train step (loss + grads + Adam, all in one jitted graph), and a KV-cache
decode step for generation.

Everything here is build-time only: `aot.py` lowers these functions to HLO
text once; the Rust runtime loads and executes the artifacts. The attention
hot spot calls the L1 Pallas kernel (`use_pallas=True`) or the jnp oracle
(`use_pallas=False`) — both lower to plain HLO; numerics are identical
(tests assert this) and the jnp path is faster under the CPU backend, so it
is the default for the long end-to-end runs.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ppo_loss as loss_kernel
from .kernels import ref as kref


class ModelConfig(NamedTuple):
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    ffn: int = 1024
    max_seq: int = 96

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def config_by_name(name: str) -> ModelConfig:
    """Mirrors rust/src/mem/arch.rs presets (seq shortened for CPU speed)."""
    if name == "opt-nano":
        return ModelConfig(512, 256, 4, 8, 1024, 96)
    if name == "opt-tiny":
        return ModelConfig(8192, 512, 8, 8, 2048, 96)
    if name == "opt-110m":
        return ModelConfig(32768, 768, 12, 12, 3072, 96)
    raise ValueError(f"unknown config {name}")


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialize parameters as a flat dict (stable iteration order)."""
    keys = jax.random.split(key, 4 + 8 * cfg.n_layers)
    ki = iter(keys)
    s = 0.02
    p = {
        "tok_emb": jax.random.normal(next(ki), (cfg.vocab, cfg.d_model)) * s,
        "pos_emb": jax.random.normal(next(ki), (cfg.max_seq, cfg.d_model)) * s,
        "final_ln_w": jnp.ones((cfg.d_model,)),
        "final_ln_b": jnp.zeros((cfg.d_model,)),
        "v_head": jax.random.normal(next(ki), (cfg.d_model,)) * s,
    }
    for l in range(cfg.n_layers):
        p[f"l{l}.ln1_w"] = jnp.ones((cfg.d_model,))
        p[f"l{l}.ln1_b"] = jnp.zeros((cfg.d_model,))
        p[f"l{l}.wqkv"] = jax.random.normal(next(ki), (cfg.d_model, 3 * cfg.d_model)) * s
        p[f"l{l}.wo"] = jax.random.normal(next(ki), (cfg.d_model, cfg.d_model)) * s
        p[f"l{l}.ln2_w"] = jnp.ones((cfg.d_model,))
        p[f"l{l}.ln2_b"] = jnp.zeros((cfg.d_model,))
        p[f"l{l}.w1"] = jax.random.normal(next(ki), (cfg.d_model, cfg.ffn)) * s
        p[f"l{l}.w2"] = jax.random.normal(next(ki), (cfg.ffn, cfg.d_model)) * s
    return p


def param_order(cfg: ModelConfig):
    """Deterministic leaf order shared with the Rust runtime manifest."""
    names = ["tok_emb", "pos_emb", "final_ln_w", "final_ln_b", "v_head"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.ln1_w", f"l{l}.ln1_b", f"l{l}.wqkv", f"l{l}.wo",
            f"l{l}.ln2_w", f"l{l}.ln2_b", f"l{l}.w1", f"l{l}.w2",
        ]
    return names


def params_to_list(cfg, params):
    return [params[n] for n in param_order(cfg)]


def list_to_params(cfg, leaves):
    return dict(zip(param_order(cfg), leaves))


def num_params(cfg: ModelConfig) -> int:
    key = jax.random.PRNGKey(0)
    p = init_params(cfg, key)
    return sum(int(x.size) for x in p.values())


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _ln(x, w, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * w + b


def _attention(q, k, v, use_pallas: bool):
    """q,k,v: [b, h, s, hd] -> [b, h, s, hd], causal."""
    if use_pallas:
        return jax.vmap(attn_kernel.causal_attention)(q, k, v)
    return jax.vmap(kref.causal_attention_ref)(q, k, v)


def forward(cfg: ModelConfig, params, tokens, use_pallas=False):
    """tokens [b, s] int32 -> (logits [b, s, vocab], values [b, s])."""
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    for l in range(cfg.n_layers):
        h = _ln(x, params[f"l{l}.ln1_w"], params[f"l{l}.ln1_b"])
        qkv = h @ params[f"l{l}.wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        ctx = _attention(heads(q), heads(k), heads(v), use_pallas)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + ctx @ params[f"l{l}.wo"]
        h = _ln(x, params[f"l{l}.ln2_w"], params[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(h @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]
    x = _ln(x, params["final_ln_w"], params["final_ln_b"])
    logits = x @ params["tok_emb"].T  # tied head
    values = x @ params["v_head"]
    return logits, values


def token_logprobs(logits, tokens):
    """Per-token logprob of the NEXT token: [b, s] -> [b, s-1]."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    return jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)[..., 0]


def score_fn(cfg: ModelConfig, params, tokens, use_pallas=False):
    """Scoring pass: (logprobs [b, s-1], values [b, s])."""
    logits, values = forward(cfg, params, tokens, use_pallas)
    return token_logprobs(logits, tokens), values


# ---------------------------------------------------------------------------
# Decode step (generation) — fixed-size KV cache, dynamic position
# ---------------------------------------------------------------------------

def init_kv(cfg: ModelConfig, batch: int):
    """Zeroed KV cache: one [b, h, max_seq, hd] pair per layer, stacked."""
    shape = (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, dtype=jnp.float32)


def decode_step(cfg: ModelConfig, params, kv, token, pos):
    """One autoregressive step.

    kv:   [L, 2, b, h, S, hd] running cache
    token:[b] int32 current input token
    pos:  [] int32 its position
    Returns (logits [b, vocab], new kv).
    """
    b = token.shape[0]
    x = params["tok_emb"][token] + params["pos_emb"][pos]
    x = x[:, None, :]  # [b, 1, d]
    positions = jnp.arange(cfg.max_seq)
    attn_mask = (positions <= pos)[None, None, :]  # [1, 1, S]
    for l in range(cfg.n_layers):
        h = _ln(x, params[f"l{l}.ln1_w"], params[f"l{l}.ln1_b"])
        qkv = h @ params[f"l{l}.wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, 1, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)  # [b, h, 1, hd]
        kv = jax.lax.dynamic_update_slice(
            kv, k[None, None, :, :, :, :].astype(kv.dtype), (l, 0, 0, 0, pos, 0)
        )
        kv = jax.lax.dynamic_update_slice(
            kv, v[None, None, :, :, :, :].astype(kv.dtype), (l, 1, 0, 0, pos, 0)
        )
        keys = kv[l, 0]    # [b, h, S, hd]
        vals = kv[l, 1]
        scale = 1.0 / (cfg.head_dim ** 0.5)
        s_logits = jnp.einsum("bhqd,bhkd->bhqk", q, keys) * scale
        s_logits = jnp.where(attn_mask[:, :, None, :], s_logits, -1e30)
        probs = jax.nn.softmax(s_logits, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, vals)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, 1, cfg.d_model)
        x = x + ctx @ params[f"l{l}.wo"]
        h = _ln(x, params[f"l{l}.ln2_w"], params[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(h @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]
    x = _ln(x, params["final_ln_w"], params["final_ln_b"])
    logits = (x @ params["tok_emb"].T)[:, 0, :]
    # Keep the value head in the argument list (jax.jit drops unused args,
    # which would break the runtime's fixed positional calling convention).
    logits = logits + 0.0 * params["v_head"].sum()
    return logits, kv


# ---------------------------------------------------------------------------
# PPO train step — loss + grads + Adam fused in one graph
# ---------------------------------------------------------------------------

def ppo_losses(cfg, params, batch, use_pallas=False, clip=0.2, vf_coef=1.0,
               ent_coef=0.0):
    """Combined PPO objective on a shared actor-critic backbone."""
    tokens, mask, old_logprobs, old_values, advantages, returns = batch
    logits, values = forward(cfg, params, tokens, use_pallas)
    logprobs = token_logprobs(logits, tokens)
    m = mask[:, 1:].astype(jnp.float32)
    if use_pallas:
        pg = loss_kernel.ppo_loss(logprobs, old_logprobs, advantages, m, clip=clip)
        vf = loss_kernel.value_loss(
            values[:, 1:], old_values[:, 1:], returns, m, clip=clip
        )
    else:
        pg = kref.ppo_loss_ref(logprobs, old_logprobs, advantages, m, clip=clip)
        vf = kref.value_loss_ref(
            values[:, 1:], old_values[:, 1:], returns, m, clip=clip
        )
    # Entropy bonus (exploration): masked mean token entropy.
    lp_all = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    ent = -(jnp.exp(lp_all) * lp_all).sum(-1)
    ent = (ent * m).sum() / jnp.maximum(m.sum(), 1.0)
    total = pg + vf_coef * vf - ent_coef * ent
    return total, (pg, vf, ent)


def adam_update(param, grad, m, v, step, lr=1e-4, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * grad
    v = b2 * v + (1 - b2) * grad * grad
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    return param - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def train_step(cfg, leaves, m_leaves, v_leaves, step, tokens, mask,
               old_logprobs, old_values, advantages, returns,
               use_pallas=False, lr=1e-4):
    """One PPO update over flat leaf lists (the AOT entry point).

    Returns (new_leaves, new_m, new_v, policy_loss, value_loss, entropy).
    """
    params = list_to_params(cfg, leaves)
    batch = (tokens, mask, old_logprobs, old_values, advantages, returns)

    def loss_fn(p):
        total, aux = ppo_losses(cfg, p, batch, use_pallas=use_pallas)
        return total, aux

    grads, (pg, vf, ent) = jax.grad(loss_fn, has_aux=True)(params)
    order = param_order(cfg)
    new_leaves, new_m, new_v = [], [], []
    for name, leaf, gm, gv in zip(order, leaves, m_leaves, v_leaves):
        g = grads[name]
        nl, nm, nv = adam_update(leaf, g, gm, gv, step, lr=lr)
        new_leaves.append(nl)
        new_m.append(nm)
        new_v.append(nv)
    return new_leaves, new_m, new_v, pg, vf, ent
