"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and value distributions; assert_allclose against
the pure-jnp references in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ppo_loss as L
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,s,d", [(1, 8, 16), (2, 32, 16), (4, 64, 32), (8, 128, 32)])
def test_attention_matches_ref(h, s, d):
    q, k, v = (rand(i, (h, s, d)) for i in range(3))
    out = A.causal_attention(q, k, v)
    ref = R.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([0.1, 1.0, 4.0]),
)
def test_attention_hypothesis_sweep(h, s, d, seed, scale):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(kk, (h, s, d)) * scale for kk in ks)
    out = A.causal_attention(q, k, v)
    ref = R.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


def test_attention_is_causal():
    # Future tokens must not influence earlier outputs.
    h, s, d = 2, 16, 8
    q, k, v = (rand(i, (h, s, d)) for i in range(3))
    out1 = A.causal_attention(q, k, v)
    k2 = k.at[:, -1, :].set(999.0)
    v2 = v.at[:, -1, :].set(-999.0)
    out2 = A.causal_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, -1], out2[:, -1])


def test_attention_block_shapes_agree():
    # Different (block_q, block_k) tilings must give identical numerics.
    h, s, d = 2, 64, 16
    q, k, v = (rand(i, (h, s, d)) for i in range(3))
    base = A.causal_attention(q, k, v, block_q=64, block_k=64)
    for bq in (8, 16, 32):
        for bk in (16, 32):
            out = A.causal_attention(q, k, v, block_q=bq, block_k=bk)
            np.testing.assert_allclose(out, base, rtol=2e-5, atol=2e-5)


def test_vmem_and_mxu_estimates():
    assert A.vmem_bytes(128, 64) < 16 * 2**20
    u = A.mxu_utilization_estimate(128, 64)
    assert 0.0 < u <= 1.0
    # Bigger blocks fill the MXU better.
    assert A.mxu_utilization_estimate(128, 64) >= A.mxu_utilization_estimate(8, 8)


# ---------------------------------------------------------------------------
# ppo loss
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([7, 16, 33]),
    seed=st.integers(0, 2**16),
)
def test_ppo_loss_hypothesis(b, s, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    lp = jax.random.normal(ks[0], (b, s)) * 0.1 - 3.0
    old = lp + jax.random.normal(ks[1], (b, s)) * 0.05
    adv = jax.random.normal(ks[2], (b, s))
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.3).astype(jnp.float32)
    out = L.ppo_loss(lp, old, adv, mask)
    ref = R.ppo_loss_ref(lp, old, adv, mask)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([7, 16, 33]),
    seed=st.integers(0, 2**16),
)
def test_value_loss_hypothesis(b, s, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    v = jax.random.normal(ks[0], (b, s))
    ov = v + jax.random.normal(ks[1], (b, s)) * 0.1
    ret = jax.random.normal(ks[2], (b, s))
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.3).astype(jnp.float32)
    out = L.value_loss(v, ov, ret, mask)
    ref = R.value_loss_ref(v, ov, ret, mask)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_ppo_loss_all_masked_is_finite():
    z = jnp.zeros((2, 8))
    out = L.ppo_loss(z, z, z, z)
    assert np.isfinite(float(out))
    assert float(out) == 0.0


def test_ppo_loss_clip_engages():
    # Large ratio with negative advantage: clipping must bound the loss.
    lp = jnp.full((1, 4), 0.0)
    old = jnp.full((1, 4), -2.0)  # ratio = e^2 ~ 7.4
    adv = jnp.full((1, 4), -1.0)
    mask = jnp.ones((1, 4))
    out = float(L.ppo_loss(lp, old, adv, mask))
    ref = float(R.ppo_loss_ref(lp, old, adv, mask))
    assert abs(out - ref) < 1e-5
    # max(-adv*ratio, -adv*clip) with adv=-1: max(ratio, 1.2) = 7.38...
    assert out > 7.0
