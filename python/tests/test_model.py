"""L2 model tests: shapes, pallas/jnp forward equivalence, decode-vs-full
consistency, and the PPO train step actually learning."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, ffn=64, max_seq=16)


def setup():
    key = jax.random.PRNGKey(0)
    return M.init_params(CFG, key)


def test_forward_shapes():
    p = setup()
    tokens = jnp.zeros((3, 16), dtype=jnp.int32)
    logits, values = M.forward(CFG, p, tokens)
    assert logits.shape == (3, 16, 64)
    assert values.shape == (3, 16)


def test_pallas_and_jnp_forward_agree():
    p = setup()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    l1, v1 = M.forward(CFG, p, tokens, use_pallas=False)
    l2, v2 = M.forward(CFG, p, tokens, use_pallas=True)
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)


def test_decode_matches_full_forward():
    """Autoregressive decode with the KV cache must reproduce the full
    forward's next-token logits position by position."""
    p = setup()
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, 64)
    full_logits, _ = M.forward(CFG, p, tokens)
    kv = M.init_kv(CFG, b)
    for pos in range(s):
        step_logits, kv = M.decode_step(CFG, p, kv, tokens[:, pos], jnp.int32(pos))
        np.testing.assert_allclose(
            step_logits, full_logits[:, pos, :], rtol=2e-4, atol=2e-4,
            err_msg=f"pos {pos}",
        )


def test_token_logprobs_are_logprobs():
    p = setup()
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 64)
    lp, _ = M.score_fn(CFG, p, tokens)
    assert lp.shape == (2, 15)
    assert np.all(np.asarray(lp) <= 1e-6)


def test_param_order_roundtrip():
    p = setup()
    leaves = M.params_to_list(CFG, p)
    p2 = M.list_to_params(CFG, leaves)
    assert set(p.keys()) == set(p2.keys())
    for k in p:
        np.testing.assert_array_equal(p[k], p2[k])


def test_train_step_reduces_value_loss():
    """A few PPO steps on a fixed synthetic batch must reduce the loss
    (mostly the value head fitting the returns)."""
    p = setup()
    leaves = M.params_to_list(CFG, p)
    m = [jnp.zeros_like(x) for x in leaves]
    v = [jnp.zeros_like(x) for x in leaves]
    b, s = 2, 16
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (b, s), 0, 64)
    mask = jnp.ones((b, s), dtype=jnp.float32)
    with jax.disable_jit(False):
        lp0, v0 = M.score_fn(CFG, M.list_to_params(CFG, leaves), tokens)
    old_logprobs = lp0
    old_values = v0
    advantages = jax.random.normal(jax.random.PRNGKey(5), (b, s - 1)) * 0.1
    returns = jnp.ones((b, s - 1)) * 0.5

    step_fn = jax.jit(
        lambda lv, mm, vv, st: M.train_step(
            CFG, lv, mm, vv, st, tokens, mask, old_logprobs, old_values,
            advantages, returns, lr=1e-3,
        )
    )
    losses = []
    for i in range(8):
        leaves, m, v, pg, vf, ent = step_fn(leaves, m, v, jnp.float32(i + 1))
        losses.append(float(pg + vf))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"loss did not fall: {losses}"


def test_config_presets():
    nano = M.config_by_name("opt-nano")
    assert M.num_params(nano) > 1_000_000
    tiny = M.config_by_name("opt-tiny")
    assert tiny.d_model == 512
