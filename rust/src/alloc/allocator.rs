//! The caching allocator — a faithful reimplementation of PyTorch's
//! `CUDACachingAllocator` algorithm over the simulated driver.
//!
//! Semantics implemented (see DESIGN.md §6):
//! * 512 B request rounding; small (≤1 MiB) vs large pools;
//! * segment sizing: 2 MiB small buffers, 20 MiB large buffers, exact
//!   2 MiB-rounded segments for ≥10 MiB requests;
//! * best-fit from the pool with PyTorch's split rules (remainder ≥512 B
//!   small / >1 MiB large; `max_split_size` blocks splitting and bounds
//!   which cached blocks may serve small requests);
//! * `free` coalesces with free neighbours within the segment;
//! * driver OOM triggers release of all cached (fully-free) segments and a
//!   retry before surfacing the error;
//! * `empty_cache()` returns every fully-free segment to the driver;
//! * stats + event stream per the paper's Appendix B definitions.
//!
//! Hot-path complexity (the speed layer under every sweep/planner/cluster
//! run): best-fit is O(log n) over the per-pool size index, coalescing is
//! O(1) over the blocks' address-ordered `prev`/`next` handles, and the
//! release paths (`empty_cache`, OOM retry, gc-threshold) walk only the
//! pool's fully-free-segment index ([`BlockPool`] keeps it in sync on
//! every insert/remove) instead of scanning every cached block or every
//! segment. The golden tests in `rust/tests/alloc_golden.rs` pin the
//! event log byte-identical to the pre-index scan implementation.

use super::block::{Block, BlockId, BlockSlab, BlockState, NO_BLOCK};
use super::config::{AllocatorConfig, PoolKind};
use super::driver::{DriverOom, SegmentId, SimDriver};
use super::pool::BlockPool;
use super::stats::{AllocEvent, AllocStats, PhaseTag, StatSnapshot};
use crate::util::bytes::{round_down, round_up};
use crate::util::fasthash::FastMap;

/// Index of a pool in per-pool side tables (`[small, large]`).
fn pool_idx(kind: PoolKind) -> usize {
    match kind {
        PoolKind::Small => 0,
        PoolKind::Large => 1,
    }
}

/// Opaque user handle to a live allocation (a "tensor").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AllocId(pub u64);

/// Error from [`CachingAllocator::alloc`].
#[derive(Debug)]
pub enum AllocError {
    Oom(DriverOom, StatSnapshot),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let AllocError::Oom(oom, snap) = self;
        write!(
            f,
            "{oom}; allocator state: reserved={} allocated={} cached={}",
            snap.reserved,
            snap.allocated,
            snap.reserved - snap.allocated
        )
    }
}

impl std::error::Error for AllocError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        let AllocError::Oom(oom, _) = self;
        Some(oom)
    }
}

/// The allocator. Single-stream (RLHF phases are serialized; see paper
/// Appendix A), one instance per simulated GPU.
///
/// The allocator is a plain `Send` value: instead of pushing events into a
/// shared observer, it appends them (with a [`StatSnapshot`] taken at emit
/// time) to an internal log when [`Self::set_event_recording`] is on. The
/// replay loop drains that log after every op and forwards it to the
/// profiler — which is what lets the sweep engine hand one allocator +
/// profiler pair to each worker thread.
pub struct CachingAllocator {
    cfg: AllocatorConfig,
    driver: SimDriver,
    slab: BlockSlab,
    small: BlockPool,
    large: BlockPool,
    /// Live user allocations.
    live: FastMap<u64, BlockId>,
    next_handle: u64,
    /// Head block of each live segment (offset 0; stable across split and
    /// coalesce because merges fold into the earlier block).
    seg_heads: FastMap<SegmentId, BlockId>,
    /// The per-pool growable segment when `cfg.expandable_segments` is on
    /// (`[small, large]`); `None` until the pool's first driver miss, and
    /// cleared again if the segment is fully released.
    expandable: [Option<SegmentId>; 2],
    /// Monotone op counter ordering `seg_last_use` (gc aging).
    tick: u64,
    /// Tick of the last allocation served from each segment — the
    /// least-recently-used order `garbage_collection_threshold` reclaims
    /// in. Only maintained while that knob is set.
    seg_last_use: FastMap<SegmentId, u64>,
    stats: AllocStats,
    phase: PhaseTag,
    record_events: bool,
    events: Vec<(AllocEvent, StatSnapshot)>,
}

impl CachingAllocator {
    pub fn new(capacity: u64, cfg: AllocatorConfig) -> Self {
        let driver = SimDriver::new(capacity, cfg.cost.clone());
        CachingAllocator {
            cfg,
            driver,
            slab: BlockSlab::new(),
            small: BlockPool::new(),
            large: BlockPool::new(),
            live: FastMap::default(),
            next_handle: 1,
            seg_heads: FastMap::default(),
            expandable: [None, None],
            tick: 0,
            seg_last_use: FastMap::default(),
            stats: AllocStats::default(),
            phase: 0,
            record_events: false,
            events: Vec::new(),
        }
    }

    pub fn with_default_config(capacity: u64) -> Self {
        Self::new(capacity, AllocatorConfig::default())
    }

    /// Turn the event log on or off. While on, every operation appends its
    /// [`AllocEvent`]s (with point-in-time snapshots) to an internal buffer
    /// that [`Self::drain_events_into`] empties.
    pub fn set_event_recording(&mut self, on: bool) {
        self.record_events = on;
    }

    /// Move all buffered events into `out` (appending), leaving the
    /// internal buffer empty but with its capacity retained.
    pub fn drain_events_into(&mut self, out: &mut Vec<(AllocEvent, StatSnapshot)>) {
        out.append(&mut self.events);
    }

    /// Tag subsequent driver segments / events with an RLHF phase id.
    pub fn set_phase(&mut self, phase: PhaseTag) {
        self.phase = phase;
    }

    pub fn phase(&self) -> PhaseTag {
        self.phase
    }

    pub fn stats(&self) -> &AllocStats {
        self.stats_refresh()
    }

    fn stats_refresh(&self) -> &AllocStats {
        // time_us is owned partly by the driver; merge lazily via snapshot().
        &self.stats
    }

    pub fn config(&self) -> &AllocatorConfig {
        &self.cfg
    }

    pub fn reserved(&self) -> u64 {
        self.driver.reserved()
    }

    pub fn allocated(&self) -> u64 {
        self.stats.allocated
    }

    pub fn capacity(&self) -> u64 {
        self.driver.capacity()
    }

    /// Total simulated time consumed by allocator + driver, microseconds.
    pub fn time_us(&self) -> f64 {
        self.stats.time_us + self.driver.time_us
    }

    pub fn snapshot(&self) -> StatSnapshot {
        StatSnapshot {
            reserved: self.driver.reserved(),
            allocated: self.stats.allocated,
            requested: self.stats.requested,
            time_us: self.time_us(),
            phase: self.phase,
        }
    }

    fn emit(&mut self, ev: AllocEvent) {
        if self.record_events {
            let snap = self.snapshot();
            self.events.push((ev, snap));
        }
    }

    fn pool(&mut self, kind: PoolKind) -> &mut BlockPool {
        match kind {
            PoolKind::Small => &mut self.small,
            PoolKind::Large => &mut self.large,
        }
    }

    pub fn pool_cached_bytes(&self, kind: PoolKind) -> u64 {
        match kind {
            PoolKind::Small => self.small.cached_bytes(),
            PoolKind::Large => self.large.cached_bytes(),
        }
    }

    /// Allocate `requested` bytes; returns a handle for later [`Self::free`].
    pub fn alloc(&mut self, requested: u64) -> Result<AllocId, AllocError> {
        assert!(requested > 0, "alloc(0)");
        let rounded = self.cfg.round_size(requested);
        let pool_kind = self.cfg.pool_for(rounded);

        // 1. Try the cache.
        let found = self.find_cached(rounded, pool_kind);
        let (block_id, cache_hit) = match found {
            Some(id) => (id, true),
            None => {
                // 2. Go to the driver, with PyTorch's OOM-retry cascade —
                // either a discrete segment, or (expandable_segments) the
                // pool's growable segment's tail.
                let seg_block = if self.cfg.expandable_segments {
                    self.grow_expandable(rounded, pool_kind)?
                } else {
                    self.alloc_segment(rounded, pool_kind)?
                };
                (seg_block, false)
            }
        };

        // 3. Split if profitable.
        let block_id = self.maybe_split(block_id, rounded, pool_kind);

        // 4. Mark allocated, register handle.
        {
            let b = self.slab.get_mut(block_id);
            debug_assert_eq!(b.state, BlockState::Free);
            b.state = BlockState::Allocated;
            b.requested = requested;
        }
        let size = self.slab.get(block_id).size;
        self.stats.num_allocs += 1;
        if cache_hit {
            self.stats.num_cache_hits += 1;
        }
        self.stats.time_us += self.cfg.cost.cache_hit_us;
        self.stats.requested += requested;
        // Sync peaks only now, when both counters reflect the completed op
        // (alloc_segment may have raised reserved mid-flight).
        let allocated = self.stats.allocated + size;
        self.stats.sync(self.driver.reserved(), allocated);

        let handle = AllocId(self.next_handle);
        self.next_handle += 1;
        self.live.insert(handle.0, block_id);

        if self.cfg.garbage_collection_threshold.is_some() {
            self.tick += 1;
            let seg = self.slab.get(block_id).segment;
            self.seg_last_use.insert(seg, self.tick);
        }

        self.emit(AllocEvent::Alloc {
            requested,
            rounded,
            cache_hit,
        });
        Ok(handle)
    }

    /// Look up a suitable cached block and detach it from its pool.
    fn find_cached(&mut self, rounded: u64, pool_kind: PoolKind) -> Option<BlockId> {
        // With expandable segments, the oversized-reservation rule is moot
        // (blocks merge with the growth frontier instead of stranding), so
        // max_split only applies to classic discrete segments.
        let max_split = self
            .cfg
            .max_split_size
            .filter(|_| !self.cfg.expandable_segments);
        let (size, id) = {
            let pool = self.pool(pool_kind);
            match (pool_kind, max_split) {
                // PyTorch: with max_split_size set, a "small" (< max_split)
                // request must not nibble an oversized block; oversized
                // blocks are reserved for oversized requests (close-fit
                // allowed, no split).
                (PoolKind::Large, Some(max)) if rounded < max => {
                    pool.best_fit_bounded(rounded, max)
                }
                _ => pool.best_fit(rounded),
            }
        }?;
        self.pool(pool_kind).remove(size, id);
        Some(id)
    }

    /// cudaMalloc a fresh segment sized for `rounded`, creating its head
    /// block (free, covering the whole segment). Runs the gc pass (when
    /// `garbage_collection_threshold` is set) and PyTorch's OOM cascade.
    fn alloc_segment(&mut self, rounded: u64, pool_kind: PoolKind) -> Result<BlockId, AllocError> {
        let seg_size = self.cfg.segment_size_for(rounded);
        self.maybe_gc(seg_size, None);
        // Paper Appendix B: fragmentation is sampled at a cudaMalloc only
        // when the miss is *fragmentation-caused* — the request's own pool
        // holds enough cached bytes to cover it, yet no contiguous block
        // fits. A malloc whose pool simply lacks the bytes is legitimate
        // capacity growth and contributes no fragmentation (a small-pool
        // request can never be served from large-pool cache, so cross-pool
        // bytes don't make its miss a fragmentation event). Sampled after
        // the gc pass — the paper defines the sample at the driver call.
        let cached_free = self.driver.reserved() - self.stats.allocated;
        let pool_cached = self.pool_cached_bytes(pool_kind);
        let frag_sample = if pool_cached >= rounded { cached_free } else { 0 };

        let seg = match self.driver.cuda_malloc(seg_size) {
            Ok(s) => s,
            Err(_) => {
                // Retry 1: release all cached fully-free segments.
                let released = self.release_cached_segments();
                self.emit(AllocEvent::OomRetry {
                    released_bytes: released,
                });
                match self.driver.cuda_malloc(seg_size) {
                    Ok(s) => s,
                    Err(e) => {
                        return Err(AllocError::Oom(e, self.snapshot()));
                    }
                }
            }
        };
        self.note_driver_growth(seg_size, rounded, frag_sample);

        let block = Block {
            segment: seg,
            pool: pool_kind,
            offset: 0,
            size: seg_size,
            requested: 0,
            state: BlockState::Free,
            prev: NO_BLOCK,
            next: NO_BLOCK,
            origin_phase: self.phase,
            live: true,
        };
        let id = self.slab.insert(block);
        self.seg_heads.insert(seg, id);
        if self.cfg.garbage_collection_threshold.is_some() {
            self.tick += 1;
            self.seg_last_use.insert(seg, self.tick);
        }
        Ok(id)
    }

    /// Bookkeeping shared by every path that maps new driver memory — a
    /// fresh segment or an expandable grow: the paper's fragmentation
    /// sample, counters, and peak tracking. Reserved only ever rises here,
    /// so the peak and its fragmentation are recorded here:
    /// `frag_at_peak_reserved` is the fragmentation-caused sample at the
    /// driver call that set the reserved peak (Figure 1's yellow gap).
    fn note_driver_growth(&mut self, mapped_bytes: u64, rounded: u64, frag_sample: u64) {
        self.stats.last_frag_sample = frag_sample;
        if frag_sample > self.stats.max_frag_sample {
            self.stats.max_frag_sample = frag_sample;
        }
        self.stats.num_cuda_mallocs += 1;
        self.stats.reserved = self.driver.reserved();
        if self.stats.reserved > self.stats.peak_reserved {
            self.stats.peak_reserved = self.stats.reserved;
            self.stats.frag_at_peak_reserved = frag_sample;
        }
        self.emit(AllocEvent::CudaMalloc {
            segment_bytes: mapped_bytes,
            rounded,
            frag_sample,
        });
    }

    /// `expandable_segments` emulation: route a cache miss to the pool's
    /// single growable segment instead of a fresh cudaMalloc. The chain
    /// tail is the growth frontier — a trailing free block is extended in
    /// place, merging old cached space with newly mapped granules, so
    /// allocation-size drift across PPO steps reuses one address range
    /// rather than stranding whole segments (the fragmentation mechanism
    /// §3.2 diagnoses).
    fn grow_expandable(
        &mut self,
        rounded: u64,
        pool_kind: PoolKind,
    ) -> Result<BlockId, AllocError> {
        let idx = pool_idx(pool_kind);
        let granule = self.cfg.expandable_granule();
        let mut retried = false;
        loop {
            let Some(seg) = self.expandable[idx] else {
                // First miss of this pool (or its segment was fully
                // released): open the growable segment via the ordinary
                // segment path, then register it.
                let block = self.alloc_segment(rounded, pool_kind)?;
                self.expandable[idx] = Some(self.slab.get(block).segment);
                return Ok(block);
            };
            // Walk to the chain tail — the growth frontier. O(chain) per
            // driver miss; misses are orders of magnitude rarer than
            // pool-served allocs, so this stays off the hot path (a cached
            // tail pointer would have to survive split/coalesce/shrink —
            // not worth the bookkeeping until profiles say otherwise).
            let head = *self.seg_heads.get(&seg).expect("expandable segment head");
            let mut tail = head;
            while self.slab.get(tail).next != NO_BLOCK {
                tail = BlockId(self.slab.get(tail).next);
            }
            let (tail_state, tail_size) = {
                let b = self.slab.get(tail);
                (b.state, b.size)
            };
            let free_tail = if tail_state == BlockState::Free {
                tail_size
            } else {
                0
            };
            let need = rounded.saturating_sub(free_tail);
            if need == 0 {
                // Defensive: a free tail big enough for the request is
                // normally served by the cache lookup; serve it directly
                // if a future lookup rule ever excludes it.
                self.pool(pool_kind).remove(tail_size, tail);
                return Ok(tail);
            }
            let delta = round_up(need, granule);
            self.maybe_gc(delta, Some(seg));
            // Appendix-B fragmentation sample at the driver call (post-gc),
            // same rule as the discrete-segment path.
            let cached_free = self.driver.reserved() - self.stats.allocated;
            let pool_cached = self.pool_cached_bytes(pool_kind);
            let frag_sample = if pool_cached >= rounded { cached_free } else { 0 };
            match self.driver.grow_segment(seg, delta) {
                Ok(()) => {
                    self.note_driver_growth(delta, rounded, frag_sample);
                    if tail_state == BlockState::Free {
                        // Fold the new granules into the free tail.
                        self.pool(pool_kind).remove(tail_size, tail);
                        self.slab.get_mut(tail).size = tail_size + delta;
                        return Ok(tail);
                    }
                    // Busy tail: append the new granules as a fresh free
                    // block at the end of the chain.
                    let offset = {
                        let b = self.slab.get(tail);
                        b.offset + b.size
                    };
                    let grown = Block {
                        segment: seg,
                        pool: pool_kind,
                        offset,
                        size: delta,
                        requested: 0,
                        state: BlockState::Free,
                        prev: tail.0,
                        next: NO_BLOCK,
                        origin_phase: self.phase,
                        live: true,
                    };
                    let grown_id = self.slab.insert(grown);
                    self.slab.get_mut(tail).next = grown_id.0;
                    return Ok(grown_id);
                }
                Err(e) => {
                    if retried {
                        return Err(AllocError::Oom(e, self.snapshot()));
                    }
                    retried = true;
                    // Same retry as the segment path: flush the cache —
                    // which may release or shrink this very segment — and
                    // re-derive the frontier from scratch.
                    let released = self.release_cached_segments();
                    self.emit(AllocEvent::OomRetry {
                        released_bytes: released,
                    });
                }
            }
        }
    }

    /// `garbage_collection_threshold` emulation: when `incoming` more
    /// bytes from the driver would push reserved memory past
    /// `threshold × capacity`, reclaim cached fully-free segments,
    /// least-recently-used first, until back under the threshold (or
    /// nothing reclaimable remains). Runs at malloc time, *before* the
    /// driver call — PyTorch's placement. `keep` protects the segment the
    /// caller is about to grow.
    fn maybe_gc(&mut self, incoming: u64, keep: Option<SegmentId>) {
        let Some(threshold) = self.cfg.garbage_collection_threshold else {
            return;
        };
        let target = (threshold * self.driver.capacity() as f64) as u64;
        if self.driver.reserved() + incoming <= target {
            return;
        }
        // Candidates come straight from the pools' fully-free-segment
        // indexes — the same set a scan over `seg_heads` for free,
        // single-block chains would find, without visiting busy segments.
        let mut candidates: Vec<(u64, u32, BlockId, u64, PoolKind)> = Vec::new();
        for (pool, pool_kind) in [
            (&self.small, PoolKind::Small),
            (&self.large, PoolKind::Large),
        ] {
            for (size, head, seg) in pool.fully_free() {
                if keep == Some(seg) {
                    continue;
                }
                let age = self.seg_last_use.get(&seg).copied().unwrap_or(0);
                candidates.push((age, seg.0, head, size, pool_kind));
            }
        }
        candidates.sort_unstable_by_key(|&(age, seg, ..)| (age, seg));
        let mut released = 0u64;
        let mut segments = 0u64;
        for (_, seg_raw, head, size, pool_kind) in candidates {
            if self.driver.reserved() + incoming <= target {
                break;
            }
            self.release_full_segment(SegmentId(seg_raw), head, size, pool_kind);
            released += size;
            segments += 1;
        }
        if segments > 0 {
            self.stats.num_gc_passes += 1;
            self.stats.gc_reclaimed += released;
            self.stats.sync(self.driver.reserved(), self.stats.allocated);
            self.emit(AllocEvent::GcReclaim {
                segments,
                bytes: released,
            });
        }
    }

    /// Release one fully-free segment (a single free block spanning it)
    /// back to the driver, unregistering every side table that knows
    /// about it.
    fn release_full_segment(
        &mut self,
        seg: SegmentId,
        head: BlockId,
        size: u64,
        pool_kind: PoolKind,
    ) {
        self.pool(pool_kind).remove(size, head);
        self.slab.remove(head);
        self.seg_heads.remove(&seg);
        self.seg_last_use.remove(&seg);
        for slot in self.expandable.iter_mut() {
            if *slot == Some(seg) {
                *slot = None;
            }
        }
        self.driver.cuda_free(seg);
        self.stats.num_cuda_frees += 1;
    }

    /// Split `block_id` down to `rounded` if the split rules allow, putting
    /// the remainder in the pool. Returns the (possibly unchanged) block to
    /// hand out.
    fn maybe_split(&mut self, block_id: BlockId, rounded: u64, pool_kind: PoolKind) -> BlockId {
        let (size, offset, seg, next, origin_phase) = {
            let b = self.slab.get(block_id);
            (b.size, b.offset, b.segment, b.next, b.origin_phase)
        };
        debug_assert!(size >= rounded);
        if !self.cfg.should_split(size, rounded, pool_kind) {
            return block_id;
        }
        // Carve [offset, offset+rounded) for the caller; remainder becomes
        // a new free block linked after it.
        let rem = Block {
            segment: seg,
            pool: pool_kind,
            offset: offset + rounded,
            size: size - rounded,
            requested: 0,
            state: BlockState::Free,
            prev: block_id.0,
            next,
            origin_phase,
            live: true,
        };
        let rem_id = self.slab.insert(rem);
        if next != NO_BLOCK {
            self.slab.get_mut(BlockId(next)).prev = rem_id.0;
        }
        {
            let b = self.slab.get_mut(block_id);
            b.size = rounded;
            b.next = rem_id.0;
        }
        let rem_size = size - rounded;
        // A split remainder starts past offset 0 — never a whole segment.
        self.pool(pool_kind).insert(rem_size, rem_id, seg, false);
        block_id
    }

    /// Free a live allocation: coalesce with free neighbours and cache it.
    pub fn free(&mut self, handle: AllocId) {
        let block_id = self
            .live
            .remove(&handle.0)
            .unwrap_or_else(|| panic!("free of unknown handle {handle:?}"));
        let (size, requested, pool_kind) = {
            let b = self.slab.get_mut(block_id);
            debug_assert_eq!(b.state, BlockState::Allocated);
            b.state = BlockState::Free;
            let r = b.requested;
            b.requested = 0;
            (b.size, r, b.pool)
        };
        self.stats.num_frees += 1;
        self.stats.time_us += self.cfg.cost.pool_free_us;
        self.stats.requested -= requested;
        let allocated = self.stats.allocated - size;
        self.stats.sync(self.driver.reserved(), allocated);

        let merged = self.coalesce(block_id, pool_kind);
        let (merged_size, merged_seg, spans) = {
            let b = self.slab.get(merged);
            // offset 0 with no successor ⟺ the single block tiling the
            // segment — the fully-free-segment index's membership rule.
            (b.size, b.segment, b.offset == 0 && b.next == NO_BLOCK)
        };
        self.pool(pool_kind)
            .insert(merged_size, merged, merged_seg, spans);

        self.emit(AllocEvent::Free { size });
    }

    /// Merge `block_id` (free, not pooled) with free neighbours. Neighbours
    /// are detached from the pool; the merge always folds into the
    /// earliest block so segment heads stay stable. Returns the survivor.
    fn coalesce(&mut self, block_id: BlockId, pool_kind: PoolKind) -> BlockId {
        let mut cur = block_id;

        // Fold into previous if free.
        let prev = self.slab.get(cur).prev;
        if prev != NO_BLOCK {
            let prev_id = BlockId(prev);
            if self.slab.get(prev_id).state == BlockState::Free {
                let prev_size = self.slab.get(prev_id).size;
                self.pool(pool_kind).remove(prev_size, prev_id);
                let (cur_size, cur_next) = {
                    let c = self.slab.get(cur);
                    (c.size, c.next)
                };
                {
                    let p = self.slab.get_mut(prev_id);
                    p.size += cur_size;
                    p.next = cur_next;
                }
                if cur_next != NO_BLOCK {
                    self.slab.get_mut(BlockId(cur_next)).prev = prev_id.0;
                }
                self.slab.remove(cur);
                cur = prev_id;
            }
        }

        // Fold next into current if free.
        let next = self.slab.get(cur).next;
        if next != NO_BLOCK {
            let next_id = BlockId(next);
            if self.slab.get(next_id).state == BlockState::Free {
                let next_size = self.slab.get(next_id).size;
                self.pool(pool_kind).remove(next_size, next_id);
                let next_next = self.slab.get(next_id).next;
                {
                    let c = self.slab.get_mut(cur);
                    c.size += next_size;
                    c.next = next_next;
                }
                if next_next != NO_BLOCK {
                    self.slab.get_mut(BlockId(next_next)).prev = cur.0;
                }
                self.slab.remove(next_id);
            }
        }
        cur
    }

    /// Release every fully-free segment back to the driver, and — with
    /// `expandable_segments` — unmap trailing free granules of still-used
    /// growable segments. Returns bytes released. (`empty_cache()` = this
    /// + the event + fixed latency.)
    fn release_cached_segments(&mut self) -> u64 {
        let mut released = 0u64;
        for pool_kind in [PoolKind::Small, PoolKind::Large] {
            // Snapshot the fully-free-segment index (can't mutate while
            // iterating). Its `(size, BlockId)` order is the relative
            // order a scan over the whole pool would have released in.
            let candidates: Vec<(u64, BlockId, SegmentId)> = match pool_kind {
                PoolKind::Small => self.small.fully_free().collect(),
                PoolKind::Large => self.large.fully_free().collect(),
            };
            for (size, id, seg) in candidates {
                self.release_full_segment(seg, id, size, pool_kind);
                released += size;
                self.emit(AllocEvent::CudaFree {
                    segment_bytes: size,
                });
            }
        }
        if self.cfg.expandable_segments {
            released += self.shrink_expandable_tails();
        }
        if released > 0 {
            self.stats.sync(self.driver.reserved(), self.stats.allocated);
        }
        released
    }

    /// Unmap trailing free granules of each still-used expandable segment
    /// (`cuMemUnmap` — what `empty_cache()` does under
    /// `expandable_segments`). A fully-free growable segment was already
    /// released whole by the segment loop, so only partial tails remain.
    fn shrink_expandable_tails(&mut self) -> u64 {
        let granule = self.cfg.expandable_granule();
        let mut released = 0u64;
        for slot in self.expandable {
            let Some(seg) = slot else {
                continue;
            };
            let head = *self.seg_heads.get(&seg).expect("expandable segment head");
            let mut tail = head;
            while self.slab.get(tail).next != NO_BLOCK {
                tail = BlockId(self.slab.get(tail).next);
            }
            let (state, size, offset, prev, pool_kind) = {
                let b = self.slab.get(tail);
                (b.state, b.size, b.offset, b.prev, b.pool)
            };
            if state != BlockState::Free || offset == 0 {
                // Busy tail, or a fully-free segment (released above).
                continue;
            }
            let cut = round_down(size, granule);
            if cut == 0 {
                continue;
            }
            self.pool(pool_kind).remove(size, tail);
            if cut == size {
                // The tail block unmaps entirely; its predecessor becomes
                // the new chain tail (it exists — offset > 0 — and is
                // allocated, else coalescing would have merged them).
                self.slab.get_mut(BlockId(prev)).next = NO_BLOCK;
                self.slab.remove(tail);
            } else {
                self.slab.get_mut(tail).size = size - cut;
                // offset > 0 (checked above): never a whole segment.
                self.pool(pool_kind).insert(size - cut, tail, seg, false);
            }
            self.driver.shrink_segment(seg, cut);
            self.stats.shrunk_bytes += cut;
            self.emit(AllocEvent::SegmentShrink { bytes: cut });
            released += cut;
        }
        released
    }

    /// The paper's mitigation: `torch.cuda.empty_cache()`.
    pub fn empty_cache(&mut self) -> u64 {
        self.stats.num_empty_cache += 1;
        self.stats.time_us += self.cfg.cost.empty_cache_base_us;
        let before_segments = self.driver.live_segments() as u64;
        let released = self.release_cached_segments();
        let segs = before_segments - self.driver.live_segments() as u64;
        self.emit(AllocEvent::EmptyCache {
            segments: segs,
            bytes: released,
        });
        released
    }

    /// Number of live (user-visible) allocations.
    pub fn live_allocs(&self) -> usize {
        self.live.len()
    }

    pub fn live_segments(&self) -> usize {
        self.driver.live_segments()
    }

    /// Exhaustive invariant check — O(everything); tests and property tests
    /// call this after every operation.
    pub fn validate(&self) -> Result<(), String> {
        use std::collections::BTreeSet;
        // 1. Per-segment chains must tile the segment exactly.
        let mut total_alloc = 0u64;
        let mut total_free = 0u64;
        let mut seg_bytes = 0u64;
        let mut free_blocks: Vec<(u64, BlockId)> = Vec::new();
        // Recomputed-from-scratch fully-free sets (`[small, large]`) to
        // hold the pools' incremental indexes against.
        let mut expect_ff: [BTreeSet<(u64, BlockId)>; 2] = [BTreeSet::new(), BTreeSet::new()];
        for (&seg, &head) in &self.seg_heads {
            let seg_size = self.driver.segment_size(seg);
            seg_bytes += seg_size;
            let mut cursor = head;
            let mut expect_offset = 0u64;
            let mut prev_state: Option<BlockState> = None;
            let mut prev_id = NO_BLOCK;
            loop {
                let b = self.slab.get(cursor);
                if b.segment != seg {
                    return Err(format!("block {cursor:?} in wrong segment"));
                }
                if b.offset != expect_offset {
                    return Err(format!(
                        "segment {seg:?}: expected offset {expect_offset}, got {}",
                        b.offset
                    ));
                }
                if b.prev != prev_id {
                    return Err(format!("block {cursor:?} has broken prev link"));
                }
                if b.state == BlockState::Free
                    && prev_state == Some(BlockState::Free)
                {
                    return Err(format!(
                        "segment {seg:?}: adjacent free blocks (coalescing broken)"
                    ));
                }
                match b.state {
                    BlockState::Allocated => total_alloc += b.size,
                    BlockState::Free => {
                        total_free += b.size;
                        free_blocks.push((b.size, cursor));
                        if b.offset == 0 && b.next == NO_BLOCK {
                            expect_ff[pool_idx(b.pool)].insert((b.size, cursor));
                        }
                    }
                }
                expect_offset += b.size;
                prev_state = Some(b.state);
                prev_id = cursor.0;
                if b.next == NO_BLOCK {
                    break;
                }
                cursor = BlockId(b.next);
            }
            if expect_offset != seg_size {
                return Err(format!(
                    "segment {seg:?}: chain covers {expect_offset} of {seg_size} bytes"
                ));
            }
        }
        // 2. Byte accounting.
        if seg_bytes != self.driver.reserved() {
            return Err(format!(
                "segment bytes {seg_bytes} != driver reserved {}",
                self.driver.reserved()
            ));
        }
        if total_alloc != self.stats.allocated {
            return Err(format!(
                "chain allocated {total_alloc} != stats.allocated {}",
                self.stats.allocated
            ));
        }
        if total_alloc + total_free != seg_bytes {
            return Err("allocated + free != reserved".to_string());
        }
        // 3. Pools hold exactly the free blocks.
        let pooled: u64 = self.small.cached_bytes() + self.large.cached_bytes();
        if pooled != total_free {
            return Err(format!(
                "pool bytes {pooled} != chain free bytes {total_free}"
            ));
        }
        let pool_count = self.small.len() + self.large.len();
        if pool_count != free_blocks.len() {
            return Err(format!(
                "pool count {pool_count} != free block count {}",
                free_blocks.len()
            ));
        }
        // 3b. The fully-free-segment indexes hold exactly the free blocks
        // spanning their whole segment, with the right owning segments.
        for (pool, kind) in [(&self.small, PoolKind::Small), (&self.large, PoolKind::Large)] {
            let got: BTreeSet<(u64, BlockId)> =
                pool.fully_free().map(|(size, id, _)| (size, id)).collect();
            if got != expect_ff[pool_idx(kind)] {
                return Err(format!(
                    "{} pool fully-free index out of sync: {} indexed vs {} spanning",
                    kind.name(),
                    got.len(),
                    expect_ff[pool_idx(kind)].len()
                ));
            }
            for (size, id, seg) in pool.fully_free() {
                let b = self.slab.get(id);
                if b.segment != seg || b.size != size {
                    return Err(format!(
                        "{} pool fully-free entry {id:?} stale: indexed ({size} B, {seg:?}) \
                         vs block ({} B, {:?})",
                        kind.name(),
                        b.size,
                        b.segment
                    ));
                }
            }
        }
        // 4. Live handle map points at allocated blocks.
        for (&h, &bid) in &self.live {
            let b = self.slab.get(bid);
            if b.state != BlockState::Allocated {
                return Err(format!("handle {h} points at non-allocated block"));
            }
        }
        // 5. Slab live count = chain blocks.
        if self.slab.len_live() != free_blocks.len() + self.live.len() {
            return Err(format!(
                "slab live {} != free {} + allocated {}",
                self.slab.len_live(),
                free_blocks.len(),
                self.live.len()
            ));
        }
        // 6. Knob sanity: config values, and the structural invariants the
        // expandable-segments / gc-threshold emulations maintain.
        self.cfg.check()?;
        if self.cfg.garbage_collection_threshold.is_none() && self.stats.num_gc_passes != 0 {
            return Err("gc pass recorded without garbage_collection_threshold".to_string());
        }
        if self.cfg.expandable_segments {
            // Each pool owns at most one segment, and it is the registered
            // growable one.
            for (&seg, &head) in &self.seg_heads {
                let pool = self.slab.get(head).pool;
                if self.expandable[pool_idx(pool)] != Some(seg) {
                    return Err(format!(
                        "segment {seg:?} is not the registered expandable segment of the {} pool",
                        pool.name()
                    ));
                }
            }
            for (idx, slot) in self.expandable.iter().enumerate() {
                if let Some(seg) = slot {
                    if !self.seg_heads.contains_key(seg) {
                        return Err(format!(
                            "expandable slot {idx} points at dead segment {seg:?}"
                        ));
                    }
                }
            }
        } else if self.expandable.iter().any(|s| s.is_some()) {
            return Err("expandable segment registered without the knob".to_string());
        }
        Ok(())
    }

    /// Iterate (size, origin_phase) of live segments — used by the profiler
    /// for phase attribution of reserved memory.
    pub fn segments_by_phase(&self) -> Vec<(u64, PhaseTag)> {
        self.seg_heads
            .iter()
            .map(|(&seg, &head)| {
                (
                    self.driver.segment_size(seg),
                    self.slab.get(head).origin_phase,
                )
            })
            .collect()
    }

    /// Total cached bytes held in segments that are *entirely* free — the
    /// memory `empty_cache` / the OOM-retry cascade could release right
    /// now. Served from the pools' fully-free-segment index, O(index len).
    pub fn cached_fully_free_bytes(&self) -> u64 {
        self.small.fully_free().map(|(size, _, _)| size).sum::<u64>()
            + self.large.fully_free().map(|(size, _, _)| size).sum::<u64>()
    }

    /// Deterministic per-segment map for observability: one record per live
    /// segment (sorted by segment id — `seg_heads` is a hash map, so the
    /// iteration order must not leak into artifacts), with the allocated /
    /// free byte split obtained by walking the segment's block chain.
    pub fn segment_map(&self) -> Vec<SegmentRecord> {
        let mut out: Vec<SegmentRecord> = self
            .seg_heads
            .iter()
            .map(|(&seg, &head)| {
                let mut rec = SegmentRecord {
                    segment: seg.0,
                    pool: self.slab.get(head).pool,
                    origin_phase: self.slab.get(head).origin_phase,
                    size: self.driver.segment_size(seg),
                    allocated: 0,
                    free: 0,
                    blocks: 0,
                };
                let mut cur = head;
                loop {
                    let b = self.slab.get(cur);
                    rec.blocks += 1;
                    match b.state {
                        BlockState::Allocated => rec.allocated += b.size,
                        BlockState::Free => rec.free += b.size,
                    }
                    if b.next == NO_BLOCK {
                        break;
                    }
                    cur = BlockId(b.next);
                }
                rec
            })
            .collect();
        out.sort_by_key(|r| r.segment);
        out
    }
}

/// One live segment's composition at inspection time (see
/// [`CachingAllocator::segment_map`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentRecord {
    pub segment: u32,
    pub pool: PoolKind,
    /// Phase during which the segment was first mapped.
    pub origin_phase: PhaseTag,
    pub size: u64,
    /// Bytes in allocated blocks.
    pub allocated: u64,
    /// Bytes in free (cached) blocks.
    pub free: u64,
    /// Block-chain length.
    pub blocks: u32,
}

impl SegmentRecord {
    /// A segment with zero allocated bytes is releasable cache.
    pub fn fully_free(&self) -> bool {
        self.allocated == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GIB, KIB, MIB};

    fn alloc(cap: u64) -> CachingAllocator {
        CachingAllocator::with_default_config(cap)
    }

    #[test]
    fn small_alloc_creates_2mib_segment() {
        let mut a = alloc(GIB);
        let h = a.alloc(100).unwrap();
        assert_eq!(a.reserved(), 2 * MIB);
        assert_eq!(a.allocated(), 512); // rounded
        assert_eq!(a.stats().num_cuda_mallocs, 1);
        a.validate().unwrap();
        a.free(h);
        // Cached, not returned to driver.
        assert_eq!(a.reserved(), 2 * MIB);
        assert_eq!(a.allocated(), 0);
        a.validate().unwrap();
    }

    #[test]
    fn second_small_alloc_reuses_segment() {
        let mut a = alloc(GIB);
        let _h1 = a.alloc(100 * KIB).unwrap();
        let _h2 = a.alloc(100 * KIB).unwrap();
        // Both fit in the single 2 MiB small segment.
        assert_eq!(a.reserved(), 2 * MIB);
        assert_eq!(a.stats().num_cuda_mallocs, 1);
        assert_eq!(a.stats().num_cache_hits, 1);
        a.validate().unwrap();
    }

    #[test]
    fn medium_alloc_gets_20mib_buffer() {
        let mut a = alloc(GIB);
        let _h = a.alloc(3 * MIB).unwrap();
        assert_eq!(a.reserved(), 20 * MIB);
        assert_eq!(a.allocated(), 3 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn huge_alloc_gets_exact_rounded_segment() {
        let mut a = alloc(GIB);
        let _h = a.alloc(33 * MIB).unwrap();
        assert_eq!(a.reserved(), 34 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn free_then_alloc_same_size_is_cache_hit() {
        let mut a = alloc(GIB);
        let h = a.alloc(5 * MIB).unwrap();
        a.free(h);
        let mallocs_before = a.stats().num_cuda_mallocs;
        let _h2 = a.alloc(5 * MIB).unwrap();
        assert_eq!(a.stats().num_cuda_mallocs, mallocs_before);
        assert_eq!(a.stats().num_cache_hits, 1);
        a.validate().unwrap();
    }

    #[test]
    fn coalesce_three_way() {
        let mut a = alloc(GIB);
        // Three 4 MiB blocks carved from one 20 MiB segment.
        let h1 = a.alloc(4 * MIB).unwrap();
        let h2 = a.alloc(4 * MIB).unwrap();
        let h3 = a.alloc(4 * MIB).unwrap();
        assert_eq!(a.reserved(), 20 * MIB);
        a.free(h1);
        a.free(h3);
        a.validate().unwrap();
        // Freeing the middle merges all three + trailing remainder.
        a.free(h2);
        a.validate().unwrap();
        // Now the segment is fully free: exactly one pooled block.
        assert_eq!(a.pool_cached_bytes(PoolKind::Large), 20 * MIB);
        let released = a.empty_cache();
        assert_eq!(released, 20 * MIB);
        assert_eq!(a.reserved(), 0);
        a.validate().unwrap();
    }

    #[test]
    fn empty_cache_keeps_partially_used_segments() {
        let mut a = alloc(GIB);
        let h1 = a.alloc(4 * MIB).unwrap();
        let h2 = a.alloc(4 * MIB).unwrap();
        a.free(h1);
        let released = a.empty_cache();
        assert_eq!(released, 0, "segment still has a live block");
        assert_eq!(a.reserved(), 20 * MIB);
        a.free(h2);
        assert_eq!(a.empty_cache(), 20 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn oom_retry_releases_cache() {
        let mut a = alloc(64 * MIB);
        let h = a.alloc(40 * MIB).unwrap();
        a.free(h); // 40 MiB cached
        // 60 MiB doesn't fit alongside the cached 40 MiB, but the retry
        // path releases the cache and succeeds.
        let h2 = a.alloc(60 * MIB).unwrap();
        assert_eq!(a.reserved(), 60 * MIB);
        a.validate().unwrap();
        a.free(h2);
    }

    #[test]
    fn true_oom_errors() {
        let mut a = alloc(32 * MIB);
        let _h = a.alloc(30 * MIB).unwrap();
        let err = a.alloc(10 * MIB).unwrap_err();
        let AllocError::Oom(_, snap) = err;
        assert_eq!(snap.allocated, 30 * MIB);
    }

    #[test]
    fn fragmentation_sample_records_gap() {
        // Two discontiguous cached 16 MiB segments cannot serve one 30 MiB
        // request even though 32 MiB is cached: a fragmentation-caused
        // cudaMalloc (paper Appendix B), sampled as the cached 32 MiB.
        let mut a = alloc(GIB);
        let h1 = a.alloc(15 * MIB).unwrap();
        let h2 = a.alloc(15 * MIB).unwrap();
        a.free(h1);
        a.free(h2);
        assert_eq!(a.reserved(), 32 * MIB);
        let _h3 = a.alloc(30 * MIB).unwrap();
        assert_eq!(a.stats().max_frag_sample, 32 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn capacity_miss_is_not_fragmentation() {
        // A cudaMalloc with insufficient cached bytes is capacity growth,
        // not fragmentation: the sample must be zero.
        let mut a = alloc(GIB);
        let h = a.alloc(15 * MIB).unwrap();
        a.free(h); // 16 MiB cached
        let _big = a.alloc(64 * MIB).unwrap(); // 16 < 64: capacity miss
        assert_eq!(a.stats().max_frag_sample, 0);
        a.validate().unwrap();
    }

    #[test]
    fn split_leaves_remainder_in_pool() {
        let mut a = alloc(GIB);
        let _h = a.alloc(2 * MIB).unwrap(); // 20 MiB segment, 18 MiB remainder
        assert_eq!(a.pool_cached_bytes(PoolKind::Large), 18 * MIB);
        let _h2 = a.alloc(17 * MIB).unwrap(); // served from remainder
        assert_eq!(a.stats().num_cuda_mallocs, 1);
        a.validate().unwrap();
    }

    #[test]
    fn large_pool_no_tiny_split_remainder() {
        // Splitting a large block must leave >1 MiB remainders only.
        let mut a = alloc(GIB);
        let _h = a.alloc(19 * MIB + 512 * KIB).unwrap();
        // Remainder would be 512 KiB (< 1 MiB): no split, whole 20 MiB used.
        assert_eq!(a.allocated(), 20 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn max_split_size_reserves_oversized_blocks() {
        let cfg = AllocatorConfig {
            max_split_size: Some(32 * MIB),
            ..AllocatorConfig::default()
        };
        let mut a = CachingAllocator::new(GIB, cfg);
        let h = a.alloc(64 * MIB).unwrap();
        a.free(h); // 64 MiB oversized block cached
        // A 2 MiB request must NOT nibble the 64 MiB block.
        let _h2 = a.alloc(2 * MIB).unwrap();
        assert_eq!(a.stats().num_cuda_mallocs, 2);
        // But a 60 MiB request may use it (close fit, no split).
        let _h3 = a.alloc(60 * MIB).unwrap();
        assert_eq!(a.stats().num_cuda_mallocs, 2);
        a.validate().unwrap();
    }

    #[test]
    fn phase_tagging_on_segments() {
        let mut a = alloc(GIB);
        a.set_phase(3);
        let _h = a.alloc(5 * MIB).unwrap();
        a.set_phase(7);
        let _h2 = a.alloc(30 * MIB).unwrap();
        let mut phases: Vec<u16> = a.segments_by_phase().iter().map(|&(_, p)| p).collect();
        phases.sort();
        assert_eq!(phases, [3, 7]);
    }

    #[test]
    fn handles_are_unique_and_freeable_once() {
        let mut a = alloc(GIB);
        let h1 = a.alloc(MIB).unwrap();
        let h2 = a.alloc(MIB).unwrap();
        assert_ne!(h1, h2);
        a.free(h1);
        a.free(h2);
        assert_eq!(a.live_allocs(), 0);
    }

    #[test]
    #[should_panic(expected = "free of unknown handle")]
    fn double_free_panics() {
        let mut a = alloc(GIB);
        let h = a.alloc(MIB).unwrap();
        a.free(h);
        a.free(h);
    }

    fn expandable(cap: u64) -> CachingAllocator {
        let cfg = AllocatorConfig {
            expandable_segments: true,
            ..AllocatorConfig::default()
        };
        CachingAllocator::new(cap, cfg)
    }

    #[test]
    fn expandable_grows_one_segment_per_pool() {
        let mut a = expandable(GIB);
        let _h1 = a.alloc(15 * MIB).unwrap(); // opens the large segment (16 MiB)
        let _h2 = a.alloc(30 * MIB).unwrap(); // grows it instead of a new one
        assert_eq!(a.live_segments(), 1);
        assert_eq!(a.reserved(), 46 * MIB);
        let _s = a.alloc(100).unwrap(); // small pool opens its own segment
        assert_eq!(a.live_segments(), 2);
        a.validate().unwrap();
    }

    #[test]
    fn expandable_reuses_freed_tail_across_size_drift() {
        // The §3.2 failure mode: a 15 MiB tensor freed, then a 30 MiB one
        // requested. Classic segments strand the 16 MiB segment and map 30
        // more (46 MiB reserved); an expandable segment folds the freed
        // tail into 14 MiB of growth — 30 MiB reserved, zero stranding.
        let mut a = expandable(GIB);
        let h = a.alloc(15 * MIB).unwrap();
        a.free(h);
        let _h2 = a.alloc(30 * MIB).unwrap();
        assert_eq!(a.reserved(), 30 * MIB);
        assert_eq!(a.live_segments(), 1);
        assert_eq!(a.stats().max_frag_sample, 0, "no stranded cache");
        a.validate().unwrap();

        // Classic allocator on the same ops strands the first segment.
        let mut c = alloc(GIB);
        let h = c.alloc(15 * MIB).unwrap();
        c.free(h);
        let _h2 = c.alloc(30 * MIB).unwrap();
        assert_eq!(c.reserved(), 46 * MIB);
    }

    #[test]
    fn expandable_empty_cache_shrinks_trailing_granules() {
        let mut a = expandable(GIB);
        let h1 = a.alloc(4 * MIB).unwrap(); // 20 MiB initial segment
        let h2 = a.alloc(4 * MIB).unwrap(); // served from the free tail
        assert_eq!(a.reserved(), 20 * MIB);
        a.free(h2);
        // Tail (16 MiB free behind h1) unmaps; h1's 4 MiB stay.
        let released = a.empty_cache();
        assert_eq!(released, 16 * MIB);
        assert_eq!(a.reserved(), 4 * MIB);
        assert_eq!(a.live_segments(), 1);
        assert_eq!(a.stats().shrunk_bytes, 16 * MIB);
        a.validate().unwrap();
        a.free(h1);
        assert_eq!(a.empty_cache(), 4 * MIB);
        assert_eq!(a.reserved(), 0);
        a.validate().unwrap();
    }

    #[test]
    fn expandable_segment_reopens_after_full_release() {
        let mut a = expandable(GIB);
        let h = a.alloc(12 * MIB).unwrap();
        a.free(h);
        assert_eq!(a.empty_cache(), 12 * MIB);
        assert_eq!(a.live_segments(), 0);
        a.validate().unwrap();
        let _h2 = a.alloc(5 * MIB).unwrap();
        assert_eq!(a.live_segments(), 1);
        a.validate().unwrap();
    }

    #[test]
    fn expandable_tight_capacity_grows_in_place() {
        // 64 MiB device: the freed 40 MiB tail merges with 20 MiB of
        // growth, so the 60 MiB request fits with no release at all (the
        // classic allocator needs the OOM-retry cudaFree here).
        let mut a = expandable(64 * MIB);
        let h = a.alloc(40 * MIB).unwrap();
        a.free(h);
        let h2 = a.alloc(60 * MIB).unwrap();
        assert_eq!(a.reserved(), 60 * MIB);
        assert_eq!(a.live_segments(), 1);
        assert_eq!(a.stats().num_cuda_frees, 0, "no retry needed");
        a.validate().unwrap();
        a.free(h2);
    }

    #[test]
    fn expandable_oom_retry_releases_and_rederives() {
        // 64 MiB device. Fill the small pool's growable segment to 10 MiB
        // and cache it all; keep 4 MiB live in the large segment (20 MiB
        // mapped). A 52 MiB request then needs the retry: release the
        // fully-free small segment, unmap the large segment's 16 MiB free
        // tail, and re-derive the growth frontier.
        let mut a = expandable(64 * MIB);
        let smalls: Vec<AllocId> = (0..10).map(|_| a.alloc(MIB).unwrap()).collect();
        let h1 = a.alloc(4 * MIB).unwrap();
        for s in smalls {
            a.free(s);
        }
        assert_eq!(a.reserved(), 30 * MIB); // 10 small + 20 large
        let h2 = a.alloc(52 * MIB).unwrap();
        assert_eq!(a.reserved(), 56 * MIB); // 4 live + 52 grown
        assert_eq!(a.live_segments(), 1, "small segment released");
        assert_eq!(a.stats().num_cuda_frees, 1);
        assert_eq!(a.stats().shrunk_bytes, 16 * MIB);
        a.validate().unwrap();
        a.free(h1);
        a.free(h2);
        a.empty_cache();
        assert_eq!(a.reserved(), 0);
        a.validate().unwrap();
    }

    #[test]
    fn expandable_neutralizes_max_split_reservation() {
        let cfg = AllocatorConfig {
            expandable_segments: true,
            max_split_size: Some(32 * MIB),
            ..AllocatorConfig::default()
        };
        let mut a = CachingAllocator::new(GIB, cfg);
        let h = a.alloc(64 * MIB).unwrap();
        a.free(h);
        // Classic max_split reserves the 64 MiB block for oversized
        // requests; with expandable segments the block is just cache.
        let _h2 = a.alloc(2 * MIB).unwrap();
        assert_eq!(a.stats().num_cuda_mallocs, 1, "served from cache");
        a.validate().unwrap();
    }

    fn gc_alloc(cap: u64, threshold: f64) -> CachingAllocator {
        let cfg = AllocatorConfig {
            garbage_collection_threshold: Some(threshold),
            ..AllocatorConfig::default()
        };
        CachingAllocator::new(cap, cfg)
    }

    #[test]
    fn gc_threshold_reclaims_before_driver_growth() {
        // 64 MiB device, threshold 0.5 (= 32 MiB target): a cached 20 MiB
        // segment is reclaimed before the 30 MiB malloc, so reserved never
        // climbs to the 50 MiB the default allocator would hold.
        let mut a = gc_alloc(64 * MIB, 0.5);
        let h = a.alloc(20 * MIB).unwrap();
        a.free(h);
        let _h2 = a.alloc(30 * MIB).unwrap();
        assert_eq!(a.reserved(), 30 * MIB);
        assert_eq!(a.stats().num_gc_passes, 1);
        assert_eq!(a.stats().gc_reclaimed, 20 * MIB);
        a.validate().unwrap();

        let mut c = alloc(64 * MIB);
        let h = c.alloc(20 * MIB).unwrap();
        c.free(h);
        let _h2 = c.alloc(30 * MIB).unwrap();
        assert_eq!(c.reserved(), 50 * MIB, "default keeps the cold cache");
    }

    #[test]
    fn gc_reclaims_least_recently_used_first() {
        // target = 0.625 × 128 MiB = 80 MiB.
        let mut a = gc_alloc(128 * MIB, 0.625);
        let a1 = a.alloc(20 * MIB).unwrap(); // segment A, tick 1
        let b1 = a.alloc(30 * MIB).unwrap(); // segment B, tick 2
        a.free(a1);
        let a2 = a.alloc(20 * MIB).unwrap(); // segment A again, tick 3
        a.free(a2);
        a.free(b1);
        // 50 MiB cached + 40 incoming > 80: reclaim B (older) only.
        let _c = a.alloc(40 * MIB).unwrap();
        assert_eq!(a.stats().num_gc_passes, 1);
        assert_eq!(a.stats().gc_reclaimed, 30 * MIB, "B freed, A kept");
        assert_eq!(a.reserved(), 60 * MIB);
        assert_eq!(a.pool_cached_bytes(PoolKind::Large), 20 * MIB);
        a.validate().unwrap();
    }

    #[test]
    fn gc_and_expandable_compose() {
        let cfg = AllocatorConfig {
            expandable_segments: true,
            garbage_collection_threshold: Some(0.8),
            ..AllocatorConfig::default()
        };
        let mut a = CachingAllocator::new(256 * MIB, cfg);
        let mut live = Vec::new();
        for i in 1..=20u64 {
            live.push(a.alloc(i * MIB).unwrap());
            if i % 3 == 0 {
                a.free(live.swap_remove(0));
            }
            a.validate().unwrap();
        }
        for h in live {
            a.free(h);
        }
        a.empty_cache();
        assert_eq!(a.reserved(), 0);
        a.validate().unwrap();
    }

    #[test]
    fn requested_tracks_internal_fragmentation() {
        let mut a = alloc(GIB);
        let _h = a.alloc(100).unwrap(); // rounds to 512
        let snap = a.snapshot();
        assert_eq!(snap.requested, 100);
        assert_eq!(snap.allocated, 512);
    }

    #[test]
    fn peak_frag_at_reserved_peak() {
        // Two cached 16 MiB segments; a 30 MiB request sets a new reserved
        // peak via a fragmentation-caused malloc -> frag-at-peak = 32 MiB.
        let mut a = alloc(GIB);
        let h1 = a.alloc(15 * MIB).unwrap();
        let h2 = a.alloc(15 * MIB).unwrap();
        a.free(h1);
        a.free(h2);
        let _h3 = a.alloc(30 * MIB).unwrap();
        let s = a.stats();
        assert_eq!(s.peak_reserved, 62 * MIB);
        assert_eq!(s.frag_at_peak_reserved, 32 * MIB);
    }

    #[test]
    fn allocator_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<CachingAllocator>();
    }

    #[test]
    fn event_log_records_and_drains() {
        let mut a = alloc(GIB);
        let h = a.alloc(5 * MIB).unwrap(); // CudaMalloc + Alloc
        a.free(h); // Free
        let mut out = Vec::new();
        a.drain_events_into(&mut out);
        assert!(out.is_empty(), "recording off: no events");

        a.set_event_recording(true);
        let h = a.alloc(5 * MIB).unwrap(); // cache hit: Alloc only
        a.free(h);
        a.empty_cache();
        a.drain_events_into(&mut out);
        let kinds: Vec<&AllocEvent> = out.iter().map(|(e, _)| e).collect();
        assert!(matches!(kinds[0], AllocEvent::Alloc { cache_hit: true, .. }));
        assert!(matches!(kinds[1], AllocEvent::Free { .. }));
        assert!(kinds.iter().any(|e| matches!(e, AllocEvent::EmptyCache { .. })));
        // Snapshots are point-in-time: the Alloc snapshot sees the bytes.
        assert_eq!(out[0].1.allocated, 5 * MIB);
        let mut again = Vec::new();
        a.drain_events_into(&mut again);
        assert!(again.is_empty(), "drained");
    }

    #[test]
    fn stress_mixed_sizes_validate() {
        use crate::util::prng::Rng;
        let mut rng = Rng::seeded(0xC0FFEE);
        let mut a = alloc(4 * GIB);
        let mut live: Vec<AllocId> = Vec::new();
        for step in 0..5_000 {
            if live.is_empty() || rng.bernoulli(0.6) {
                let class = rng.gen_range(4);
                let sz = match class {
                    0 => rng.gen_range(4 * KIB) + 1,
                    1 => rng.gen_range(900 * KIB) + KIB,
                    2 => rng.gen_range(8 * MIB) + MIB,
                    _ => rng.gen_range(64 * MIB) + 10 * MIB,
                };
                if let Ok(h) = a.alloc(sz) {
                    live.push(h);
                }
            } else {
                let i = rng.range_usize(0, live.len());
                let h = live.swap_remove(i);
                a.free(h);
            }
            if step % 500 == 0 {
                a.validate().unwrap();
            }
            if step % 1000 == 999 {
                a.empty_cache();
                a.validate().unwrap();
            }
        }
        for h in live {
            a.free(h);
        }
        a.empty_cache();
        assert_eq!(a.reserved(), 0);
        a.validate().unwrap();
    }
}
