//! Block storage for the caching allocator.
//!
//! Blocks live in a slab (`Vec<Block>` indexed by `BlockId`) — no per-block
//! heap allocation on the hot path. Each driver segment is carved into a
//! doubly-linked chain of blocks ordered by offset; splitting and
//! coalescing rewire the chain.

use super::config::PoolKind;
use super::driver::SegmentId;

/// Index into the block slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

pub const NO_BLOCK: u32 = u32::MAX;

/// Allocation state of a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockState {
    Free,
    Allocated,
}

/// One contiguous range within a segment.
#[derive(Debug, Clone)]
pub struct Block {
    pub segment: SegmentId,
    pub pool: PoolKind,
    pub offset: u64,
    pub size: u64,
    /// Bytes the caller actually asked for (≤ size); used for internal-
    /// fragmentation accounting. Zero while free.
    pub requested: u64,
    pub state: BlockState,
    /// Chain links within the segment (offset order). `NO_BLOCK` = none.
    pub prev: u32,
    pub next: u32,
    /// Epoch/phase tag of the *allocation that created the segment* —
    /// used by the profiler to attribute reserved memory to RLHF phases.
    pub origin_phase: u16,
    /// Slab slot generation to catch stale ids in debug builds.
    pub live: bool,
}

/// Slab of blocks with free-slot recycling.
#[derive(Debug, Default, Clone)]
pub struct BlockSlab {
    blocks: Vec<Block>,
    free_slots: Vec<u32>,
}

impl BlockSlab {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, block: Block) -> BlockId {
        debug_assert!(block.live);
        match self.free_slots.pop() {
            Some(slot) => {
                self.blocks[slot as usize] = block;
                BlockId(slot)
            }
            None => {
                self.blocks.push(block);
                BlockId((self.blocks.len() - 1) as u32)
            }
        }
    }

    pub fn remove(&mut self, id: BlockId) {
        let b = &mut self.blocks[id.0 as usize];
        debug_assert!(b.live, "remove of dead block {id:?}");
        b.live = false;
        self.free_slots.push(id.0);
    }

    #[inline]
    pub fn get(&self, id: BlockId) -> &Block {
        let b = &self.blocks[id.0 as usize];
        debug_assert!(b.live, "access to dead block {id:?}");
        b
    }

    #[inline]
    pub fn get_mut(&mut self, id: BlockId) -> &mut Block {
        let b = &mut self.blocks[id.0 as usize];
        debug_assert!(b.live, "access to dead block {id:?}");
        b
    }

    pub fn len_live(&self) -> usize {
        self.blocks.len() - self.free_slots.len()
    }

    /// Iterate live blocks (diagnostics / invariant checks only — O(slab)).
    pub fn iter_live(&self) -> impl Iterator<Item = (BlockId, &Block)> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.live)
            .map(|(i, b)| (BlockId(i as u32), b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(offset: u64, size: u64) -> Block {
        Block {
            segment: SegmentId(0),
            pool: PoolKind::Small,
            offset,
            size,
            requested: 0,
            state: BlockState::Free,
            prev: NO_BLOCK,
            next: NO_BLOCK,
            origin_phase: 0,
            live: true,
        }
    }

    #[test]
    fn insert_get_remove() {
        let mut slab = BlockSlab::new();
        let a = slab.insert(blk(0, 512));
        let b = slab.insert(blk(512, 1024));
        assert_eq!(slab.get(a).size, 512);
        assert_eq!(slab.get(b).offset, 512);
        assert_eq!(slab.len_live(), 2);
        slab.remove(a);
        assert_eq!(slab.len_live(), 1);
    }

    #[test]
    fn slot_recycling() {
        let mut slab = BlockSlab::new();
        let a = slab.insert(blk(0, 512));
        slab.remove(a);
        let b = slab.insert(blk(0, 256));
        assert_eq!(a.0, b.0, "slot should be recycled");
        assert_eq!(slab.get(b).size, 256);
    }

    #[test]
    fn iter_live_skips_dead() {
        let mut slab = BlockSlab::new();
        let a = slab.insert(blk(0, 512));
        let _b = slab.insert(blk(512, 512));
        slab.remove(a);
        let live: Vec<_> = slab.iter_live().collect();
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].1.offset, 512);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn stale_access_panics_in_debug() {
        let mut slab = BlockSlab::new();
        let a = slab.insert(blk(0, 512));
        slab.remove(a);
        let _ = slab.get(a);
    }
}
