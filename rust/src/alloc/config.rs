//! Configuration for the caching-allocator simulator.
//!
//! Defaults mirror PyTorch's `CUDACachingAllocator` constants:
//! `kMinBlockSize = 512`, `kSmallSize = 1 MiB`, `kSmallBuffer = 2 MiB`,
//! `kLargeBuffer = 20 MiB`, `kMinLargeAlloc = 10 MiB`, `kRoundLarge = 2 MiB`,
//! plus the three `PYTORCH_CUDA_ALLOC_CONF` mitigation knobs the planner
//! searches over: `max_split_size` (`max_split_size_mb`),
//! [`AllocatorConfig::expandable_segments`] and
//! [`AllocatorConfig::garbage_collection_threshold`]. All three are
//! *algorithmic* emulations inside [`super::CachingAllocator`] — they change
//! how malloc/free behave, never what numbers come out (DESIGN.md §6, §10).

use crate::util::bytes::MIB;

/// Latency model for driver / allocator operations, in microseconds.
///
/// The absolute values follow published microbenchmarks of CUDA driver
/// calls (cudaMalloc ≈ 0.2–1 ms depending on size, cudaFree ≈ 0.1 ms plus
/// an implicit synchronization). Only *ratios* matter for the paper's
/// "+2% end-to-end time" claim (E8), and those are insensitive to ±2×
/// changes in these constants (see `benches/empty_cache_overhead.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Fixed cost of one `cudaMalloc` call.
    pub cuda_malloc_base_us: f64,
    /// Additional cost of `cudaMalloc` per GiB requested (page mapping).
    pub cuda_malloc_per_gib_us: f64,
    /// Fixed cost of one `cudaFree` call (includes implicit sync).
    pub cuda_free_us: f64,
    /// Cost of an allocation served from the cached pool.
    pub cache_hit_us: f64,
    /// Cost of returning a block to the pool.
    pub pool_free_us: f64,
    /// Fixed cost of an `empty_cache()` call on top of the per-segment
    /// `cudaFree`s it issues.
    pub empty_cache_base_us: f64,
    /// Fixed cost of growing an expandable segment (`cuMemCreate` +
    /// `cuMemMap` of new granules — no fresh VA reservation, no implicit
    /// sync, so cheaper than a full `cudaMalloc`).
    pub segment_grow_base_us: f64,
    /// Fixed cost of unmapping trailing granules of an expandable segment
    /// (`cuMemUnmap` + `cuMemRelease`).
    pub segment_unmap_us: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cuda_malloc_base_us: 250.0,
            cuda_malloc_per_gib_us: 180.0,
            cuda_free_us: 110.0,
            cache_hit_us: 1.6,
            pool_free_us: 0.9,
            empty_cache_base_us: 40.0,
            segment_grow_base_us: 60.0,
            segment_unmap_us: 70.0,
        }
    }
}

/// Allocator tunables (PyTorch constants by default).
#[derive(Debug, Clone, PartialEq)]
pub struct AllocatorConfig {
    /// All requests are rounded up to a multiple of this (512 B).
    pub min_block_size: u64,
    /// Requests ≤ this go to the small pool (1 MiB).
    pub small_size: u64,
    /// Segment size for small-pool cudaMallocs (2 MiB).
    pub small_buffer: u64,
    /// Segment size for "medium" large-pool requests (20 MiB).
    pub large_buffer: u64,
    /// Requests ≥ this get their own rounded segment (10 MiB).
    pub min_large_alloc: u64,
    /// Rounding granularity for big segments (2 MiB).
    pub round_large: u64,
    /// Blocks larger than this are never split (None = unlimited, the
    /// PyTorch default).
    pub max_split_size: Option<u64>,
    /// Remainder threshold for splitting a large-pool block: PyTorch keeps
    /// the remainder only if it exceeds `kSmallSize` (1 MiB).
    pub large_split_remainder: u64,
    /// PyTorch's `expandable_segments:True`: instead of cudaMalloc'ing a
    /// discrete segment per cache miss, each pool owns at most one segment
    /// whose tail grows by physical granules (`cuMemMap`); a miss extends
    /// the tail, merging with a trailing free block, so differently-sized
    /// retries reuse the same address range instead of stranding old
    /// segments. `empty_cache()` additionally unmaps trailing free
    /// granules of a still-used segment.
    pub expandable_segments: bool,
    /// PyTorch's `garbage_collection_threshold` (a fraction of device
    /// capacity in `(0, 1]`): when a cache miss would push reserved memory
    /// past `threshold × capacity`, the allocator first reclaims cached
    /// fully-free segments — least-recently-used first — before going to
    /// the driver, avoiding both the OOM-retry sync and unbounded cache
    /// growth.
    pub garbage_collection_threshold: Option<f64>,
    /// Latency model.
    pub cost: CostModel,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            min_block_size: 512,
            small_size: MIB,
            small_buffer: 2 * MIB,
            large_buffer: 20 * MIB,
            min_large_alloc: 10 * MIB,
            round_large: 2 * MIB,
            max_split_size: None,
            large_split_remainder: MIB,
            expandable_segments: false,
            garbage_collection_threshold: None,
            cost: CostModel::default(),
        }
    }
}

impl AllocatorConfig {
    /// PyTorch's `round_size`: everything is a multiple of 512 B.
    pub fn round_size(&self, size: u64) -> u64 {
        if size < self.min_block_size {
            self.min_block_size
        } else {
            size.div_ceil(self.min_block_size) * self.min_block_size
        }
    }

    /// Which pool serves a (rounded) request.
    pub fn pool_for(&self, rounded: u64) -> PoolKind {
        if rounded <= self.small_size {
            PoolKind::Small
        } else {
            PoolKind::Large
        }
    }

    /// PyTorch's `get_allocation_size`: size of the segment cudaMalloc'd
    /// when the pool cannot serve a (rounded) request.
    pub fn segment_size_for(&self, rounded: u64) -> u64 {
        if rounded <= self.small_size {
            self.small_buffer
        } else if rounded < self.min_large_alloc {
            self.large_buffer
        } else {
            rounded.div_ceil(self.round_large) * self.round_large
        }
    }

    /// Physical mapping granule for expandable segments (PyTorch maps
    /// 2 MiB handles; we reuse `round_large` so segment sizes stay
    /// granule-aligned).
    pub fn expandable_granule(&self) -> u64 {
        self.round_large
    }

    /// Short stable label naming the non-default knobs, used in sweep-cell
    /// keys and planner reports ("default", "max_split:128MiB",
    /// "expandable+gc:0.80", ...).
    pub fn knob_label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(max) = self.max_split_size {
            parts.push(format!("max_split:{}MiB", max / MIB));
        }
        if self.expandable_segments {
            parts.push("expandable".to_string());
        }
        if let Some(t) = self.garbage_collection_threshold {
            parts.push(format!("gc:{t:.2}"));
        }
        if parts.is_empty() {
            "default".to_string()
        } else {
            parts.join("+")
        }
    }

    /// Knob sanity (called from [`super::CachingAllocator::validate`]).
    pub fn check(&self) -> Result<(), String> {
        if let Some(t) = self.garbage_collection_threshold {
            if t.is_nan() || t <= 0.0 || t > 1.0 {
                return Err(format!(
                    "garbage_collection_threshold {t} outside (0, 1]"
                ));
            }
        }
        if let Some(max) = self.max_split_size {
            if max < self.large_buffer {
                return Err(format!(
                    "max_split_size {max} below large_buffer {}",
                    self.large_buffer
                ));
            }
        }
        Ok(())
    }

    /// PyTorch's `should_split` predicate. The `max_split_size` no-split
    /// rule only governs classic discrete segments: with
    /// `expandable_segments` the oversized blocks it protects against
    /// merge back into the growth frontier instead of stranding, so the
    /// two knobs don't stack.
    pub fn should_split(&self, block_size: u64, requested: u64, pool: PoolKind) -> bool {
        if let Some(max) = self.max_split_size {
            if !self.expandable_segments && block_size > max {
                return false;
            }
        }
        let remaining = block_size - requested;
        match pool {
            PoolKind::Small => remaining >= self.min_block_size,
            PoolKind::Large => remaining > self.large_split_remainder,
        }
    }
}

/// The two block pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PoolKind {
    Small,
    Large,
}

impl PoolKind {
    pub fn name(self) -> &'static str {
        match self {
            PoolKind::Small => "small",
            PoolKind::Large => "large",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{KIB, MIB};

    #[test]
    fn round_size_matches_pytorch() {
        let c = AllocatorConfig::default();
        assert_eq!(c.round_size(1), 512);
        assert_eq!(c.round_size(512), 512);
        assert_eq!(c.round_size(513), 1024);
        assert_eq!(c.round_size(1000), 1024);
        assert_eq!(c.round_size(MIB), MIB);
    }

    #[test]
    fn pool_selection() {
        let c = AllocatorConfig::default();
        assert_eq!(c.pool_for(512), PoolKind::Small);
        assert_eq!(c.pool_for(MIB), PoolKind::Small);
        assert_eq!(c.pool_for(MIB + 512), PoolKind::Large);
    }

    #[test]
    fn segment_sizing_matches_pytorch() {
        let c = AllocatorConfig::default();
        // small request -> 2 MiB segment
        assert_eq!(c.segment_size_for(512), 2 * MIB);
        assert_eq!(c.segment_size_for(MIB), 2 * MIB);
        // 1 MiB < r < 10 MiB -> 20 MiB segment
        assert_eq!(c.segment_size_for(MIB + 512), 20 * MIB);
        assert_eq!(c.segment_size_for(9 * MIB), 20 * MIB);
        // >= 10 MiB -> round to 2 MiB
        assert_eq!(c.segment_size_for(10 * MIB), 10 * MIB);
        assert_eq!(c.segment_size_for(10 * MIB + 1), 12 * MIB);
        assert_eq!(c.segment_size_for(33 * MIB), 34 * MIB);
    }

    #[test]
    fn knob_labels_are_stable() {
        let mut c = AllocatorConfig::default();
        assert_eq!(c.knob_label(), "default");
        c.max_split_size = Some(128 * MIB);
        assert_eq!(c.knob_label(), "max_split:128MiB");
        c.max_split_size = None;
        c.expandable_segments = true;
        assert_eq!(c.knob_label(), "expandable");
        c.garbage_collection_threshold = Some(0.8);
        assert_eq!(c.knob_label(), "expandable+gc:0.80");
    }

    #[test]
    fn check_rejects_bad_knobs() {
        let mut c = AllocatorConfig::default();
        assert!(c.check().is_ok());
        c.garbage_collection_threshold = Some(0.0);
        assert!(c.check().is_err());
        c.garbage_collection_threshold = Some(1.5);
        assert!(c.check().is_err());
        c.garbage_collection_threshold = Some(0.75);
        assert!(c.check().is_ok());
        c.max_split_size = Some(MIB);
        assert!(c.check().is_err(), "below kLargeBuffer");
    }

    #[test]
    fn expandable_granule_matches_round_large() {
        let c = AllocatorConfig::default();
        assert_eq!(c.expandable_granule(), 2 * MIB);
    }

    #[test]
    fn split_predicates() {
        let c = AllocatorConfig::default();
        // Small pool: remainder >= 512 B.
        assert!(c.should_split(2 * KIB, KIB, PoolKind::Small));
        assert!(!c.should_split(KIB + 256, KIB, PoolKind::Small));
        // Large pool: remainder must exceed 1 MiB.
        assert!(c.should_split(20 * MIB, 2 * MIB, PoolKind::Large));
        assert!(!c.should_split(2 * MIB + 512, 2 * MIB, PoolKind::Large));
        // max_split_size blocks splitting of huge blocks.
        let mut c2 = c.clone();
        c2.max_split_size = Some(32 * MIB);
        assert!(!c2.should_split(64 * MIB, 2 * MIB, PoolKind::Large));
        assert!(c2.should_split(32 * MIB, 2 * MIB, PoolKind::Large));
        // ...unless expandable segments neutralize the rule.
        c2.expandable_segments = true;
        assert!(c2.should_split(64 * MIB, 2 * MIB, PoolKind::Large));
    }
}
