//! Configuration for the caching-allocator simulator.
//!
//! Defaults mirror PyTorch's `CUDACachingAllocator` constants:
//! `kMinBlockSize = 512`, `kSmallSize = 1 MiB`, `kSmallBuffer = 2 MiB`,
//! `kLargeBuffer = 20 MiB`, `kMinLargeAlloc = 10 MiB`, `kRoundLarge = 2 MiB`,
//! and an optional `max_split_size` (PyTorch's
//! `PYTORCH_CUDA_ALLOC_CONF=max_split_size_mb`).

use crate::util::bytes::MIB;

/// Latency model for driver / allocator operations, in microseconds.
///
/// The absolute values follow published microbenchmarks of CUDA driver
/// calls (cudaMalloc ≈ 0.2–1 ms depending on size, cudaFree ≈ 0.1 ms plus
/// an implicit synchronization). Only *ratios* matter for the paper's
/// "+2% end-to-end time" claim (E8), and those are insensitive to ±2×
/// changes in these constants (see `benches/empty_cache_overhead.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Fixed cost of one `cudaMalloc` call.
    pub cuda_malloc_base_us: f64,
    /// Additional cost of `cudaMalloc` per GiB requested (page mapping).
    pub cuda_malloc_per_gib_us: f64,
    /// Fixed cost of one `cudaFree` call (includes implicit sync).
    pub cuda_free_us: f64,
    /// Cost of an allocation served from the cached pool.
    pub cache_hit_us: f64,
    /// Cost of returning a block to the pool.
    pub pool_free_us: f64,
    /// Fixed cost of an `empty_cache()` call on top of the per-segment
    /// `cudaFree`s it issues.
    pub empty_cache_base_us: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cuda_malloc_base_us: 250.0,
            cuda_malloc_per_gib_us: 180.0,
            cuda_free_us: 110.0,
            cache_hit_us: 1.6,
            pool_free_us: 0.9,
            empty_cache_base_us: 40.0,
        }
    }
}

/// Allocator tunables (PyTorch constants by default).
#[derive(Debug, Clone, PartialEq)]
pub struct AllocatorConfig {
    /// All requests are rounded up to a multiple of this (512 B).
    pub min_block_size: u64,
    /// Requests ≤ this go to the small pool (1 MiB).
    pub small_size: u64,
    /// Segment size for small-pool cudaMallocs (2 MiB).
    pub small_buffer: u64,
    /// Segment size for "medium" large-pool requests (20 MiB).
    pub large_buffer: u64,
    /// Requests ≥ this get their own rounded segment (10 MiB).
    pub min_large_alloc: u64,
    /// Rounding granularity for big segments (2 MiB).
    pub round_large: u64,
    /// Blocks larger than this are never split (None = unlimited, the
    /// PyTorch default).
    pub max_split_size: Option<u64>,
    /// Remainder threshold for splitting a large-pool block: PyTorch keeps
    /// the remainder only if it exceeds `kSmallSize` (1 MiB).
    pub large_split_remainder: u64,
    /// Latency model.
    pub cost: CostModel,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            min_block_size: 512,
            small_size: MIB,
            small_buffer: 2 * MIB,
            large_buffer: 20 * MIB,
            min_large_alloc: 10 * MIB,
            round_large: 2 * MIB,
            max_split_size: None,
            large_split_remainder: MIB,
            cost: CostModel::default(),
        }
    }
}

impl AllocatorConfig {
    /// PyTorch's `round_size`: everything is a multiple of 512 B.
    pub fn round_size(&self, size: u64) -> u64 {
        if size < self.min_block_size {
            self.min_block_size
        } else {
            size.div_ceil(self.min_block_size) * self.min_block_size
        }
    }

    /// Which pool serves a (rounded) request.
    pub fn pool_for(&self, rounded: u64) -> PoolKind {
        if rounded <= self.small_size {
            PoolKind::Small
        } else {
            PoolKind::Large
        }
    }

    /// PyTorch's `get_allocation_size`: size of the segment cudaMalloc'd
    /// when the pool cannot serve a (rounded) request.
    pub fn segment_size_for(&self, rounded: u64) -> u64 {
        if rounded <= self.small_size {
            self.small_buffer
        } else if rounded < self.min_large_alloc {
            self.large_buffer
        } else {
            rounded.div_ceil(self.round_large) * self.round_large
        }
    }

    /// PyTorch's `should_split` predicate.
    pub fn should_split(&self, block_size: u64, requested: u64, pool: PoolKind) -> bool {
        if let Some(max) = self.max_split_size {
            if block_size > max {
                return false;
            }
        }
        let remaining = block_size - requested;
        match pool {
            PoolKind::Small => remaining >= self.min_block_size,
            PoolKind::Large => remaining > self.large_split_remainder,
        }
    }
}

/// The two block pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PoolKind {
    Small,
    Large,
}

impl PoolKind {
    pub fn name(self) -> &'static str {
        match self {
            PoolKind::Small => "small",
            PoolKind::Large => "large",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{KIB, MIB};

    #[test]
    fn round_size_matches_pytorch() {
        let c = AllocatorConfig::default();
        assert_eq!(c.round_size(1), 512);
        assert_eq!(c.round_size(512), 512);
        assert_eq!(c.round_size(513), 1024);
        assert_eq!(c.round_size(1000), 1024);
        assert_eq!(c.round_size(MIB), MIB);
    }

    #[test]
    fn pool_selection() {
        let c = AllocatorConfig::default();
        assert_eq!(c.pool_for(512), PoolKind::Small);
        assert_eq!(c.pool_for(MIB), PoolKind::Small);
        assert_eq!(c.pool_for(MIB + 512), PoolKind::Large);
    }

    #[test]
    fn segment_sizing_matches_pytorch() {
        let c = AllocatorConfig::default();
        // small request -> 2 MiB segment
        assert_eq!(c.segment_size_for(512), 2 * MIB);
        assert_eq!(c.segment_size_for(MIB), 2 * MIB);
        // 1 MiB < r < 10 MiB -> 20 MiB segment
        assert_eq!(c.segment_size_for(MIB + 512), 20 * MIB);
        assert_eq!(c.segment_size_for(9 * MIB), 20 * MIB);
        // >= 10 MiB -> round to 2 MiB
        assert_eq!(c.segment_size_for(10 * MIB), 10 * MIB);
        assert_eq!(c.segment_size_for(10 * MIB + 1), 12 * MIB);
        assert_eq!(c.segment_size_for(33 * MIB), 34 * MIB);
    }

    #[test]
    fn split_predicates() {
        let c = AllocatorConfig::default();
        // Small pool: remainder >= 512 B.
        assert!(c.should_split(2 * KIB, KIB, PoolKind::Small));
        assert!(!c.should_split(KIB + 256, KIB, PoolKind::Small));
        // Large pool: remainder must exceed 1 MiB.
        assert!(c.should_split(20 * MIB, 2 * MIB, PoolKind::Large));
        assert!(!c.should_split(2 * MIB + 512, 2 * MIB, PoolKind::Large));
        // max_split_size blocks splitting of huge blocks.
        let mut c2 = c.clone();
        c2.max_split_size = Some(32 * MIB);
        assert!(!c2.should_split(64 * MIB, 2 * MIB, PoolKind::Large));
        assert!(c2.should_split(32 * MIB, 2 * MIB, PoolKind::Large));
    }
}
