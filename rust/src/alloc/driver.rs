//! Simulated CUDA driver: segment-granular device memory with a fixed
//! capacity, the substrate under the caching allocator.
//!
//! The real driver hands out device pointers; fragmentation *inside the
//! paper* is allocator-level (reserved vs allocated), not VA-level, so the
//! driver only needs capacity accounting, OOM behaviour, and latency. Each
//! `cuda_malloc` returns a [`SegmentId`]; the allocator owns the block
//! structure within segments.

use super::config::CostModel;
use crate::util::bytes::fmt_bytes;

/// Identifier of one driver-level allocation (one `cudaMalloc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SegmentId(pub u32);

/// Error returned when the device cannot satisfy a `cudaMalloc`.
#[derive(Debug, Clone)]
pub struct DriverOom {
    pub requested: u64,
    pub capacity: u64,
    pub reserved: u64,
}

impl std::fmt::Display for DriverOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CUDA out of memory: tried to allocate {} ({} bytes); \
             device capacity {} with {} already reserved",
            fmt_bytes(self.requested),
            self.requested,
            fmt_bytes(self.capacity),
            fmt_bytes(self.reserved)
        )
    }
}

impl std::error::Error for DriverOom {}

/// The simulated device + driver.
#[derive(Debug, Clone)]
pub struct SimDriver {
    capacity: u64,
    reserved: u64,
    segments: Vec<Option<u64>>, // SegmentId -> size (None = freed)
    free_slots: Vec<u32>,
    /// Live-segment count, maintained incrementally so
    /// [`Self::live_segments`] (on the `empty_cache` path) is O(1)
    /// instead of a scan over every slot ever allocated.
    live: usize,
    pub num_mallocs: u64,
    pub num_frees: u64,
    /// `cuMemMap` growths of expandable segments.
    pub num_grows: u64,
    /// `cuMemUnmap` shrinks of expandable segments.
    pub num_shrinks: u64,
    /// Simulated wall-clock consumed by driver calls, microseconds.
    pub time_us: f64,
    cost: CostModel,
}

impl SimDriver {
    pub fn new(capacity: u64, cost: CostModel) -> Self {
        SimDriver {
            capacity,
            reserved: 0,
            segments: Vec::new(),
            free_slots: Vec::new(),
            live: 0,
            num_mallocs: 0,
            num_frees: 0,
            num_grows: 0,
            num_shrinks: 0,
            time_us: 0.0,
            cost,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Total bytes currently held by live segments (= "reserved" memory in
    /// PyTorch terms, since only the caching allocator calls the driver).
    pub fn reserved(&self) -> u64 {
        self.reserved
    }

    pub fn free_capacity(&self) -> u64 {
        self.capacity - self.reserved
    }

    /// `cudaMalloc`: claim `size` bytes or report OOM.
    pub fn cuda_malloc(&mut self, size: u64) -> Result<SegmentId, DriverOom> {
        assert!(size > 0, "cuda_malloc(0)");
        if self.reserved + size > self.capacity {
            return Err(DriverOom {
                requested: size,
                capacity: self.capacity,
                reserved: self.reserved,
            });
        }
        self.reserved += size;
        self.live += 1;
        self.num_mallocs += 1;
        self.time_us += self.cost.cuda_malloc_base_us
            + self.cost.cuda_malloc_per_gib_us * (size as f64 / (1u64 << 30) as f64);
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.segments[slot as usize] = Some(size);
                SegmentId(slot)
            }
            None => {
                self.segments.push(Some(size));
                SegmentId((self.segments.len() - 1) as u32)
            }
        };
        Ok(id)
    }

    /// `cudaFree`: release a segment back to the device.
    pub fn cuda_free(&mut self, id: SegmentId) {
        let size = self.segments[id.0 as usize]
            .take()
            .expect("double cuda_free");
        self.reserved -= size;
        self.live -= 1;
        self.num_frees += 1;
        self.free_slots.push(id.0);
        self.time_us += self.cost.cuda_free_us;
    }

    /// Grow an expandable segment by `delta` bytes (`cuMemMap` of fresh
    /// physical granules at the tail). Capacity-checked like a malloc but
    /// cheaper: no new VA reservation, no implicit synchronization.
    pub fn grow_segment(&mut self, id: SegmentId, delta: u64) -> Result<(), DriverOom> {
        assert!(delta > 0, "grow_segment(0)");
        if self.reserved + delta > self.capacity {
            return Err(DriverOom {
                requested: delta,
                capacity: self.capacity,
                reserved: self.reserved,
            });
        }
        let size = self.segments[id.0 as usize]
            .as_mut()
            .expect("grow of freed segment");
        *size += delta;
        self.reserved += delta;
        self.num_grows += 1;
        self.time_us += self.cost.segment_grow_base_us
            + self.cost.cuda_malloc_per_gib_us * (delta as f64 / (1u64 << 30) as f64);
        Ok(())
    }

    /// Unmap `delta` trailing bytes of an expandable segment
    /// (`cuMemUnmap`). The segment must stay nonempty — a fully-free
    /// expandable segment is released through [`Self::cuda_free`] instead.
    pub fn shrink_segment(&mut self, id: SegmentId, delta: u64) {
        let size = self.segments[id.0 as usize]
            .as_mut()
            .expect("shrink of freed segment");
        assert!(
            delta > 0 && delta < *size,
            "shrink_segment must leave a nonempty segment ({} of {})",
            delta,
            *size
        );
        *size -= delta;
        self.reserved -= delta;
        self.num_shrinks += 1;
        self.time_us += self.cost.segment_unmap_us;
    }

    pub fn segment_size(&self, id: SegmentId) -> u64 {
        self.segments[id.0 as usize].expect("segment freed")
    }

    pub fn live_segments(&self) -> usize {
        debug_assert_eq!(
            self.live,
            self.segments.iter().filter(|s| s.is_some()).count()
        );
        self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GIB, MIB};

    fn driver(cap: u64) -> SimDriver {
        SimDriver::new(cap, CostModel::default())
    }

    #[test]
    fn malloc_free_accounting() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(100 * MIB).unwrap();
        let b = d.cuda_malloc(200 * MIB).unwrap();
        assert_eq!(d.reserved(), 300 * MIB);
        assert_eq!(d.live_segments(), 2);
        d.cuda_free(a);
        assert_eq!(d.reserved(), 200 * MIB);
        assert_eq!(d.segment_size(b), 200 * MIB);
        d.cuda_free(b);
        assert_eq!(d.reserved(), 0);
        assert_eq!(d.num_mallocs, 2);
        assert_eq!(d.num_frees, 2);
    }

    #[test]
    fn oom_at_capacity() {
        let mut d = driver(GIB);
        let _a = d.cuda_malloc(GIB).unwrap();
        let err = d.cuda_malloc(1).unwrap_err();
        assert_eq!(err.reserved, GIB);
        assert_eq!(err.capacity, GIB);
        assert_eq!(err.requested, 1);
    }

    #[test]
    fn oom_recovers_after_free() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(900 * MIB).unwrap();
        assert!(d.cuda_malloc(200 * MIB).is_err());
        d.cuda_free(a);
        assert!(d.cuda_malloc(200 * MIB).is_ok());
    }

    #[test]
    fn slot_reuse() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(MIB).unwrap();
        d.cuda_free(a);
        let b = d.cuda_malloc(2 * MIB).unwrap();
        // Slot recycled, accounting correct.
        assert_eq!(a.0, b.0);
        assert_eq!(d.reserved(), 2 * MIB);
    }

    #[test]
    #[should_panic(expected = "double cuda_free")]
    fn double_free_panics() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(MIB).unwrap();
        d.cuda_free(a);
        d.cuda_free(a);
    }

    #[test]
    fn grow_and_shrink_accounting() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(20 * MIB).unwrap();
        d.grow_segment(a, 6 * MIB).unwrap();
        assert_eq!(d.segment_size(a), 26 * MIB);
        assert_eq!(d.reserved(), 26 * MIB);
        assert_eq!(d.num_grows, 1);
        d.shrink_segment(a, 4 * MIB);
        assert_eq!(d.segment_size(a), 22 * MIB);
        assert_eq!(d.reserved(), 22 * MIB);
        assert_eq!(d.num_shrinks, 1);
        d.cuda_free(a);
        assert_eq!(d.reserved(), 0);
    }

    #[test]
    fn grow_respects_capacity() {
        let mut d = driver(64 * MIB);
        let a = d.cuda_malloc(60 * MIB).unwrap();
        let err = d.grow_segment(a, 8 * MIB).unwrap_err();
        assert_eq!(err.requested, 8 * MIB);
        assert_eq!(d.segment_size(a), 60 * MIB, "failed grow leaves size");
        assert!(d.grow_segment(a, 4 * MIB).is_ok());
    }

    #[test]
    #[should_panic(expected = "nonempty segment")]
    fn shrink_to_zero_panics() {
        let mut d = driver(GIB);
        let a = d.cuda_malloc(2 * MIB).unwrap();
        d.shrink_segment(a, 2 * MIB);
    }

    #[test]
    fn time_model_advances() {
        let mut d = driver(GIB);
        let t0 = d.time_us;
        let a = d.cuda_malloc(512 * MIB).unwrap();
        assert!(d.time_us > t0);
        let t1 = d.time_us;
        d.cuda_free(a);
        assert!(d.time_us > t1);
    }
}
