//! Caching-allocator simulator: a faithful reimplementation of PyTorch's
//! CUDA caching allocator over a simulated driver. This is the substrate on
//! which the paper's fragmentation phenomenon *emerges* (nothing here is
//! RLHF-specific). See DESIGN.md §6.

pub mod allocator;
pub mod block;
pub mod config;
pub mod driver;
pub mod paged;
pub mod pool;
pub mod stats;

pub use allocator::{AllocError, AllocId, CachingAllocator, SegmentRecord};
pub use config::{AllocatorConfig, CostModel, PoolKind};
pub use driver::{DriverOom, SegmentId, SimDriver};
pub use stats::{
    fingerprint_events, AllocEvent, AllocObserver, AllocStats, NullObserver, PhaseTag,
    StatSnapshot,
};
