//! KV-cache pool disciplines for the serving simulator (DESIGN.md §18).
//!
//! Two ways to carve a fixed KV budget among concurrent requests, both
//! measured in *token slots* (the engine converts to bytes via the model's
//! per-token KV size):
//!
//! * [`PagedKvPool`] — vLLM-style fixed-size pages allocated on demand as a
//!   sequence grows. Waste is bounded by one partially-filled page per
//!   request (internal fragmentation only).
//! * [`BestFitKvPool`] — the classic contiguous discipline: each request
//!   reserves its worst-case extent (`prompt + max_new` tokens) up front
//!   from a best-fit free list. Waste is the whole unwritten tail of every
//!   reservation, plus external holes between extents.
//!
//! Both reuse [`BlockPool`]'s `(size, BlockId)` index machinery, so "which
//! free page / extent is picked" is deterministic: smallest sufficient
//! size, lowest id (= lowest offset) on ties.

use super::block::BlockId;
use super::driver::SegmentId;
use super::pool::BlockPool;
use std::collections::BTreeMap;

/// A request's hold on KV storage. Opaque to the engine beyond the
/// accounting accessors; returned to the owning pool on release.
#[derive(Debug, Clone)]
pub struct KvLease {
    /// Tokens actually written (prompt + generated so far).
    used: u64,
    /// Token slots held from the pool on this lease's behalf.
    held: u64,
    shape: LeaseShape,
}

#[derive(Debug, Clone)]
enum LeaseShape {
    /// Page indices held, in allocation order (last one is the open page).
    Paged(Vec<u32>),
    /// Contiguous extent `[offset, offset + held)` in token slots.
    Extent { offset: u32 },
}

impl KvLease {
    /// Tokens actually written under this lease.
    pub fn used_tokens(&self) -> u64 {
        self.used
    }
    /// Token slots held (≥ used; the difference is this lease's waste).
    pub fn held_tokens(&self) -> u64 {
        self.held
    }
}

/// The KV pool discipline for one serve cell.
#[derive(Debug)]
pub enum KvPool {
    Paged(PagedKvPool),
    BestFit(BestFitKvPool),
}

impl KvPool {
    /// Admit a request arriving with `prompt` tokens that may generate up
    /// to `max_new` more. `None` (nothing mutated) when the pool cannot
    /// hold it right now.
    pub fn try_admit(&mut self, prompt: u64, max_new: u64) -> Option<KvLease> {
        match self {
            KvPool::Paged(p) => p.try_admit(prompt),
            KvPool::BestFit(p) => p.try_admit(prompt, max_new),
        }
    }

    /// Record one more generated token under `lease`. `false` (lease
    /// unchanged) when the pool cannot supply the next page.
    pub fn try_extend(&mut self, lease: &mut KvLease) -> bool {
        match self {
            KvPool::Paged(p) => p.try_extend(lease),
            KvPool::BestFit(p) => p.try_extend(lease),
        }
    }

    /// Return a lease's storage to the pool.
    pub fn release(&mut self, lease: KvLease) {
        match self {
            KvPool::Paged(p) => p.release(lease),
            KvPool::BestFit(p) => p.release(lease),
        }
    }

    /// Token slots currently held by live leases.
    pub fn held_tokens(&self) -> u64 {
        match self {
            KvPool::Paged(p) => p.held_tokens,
            KvPool::BestFit(p) => p.held_tokens,
        }
    }

    /// Total token slots this pool can ever hold.
    pub fn capacity_tokens(&self) -> u64 {
        match self {
            KvPool::Paged(p) => p.pages_total * p.page_tokens,
            KvPool::BestFit(p) => p.capacity_tokens,
        }
    }
}

/// vLLM-style paged KV pool: `pages_total` fixed pages of `page_tokens`
/// token slots each, allocated on demand.
#[derive(Debug)]
pub struct PagedKvPool {
    page_tokens: u64,
    pages_total: u64,
    /// Free pages, indexed by the shared [`BlockPool`]: every entry is
    /// `(page_tokens, BlockId(page_index))`, so best-fit degenerates to
    /// "lowest free page index" — deterministic.
    free: BlockPool,
    held_tokens: u64,
}

impl PagedKvPool {
    /// A pool of `capacity_tokens / page_tokens` pages (remainder slots
    /// are unusable and simply dropped).
    pub fn new(capacity_tokens: u64, page_tokens: u64) -> Self {
        assert!(page_tokens > 0, "page_tokens must be positive");
        let pages_total = capacity_tokens / page_tokens;
        assert!(
            pages_total <= u32::MAX as u64,
            "page count exceeds index space"
        );
        let mut free = BlockPool::new();
        for i in 0..pages_total {
            free.insert(page_tokens, BlockId(i as u32), SegmentId(0), false);
        }
        Self {
            page_tokens,
            pages_total,
            free,
            held_tokens: 0,
        }
    }

    fn alloc_page(&mut self) -> Option<u32> {
        let (size, id) = self.free.best_fit(self.page_tokens)?;
        self.free.remove(size, id);
        self.held_tokens += self.page_tokens;
        Some(id.0)
    }

    fn free_page(&mut self, page: u32) {
        self.free
            .insert(self.page_tokens, BlockId(page), SegmentId(0), false);
        self.held_tokens -= self.page_tokens;
    }

    fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens)
    }

    fn try_admit(&mut self, prompt: u64) -> Option<KvLease> {
        let need = self.pages_for(prompt.max(1));
        if need > self.free.len() as u64 {
            return None;
        }
        let mut pages = Vec::with_capacity(need as usize);
        for _ in 0..need {
            pages.push(self.alloc_page().expect("free count checked above"));
        }
        Some(KvLease {
            used: prompt,
            held: need * self.page_tokens,
            shape: LeaseShape::Paged(pages),
        })
    }

    fn try_extend(&mut self, lease: &mut KvLease) -> bool {
        if lease.used + 1 > lease.held {
            // Open page is full — need a fresh one.
            match self.alloc_page() {
                Some(page) => {
                    let LeaseShape::Paged(pages) = &mut lease.shape else {
                        panic!("paged pool given a non-paged lease");
                    };
                    pages.push(page);
                    lease.held += self.page_tokens;
                }
                None => return false,
            }
        }
        lease.used += 1;
        true
    }

    fn release(&mut self, lease: KvLease) {
        let LeaseShape::Paged(pages) = lease.shape else {
            panic!("paged pool given a non-paged lease");
        };
        for page in pages {
            self.free_page(page);
        }
    }
}

/// Contiguous best-fit KV pool: one worst-case extent per request, carved
/// from a coalescing free list.
#[derive(Debug)]
pub struct BestFitKvPool {
    capacity_tokens: u64,
    /// Free extents by `(len, BlockId(offset))` — best-fit, lowest offset
    /// on ties.
    free: BlockPool,
    /// The same free extents by offset, for O(log n) neighbor coalescing.
    by_offset: BTreeMap<u32, u64>,
    held_tokens: u64,
}

impl BestFitKvPool {
    pub fn new(capacity_tokens: u64) -> Self {
        assert!(
            capacity_tokens <= u32::MAX as u64,
            "token capacity exceeds offset space"
        );
        let mut pool = Self {
            capacity_tokens,
            free: BlockPool::new(),
            by_offset: BTreeMap::new(),
            held_tokens: 0,
        };
        if capacity_tokens > 0 {
            pool.insert_free(0, capacity_tokens);
        }
        pool
    }

    fn insert_free(&mut self, offset: u32, len: u64) {
        self.free.insert(len, BlockId(offset), SegmentId(0), false);
        self.by_offset.insert(offset, len);
    }

    fn remove_free(&mut self, offset: u32, len: u64) {
        self.free.remove(len, BlockId(offset));
        self.by_offset.remove(&offset);
    }

    fn try_admit(&mut self, prompt: u64, max_new: u64) -> Option<KvLease> {
        let want = (prompt + max_new).max(1);
        let (len, id) = self.free.best_fit(want)?;
        let offset = id.0;
        self.remove_free(offset, len);
        if len > want {
            // Split: the tail stays free.
            self.insert_free(offset + want as u32, len - want);
        }
        self.held_tokens += want;
        Some(KvLease {
            used: prompt,
            held: want,
            shape: LeaseShape::Extent { offset },
        })
    }

    fn try_extend(&mut self, lease: &mut KvLease) -> bool {
        // The extent was reserved for the worst case at admission; growth
        // within it always succeeds.
        debug_assert!(lease.used < lease.held, "extent overrun");
        lease.used += 1;
        true
    }

    fn release(&mut self, lease: KvLease) {
        let LeaseShape::Extent { offset } = lease.shape else {
            panic!("best-fit pool given a paged lease");
        };
        let mut offset = offset;
        let mut len = lease.held;
        self.held_tokens -= len;
        // Coalesce with the free predecessor, if adjacent.
        if let Some((&prev_off, &prev_len)) = self.by_offset.range(..offset).next_back() {
            if prev_off as u64 + prev_len == offset as u64 {
                self.remove_free(prev_off, prev_len);
                offset = prev_off;
                len += prev_len;
            }
        }
        // Coalesce with the free successor, if adjacent.
        let end = offset as u64 + len;
        if let Some((&next_off, &next_len)) = self.by_offset.range(offset + 1..).next() {
            if next_off as u64 == end {
                self.remove_free(next_off, next_len);
                len += next_len;
            }
        }
        self.insert_free(offset, len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paged_allocates_lowest_free_page_first() {
        let mut p = PagedKvPool::new(64, 16); // 4 pages
        let a = p.try_admit(20).unwrap(); // 2 pages: 0, 1
        assert_eq!(a.held_tokens(), 32);
        assert_eq!(a.used_tokens(), 20);
        let b = p.try_admit(1).unwrap(); // page 2
        p.release(a);
        let c = p.try_admit(1).unwrap(); // reuses page 0 (lowest index)
        let LeaseShape::Paged(pages) = &c.shape else {
            panic!()
        };
        assert_eq!(pages, &[0]);
        p.release(b);
        p.release(c);
        assert_eq!(p.held_tokens, 0);
    }

    #[test]
    fn paged_extend_crosses_page_boundary_and_exhausts() {
        let mut p = PagedKvPool::new(32, 16); // 2 pages
        let mut a = p.try_admit(15).unwrap(); // page 0
        assert!(p.try_extend(&mut a)); // fills page 0 (16/16)
        assert!(p.try_extend(&mut a)); // opens page 1
        assert_eq!(a.held_tokens(), 32);
        assert_eq!(a.used_tokens(), 17);
        // Pool is out of pages: a second admit and further growth past the
        // last page must fail without mutating anything.
        assert!(p.try_admit(1).is_none());
        for _ in 17..32 {
            assert!(p.try_extend(&mut a));
        }
        assert!(!p.try_extend(&mut a));
        assert_eq!(a.used_tokens(), 32);
        p.release(a);
        assert!(p.try_admit(32).is_some());
    }

    #[test]
    fn best_fit_reserves_worst_case_and_coalesces() {
        let mut p = BestFitKvPool::new(100);
        let a = p.try_admit(10, 10).unwrap(); // [0, 20)
        let b = p.try_admit(5, 5).unwrap(); // [20, 30)
        let c = p.try_admit(1, 1).unwrap(); // [30, 32)
        assert_eq!(p.held_tokens, 32);
        assert_eq!(a.held_tokens(), 20);
        // Free the middle extent, then its neighbors: everything coalesces
        // back into one run covering the whole pool.
        p.release(b);
        p.release(a);
        p.release(c);
        assert_eq!(p.held_tokens, 0);
        assert!(p.try_admit(50, 50).is_some());
    }

    #[test]
    fn best_fit_prefers_tightest_hole() {
        let mut p = BestFitKvPool::new(100);
        let a = p.try_admit(5, 5).unwrap(); // [0, 10)
        let b = p.try_admit(20, 0).unwrap(); // [10, 30)
        let c = p.try_admit(4, 0).unwrap(); // [30, 34)
        p.release(a); // hole [0, 10)
        p.release(c); // hole [30, 34) + tail [34, 100) coalesce -> [30, 100)
        // A 9-token request fits both holes; best fit takes the 10-slot one.
        let d = p.try_admit(9, 0).unwrap();
        let LeaseShape::Extent { offset } = d.shape else {
            panic!()
        };
        assert_eq!(offset, 0);
        p.release(b);
        p.release(d);
        assert_eq!(p.held_tokens, 0);
    }

    #[test]
    fn admit_failure_leaves_pool_untouched() {
        let mut bf = BestFitKvPool::new(10);
        let a = bf.try_admit(4, 4).unwrap();
        assert!(bf.try_admit(2, 4).is_none()); // needs 6 slots, only 2 free
        assert_eq!(bf.held_tokens, 8);
        bf.release(a);

        let mut pg = PagedKvPool::new(32, 16);
        let a = pg.try_admit(17).unwrap(); // both pages
        assert!(pg.try_admit(1).is_none());
        assert_eq!(pg.held_tokens, 32);
        pg.release(a);
    }
}
