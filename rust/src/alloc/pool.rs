//! Free-block pools: size-ordered sets supporting best-fit lookup, keyed
//! `(size, BlockId)` exactly like PyTorch's `BlockComparator`.

use super::block::BlockId;
use std::collections::BTreeSet;
use std::ops::Bound;

/// One pool (small or large) of cached free blocks.
#[derive(Debug, Default, Clone)]
pub struct BlockPool {
    set: BTreeSet<(u64, BlockId)>,
    /// Total bytes cached in this pool (Σ sizes of free blocks).
    cached_bytes: u64,
}

impl BlockPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, size: u64, id: BlockId) {
        let fresh = self.set.insert((size, id));
        debug_assert!(fresh, "block {id:?} already pooled");
        self.cached_bytes += size;
    }

    pub fn remove(&mut self, size: u64, id: BlockId) {
        let was = self.set.remove(&(size, id));
        debug_assert!(was, "block {id:?} not in pool");
        self.cached_bytes -= size;
    }

    /// Best fit: the smallest cached block with `size >= want`.
    pub fn best_fit(&self, want: u64) -> Option<(u64, BlockId)> {
        self.set
            .range((Bound::Included((want, BlockId(0))), Bound::Unbounded))
            .next()
            .copied()
    }

    /// Best fit bounded above: PyTorch with `max_split_size` set refuses
    /// to serve a request < max_split_size from an *oversized* (>
    /// max_split_size) block unless the fit is close (within kLargeBuffer).
    /// We expose the bound so the allocator can express that rule.
    pub fn best_fit_bounded(&self, want: u64, max: u64) -> Option<(u64, BlockId)> {
        self.best_fit(want).filter(|(sz, _)| *sz <= max)
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    pub fn cached_bytes(&self) -> u64 {
        self.cached_bytes
    }

    pub fn iter(&self) -> impl Iterator<Item = &(u64, BlockId)> {
        self.set.iter()
    }

    /// Drain every entry (used by empty_cache / OOM recovery paths, which
    /// re-examine blocks segment-by-segment).
    pub fn drain_all(&mut self) -> Vec<(u64, BlockId)> {
        self.cached_bytes = 0;
        std::mem::take(&mut self.set).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let mut p = BlockPool::new();
        p.insert(512, BlockId(1));
        p.insert(2048, BlockId(2));
        p.insert(4096, BlockId(3));
        assert_eq!(p.best_fit(1024), Some((2048, BlockId(2))));
        assert_eq!(p.best_fit(2048), Some((2048, BlockId(2))));
        assert_eq!(p.best_fit(4097), None);
        assert_eq!(p.cached_bytes(), 512 + 2048 + 4096);
    }

    #[test]
    fn ties_broken_by_block_id() {
        let mut p = BlockPool::new();
        p.insert(1024, BlockId(9));
        p.insert(1024, BlockId(3));
        assert_eq!(p.best_fit(100), Some((1024, BlockId(3))));
    }

    #[test]
    fn remove_updates_bytes() {
        let mut p = BlockPool::new();
        p.insert(1024, BlockId(1));
        p.insert(512, BlockId(2));
        p.remove(1024, BlockId(1));
        assert_eq!(p.cached_bytes(), 512);
        assert_eq!(p.len(), 1);
        assert_eq!(p.best_fit(600), None);
    }

    #[test]
    fn bounded_fit() {
        let mut p = BlockPool::new();
        p.insert(64 << 20, BlockId(1)); // 64 MiB oversized block
        assert!(p.best_fit_bounded(1 << 20, 32 << 20).is_none());
        assert!(p.best_fit_bounded(1 << 20, 64 << 20).is_some());
    }

    #[test]
    fn drain_resets() {
        let mut p = BlockPool::new();
        p.insert(512, BlockId(1));
        p.insert(1024, BlockId(2));
        let drained = p.drain_all();
        assert_eq!(drained.len(), 2);
        assert!(p.is_empty());
        assert_eq!(p.cached_bytes(), 0);
    }
}
