//! Free-block pools: per-pool size-indexed free maps supporting O(log n)
//! best-fit lookup, keyed `(size, BlockId)` exactly like PyTorch's
//! `BlockComparator` (size first, then an arbitrary-but-stable id as the
//! tie-break — kept as `BlockId`, not address, so the indexed pool serves
//! the exact block the seed allocator's scan would have picked and the
//! event-log golden tests hold bit-for-bit).
//!
//! On top of the size index the pool maintains a **fully-free-segment
//! index**: the subset of cached blocks that span their whole segment
//! (offset 0, no successor — by the chain-tiling invariant that is exactly
//! "the segment is fully free"). `empty_cache()`, the OOM-retry cascade and
//! the `garbage_collection_threshold` pass used to rediscover those by
//! walking every pooled block / every segment; with the index they touch
//! only the segments they will actually release. The index is maintained
//! in O(log n) at insert and O(log n) at remove — no separate bookkeeping
//! pass can forget it, because every pool mutation goes through
//! [`BlockPool::insert`] / [`BlockPool::remove`].

use super::block::BlockId;
use super::driver::SegmentId;
use std::collections::BTreeMap;
use std::ops::Bound;

/// One pool (small or large) of cached free blocks.
#[derive(Debug, Default, Clone)]
pub struct BlockPool {
    /// Size index: every cached block, keyed `(size, BlockId)`, valued by
    /// its owning segment.
    map: BTreeMap<(u64, BlockId), SegmentId>,
    /// Fully-free-segment index: the subset of `map` whose blocks span
    /// their whole segment, same key order. Iterating it yields releases
    /// in the identical relative order a full `map` scan would have.
    fully_free: BTreeMap<(u64, BlockId), SegmentId>,
    /// Total bytes cached in this pool (Σ sizes of free blocks).
    cached_bytes: u64,
}

impl BlockPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a free block. `spans_segment` marks blocks covering their
    /// whole segment (offset 0 and no successor); those also enter the
    /// fully-free-segment index.
    pub fn insert(&mut self, size: u64, id: BlockId, segment: SegmentId, spans_segment: bool) {
        let fresh = self.map.insert((size, id), segment).is_none();
        debug_assert!(fresh, "block {id:?} already pooled");
        if spans_segment {
            self.fully_free.insert((size, id), segment);
        }
        self.cached_bytes += size;
    }

    pub fn remove(&mut self, size: u64, id: BlockId) {
        let was = self.map.remove(&(size, id)).is_some();
        debug_assert!(was, "block {id:?} not in pool");
        self.fully_free.remove(&(size, id));
        self.cached_bytes -= size;
    }

    /// Best fit: the smallest cached block with `size >= want`.
    pub fn best_fit(&self, want: u64) -> Option<(u64, BlockId)> {
        self.map
            .range((Bound::Included((want, BlockId(0))), Bound::Unbounded))
            .next()
            .map(|(&key, _)| key)
    }

    /// Best fit bounded above: PyTorch with `max_split_size` set refuses
    /// to serve a request < max_split_size from an *oversized* (>=
    /// max_split_size) block — `get_free_block` treats `size >=
    /// max_split_size` as oversized, so the bound is exclusive. We expose
    /// the bound so the allocator can express that rule.
    pub fn best_fit_bounded(&self, want: u64, max: u64) -> Option<(u64, BlockId)> {
        self.best_fit(want).filter(|(sz, _)| *sz < max)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn cached_bytes(&self) -> u64 {
        self.cached_bytes
    }

    /// Every cached block, `(size, BlockId)` ascending.
    pub fn iter(&self) -> impl Iterator<Item = (u64, BlockId, SegmentId)> + '_ {
        self.map.iter().map(|(&(size, id), &seg)| (size, id, seg))
    }

    /// The fully-free segments' blocks, `(size, BlockId)` ascending — the
    /// order `empty_cache()` / OOM retry release them in.
    pub fn fully_free(&self) -> impl Iterator<Item = (u64, BlockId, SegmentId)> + '_ {
        self.fully_free
            .iter()
            .map(|(&(size, id), &seg)| (size, id, seg))
    }

    pub fn fully_free_len(&self) -> usize {
        self.fully_free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(n: u32) -> SegmentId {
        SegmentId(n)
    }

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let mut p = BlockPool::new();
        p.insert(512, BlockId(1), seg(1), false);
        p.insert(2048, BlockId(2), seg(2), false);
        p.insert(4096, BlockId(3), seg(3), false);
        assert_eq!(p.best_fit(1024), Some((2048, BlockId(2))));
        assert_eq!(p.best_fit(2048), Some((2048, BlockId(2))));
        assert_eq!(p.best_fit(4097), None);
        assert_eq!(p.cached_bytes(), 512 + 2048 + 4096);
    }

    #[test]
    fn ties_broken_by_block_id() {
        let mut p = BlockPool::new();
        p.insert(1024, BlockId(9), seg(9), false);
        p.insert(1024, BlockId(3), seg(3), false);
        assert_eq!(p.best_fit(100), Some((1024, BlockId(3))));
    }

    #[test]
    fn remove_updates_bytes() {
        let mut p = BlockPool::new();
        p.insert(1024, BlockId(1), seg(1), false);
        p.insert(512, BlockId(2), seg(2), false);
        p.remove(1024, BlockId(1));
        assert_eq!(p.cached_bytes(), 512);
        assert_eq!(p.len(), 1);
        assert_eq!(p.best_fit(600), None);
    }

    #[test]
    fn bounded_fit() {
        let mut p = BlockPool::new();
        p.insert(64 << 20, BlockId(1), seg(1), true); // 64 MiB oversized block
        assert!(p.best_fit_bounded(1 << 20, 32 << 20).is_none());
        // Exact-max hit: a block of exactly max_split_size is oversized
        // (PyTorch's `size >= max_split_size` test), so it must be refused.
        assert!(p.best_fit_bounded(1 << 20, 64 << 20).is_none());
        assert!(p.best_fit_bounded(1 << 20, (64 << 20) + 1).is_some());
    }

    #[test]
    fn bounded_fit_empty_range() {
        let mut p = BlockPool::new();
        p.insert(64 << 20, BlockId(1), seg(1), true);
        // want >= max leaves no admissible size in [want, max): never a hit,
        // even when a block of exactly `want` is cached.
        assert!(p.best_fit_bounded(64 << 20, 64 << 20).is_none());
        assert!(p.best_fit_bounded(64 << 20, 32 << 20).is_none());
        // Empty pool: trivially none.
        let empty = BlockPool::new();
        assert!(empty.best_fit_bounded(1, u64::MAX).is_none());
    }

    #[test]
    fn bounded_fit_serves_strictly_under_max() {
        let mut p = BlockPool::new();
        p.insert((32 << 20) - 1, BlockId(1), seg(1), false);
        p.insert(32 << 20, BlockId(2), seg(2), false);
        // Only the strictly-under-max block is admissible; the exact-max
        // block stays reserved for oversized requests.
        assert_eq!(
            p.best_fit_bounded(1 << 20, 32 << 20),
            Some(((32 << 20) - 1, BlockId(1)))
        );
    }

    #[test]
    fn fully_free_index_tracks_spanning_blocks() {
        let mut p = BlockPool::new();
        p.insert(2048, BlockId(1), seg(1), true);
        p.insert(1024, BlockId(2), seg(2), false);
        p.insert(512, BlockId(3), seg(3), true);
        assert_eq!(p.len(), 3);
        assert_eq!(p.fully_free_len(), 2);
        // (size, id) ascending — identical to a full-scan release order.
        let ff: Vec<_> = p.fully_free().collect();
        assert_eq!(
            ff,
            vec![(512, BlockId(3), seg(3)), (2048, BlockId(1), seg(1))]
        );
        // Removing a spanning block clears it from both indexes.
        p.remove(2048, BlockId(1));
        assert_eq!(p.fully_free_len(), 1);
        assert_eq!(p.len(), 2);
        // Removing a non-spanning block leaves the fully-free index alone.
        p.remove(1024, BlockId(2));
        assert_eq!(p.fully_free_len(), 1);
        assert_eq!(p.fully_free().next(), Some((512, BlockId(3), seg(3))));
    }

}
