//! Allocator statistics and event stream.
//!
//! Definitions follow the paper (Appendix B) and PyTorch:
//! * **reserved** — bytes held from the driver (Σ live segments);
//! * **allocated** — bytes in live (allocated-state) blocks;
//! * **fragmentation** — sampled *at each `cudaMalloc`* as
//!   `reserved − allocated` at that instant: "the difference between
//!   reserved and allocated memory when the allocator cannot satisfy the
//!   requested size due to non-contiguous freed objects".
//!
//! The peak-tracking distinguishes `peak_reserved` and the fragmentation
//! observed *at the time of the reserved peak* — exactly what Figure 1's
//! red/yellow crosses mark.

/// Phase tag attached to events (the profiler maps these to RLHF phases;
/// the allocator itself only stores an opaque `u16`).
pub type PhaseTag = u16;

/// An observable allocator event.
#[derive(Debug, Clone, PartialEq)]
pub enum AllocEvent {
    /// A request was served (either from cache or a fresh segment).
    Alloc {
        requested: u64,
        rounded: u64,
        cache_hit: bool,
    },
    /// A block was returned to the pool.
    Free { size: u64 },
    /// The allocator went to the driver.
    CudaMalloc {
        segment_bytes: u64,
        /// The rounded request that forced this segment.
        rounded: u64,
        /// Fragmentation-caused sample (Appendix B): the cached free bytes
        /// at this instant if they would have covered the request, else 0.
        frag_sample: u64,
    },
    /// A segment was returned to the driver (empty_cache or OOM retry).
    CudaFree { segment_bytes: u64 },
    /// `empty_cache()` released this many segments / bytes.
    EmptyCache { segments: u64, bytes: u64 },
    /// OOM-retry path released cached segments before retrying.
    OomRetry { released_bytes: u64 },
    /// A `garbage_collection_threshold` pass reclaimed cached fully-free
    /// segments at malloc time (before the driver was asked for more).
    GcReclaim { segments: u64, bytes: u64 },
    /// Trailing free granules of an expandable segment were unmapped
    /// (`empty_cache` / OOM retry with `expandable_segments` on).
    SegmentShrink { bytes: u64 },
}

/// Point-in-time state attached to each event delivery.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StatSnapshot {
    pub reserved: u64,
    pub allocated: u64,
    pub requested: u64,
    /// Simulated time, microseconds, including driver latency.
    pub time_us: f64,
    pub phase: PhaseTag,
}

impl StatSnapshot {
    /// Cached-but-unused bytes right now.
    pub fn cached_free(&self) -> u64 {
        self.reserved - self.allocated
    }
}

/// Order-sensitive digest of a drained event log (event kinds, every
/// field, and each event's point-in-time snapshot — `time_us` hashed by
/// its exact bit pattern). Two logs share a fingerprint iff the allocator
/// behaved identically op for op; the alloc golden tests pin the indexed
/// allocator against the seed scan implementation with this, and the
/// bench subsystem records it so perf work can't silently change results.
pub fn fingerprint_events(events: &[(AllocEvent, StatSnapshot)]) -> u64 {
    use crate::util::fasthash::FastHasher;
    use std::hash::Hasher;
    let mut h = FastHasher::default();
    h.write_u64(events.len() as u64);
    for (ev, snap) in events {
        match *ev {
            AllocEvent::Alloc {
                requested,
                rounded,
                cache_hit,
            } => {
                h.write_u64(1);
                h.write_u64(requested);
                h.write_u64(rounded);
                h.write_u64(cache_hit as u64);
            }
            AllocEvent::Free { size } => {
                h.write_u64(2);
                h.write_u64(size);
            }
            AllocEvent::CudaMalloc {
                segment_bytes,
                rounded,
                frag_sample,
            } => {
                h.write_u64(3);
                h.write_u64(segment_bytes);
                h.write_u64(rounded);
                h.write_u64(frag_sample);
            }
            AllocEvent::CudaFree { segment_bytes } => {
                h.write_u64(4);
                h.write_u64(segment_bytes);
            }
            AllocEvent::EmptyCache { segments, bytes } => {
                h.write_u64(5);
                h.write_u64(segments);
                h.write_u64(bytes);
            }
            AllocEvent::OomRetry { released_bytes } => {
                h.write_u64(6);
                h.write_u64(released_bytes);
            }
            AllocEvent::GcReclaim { segments, bytes } => {
                h.write_u64(7);
                h.write_u64(segments);
                h.write_u64(bytes);
            }
            AllocEvent::SegmentShrink { bytes } => {
                h.write_u64(8);
                h.write_u64(bytes);
            }
        }
        h.write_u64(snap.reserved);
        h.write_u64(snap.allocated);
        h.write_u64(snap.requested);
        h.write_u64(snap.time_us.to_bits());
        h.write_u64(snap.phase as u64);
    }
    h.finish()
}

/// Observer of the allocator's event stream (the profiler implements
/// this). Events are buffered inside the allocator while
/// `set_event_recording(true)` is on; the replay loop drains them and
/// forwards each pair to its sink, which typically delegates here.
pub trait AllocObserver {
    fn on_event(&mut self, event: &AllocEvent, state: &StatSnapshot);
}

/// No-op observer.
pub struct NullObserver;
impl AllocObserver for NullObserver {
    fn on_event(&mut self, _event: &AllocEvent, _state: &StatSnapshot) {}
}

/// Aggregate counters maintained by the allocator itself (cheap, always on).
#[derive(Debug, Clone, Default)]
pub struct AllocStats {
    pub reserved: u64,
    pub allocated: u64,
    /// Σ caller-requested bytes of live blocks (≤ allocated; the gap is
    /// internal fragmentation from 512 B rounding).
    pub requested: u64,
    pub peak_reserved: u64,
    pub peak_allocated: u64,
    /// Fragmentation sample (reserved − allocated) recorded at the most
    /// recent cudaMalloc.
    pub last_frag_sample: u64,
    /// Max fragmentation sample seen at any cudaMalloc — the paper's
    /// "Frag." column.
    pub max_frag_sample: u64,
    /// reserved − allocated at the moment `peak_reserved` was set: the
    /// fragmentation overhead at the peak (Figure 1's yellow gap).
    pub frag_at_peak_reserved: u64,
    pub num_allocs: u64,
    pub num_frees: u64,
    pub num_cache_hits: u64,
    pub num_cuda_mallocs: u64,
    pub num_cuda_frees: u64,
    pub num_empty_cache: u64,
    /// `garbage_collection_threshold` passes that reclaimed ≥ 1 segment.
    pub num_gc_passes: u64,
    /// Total bytes reclaimed by gc passes.
    pub gc_reclaimed: u64,
    /// Total trailing bytes unmapped from expandable segments.
    pub shrunk_bytes: u64,
    /// Simulated allocator+driver time, microseconds.
    pub time_us: f64,
}

impl AllocStats {
    /// Update both counters. `peak_reserved` / `frag_at_peak_reserved` are
    /// maintained by the allocator at cudaMalloc time (reserved only rises
    /// there, and the paper's fragmentation metric is defined at that
    /// event); this only tracks the allocated peak.
    pub fn sync(&mut self, reserved: u64, allocated: u64) {
        self.reserved = reserved;
        self.allocated = allocated;
        if allocated > self.peak_allocated {
            self.peak_allocated = allocated;
        }
        if reserved > self.peak_reserved {
            // Only reachable from the allocator's cudaMalloc path, which
            // records the fragmentation sample itself before syncing.
            self.peak_reserved = reserved;
        }
    }

    /// The paper's "memory fragmentation overhead": peak reserved minus
    /// what the peak would have been without the fragmentation present at
    /// that moment.
    pub fn frag_overhead(&self) -> u64 {
        self.frag_at_peak_reserved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracking() {
        let mut s = AllocStats::default();
        s.sync(150, 100);
        assert_eq!(s.peak_reserved, 150);
        // Lower reserved does not move the peak.
        s.sync(120, 100);
        assert_eq!(s.peak_reserved, 150);
        s.sync(200, 180);
        assert_eq!(s.peak_reserved, 200);
        assert_eq!(s.peak_allocated, 180);
    }

    #[test]
    fn event_fingerprint_is_order_and_field_sensitive() {
        let snap = StatSnapshot::default();
        let a = vec![
            (
                AllocEvent::Alloc {
                    requested: 100,
                    rounded: 512,
                    cache_hit: false,
                },
                snap,
            ),
            (AllocEvent::Free { size: 512 }, snap),
        ];
        assert_eq!(fingerprint_events(&a), fingerprint_events(&a));
        let mut reordered = a.clone();
        reordered.reverse();
        assert_ne!(fingerprint_events(&a), fingerprint_events(&reordered));
        let mut tweaked = a.clone();
        tweaked[0].1.reserved = 1;
        assert_ne!(fingerprint_events(&a), fingerprint_events(&tweaked));
        assert_ne!(fingerprint_events(&a), fingerprint_events(&a[..1]));
    }

    #[test]
    fn snapshot_cached_free() {
        let snap = StatSnapshot {
            reserved: 100,
            allocated: 70,
            ..Default::default()
        };
        assert_eq!(snap.cached_free(), 30);
    }
}
