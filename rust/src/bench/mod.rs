//! The perf subsystem behind `rlhf-mem bench` and the `benches/*.rs`
//! harnesses.
//!
//! * this module — a mini benchmark harness (no `criterion` offline):
//!   warmup + timed iterations with summary statistics;
//! * [`workloads`] — the canonical deterministic workloads whose counters
//!   populate the repo's `BENCH_<n>.json` trajectory;
//! * [`report`] — the `BENCH` JSON schema writer and the CI regression
//!   gate's comparison logic (deterministic counters exact, wall time
//!   within a generous tolerance).
//!
//! See DESIGN.md §13 for the methodology (what is deterministic vs timed,
//! and the baseline-update procedure).

pub mod report;
pub mod workloads;

use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10.3} ms/iter (median {:.3}, p95 {:.3}, n={})",
            self.name,
            s.mean * 1e3,
            s.median * 1e3,
            s.p95 * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        iters,
    }
}

/// Throughput helper: ops per second given per-iter op count.
pub fn throughput(result: &BenchResult, ops_per_iter: f64) -> f64 {
    ops_per_iter / result.summary.median
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let mut count = 0u64;
        let r = bench("spin", 1, 5, || {
            for i in 0..10_000u64 {
                count = count.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.report().contains("spin"));
        assert!(throughput(&r, 10_000.0) > 0.0);
    }
}
