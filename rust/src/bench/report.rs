//! The `BENCH_<n>.json` schema: writer, baseline comparison (the CI
//! regression gate), and the shared emitter the `benches/*.rs` harnesses
//! use so local `cargo bench` numbers and the CI `rlhf-mem bench` gate
//! speak the same format.
//!
//! Schema (`rlhf-mem-bench-v1`): a document holds `index` (position in
//! the repo's BENCH trajectory), `locked` (whether the CI gate enforces
//! exact counter equality), `peak_rss_bytes`, and one entry per workload
//! with a `deterministic` counter object (machine-independent — op
//! counts, peaks, output fingerprints) and a `timed` object (`wall_s`,
//! `ops_per_s` — machine-dependent, gated only by tolerance). See
//! DESIGN.md §13 for the baseline-update procedure.

use super::workloads::WorkloadRun;
use super::BenchResult;
use crate::util::json::Json;

pub const SCHEMA: &str = "rlhf-mem-bench-v1";

/// Render a suite run as one BENCH document.
pub fn to_doc(index: u64, locked: bool, runs: &[WorkloadRun], peak_rss_bytes: u64) -> Json {
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("index", Json::from(index)),
        ("locked", Json::from(locked)),
        (
            "regenerate",
            Json::str(format!(
                "cargo run --release -- bench --out BENCH_{index}.json --index {index} --lock"
            )),
        ),
        ("peak_rss_bytes", Json::from(peak_rss_bytes)),
        (
            "workloads",
            Json::Arr(runs.iter().map(workload_json).collect()),
        ),
    ])
}

fn workload_json(r: &WorkloadRun) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name)),
        ("deterministic", r.deterministic.clone()),
        ("ops", Json::from(r.ops)),
        (
            "timed",
            Json::obj(vec![
                ("wall_s", Json::from(r.wall_s)),
                (
                    "ops_per_s",
                    Json::from(r.ops as f64 / r.wall_s.max(1e-9)),
                ),
            ]),
        ),
    ])
}

fn workloads_of(doc: &Json) -> Vec<(&str, &Json)> {
    doc.get("workloads")
        .and_then(|w| w.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|w| w.get("name").and_then(|n| n.as_str()).map(|n| (n, w)))
                .collect()
        })
        .unwrap_or_default()
}

/// Compare a fresh BENCH document against a baseline: every baseline
/// workload must exist in `current` with an **exactly equal**
/// `deterministic` object, and its wall time must not exceed
/// `baseline_wall × tolerance`. Returns the violations (empty = clean).
/// Schema mismatches are errors, not violations.
pub fn compare(current: &Json, baseline: &Json, tolerance: f64) -> Result<Vec<String>, String> {
    for (label, doc) in [("current", current), ("baseline", baseline)] {
        let schema = doc
            .get("schema")
            .and_then(|s| s.as_str())
            .ok_or_else(|| format!("{label} document has no schema field"))?;
        if schema != SCHEMA {
            return Err(format!("{label} schema '{schema}' != '{SCHEMA}'"));
        }
    }
    let cur = workloads_of(current);
    let mut violations = Vec::new();
    for (name, base_w) in workloads_of(baseline) {
        let Some((_, cur_w)) = cur.iter().find(|(n, _)| *n == name) else {
            violations.push(format!("workload '{name}' missing from current run"));
            continue;
        };
        match (base_w.get("deterministic"), cur_w.get("deterministic")) {
            (Some(b), Some(c)) if b == c => {}
            (Some(b), Some(c)) => violations.push(format!(
                "workload '{name}': deterministic counters diverged\n  baseline: {b}\n  current:  {c}"
            )),
            _ => violations.push(format!(
                "workload '{name}': missing deterministic section"
            )),
        }
        let base_wall = base_w.get("timed").and_then(|t| t.req_f64("wall_s").ok());
        let cur_wall = cur_w.get("timed").and_then(|t| t.req_f64("wall_s").ok());
        if let (Some(b), Some(c)) = (base_wall, cur_wall) {
            if c > b * tolerance {
                violations.push(format!(
                    "workload '{name}': wall {c:.3}s exceeds baseline {b:.3}s × tolerance {tolerance}"
                ));
            }
        }
    }
    Ok(violations)
}

/// Peak resident set size of this process (Linux `VmHWM`; 0 elsewhere).
pub fn peak_rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<u64>().ok())
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

/// Next free index in a directory's `BENCH_<n>.json` trajectory.
pub fn next_bench_index(dir: &str) -> u64 {
    let mut max = 0u64;
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(n) = name
                .strip_prefix("BENCH_")
                .and_then(|rest| rest.strip_suffix(".json"))
                .and_then(|digits| digits.parse::<u64>().ok())
            {
                max = max.max(n);
            }
        }
    }
    max + 1
}

/// One entry of a local `benches/*.rs` harness run.
pub struct LocalEntry {
    pub name: String,
    /// Machine-independent counters, when the harness has them.
    pub deterministic: Option<Json>,
    /// Median per-iteration wall time, seconds.
    pub wall_s: Option<f64>,
    /// Throughput at the median, when an op count is known.
    pub ops_per_s: Option<f64>,
}

impl LocalEntry {
    /// From a timed [`BenchResult`] (median wall; throughput if the
    /// per-iteration op count is known).
    pub fn timed(result: &BenchResult, ops_per_iter: Option<f64>) -> LocalEntry {
        LocalEntry {
            name: result.name.clone(),
            deterministic: None,
            wall_s: Some(result.summary.median),
            ops_per_s: ops_per_iter.map(|ops| ops / result.summary.median.max(1e-12)),
        }
    }

    /// From deterministic counters only (harnesses that assert orderings
    /// rather than time loops).
    pub fn counters(name: impl Into<String>, deterministic: Json) -> LocalEntry {
        LocalEntry {
            name: name.into(),
            deterministic: Some(deterministic),
            wall_s: None,
            ops_per_s: None,
        }
    }
}

/// Write a local harness's entries as one BENCH-schema document to
/// `<dir>/<name>.json`, where `<dir>` is `$BENCH_JSON_DIR` if set, else
/// `target/bench-json` (always keyed by harness name — a whole
/// `cargo bench` run must not overwrite itself down to one file).
/// Returns the path written, or an error string (harnesses print it and
/// continue — local JSON is best-effort, the asserts are the gate).
pub fn write_local(bench_name: &str, entries: &[LocalEntry]) -> Result<String, String> {
    let dir = match std::env::var("BENCH_JSON_DIR") {
        Ok(d) if !d.is_empty() => d,
        _ => "target/bench-json".to_string(),
    };
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    let path = format!("{dir}/{bench_name}.json");
    let workloads: Vec<Json> = entries
        .iter()
        .map(|e| {
            let mut fields = vec![("name".to_string(), Json::str(e.name.clone()))];
            if let Some(d) = &e.deterministic {
                fields.push(("deterministic".to_string(), d.clone()));
            }
            let mut timed = Vec::new();
            if let Some(w) = e.wall_s {
                timed.push(("wall_s".to_string(), Json::from(w)));
            }
            if let Some(t) = e.ops_per_s {
                timed.push(("ops_per_s".to_string(), Json::from(t)));
            }
            if !timed.is_empty() {
                fields.push(("timed".to_string(), Json::Obj(timed)));
            }
            Json::Obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("kind", Json::str("local-bench")),
        ("name", Json::str(bench_name)),
        ("peak_rss_bytes", Json::from(peak_rss_bytes())),
        ("workloads", Json::Arr(workloads)),
    ]);
    std::fs::write(&path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
    Ok(path)
}

/// [`write_local`] + a one-line confirmation / warning on stdout — the
/// tail call of every `benches/*.rs` harness.
pub fn emit_local(bench_name: &str, entries: &[LocalEntry]) {
    match write_local(bench_name, entries) {
        Ok(path) => println!("bench JSON -> {path}"),
        Err(e) => println!("bench JSON skipped ({e})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc_with(counters: u64, wall: f64) -> Json {
        let runs = vec![WorkloadRun {
            name: "w",
            deterministic: Json::obj(vec![("count", Json::from(counters))]),
            ops: 10,
            wall_s: wall,
        }];
        to_doc(1, true, &runs, 0)
    }

    #[test]
    fn doc_roundtrips_and_compares_clean() {
        let doc = doc_with(7, 0.5);
        let parsed = crate::util::json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert!(compare(&parsed, &doc, 2.0).unwrap().is_empty());
    }

    #[test]
    fn counter_drift_is_a_violation() {
        let base = doc_with(7, 0.5);
        let cur = doc_with(8, 0.5);
        let v = compare(&cur, &base, 2.0).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("deterministic counters diverged"), "{}", v[0]);
    }

    #[test]
    fn wall_regression_beyond_tolerance_is_a_violation() {
        let base = doc_with(7, 0.5);
        let ok = doc_with(7, 0.9);
        assert!(compare(&ok, &base, 2.0).unwrap().is_empty());
        let slow = doc_with(7, 1.5);
        let v = compare(&slow, &base, 2.0).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("exceeds baseline"), "{}", v[0]);
    }

    #[test]
    fn missing_workload_is_a_violation() {
        let base = doc_with(7, 0.5);
        let empty = to_doc(1, false, &[], 0);
        let v = compare(&empty, &base, 2.0).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("missing from current run"), "{}", v[0]);
        // And an empty baseline gates nothing (the unlocked-seed state).
        assert!(compare(&base, &empty, 2.0).unwrap().is_empty());
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let base = doc_with(7, 0.5);
        let bogus = Json::obj(vec![("schema", Json::str("other"))]);
        assert!(compare(&base, &bogus, 2.0).is_err());
    }

    #[test]
    fn next_index_scans_trajectory() {
        let dir = std::env::temp_dir().join("rlhf-mem-bench-idx-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let d = dir.to_str().unwrap();
        assert_eq!(next_bench_index(d), 1);
        std::fs::write(dir.join("BENCH_3.json"), "{}").unwrap();
        std::fs::write(dir.join("BENCH_7.json"), "{}").unwrap();
        std::fs::write(dir.join("BENCH_x.json"), "{}").unwrap();
        assert_eq!(next_bench_index(d), 8);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
