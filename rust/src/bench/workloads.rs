//! Canonical workloads behind `rlhf-mem bench`: the allocator micro and
//! large-pool-churn loops, PPO trace generation, a Table-1 cell, an
//! `advise` planner search, the surrogate-screened `advise --surrogate`
//! two-tier search (fit + screen + frontier-identity check), a 4-GPU
//! `cluster` sweep, the `peft` model-sharing comparison, and the
//! `serve` continuous-batching stream — one per layer of the speed
//! stack.
//!
//! Each workload returns machine-independent **deterministic counters**
//! (op counts, peaks, fingerprints of the exact outputs — seeded
//! simulation, no wall-clock inputs) next to its measured wall time. The
//! CI gate compares the counters exactly and the wall time within a
//! generous tolerance, so a perf "optimization" that changes results
//! cannot land silently (DESIGN.md §13).

use crate::alloc::{AllocatorConfig, CachingAllocator};
use crate::coordinator::schedule::{cluster_key, run_configs, ClusterConfig};
use crate::coordinator::PlacementPlan;
use crate::experiment::{run_scenario, RTX3090_HBM};
use crate::frameworks::{FrameworkKind, FrameworkProfile};
use crate::obs::{explain_scenario, ExplainOptions};
use crate::planner::{plan, Budget};
use crate::policy::EmptyCachePolicy;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::RoleSet;
use crate::rlhf::program::{Algo, Sharing};
use crate::rlhf::sim::{build_trace, ScenarioMode, SimScenario};
use crate::strategies::StrategyConfig;
use crate::sweep::{model_set_by_name, SweepGrid, SweepRunner};
use crate::util::bytes::{GIB, KIB, MIB};
use crate::util::fasthash::FastHasher;
use crate::util::json::Json;
use crate::util::prng::Rng;
use std::hash::Hasher;
use std::time::Instant;

/// One executed workload: deterministic counters + the timed side.
pub struct WorkloadRun {
    pub name: &'static str,
    /// Machine-independent counters (compared exactly by the CI gate).
    pub deterministic: Json,
    /// Operations executed (throughput denominator).
    pub ops: u64,
    /// Measured wall time, seconds.
    pub wall_s: f64,
}

/// The canonical suite, in execution order.
pub const NAMES: &[&str] = &[
    "alloc_micro",
    "alloc_churn",
    "trace_gen",
    "table1_cell",
    "advise_search",
    "advise_surrogate",
    "cluster_sweep",
    "peft_sweep",
    "explain",
    "serve_stream",
];

/// Run one canonical workload by name.
pub fn run_by_name(name: &str) -> Option<WorkloadRun> {
    match name {
        "alloc_micro" => Some(alloc_micro()),
        "alloc_churn" => Some(alloc_churn()),
        "trace_gen" => Some(trace_gen()),
        "table1_cell" => Some(table1_cell()),
        "advise_search" => Some(advise_search()),
        "advise_surrogate" => Some(advise_surrogate()),
        "cluster_sweep" => Some(cluster_sweep()),
        "peft_sweep" => Some(peft_sweep()),
        "explain" => Some(explain_run()),
        "serve_stream" => Some(serve_stream()),
        _ => None,
    }
}

/// Run the whole canonical suite.
pub fn run_all() -> Vec<WorkloadRun> {
    NAMES
        .iter()
        .map(|n| run_by_name(n).expect("canonical workload"))
        .collect()
}

/// Stable digest of a JSONL/JSON artifact, formatted for the BENCH schema.
pub fn hash_text(text: &str) -> String {
    let mut h = FastHasher::default();
    h.write(text.as_bytes());
    fmt_fingerprint(h.finish())
}

/// `u64` fingerprints don't fit losslessly in a JSON number — record them
/// as fixed-width hex strings.
pub fn fmt_fingerprint(fp: u64) -> String {
    format!("0x{fp:016x}")
}

fn alloc_stat_counters(a: &CachingAllocator) -> Json {
    let s = a.stats();
    Json::obj(vec![
        ("num_allocs", Json::from(s.num_allocs)),
        ("num_frees", Json::from(s.num_frees)),
        ("num_cache_hits", Json::from(s.num_cache_hits)),
        ("num_cuda_mallocs", Json::from(s.num_cuda_mallocs)),
        ("num_cuda_frees", Json::from(s.num_cuda_frees)),
        ("num_empty_cache", Json::from(s.num_empty_cache)),
        ("peak_reserved", Json::from(s.peak_reserved)),
        ("peak_allocated", Json::from(s.peak_allocated)),
        ("max_frag_sample", Json::from(s.max_frag_sample)),
    ])
}

/// Allocator micro: the cache-hit ping-pong — the pool's O(log n) fast
/// path with zero driver traffic after the first segment.
pub fn alloc_micro() -> WorkloadRun {
    const PAIRS: u64 = 100_000;
    let t = Instant::now();
    let mut a = CachingAllocator::with_default_config(GIB);
    for _ in 0..PAIRS {
        let h = a.alloc(64 * KIB).expect("micro alloc");
        a.free(h);
    }
    let wall_s = t.elapsed().as_secs_f64();
    a.validate().expect("micro validate");
    WorkloadRun {
        name: "alloc_micro",
        deterministic: alloc_stat_counters(&a),
        ops: PAIRS * 2,
        wall_s,
    }
}

/// Number of pinned large-pool segments the churn loop holds: each keeps
/// a non-releasable cached block in the pool, so the seed allocator's
/// `empty_cache` scan had this many entries to wade through per call.
pub const CHURN_PINNED: u64 = 6_000;
/// Churn iterations (one 32 MiB alloc/free pair each).
pub const CHURN_ITERS: u64 = 8_000;
/// `empty_cache` cadence within the churn loop.
pub const CHURN_EMPTY_EVERY: u64 = 16;

/// The large-pool churn: thousands of partially-used segments pin cached
/// (but not releasable) blocks while a hot alloc/free/empty_cache loop
/// runs on top. The fully-free-segment index makes each `empty_cache`
/// proportional to the one segment it releases; the seed allocator
/// scanned all `CHURN_PINNED + 1` pool entries (and every driver segment
/// slot) per call. `benches/allocator_micro.rs` times this same loop —
/// the ≥2× allocator-op throughput acceptance workload.
pub fn large_pool_churn() -> CachingAllocator {
    // 6000 × 20 MiB ≈ 117 GiB of simulated segments: accounting only, no
    // real memory behind it.
    let mut a = CachingAllocator::with_default_config(256 * GIB);
    let mut pinned = Vec::with_capacity(CHURN_PINNED as usize);
    for _ in 0..CHURN_PINNED {
        // < 10 MiB ⇒ a 20 MiB buffer per request: ~9 MiB live plus a
        // ~11 MiB cached split remainder that never becomes fully free.
        pinned.push(a.alloc(9 * MIB + 512).expect("churn setup"));
    }
    for i in 0..CHURN_ITERS {
        let h = a.alloc(32 * MIB).expect("churn alloc");
        a.free(h);
        if i % CHURN_EMPTY_EVERY == CHURN_EMPTY_EVERY - 1 {
            a.empty_cache();
        }
    }
    for h in pinned {
        a.free(h);
    }
    a.empty_cache();
    assert_eq!(a.reserved(), 0, "churn must drain to zero");
    a
}

/// Ops per [`large_pool_churn`] call (allocs + frees + empty_caches).
pub fn large_pool_churn_ops() -> u64 {
    let pairs = CHURN_PINNED + CHURN_ITERS;
    2 * pairs + CHURN_ITERS / CHURN_EMPTY_EVERY + 1
}

pub fn alloc_churn() -> WorkloadRun {
    let t = Instant::now();
    let a = large_pool_churn();
    let wall_s = t.elapsed().as_secs_f64();
    WorkloadRun {
        name: "alloc_churn",
        deterministic: alloc_stat_counters(&a),
        ops: large_pool_churn_ops(),
        wall_s,
    }
}

/// PPO trace generation (the PhaseProgram interpreter's hot path).
pub fn trace_gen() -> WorkloadRun {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
    scn.steps = 2;
    let t = Instant::now();
    let trace = build_trace(&scn);
    let wall_s = t.elapsed().as_secs_f64();
    WorkloadRun {
        name: "trace_gen",
        deterministic: Json::obj(vec![
            ("trace_ops", Json::from(trace.len())),
            (
                "trace_fingerprint",
                Json::str(fmt_fingerprint(trace.fingerprint())),
            ),
        ]),
        ops: trace.len() as u64,
        wall_s,
    }
}

/// One Table-1 cell end to end: trace generation + allocator replay +
/// profiling on the paper's RTX-3090 capacity.
pub fn table1_cell() -> WorkloadRun {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = 3;
    let t = Instant::now();
    let res = run_scenario(&scn, RTX3090_HBM);
    let wall_s = t.elapsed().as_secs_f64();
    let s = &res.summary;
    WorkloadRun {
        name: "table1_cell",
        deterministic: Json::obj(vec![
            ("peak_reserved", Json::from(s.peak_reserved)),
            ("peak_allocated", Json::from(s.peak_allocated)),
            ("frag", Json::from(s.frag)),
            ("cuda_mallocs", Json::from(s.cuda_mallocs)),
            ("oom", Json::from(s.oom)),
            ("ops_executed", Json::from(res.replay.ops_executed)),
        ]),
        ops: res.replay.ops_executed as u64,
        wall_s,
    }
}

/// A full `advise` planner search over the paper's RTX-3090 budget
/// (2 workers — parallelism exercised, output jobs-independent).
pub fn advise_search() -> WorkloadRun {
    let budget = Budget::rtx3090_table1();
    let t = Instant::now();
    let report = plan(&budget, 2).expect("advise search");
    let wall_s = t.elapsed().as_secs_f64();
    let best = report
        .best()
        .map(|o| o.candidate.key())
        .unwrap_or_else(|| "none".to_string());
    WorkloadRun {
        name: "advise_search",
        deterministic: Json::obj(vec![
            ("candidates", Json::from(report.outcomes.len())),
            (
                "feasible",
                Json::from(report.outcomes.iter().filter(|o| o.feasible).count()),
            ),
            ("best", Json::str(best)),
            ("jsonl_fingerprint", Json::str(hash_text(&report.jsonl()))),
        ]),
        ops: report.outcomes.len() as u64,
        wall_s,
    }
}

/// The two-tier `advise --surrogate` search on the same RTX-3090 budget
/// as [`advise_search`], timed end to end *including the fit*: fit the
/// surrogate, screen the candidate product, simulate only the survivors
/// and their baselines, and byte-compare the resulting frontier against
/// the exhaustive search's. The headline counters are the simulated /
/// screened reduction and the frontier-identity bit — a screening
/// "optimization" that changes the frontier or quietly simulates more
/// cells fails the exact-counter gate.
pub fn advise_surrogate() -> WorkloadRun {
    let budget = Budget::rtx3090_table1();
    let t = Instant::now();
    let opts = crate::surrogate::FitOptions::for_budget(&budget);
    let model = crate::surrogate::fit(&budget, 2, &opts).expect("surrogate fit");
    let screened = crate::surrogate::plan_surrogate(&budget, 2, &model).expect("surrogate advise");
    let wall_s = t.elapsed().as_secs_f64();
    let exhaustive = plan(&budget, 2).expect("exhaustive advise");
    let identical = screened.frontier_jsonl() == exhaustive.frontier_jsonl();
    WorkloadRun {
        name: "advise_surrogate",
        deterministic: Json::obj(vec![
            ("candidates", Json::from(screened.screened)),
            ("screened_out", Json::from(screened.screened_out)),
            ("simulated", Json::from(screened.simulated)),
            ("refined", Json::from(screened.refined)),
            ("fallback", Json::from(screened.fallback)),
            ("frontier_identical", Json::from(identical)),
            (
                "reduction_pct",
                Json::from(
                    (100 * (screened.screened - screened.simulated)) / screened.screened.max(1),
                ),
            ),
            (
                "max_rel_err_ppm",
                Json::from((screened.max_rel_err * 1e6).round() as u64),
            ),
            (
                "frontier_fingerprint",
                Json::str(hash_text(&screened.frontier_jsonl())),
            ),
        ]),
        ops: screened.screened,
        wall_s,
    }
}

/// A 4-GPU cluster placement sweep (colocated vs dedicated × none/zero3),
/// exercising per-GPU trace generation, collectives and aggregation.
pub fn cluster_sweep() -> WorkloadRun {
    let kind = FrameworkKind::by_name("ds").expect("ds framework");
    let profile = FrameworkProfile::by_kind(kind);
    let (_mlabel, models) = model_set_by_name("opt").expect("opt models");
    let world = 4u64;
    let mut configs: Vec<ClusterConfig> = Vec::new();
    for plan_name in ["colocated", "dedicated"] {
        let placement = PlacementPlan::by_name(plan_name, world).expect("placement preset");
        for (label, strategy) in [
            ("none", StrategyConfig::none()),
            ("zero3", StrategyConfig::zero3()),
        ] {
            if !profile.supports(&strategy) {
                continue;
            }
            let base = SimScenario {
                framework: profile.clone(),
                models: models.clone(),
                strategy,
                world,
                policy: EmptyCachePolicy::Never,
                steps: 1,
                mode: ScenarioMode::Full,
                algo: Algo::Ppo,
                sharing: Sharing::Separate,
                gpu: GpuSpec::rtx3090(),
                seed: 0x5EED,
                len_jitter: kind.default_len_jitter(),
                roles: RoleSet::ALL,
                time_shared: RoleSet::EMPTY,
                rank: 0,
            };
            configs.push(ClusterConfig {
                key: cluster_key(world, &placement.name, label, Algo::Ppo, Sharing::Separate),
                strategy_label: label.to_string(),
                plan: placement.clone(),
                base,
            });
        }
    }
    let t = Instant::now();
    let batch = run_configs(&configs, 24 * GIB, 2).expect("cluster sweep");
    let wall_s = t.elapsed().as_secs_f64();
    let runs: Vec<(String, crate::coordinator::ClusterRun)> = configs
        .iter()
        .map(|c| c.key.clone())
        .zip(batch.runs)
        .collect();
    let ooms = runs.iter().filter(|(_, r)| r.oom()).count();
    WorkloadRun {
        name: "cluster_sweep",
        deterministic: Json::obj(vec![
            ("configurations", Json::from(runs.len())),
            ("gpu_traces", Json::from(batch.cells)),
            ("ooms", Json::from(ooms)),
            (
                "jsonl_fingerprint",
                Json::str(hash_text(&crate::report::cluster::jsonl(&runs))),
            ),
        ]),
        ops: batch.cells as u64,
        wall_s,
    }
}

/// The `peft` model-sharing comparison: every sharing placement ×
/// {none, zero3} on the paper testbed — the Efficient-RLHF LoRA-PPO /
/// Hydra-PPO memory-ordering sweep, fingerprinted end to end.
pub fn peft_sweep() -> WorkloadRun {
    let cells = SweepGrid::new()
        .strategies([
            ("None", StrategyConfig::none()),
            ("ZeRO-3", StrategyConfig::zero3()),
        ])
        .sharings(Sharing::ALL)
        .steps(1)
        .build()
        .expect("peft grid");
    let t = Instant::now();
    let report = SweepRunner::new(2).run(cells);
    let wall_s = t.elapsed().as_secs_f64();
    let peak = |sharing: &str| -> u64 {
        report
            .cells
            .iter()
            .filter(|c| c.sharing == sharing && c.strategy == "None")
            .map(|c| c.summary.peak_reserved)
            .max()
            .unwrap_or(0)
    };
    let ordered = peak("hydra") < peak("lora") && peak("lora") < peak("separate");
    WorkloadRun {
        name: "peft_sweep",
        deterministic: Json::obj(vec![
            ("cells", Json::from(report.cells.len())),
            ("paper_ordering_holds", Json::from(ordered)),
            ("jsonl_fingerprint", Json::str(hash_text(&report.jsonl()))),
        ]),
        ops: report.cells.len() as u64,
        wall_s,
    }
}

/// The observability stack end-to-end: one `explain` run over the paper's
/// DeepSpeed/OPT preset with the peak flight recorder, the ranked shrink
/// table and a Perfetto export all armed. The counters pin the exact
/// five-way peak decomposition against drift.
pub fn explain_run() -> WorkloadRun {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = 1;
    let opts = ExplainOptions {
        perfetto_pid: Some(0),
        ..ExplainOptions::default()
    };
    let t = Instant::now();
    let out = explain_scenario(&scn, RTX3090_HBM, &AllocatorConfig::default(), &opts);
    let wall_s = t.elapsed().as_secs_f64();
    let peak = out.report.peak.as_ref().expect("preset must reserve");
    let b = peak.breakdown;
    let trace_events = out.perfetto.as_ref().map(|d| d.event_count()).unwrap_or(0);
    WorkloadRun {
        name: "explain",
        deterministic: Json::obj(vec![
            ("reserved", Json::from(peak.reserved)),
            ("census_requested", Json::from(b.census_requested)),
            ("rounding_waste", Json::from(b.rounding_waste)),
            ("block_slack", Json::from(b.block_slack)),
            ("free_gaps", Json::from(b.free_gaps)),
            ("cached_free", Json::from(b.cached_free)),
            ("rows", Json::from(out.report.rows.len())),
            ("trace_events", Json::from(trace_events)),
            (
                "render_fingerprint",
                Json::str(hash_text(&out.report.render())),
            ),
        ]),
        ops: trace_events as u64,
        wall_s,
    }
}

/// The serving-scale workload: the default serving grid (paged page
/// sizes vs best-fit reservation × concurrency ceilings) replayed
/// through the continuous-batching engine on 2 workers. The counters pin
/// request accounting, the per-discipline worst-case KV fragmentation
/// and the exact JSONL artifact.
pub fn serve_stream() -> WorkloadRun {
    let spec = crate::serve::ServeSpec::default();
    let cells = spec
        .cells("rtx3090", GpuSpec::rtx3090())
        .expect("serve grid");
    let t = Instant::now();
    let report = crate::serve::run_cells(&cells, 2);
    let wall_s = t.elapsed().as_secs_f64();
    let tel = report.telemetry();
    let max_frag = |disc: &str| -> u64 {
        report
            .cells
            .iter()
            .filter(|c| c.discipline == disc)
            .map(|c| c.kv_frag_bytes())
            .max()
            .unwrap_or(0)
    };
    WorkloadRun {
        name: "serve_stream",
        deterministic: Json::obj(vec![
            ("cells", Json::from(report.cells.len())),
            ("completed", Json::from(tel.get("completed").unwrap_or(0))),
            ("failed", Json::from(tel.get("failed").unwrap_or(0))),
            ("preempted", Json::from(tel.get("preempted").unwrap_or(0))),
            (
                "decode_steps",
                Json::from(tel.get("decode_steps").unwrap_or(0)),
            ),
            ("paged_max_frag", Json::from(max_frag("paged"))),
            ("best_fit_max_frag", Json::from(max_frag("best-fit"))),
            ("jsonl_fingerprint", Json::str(hash_text(&report.jsonl()))),
        ]),
        ops: tel.get("decode_steps").unwrap_or(0) + tel.get("admissions").unwrap_or(0),
        wall_s,
    }
}

/// A fast deterministic churn used by `--smoke` and tests: same shape as
/// [`large_pool_churn`], two orders of magnitude smaller.
pub fn smoke_churn_counters() -> Json {
    let mut a = CachingAllocator::with_default_config(8 * GIB);
    let mut rng = Rng::seeded(0x5EED);
    let mut live = Vec::new();
    for _ in 0..64 {
        live.push(a.alloc(9 * MIB + 512).expect("smoke setup"));
    }
    for i in 0..400u64 {
        if live.is_empty() || rng.bernoulli(0.6) {
            if let Ok(h) = a.alloc(rng.gen_range(24 * MIB) + MIB) {
                live.push(h);
            }
        } else {
            let i = rng.range_usize(0, live.len());
            a.free(live.swap_remove(i));
        }
        if i % 50 == 49 {
            a.empty_cache();
        }
    }
    for h in live {
        a.free(h);
    }
    a.empty_cache();
    a.validate().expect("smoke churn validate");
    alloc_stat_counters(&a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_micro_counters_are_exact() {
        let w = alloc_micro();
        let d = &w.deterministic;
        assert_eq!(d.req_u64("num_allocs").unwrap(), 100_000);
        assert_eq!(d.req_u64("num_frees").unwrap(), 100_000);
        // Everything after the first alloc is a cache hit of the same block.
        assert_eq!(d.req_u64("num_cache_hits").unwrap(), 99_999);
        assert_eq!(d.req_u64("num_cuda_mallocs").unwrap(), 1);
        assert!(w.wall_s > 0.0);
    }

    #[test]
    fn churn_is_deterministic_and_release_heavy() {
        let a = large_pool_churn();
        let b = large_pool_churn();
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.num_cuda_mallocs, sb.num_cuda_mallocs);
        assert_eq!(sa.peak_reserved, sb.peak_reserved);
        assert_eq!(sa.max_frag_sample, sb.max_frag_sample);
        // The churn loop's empty_cache calls must actually release the
        // churned segment each time (the indexed release path's work).
        assert_eq!(sa.num_empty_cache, CHURN_ITERS / CHURN_EMPTY_EVERY + 1);
        assert!(sa.num_cuda_frees >= CHURN_ITERS / CHURN_EMPTY_EVERY);
    }

    #[test]
    fn trace_gen_fingerprint_stable_within_process() {
        let a = trace_gen();
        let b = trace_gen();
        assert_eq!(a.deterministic, b.deterministic);
        assert!(a.ops > 100);
    }

    #[test]
    fn smoke_churn_is_deterministic() {
        assert_eq!(smoke_churn_counters(), smoke_churn_counters());
    }

    #[test]
    fn serve_stream_counters_are_deterministic() {
        let a = serve_stream();
        let b = serve_stream();
        assert_eq!(a.deterministic, b.deterministic);
        assert!(a.ops > 0);
    }
}
