//! `rlhf-mem ablation` — §3.3 (E7): empty_cache() placement ablation:
//! never / after both / after inference only / after training only.
//!
//! A four-cell grid (one per [`EmptyCachePolicy`]) run through the sweep
//! engine; `--jobs` parallelizes the four runs.

use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::table::TextTable;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let jobs = args.get_usize("jobs", SweepRunner::default_jobs())?;
    let cells = SweepGrid::new()
        .strategies([("All Enabled", StrategyConfig::all_enabled())])
        .policies(EmptyCachePolicy::ALL)
        .steps(steps)
        .build()?;
    let report = SweepRunner::new(jobs).run(cells);

    let mut t = TextTable::new(&["Policy", "Reserved", "Frag.", "Allocated", "empty_cache calls"]);
    for cell in &report.cells {
        let s = &cell.summary;
        t.row(vec![
            cell.policy.to_string(),
            fmt_gib_paper(s.peak_reserved),
            fmt_gib_paper(s.frag),
            fmt_gib_paper(s.peak_allocated),
            s.empty_cache_calls.to_string(),
        ]);
    }
    println!("§3.3 placement ablation — DeepSpeed-Chat/OPT, all strategies, {steps} steps (GiB)");
    println!("{}", t.render());
    println!("Expectation (paper): after_inference ≈ after_both ≪ never; after_training ≈ never.");
    println!("({})", report.summary_line());
    Ok(())
}
