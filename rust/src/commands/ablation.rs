//! `rlhf-mem ablation` — §3.3 (E7): empty_cache() placement ablation:
//! never / after both / after inference only / after training only.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::table::TextTable;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let mut t = TextTable::new(&["Policy", "Reserved", "Frag.", "Allocated", "empty_cache calls"]);
    for policy in EmptyCachePolicy::ALL {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), policy);
        scn.steps = steps;
        let res = run_scenario(&scn, RTX3090_HBM);
        let s = res.summary;
        t.row(vec![
            policy.name().to_string(),
            fmt_gib_paper(s.peak_reserved),
            fmt_gib_paper(s.frag),
            fmt_gib_paper(s.peak_allocated),
            s.empty_cache_calls.to_string(),
        ]);
    }
    println!("§3.3 placement ablation — DeepSpeed-Chat/OPT, all strategies, {steps} steps (GiB)");
    println!("{}", t.render());
    println!("Expectation (paper): after_inference ≈ after_both ≪ never; after_training ≈ never.");
    Ok(())
}
