//! `rlhf-mem advise` — the memory-configuration advisor: search the
//! mitigation space for a budget and print a ranked recommendation plus
//! the memory-vs-time Pareto frontier.
//!
//! ```text
//! rlhf-mem advise --budget examples/budget_rtx3090.json --jobs 4
//! ```
//!
//! Exits non-zero when nothing in the space fits the budget — the
//! advisor's honest answer is then "buy a bigger GPU or shrink the
//! model", and scripts can branch on it.

use rlhf_mem::planner::{plan_cluster, plan_with, Budget, PlanOptions};
use rlhf_mem::report;
use rlhf_mem::serve::plan_serve;
use rlhf_mem::surrogate::{plan_surrogate, SurrogateModel};
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::cli::{Args, CommonArgs};

pub const ADVISE_USAGE: &str = "\
rlhf-mem advise — search sharing × strategy × empty_cache × allocator-knob
space for the cheapest configuration that fits a GPU budget

FLAGS:
  --budget FILE    JSON budget spec (default: the paper's RTX-3090 testbed;
                   see examples/budget_rtx3090.json for every field —
                   \"sharings\": [\"separate\",\"lora\",\"hydra\"] widens the
                   model-sharing axis)
  --cluster        search placement plan × strategy × world-size instead
                   (feasible = every GPU of the plan fits the budget;
                   ranked on the max-per-GPU-memory vs step-time frontier)
  --serve          search the serving grid of the budget's \"serve\" object
                   instead (discipline × page size × max concurrency;
                   feasible = no dropped requests and p99 within
                   p99_budget_ms; ranked by throughput on the
                   peak-KV-vs-p99 frontier)
  --prescreen-static
                   reject candidates whose static peak lower bound (see
                   `rlhf-mem lint`) already exceeds the capacity, before
                   simulating them; the surviving frontier is identical,
                   telemetry counts the pruned candidates
  --surrogate FILE two-tier search: screen the candidate product with a
                   fitted SURROGATE.json (`rlhf-mem fit`) and simulate only
                   candidates within the model's error envelope of the
                   frontier — the printed frontier (and --frontier-jsonl)
                   is byte-identical to the exhaustive search's; errors if
                   the artifact's certificates are refuted (stale: refit)
  --jobs N         worker threads (default: all cores)
  --top N          recommendations to print (default 10)
  --jsonl FILE     write one deterministic JSON line per candidate
                   (with --surrogate: the frontier lines, which is the
                   whole deterministic contract of that mode)
  --frontier-jsonl FILE
                   write the frontier-only JSON lines, no telemetry footer
                   — the search-mode-invariant identity artifact CI
                   byte-compares across exhaustive and surrogate runs
  --json FILE      write the full report as one JSON document
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{ADVISE_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, 0x5EED)?;
    let budget = match args.flag("budget") {
        Some(path) => Budget::from_file(path)?,
        None => Budget::rtx3090_table1(),
    };
    let jobs = common.jobs;
    let top = args.get_usize("top", 10)?;

    if args.bool_flag("serve") {
        if args.bool_flag("cluster") || args.has("surrogate") {
            return Err(
                "--serve is exclusive with --cluster/--surrogate: the serving grid \
                 is its own search space"
                    .to_string(),
            );
        }
        return run_serve(&common, &budget, jobs);
    }
    if let Some(model_path) = args.flag("surrogate") {
        if args.bool_flag("cluster") {
            return Err(
                "--surrogate and --cluster are mutually exclusive: the surrogate is \
                 fitted over the single-GPU mitigation space"
                    .to_string(),
            );
        }
        return run_surrogate(args, &common, &budget, jobs, model_path);
    }
    if args.bool_flag("cluster") {
        return run_cluster(&common, &budget, jobs, top);
    }

    println!(
        "advise: budget '{}' — {} GiB, ≤{}% overhead, {} / {}",
        budget.name,
        fmt_gib_paper(budget.capacity),
        budget.max_overhead_pct,
        budget.framework.name(),
        budget.models.policy_arch.name,
    );
    let opts = PlanOptions {
        prescreen_static: args.bool_flag("prescreen-static"),
    };
    let report = plan_with(&budget, jobs, opts)?;
    if let Some(p) = report.static_pruned {
        println!("static prescreen: {p} candidate(s) rejected before simulation");
    }

    println!("\n== top recommendations ==");
    println!("{}", report.to_table(top).render());
    println!("== memory-vs-time frontier ==");
    println!("{}", report.frontier_table().render());

    match report.best() {
        Some(best) => println!(
            "recommendation: {} — {} GiB reserved{}",
            best.candidate.key(),
            fmt_gib_paper(best.summary.peak_reserved),
            match best.overhead_pct {
                Some(p) => format!(", {p:+.1}% modeled time overhead"),
                None => String::new(),
            },
        ),
        None => {
            println!("({})", report.summary_line());
            return Err(format!(
                "no configuration fits the '{}' budget ({} GiB, ≤{}% overhead)",
                budget.name,
                fmt_gib_paper(budget.capacity),
                budget.max_overhead_pct
            ));
        }
    }
    if let Some(pct) = report.empty_cache_frontier_overhead() {
        println!(
            "paper anchor: empty_cache at phase boundaries (stock allocator) is on \
             the frontier at {pct:+.1}% overhead (paper §3.3 claims ≈ +2%)"
        );
    } else if let Some(pct) = report.any_empty_cache_frontier_overhead() {
        println!(
            "frontier: cheapest empty_cache placement (with allocator knobs) costs \
             {pct:+.1}% vs its un-mitigated baseline"
        );
    }
    println!("({})", report.summary_line());
    println!("{}", report::telemetry::render_telemetry(&report.telemetry()));

    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.flag("frontier-jsonl") {
        std::fs::write(path, report.frontier_jsonl()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = &common.json {
        std::fs::write(path, report.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `advise --serve`: evaluate the budget's serving grid and recommend a
/// (discipline, page size, concurrency) configuration.
fn run_serve(common: &CommonArgs, budget: &Budget, jobs: usize) -> Result<(), String> {
    println!(
        "advise --serve: budget '{}' — {} / {}",
        budget.name,
        budget.framework.name(),
        budget.models.policy_arch.name,
    );
    let plan = plan_serve(budget, jobs)?;
    println!("{}", plan.to_table());
    println!("({})", plan.report.summary_line());
    println!(
        "{}",
        report::telemetry::render_telemetry(&plan.report.telemetry())
    );
    if let Some(path) = &common.jsonl {
        std::fs::write(path, plan.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if plan.recommendation().is_none() {
        return Err(format!(
            "no serving configuration is feasible under the '{}' budget's traffic",
            budget.name
        ));
    }
    Ok(())
}

/// `advise --surrogate FILE`: screen with the fitted model, simulate
/// only the survivors and their baselines.
fn run_surrogate(
    args: &Args,
    common: &CommonArgs,
    budget: &Budget,
    jobs: usize,
    model_path: &str,
) -> Result<(), String> {
    let model = SurrogateModel::from_file(model_path)?;
    println!(
        "advise --surrogate: budget '{}' — {} GiB, ≤{}% overhead, {} / {}",
        budget.name,
        fmt_gib_paper(budget.capacity),
        budget.max_overhead_pct,
        budget.framework.name(),
        budget.models.policy_arch.name,
    );
    println!(
        "surrogate: artifact '{}' ({} cells at steps {:?}, max rel err {:.4})",
        model.budget_name, model.cells, model.steps_fit, model.max_rel_err,
    );
    let report = plan_surrogate(budget, jobs, &model)?;

    println!("\n== memory-vs-time frontier (surrogate-screened, identical to exhaustive) ==");
    println!("{}", report.frontier_table().render());

    match report.recommended_frontier() {
        Some(best) => println!(
            "cheapest feasible frontier configuration: {} — {} GiB reserved{}",
            best.candidate.key(),
            fmt_gib_paper(best.summary.peak_reserved),
            match best.overhead_pct {
                Some(p) => format!(", {p:+.1}% modeled time overhead"),
                None => String::new(),
            },
        ),
        None => {
            println!("({})", report.summary_line());
            // Sound refusal: every screened-out candidate is certified
            // infeasible or strictly dominated by a *feasible* simulated
            // one, so "no simulated fit" means "no fit at all".
            return Err(format!(
                "no configuration fits the '{}' budget ({} GiB, ≤{}% overhead)",
                budget.name,
                fmt_gib_paper(budget.capacity),
                budget.max_overhead_pct
            ));
        }
    }
    println!("({})", report.summary_line());
    println!("{}", report::telemetry::render_telemetry(&report.telemetry()));

    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.flag("frontier-jsonl") {
        std::fs::write(path, report.frontier_jsonl()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `advise --cluster`: placement × strategy × world-size search.
fn run_cluster(common: &CommonArgs, budget: &Budget, jobs: usize, top: usize) -> Result<(), String> {
    println!(
        "advise --cluster: budget '{}' — {} GiB per GPU, {} / {}",
        budget.name,
        fmt_gib_paper(budget.capacity),
        budget.framework.name(),
        budget.models.policy_arch.name,
    );
    let report = plan_cluster(budget, jobs)?;

    println!("\n== top placements ==");
    println!("{}", report.to_table(top).render());
    println!("== max-per-GPU-memory vs step-time frontier ==");
    println!("{}", report.frontier_table().render());

    match report.best() {
        Some(best) => println!(
            "recommendation: {} — {} GiB on the most loaded GPU, {:.1} ms/step",
            best.candidate.key(),
            fmt_gib_paper(best.run.max_peak_reserved()),
            best.run.step_time_us / 1000.0,
        ),
        None => {
            println!("({})", report.summary_line());
            return Err(format!(
                "no placement fits the '{}' budget ({} GiB per GPU)",
                budget.name,
                fmt_gib_paper(budget.capacity)
            ));
        }
    }
    println!("({})", report.summary_line());
    println!("{}", report::telemetry::render_telemetry(&report.telemetry()));

    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = &common.json {
        std::fs::write(path, report.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
