//! `rlhf-mem algos` — the RLHF-algorithm comparison: sweep the algorithm
//! axis (PPO / GRPO / ReMax / DPO) against a strategy list and print peak
//! reserved + fragmentation per algorithm, per strategy.
//!
//! ```text
//! rlhf-mem algos --strategies none,zero3 --steps 2 --jobs 8 \
//!                --jsonl algos.jsonl
//! ```
//!
//! The phase pipelines come from the compiled
//! [`rlhf_mem::rlhf::program::PhaseProgram`]s: GRPO/ReMax drop the critic
//! model and its update, DPO collapses to reference-only scoring with one
//! preference-loss update — so the critic-free columns should come in
//! under PPO's.

use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::algos::comparison_table;
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::program::Algo;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{model_set_by_name, SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::cli::{split_list, Args, CommonArgs};

pub const ALGOS_USAGE: &str = "\
rlhf-mem algos — compare RLHF algorithms' memory behaviour per strategy
(peak reserved + fragmentation columns per algorithm)

FLAGS (comma-separated lists):
  --algos ppo,grpo,remax,dpo     algorithm columns (default all four)
  --strategies none,zero1,zero2,zero3,offload,ckpt,all   (default none,zero3)
  --framework ds|cc              framework profile (default ds)
  --models opt|gpt2|nano         model pair (default opt)
  --steps N        PPO steps per cell (default 2)
  --world N        data-parallel ranks (default 4)
  --capacity-gib N simulated HBM per GPU (default 24)
  --gpu rtx3090|a100             time-model GPU (default rtx3090)
  --seed N         response-length seed (default 0x5EED)
  --jobs N         worker threads (default: all cores)
  --jsonl FILE     write per-cell JSON-lines (index-ordered)
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{ALGOS_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, 0x5EED)?;

    let algos: Vec<Algo> = Algo::parse_list(args.get_or("algos", "ppo,grpo,remax,dpo"))?;

    let strategies: Vec<(&'static str, StrategyConfig)> =
        split_list(args.get_or("strategies", "none,zero3"))
            .map(|n| StrategyConfig::by_name(n).ok_or_else(|| format!("unknown strategy '{n}'")))
            .collect::<Result<_, _>>()?;

    let fw_name = args.get_or("framework", "ds");
    let kind = FrameworkKind::by_name(fw_name)
        .ok_or_else(|| format!("unknown framework '{fw_name}'"))?;

    let model_name = args.get_or("models", "opt");
    let models =
        model_set_by_name(model_name).ok_or_else(|| format!("unknown model set '{model_name}'"))?;

    let mut grid = SweepGrid::new()
        .frameworks([kind])
        .model_sets([models])
        .strategies(strategies)
        .policies([EmptyCachePolicy::Never])
        .algos(algos.clone())
        .steps(args.get_u64("steps", 2)?)
        .world(args.get_u64("world", 4)?)
        .capacity(args.get_u64("capacity-gib", 24)? * GIB)
        .seeds(rlhf_mem::sweep::SeedPolicy::Fixed(common.seed));
    let gpu_name = args.get_or("gpu", "rtx3090");
    grid = grid.gpu(GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?);

    let cells = grid.build()?;
    if cells.is_empty() {
        return Err("algorithm grid is empty (axes selected no cells)".to_string());
    }
    println!("algos: {} cells", cells.len());

    let report = SweepRunner::new(common.jobs).run(cells);

    println!("{}", comparison_table(&report.cells, &algos).render());
    println!("({})", report.summary_line());
    println!(
        "Expectation: critic-free (grpo/remax) and reference-only (dpo) pipelines\n\
         reserve less than ppo for the same model set — fewer engines, fewer phases."
    );
    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
