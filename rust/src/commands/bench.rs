//! `rlhf-mem bench` — the perf subsystem's front end: run the canonical
//! workload suite, emit a `BENCH_<n>.json` trajectory point, gate against
//! a committed baseline, or run the consolidated CI smoke suite.
//!
//! ```text
//! rlhf-mem bench                          # run suite, write next BENCH_<n>.json
//! rlhf-mem bench --check BENCH_5.json     # CI gate: determinism + baseline
//! rlhf-mem bench --smoke --out-dir bench-artifacts
//! ```
//!
//! The gate is two-layered (DESIGN.md §13): the suite always runs twice
//! under `--check` and the two runs' deterministic counters must agree
//! **exactly** (hard, machine-independent); against the baseline,
//! counters must match exactly and wall time stay within `--tolerance`
//! when the baseline is `locked`, and differences are reported without
//! failing while it is not.

use rlhf_mem::bench::{report, workloads};
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::{self, Json};
use rlhf_mem::util::schema;
use std::time::Instant;

pub const BENCH_USAGE: &str = "\
rlhf-mem bench — run the canonical perf workloads and record/gate the
BENCH_<n>.json trajectory

FLAGS:
  --out FILE       write the BENCH JSON here (default: next BENCH_<n>.json
                   in the current directory; a --check run without --out
                   writes nothing — gate runs don't grow the trajectory)
  --index N        trajectory index recorded in the document (default:
                   inferred from the output path / directory scan)
  --lock           mark the emitted document locked (counters become a
                   hard CI gate when committed as the baseline)
  --check FILE     regression gate: run the suite twice (determinism is
                   always enforced), then compare against FILE —
                   deterministic counters exactly, wall time within
                   --tolerance; mismatches fail only if FILE is locked.
                   A locked FILE with no recorded workloads fails outright:
                   the arm-bench-lock CI dispatch is the only fill path
  --tolerance X    wall-clock slack factor for --check (default 5.0)
  --accept FILE    promote a CI-emitted bench document to the locked
                   baseline: FILE is re-emitted with locked=true to --out
                   (required) — the DESIGN §13 lock-from-CI step
  --smoke          run the consolidated CI smoke suite instead (cluster +
                   advise + algos + peft + serve, each writing its JSONL
                   artifact, every artifact's schema header validated)
  --out-dir DIR    smoke artifact directory (default bench-artifacts)
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{BENCH_USAGE}");
        return Ok(());
    }
    if args.bool_flag("smoke") {
        return run_smoke(args);
    }
    if let Some(artifact) = args.flag("accept") {
        return run_accept(artifact, args.flag("out"));
    }

    let suite_start = Instant::now();
    println!(
        "bench: running {} canonical workloads",
        workloads::NAMES.len()
    );
    let runs = run_suite();

    // Emit the fresh document *before* any gating, so CI's artifact
    // upload has it even when the gate fails — that failing document is
    // exactly what the DESIGN §13 lock-from-CI procedure commits. A pure
    // gate run (no --out) writes nothing: auto-indexed trajectory files
    // are only for explicit recording runs.
    let explicit_out = args.flag("out").map(|s| s.to_string());
    let write_out = explicit_out.is_some() || !args.has("check");
    let out = match explicit_out {
        Some(p) => p,
        None => format!("BENCH_{}.json", report::next_bench_index(".")),
    };
    let index = match args.flag("index") {
        Some(_) => args.get_u64("index", 0)?,
        None => infer_index(&out).unwrap_or_else(|| report::next_bench_index(".")),
    };
    let doc = report::to_doc(
        index,
        args.bool_flag("lock"),
        &runs,
        report::peak_rss_bytes(),
    );
    if write_out {
        std::fs::write(&out, doc.to_string_pretty()).map_err(|e| format!("write {out}: {e}"))?;
    }

    println!("\n{:<16} {:>12} {:>12}  deterministic", "workload", "wall", "ops/s");
    for r in &runs {
        println!(
            "{:<16} {:>11.3}s {:>12.0}  {}",
            r.name,
            r.wall_s,
            r.ops as f64 / r.wall_s.max(1e-9),
            r.deterministic
        );
    }
    if write_out {
        println!(
            "wrote {out} (index {index}, suite wall {:.2}s, peak RSS {} MiB)",
            suite_start.elapsed().as_secs_f64(),
            report::peak_rss_bytes() / (1 << 20)
        );
    } else {
        println!(
            "(suite wall {:.2}s, peak RSS {} MiB; no --out given — nothing written)",
            suite_start.elapsed().as_secs_f64(),
            report::peak_rss_bytes() / (1 << 20)
        );
    }

    if let Some(baseline_path) = args.flag("check") {
        // Layer 1 — determinism: a second in-process run must reproduce
        // every deterministic counter bit for bit. Machine-independent,
        // so it gates from the very first CI run.
        println!("bench: determinism self-check (second suite run)");
        let rerun = run_suite();
        for (a, b) in runs.iter().zip(&rerun) {
            if a.deterministic != b.deterministic {
                return Err(format!(
                    "workload '{}' is nondeterministic across two in-process runs\n  \
                     first:  {}\n  second: {}",
                    a.name, a.deterministic, b.deterministic
                ));
            }
        }
        println!("bench: determinism self-check clean");

        // Layer 2 — the committed baseline.
        let tolerance = args.get_f64("tolerance", 5.0)?;
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| format!("read {baseline_path}: {e}"))?;
        let baseline = json::parse(&text).map_err(|e| format!("parse {baseline_path}: {e}"))?;
        let locked = baseline
            .get("locked")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let baseline_workloads = baseline
            .get("workloads")
            .and_then(|v| match v {
                Json::Arr(items) => Some(items.len()),
                _ => None,
            })
            .unwrap_or(0);
        if locked && baseline_workloads == 0 {
            // An armed lock with nothing recorded gates *nothing* — a
            // state that silently waives the counter gate if tolerated.
            // Fail hard: the `arm-bench-lock` CI job (workflow_dispatch)
            // is the only fill path — it runs the suite, `--accept`s the
            // artifact this run just emitted, and commits the armed
            // baseline (DESIGN §13). Determinism and the artifact write
            // both happened above, so the failing run still leaves
            // everything arming needs.
            return Err(format!(
                "baseline {baseline_path} is locked but records no workloads: the \
                 counter gate is armed yet vacuous. Dispatch the arm-bench-lock CI \
                 job (the only fill path) to record and commit the baseline."
            ));
        }
        let violations = report::compare(&doc, &baseline, tolerance)?;
        if violations.is_empty() {
            println!("bench gate: clean vs {baseline_path} (tolerance {tolerance}x)");
        } else {
            for v in &violations {
                eprintln!("bench gate: {v}");
            }
            if locked {
                return Err(format!(
                    "{} regression(s) vs locked baseline {baseline_path}",
                    violations.len()
                ));
            }
            println!(
                "bench gate: baseline {baseline_path} is not locked — {} difference(s) \
                 recorded, not gated. Lock it by committing the freshly emitted \
                 document (see its 'regenerate' field).",
                violations.len()
            );
        }
    }
    Ok(())
}

/// `--accept`: promote a CI-emitted bench document to the locked
/// baseline. The artifact's counters were produced by the exact binary
/// CI built, so committing them (rather than numbers from a developer
/// machine) is what makes the locked gate honest — see DESIGN §13.
fn run_accept(artifact: &str, out: Option<&str>) -> Result<(), String> {
    let out = out.ok_or_else(|| {
        "--accept needs --out <baseline.json> (the committed baseline to overwrite)".to_string()
    })?;
    let text =
        std::fs::read_to_string(artifact).map_err(|e| format!("read {artifact}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| format!("parse {artifact}: {e}"))?;
    let workload_count = match doc.get("workloads") {
        Some(Json::Arr(items)) if !items.is_empty() => items.len(),
        _ => {
            return Err(format!(
                "{artifact} records no workloads — accept a full `rlhf-mem bench` \
                 document, not a smoke summary"
            ))
        }
    };
    let locked = match doc {
        Json::Obj(kvs) => Json::Obj(
            kvs.into_iter()
                .map(|(k, v)| {
                    if k == "locked" {
                        (k, Json::from(true))
                    } else {
                        (k, v)
                    }
                })
                .collect(),
        ),
        other => other,
    };
    std::fs::write(out, locked.to_string_pretty()).map_err(|e| format!("write {out}: {e}"))?;
    println!(
        "accepted {artifact} -> {out} (locked, {workload_count} workloads); \
         commit {out} to arm the gate"
    );
    Ok(())
}

fn run_suite() -> Vec<workloads::WorkloadRun> {
    workloads::NAMES
        .iter()
        .map(|name| {
            let r = workloads::run_by_name(name).expect("canonical workload");
            println!("  {:<16} {:>9.3}s  {} ops", r.name, r.wall_s, r.ops);
            r
        })
        .collect()
}

/// `BENCH_<n>.json` → `n`.
fn infer_index(path: &str) -> Option<u64> {
    std::path::Path::new(path)
        .file_name()?
        .to_str()?
        .strip_prefix("BENCH_")?
        .strip_suffix(".json")?
        .parse()
        .ok()
}

/// The consolidated smoke suite: what used to be copy-pasted CI steps
/// (cluster / advise / algos / peft / serve) becomes one invocation
/// whose JSONL artifacts land in `--out-dir`, plus a `BENCH_smoke.json`
/// summary with a fingerprint per artifact. Every artifact's versioned
/// schema header is validated against its expected kind.
fn run_smoke(args: &Args) -> Result<(), String> {
    let out_dir = args.get_or("out-dir", "bench-artifacts").to_string();
    std::fs::create_dir_all(&out_dir).map_err(|e| format!("create {out_dir}: {e}"))?;

    let smokes: Vec<(&str, &str, Vec<String>)> = vec![
        (
            "cluster",
            "cluster",
            argv(&[
                "cluster", "--gpus", "2", "--strategies", "none", "--algos", "ppo,grpo",
                "--steps", "1", "--jobs", "2", "--jsonl",
                &format!("{out_dir}/cluster-smoke.jsonl"),
            ]),
        ),
        (
            "advise",
            "planner",
            argv(&[
                "advise", "--budget", "examples/budget_rtx3090.json", "--jobs", "2",
                "--top", "3", "--jsonl", &format!("{out_dir}/advise-smoke.jsonl"),
            ]),
        ),
        (
            "algos",
            "sweep",
            argv(&[
                "algos", "--strategies", "none", "--steps", "1", "--jobs", "2",
                "--jsonl", &format!("{out_dir}/algos-smoke.jsonl"),
            ]),
        ),
        (
            "peft",
            "sweep",
            argv(&[
                "peft", "--strategies", "none", "--steps", "1", "--jobs", "2",
                "--compare-paper", "--jsonl", &format!("{out_dir}/peft-smoke.jsonl"),
            ]),
        ),
        (
            "serve",
            "serve",
            argv(&[
                "serve", "--requests", "24", "--page-tokens", "16",
                "--max-concurrency", "4,8", "--jobs", "2", "--jsonl",
                &format!("{out_dir}/serve-smoke.jsonl"),
            ]),
        ),
    ];

    let mut artifacts: Vec<Json> = Vec::new();
    for (name, kind, raw) in smokes {
        println!("== smoke: {name} ==");
        let sub = Args::parse(raw);
        match sub.subcommand.as_deref() {
            Some("cluster") => super::cluster::run(&sub)?,
            Some("advise") => super::advise::run(&sub)?,
            Some("algos") => super::algos::run(&sub)?,
            Some("peft") => super::peft::run(&sub)?,
            Some("serve") => super::serve::run(&sub)?,
            _ => unreachable!("smoke table names a known subcommand"),
        }
        let path = format!("{out_dir}/{name}-smoke.jsonl");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("smoke '{name}' left no artifact at {path}: {e}"))?;
        if text.trim().is_empty() {
            return Err(format!("smoke '{name}' wrote an empty artifact at {path}"));
        }
        schema::check_jsonl(kind, &text)
            .map_err(|e| format!("smoke '{name}' artifact {path}: {e}"))?;
        artifacts.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("path", Json::str(path)),
            ("lines", Json::from(text.lines().count())),
            ("fingerprint", Json::str(workloads::hash_text(&text))),
        ]));
    }

    let summary = Json::obj(vec![
        ("schema", Json::str(report::SCHEMA)),
        ("kind", Json::str("smoke")),
        ("alloc_churn_small", workloads::smoke_churn_counters()),
        ("artifacts", Json::Arr(artifacts)),
        ("peak_rss_bytes", Json::from(report::peak_rss_bytes())),
    ]);
    let summary_path = format!("{out_dir}/BENCH_smoke.json");
    std::fs::write(&summary_path, summary.to_string_pretty())
        .map_err(|e| format!("write {summary_path}: {e}"))?;
    println!("smoke suite clean; summary -> {summary_path}");
    Ok(())
}

fn argv(parts: &[&str]) -> Vec<String> {
    parts.iter().map(|s| s.to_string()).collect()
}
