//! `rlhf-mem cluster` — the multi-GPU placement simulator: run a
//! placement × strategy sweep over a simulated node and report per-GPU
//! peaks plus the modeled PPO step time of every configuration.
//!
//! ```text
//! rlhf-mem cluster --gpus 2,4 --plans colocated,time-shared,dedicated \
//!                  --strategies none,zero3 --steps 2 --jobs 8 \
//!                  --jsonl cluster.jsonl
//! ```
//!
//! Every GPU of every configuration replays its own trace as one cell of
//! the sweep worker pool; aggregation is serial, so the JSONL output is
//! byte-identical for any `--jobs`.

use rlhf_mem::alloc::AllocatorConfig;
use rlhf_mem::coordinator::schedule::{cluster_key, run_configs, ClusterConfig};
use rlhf_mem::coordinator::{ClusterRun, PlacementPlan};
use rlhf_mem::experiment::run_scenario_observed;
use rlhf_mem::frameworks::{FrameworkKind, FrameworkProfile};
use rlhf_mem::obs::{ObsStack, TraceDoc};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::cluster as render;
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::models::RoleSet;
use rlhf_mem::rlhf::program::{Algo, Sharing};
use rlhf_mem::rlhf::sim::{ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::model_set_by_name;
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::cli::{split_list, Args, CommonArgs};
use rlhf_mem::util::json::Json;

pub const CLUSTER_USAGE: &str = "\
rlhf-mem cluster — simulate RLHF model placement over a multi-GPU node:
per-GPU peak reserved + modeled step time per placement plan

FLAGS (comma-separated lists):
  --gpus 2,4                     node sizes to sweep (each >= 2; default 2,4)
  --plans colocated,time-shared,dedicated   placement presets (default all)
  --strategies none,zero1,zero2,zero3,offload,ckpt,all   (default none,zero3)
  --algos ppo,grpo,remax,dpo     RLHF algorithms (default ppo)
  --sharings separate,lora,hydra,frozen-shared,perl   model-sharing placements
                                 (default separate)
  --framework ds|cc              framework profile (default ds)
  --models opt|gpt2|nano         model pair (default opt)
  --steps N        PPO steps per configuration (default 2)
  --capacity-gib N simulated HBM per GPU (default 24)
  --gpu rtx3090|a100             time-model GPU (default rtx3090)
  --seed N         response-length seed (default 0x5EED)
  --jobs N         worker threads (default: all cores)
  --detail         also print the per-GPU breakdown table
  --jsonl FILE     one deterministic JSON line per configuration
  --json FILE      the whole report as one JSON array
  --trace-out FILE Perfetto trace of the first configuration: one track
                   per rank plus collective/P2P flow arrows
                   (open in ui.perfetto.dev)
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{CLUSTER_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, 0x5EED)?;

    let worlds: Vec<u64> = split_list(args.get_or("gpus", "2,4"))
        .map(|n| {
            n.parse::<u64>()
                .map_err(|_| format!("bad --gpus entry '{n}'"))
                .and_then(|w| {
                    if w >= 2 {
                        Ok(w)
                    } else {
                        Err(format!("--gpus entries must be >= 2 (got {w})"))
                    }
                })
        })
        .collect::<Result<_, _>>()?;

    let plan_names: Vec<&str> =
        split_list(args.get_or("plans", "colocated,time-shared,dedicated")).collect();

    let strategies: Vec<(&'static str, StrategyConfig)> =
        split_list(args.get_or("strategies", "none,zero3"))
            .map(|n| StrategyConfig::by_name(n).ok_or_else(|| format!("unknown strategy '{n}'")))
            .collect::<Result<_, _>>()?;

    let algos: Vec<Algo> = Algo::parse_list(args.get_or("algos", "ppo"))?;
    let sharings: Vec<Sharing> = Sharing::parse_list(args.get_or("sharings", "separate"))?;

    let fw_name = args.get_or("framework", "ds");
    let kind = FrameworkKind::by_name(fw_name)
        .ok_or_else(|| format!("unknown framework '{fw_name}'"))?;
    let profile = FrameworkProfile::by_kind(kind);

    let model_name = args.get_or("models", "opt");
    let (_mlabel, models) =
        model_set_by_name(model_name).ok_or_else(|| format!("unknown model set '{model_name}'"))?;

    let gpu_name = args.get_or("gpu", "rtx3090");
    let gpu = GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?;
    let steps = args.get_u64("steps", 2)?;
    let capacity = args.get_u64("capacity-gib", 24)? * GIB;
    let seed = common.seed;

    // Enumerate configurations (world -> plan -> strategy -> algo ->
    // sharing); the shared coordinator engine lowers each GPU to a sweep
    // cell and aggregates.
    let mut configs: Vec<ClusterConfig> = Vec::new();
    for &world in &worlds {
        for plan_name in &plan_names {
            let plan = PlacementPlan::by_name(plan_name, world)?;
            for (label, strategy) in &strategies {
                if !profile.supports(strategy) {
                    continue;
                }
                for &algo in &algos {
                    for &sharing in &sharings {
                        let base = SimScenario {
                            framework: profile.clone(),
                            models: models.clone(),
                            strategy: *strategy,
                            world,
                            policy: EmptyCachePolicy::Never,
                            steps,
                            mode: ScenarioMode::Full,
                            algo,
                            sharing,
                            gpu,
                            seed,
                            len_jitter: kind.default_len_jitter(),
                            roles: RoleSet::ALL,
                            time_shared: RoleSet::EMPTY,
                            rank: 0,
                        };
                        configs.push(ClusterConfig {
                            key: cluster_key(world, &plan.name, label, algo, sharing),
                            strategy_label: label.to_string(),
                            plan: plan.clone(),
                            base,
                        });
                    }
                }
            }
        }
    }
    if configs.is_empty() {
        return Err("cluster sweep is empty (no supported plan x strategy)".to_string());
    }
    let traces: u64 = configs.iter().map(|c| c.plan.gpus()).sum();
    println!(
        "cluster: {} configurations ({} GPU traces)",
        configs.len(),
        traces
    );

    let batch = run_configs(&configs, capacity, common.jobs)?;
    let runs: Vec<(String, ClusterRun)> = configs
        .iter()
        .map(|c| c.key.clone())
        .zip(batch.runs)
        .collect();

    println!("{}", render::summary_table(&runs).render());
    if args.bool_flag("detail") {
        println!("== per-GPU breakdown ==");
        println!("{}", render::gpu_table(&runs).render());
    }
    let ooms = runs.iter().filter(|(_, r)| r.oom()).count();
    println!(
        "({} configurations, {} GPU traces in {:.2}s on {} worker{}, {} OOM)",
        runs.len(),
        batch.cells,
        batch.wall_seconds,
        batch.jobs,
        if batch.jobs == 1 { "" } else { "s" },
        ooms
    );

    if let Some(path) = &common.jsonl {
        std::fs::write(path, render::jsonl(&runs)).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = &common.trace_out {
        let (key, run) = &runs[0];
        let doc = cluster_trace(&configs[0], run, capacity, steps);
        std::fs::write(path, doc.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path} — trace of '{key}' (open in ui.perfetto.dev)");
    }
    if let Some(path) = &common.json {
        let doc = Json::Arr(
            runs.iter()
                .map(|(key, run)| {
                    let mut fields: Vec<(String, Json)> =
                        vec![("key".to_string(), Json::str(key.clone()))];
                    if let Json::Obj(kvs) = run.to_json() {
                        fields.extend(kvs);
                    }
                    Json::Obj(fields)
                })
                .collect(),
        );
        std::fs::write(path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Record one configuration's per-rank Perfetto traces (every GPU of the
/// plan replays its own trace, each on its own `pid` track), merge them,
/// and draw the modeled per-step collective/P2P costs as flow arrows
/// between the rank tracks. Everything is derived from simulated time and
/// the deterministic step-time model — two invocations emit byte-identical
/// documents.
fn cluster_trace(config: &ClusterConfig, run: &ClusterRun, capacity: u64, steps: u64) -> TraceDoc {
    let mut merged = TraceDoc::new();
    for g in 0..config.plan.gpus() as usize {
        let scn = config.plan.scenario_for_gpu(&config.base, g);
        let mut obs = ObsStack::new().record_perfetto(g as u64);
        let outcome = run_scenario_observed(&scn, capacity, &AllocatorConfig::default(), &mut obs);
        let doc = obs
            .finish_perfetto(outcome.end_time_us)
            .expect("recorder was armed above");
        merged.merge(doc);
    }
    for step in 1..=steps {
        let t = step as f64 * run.step_time_us;
        for g in 1..config.plan.gpus() {
            merged.flow("experience p2p", 0, t, g, t + run.p2p_us, run.p2p_us);
            merged.flow(
                "grad allreduce",
                g,
                t + run.p2p_us,
                0,
                t + run.p2p_us + run.collective_us,
                run.collective_us,
            );
        }
    }
    merged
}
