//! `rlhf-mem debug` — calibration lens: ideal residency composition at the
//! peak, per-phase ideal peaks, and the fragmentation samples near the
//! reserved peak.

use rlhf_mem::experiment::{run_trace, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::analysis::{peak_composition, phase_peaks};
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let strat_name = args.get_or("strategy", "none");
    let (_, strat) = StrategyConfig::by_name(strat_name)
        .ok_or_else(|| format!("unknown strategy {strat_name}"))?;
    let policy = if args.bool_flag("ec") { EmptyCachePolicy::AfterBoth } else { EmptyCachePolicy::Never };
    let mut scn = SimScenario::deepspeed_opt(strat, policy);
    scn.steps = args.get_u64("steps", 2)?;
    if args.get_or("framework", "ds").starts_with("c") {
        scn.framework = rlhf_mem::frameworks::FrameworkProfile::colossal_chat();
        if args.get_or("model", "opt") == "gpt2" {
            scn.models = rlhf_mem::rlhf::models::RlhfModelSet::gpt2();
        }
    }
    let trace = build_trace(&scn);

    let comp = peak_composition(&trace);
    println!("== ideal residency peak: {} in {} ==", fmt_bytes(comp.total), comp.phase.name());
    for (tag, bytes) in &comp.by_tag {
        if *bytes > 0 {
            println!("  {:<18} {}", tag.name(), fmt_bytes(*bytes));
        }
    }
    println!("\n== per-phase ideal peaks ==");
    for (phase, bytes) in phase_peaks(&trace) {
        println!("  {:<18} {}", phase.name(), fmt_bytes(bytes));
    }

    let res = run_trace(&trace, RTX3090_HBM);
    let s = &res.summary;
    println!("\n== allocator view ==");
    println!("  peak reserved {}   frag-at-peak {}   peak allocated {}   peak phase {}",
        fmt_bytes(s.peak_reserved), fmt_bytes(s.frag_at_peak), fmt_bytes(s.peak_allocated), s.peak_phase.name());
    println!("  cudaMallocs {}   frag (max sample) {}", s.cuda_mallocs, fmt_bytes(s.frag));
    // Top fragmentation samples.
    let mut samples = res.profiler.frag_samples.clone();
    samples.sort_by_key(|x| std::cmp::Reverse(x.frag));
    println!("\n== top frag samples (phase, frag, request) ==");
    for s in samples.iter().take(12) {
        println!("  {:<18} frag {:<12} req {}", s.phase.name(), fmt_bytes(s.frag), fmt_bytes(s.requested));
    }
    Ok(())
}
