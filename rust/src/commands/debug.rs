//! `rlhf-mem debug` — calibration lens: ideal residency composition at the
//! peak, per-phase ideal peaks, and the fragmentation samples near the
//! reserved peak.

use rlhf_mem::experiment::{run_trace, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::{Algo, PhaseProgram};
use rlhf_mem::rlhf::sim::ScenarioPreset;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::analysis::{peak_composition, phase_peaks};
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let strat_name = args.get_or("strategy", "none");
    let (_, strat) = StrategyConfig::by_name(strat_name)
        .ok_or_else(|| format!("unknown strategy {strat_name}"))?;
    let policy = if args.bool_flag("ec") { EmptyCachePolicy::AfterBoth } else { EmptyCachePolicy::Never };
    // Scenario presets carry the framework/model/jitter triple, so the
    // calibration lens sees exactly what the sweep cells see.
    let preset_name = if args.get_or("framework", "ds").starts_with('c') {
        if args.get_or("model", "opt") == "gpt2" { "colossal-gpt2" } else { "colossal-opt" }
    } else {
        "deepspeed-opt"
    };
    let mut scn = ScenarioPreset::by_name(preset_name)
        .expect("preset table covers the debug frameworks")
        .build(strat, policy);
    scn.steps = args.get_u64("steps", 2)?;
    let algo_name = args.get_or("algo", "ppo");
    scn.algo = Algo::by_name(algo_name)
        .ok_or_else(|| format!("unknown algo '{algo_name}' (valid: {})", Algo::known_names()))?;
    let program = PhaseProgram::compile(&scn);
    let trace = rlhf_mem::rlhf::sim::build_trace(&scn);

    let comp = peak_composition(&trace);
    println!("== ideal residency peak: {} in {} ==", fmt_bytes(comp.total), comp.phase.name());
    for (tag, bytes) in &comp.by_tag {
        if *bytes > 0 {
            println!("  {:<18} {}", tag.name(), fmt_bytes(*bytes));
        }
    }
    println!("\n== per-phase ideal peaks ==");
    for (phase, bytes) in phase_peaks(&trace) {
        println!("  {:<18} {}", phase.name(), fmt_bytes(bytes));
    }

    let res = run_trace(&trace, RTX3090_HBM);
    let s = &res.summary;
    println!("\n== allocator per-phase peaks ({} program order) ==", scn.algo.name());
    for (phase, peak) in res.profiler.phase_attribution(&program) {
        println!(
            "  {:<18} reserved {:<12} allocated {}",
            phase.name(),
            fmt_bytes(peak.reserved),
            fmt_bytes(peak.allocated)
        );
    }
    println!("\n== allocator view ==");
    println!("  peak reserved {}   frag-at-peak {}   peak allocated {}   peak phase {}",
        fmt_bytes(s.peak_reserved), fmt_bytes(s.frag_at_peak), fmt_bytes(s.peak_allocated), s.peak_phase.name());
    println!("  cudaMallocs {}   frag (max sample) {}", s.cuda_mallocs, fmt_bytes(s.frag));
    // Top fragmentation samples.
    let mut samples = res.profiler.frag_samples.clone();
    samples.sort_by_key(|x| std::cmp::Reverse(x.frag));
    println!("\n== top frag samples (phase, frag, request) ==");
    for s in samples.iter().take(12) {
        println!("  {:<18} frag {:<12} req {}", s.phase.name(), fmt_bytes(s.frag), fmt_bytes(s.requested));
    }
    Ok(())
}
