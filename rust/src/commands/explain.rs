//! `rlhf-mem explain <config.json>` — attribute a run's reserved peak:
//! who owns it (live census by tag / phase / role), what overhead
//! surrounds it (the exact five-way fragmentation decomposition), and
//! which knob shrinks each slice first.

use rlhf_mem::alloc::AllocatorConfig;
use rlhf_mem::config::ExperimentConfig;
use rlhf_mem::obs::{explain_scenario, ExplainOptions};
use rlhf_mem::util::cli::Args;

const USAGE: &str =
    "usage: rlhf-mem explain <config.json> [--json FILE] [--trace-out FILE] [--top-peaks K]";

pub fn run(args: &Args) -> Result<(), String> {
    let path = args.positional.first().ok_or(USAGE)?;
    let cfg = ExperimentConfig::from_file(path)?;

    let mut opts = ExplainOptions::default();
    if let Some(k) = args.flag("top-peaks") {
        opts.top_k = k
            .parse()
            .map_err(|_| format!("--top-peaks: not a count: {k}"))?;
    }
    if args.flag("trace-out").is_some() {
        opts.perfetto_pid = Some(0);
    }

    let out = explain_scenario(&cfg.scenario, cfg.capacity, &AllocatorConfig::default(), &opts);
    print!("{}", out.report.render());
    if out.report.summary.oom {
        println!("!! OOM — peak shown is where the replay died");
    }

    if let Some(file) = args.flag("json") {
        std::fs::write(file, out.report.to_json().to_string_pretty())
            .map_err(|e| e.to_string())?;
        println!("wrote {file}");
    }
    if let Some(file) = args.flag("trace-out") {
        let doc = out.perfetto.expect("recorder was armed above");
        std::fs::write(file, doc.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {file} (open in ui.perfetto.dev)");
    }
    Ok(())
}
