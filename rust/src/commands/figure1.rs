//! `rlhf-mem figure1` — regenerate Figure 1: the memory timeline of
//! DeepSpeed-Chat/OPT with all strategies enabled, annotated with the
//! reserved peak (red cross), the fragmentation there, and the
//! "reserved w/o fragmentation" level (yellow cross).
//!
//! A one-cell sweep with profile capture on: the engine hands back the
//! full [`rlhf_mem::profiler::MemoryProfiler`] so the timeline chart and
//! CSV render exactly as the serial path did.

use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let cells = SweepGrid::new()
        .strategies([("All Enabled", StrategyConfig::all_enabled())])
        .policies([EmptyCachePolicy::Never])
        .steps(steps)
        .build()?;
    let report = SweepRunner::new(1).capture_profiles(true).run(cells);
    let cell = &report.cells[0];
    let s = &cell.summary;
    let profiler = cell.profiler.as_ref().expect("profile capture enabled");

    println!("Figure 1 — DeepSpeed-Chat/OPT, ZeRO-3 + offload + checkpointing, {steps} PPO steps");
    println!("{}", profiler.timeline.ascii_chart(110, 16));
    println!();
    println!("  peak reserved (red cross)        : {}", fmt_bytes(s.peak_reserved));
    println!("  reserved w/o frag (yellow cross) : {}", fmt_bytes(s.reserved_wo_frag()));
    println!("  memory fragmentation overhead    : {} (+{:.0}%)", fmt_bytes(s.frag), s.frag_overhead_ratio() * 100.0);
    println!("  phase of the peak                : {}", s.peak_phase.name());

    if let Some(path) = args.flag("csv") {
        std::fs::write(path, profiler.timeline.to_csv()).map_err(|e| e.to_string())?;
        println!("  timeline csv -> {path}");
    }

    if args.bool_flag("assert") {
        // E9 acceptance: the peak lands in the PPO work phases (the paper
        // reports training; our leaner training inventory sometimes puts it
        // at the inference/training boundary) and fragmentation overhead is
        // substantial (paper: +46% under its Appendix-B metric; our
        // conditional-sample rendering of the same metric measures lower —
        // see EXPERIMENTS.md E1).
        if !(s.peak_phase.is_training() || s.peak_phase.is_inference()) {
            return Err(format!("peak phase {} is not a PPO work phase", s.peak_phase.name()));
        }
        let ratio = s.frag_overhead_ratio();
        if !(0.08..=1.2).contains(&ratio) {
            return Err(format!("frag overhead ratio {ratio:.2} outside the acceptance band"));
        }
        println!("  assertions OK (peak in {}, frag overhead {:.0}%)", s.peak_phase.name(), ratio * 100.0);
    }
    Ok(())
}
