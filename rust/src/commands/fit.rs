//! `rlhf-mem fit` — mine the sweep traces of a budget's candidate
//! product into a closed-form surrogate (`SURROGATE.json`), the screening
//! tier of `advise --surrogate`.
//!
//! ```text
//! rlhf-mem fit --budget examples/budget_rtx3090.json --out SURROGATE.json
//! ```
//!
//! The artifact is *derived state*, not source: it certifies the exact
//! build, budget provenance and `steps` values it was fitted on, and
//! `advise --surrogate` falls back to plain simulation (or errors on
//! refuted certificates) when anything drifted. Refit whenever the
//! simulator, the candidate axes, or the budget changes — CI regenerates
//! it fresh on every run rather than committing it.

use rlhf_mem::planner::Budget;
use rlhf_mem::surrogate::{fit, FitOptions};
use rlhf_mem::sweep::SweepRunner;
use rlhf_mem::util::cli::{split_list, Args};

pub const FIT_USAGE: &str = "\
rlhf-mem fit — fit the planner's surrogate model from simulated sweep cells

Runs every candidate of the budget's sharing × strategy × empty_cache ×
allocator product (once per --steps value) and fits, per candidate, an
affine model of each memory/time target with a residual envelope strictly
wider than every in-sample error. `advise --surrogate` then screens the
space against the artifact and simulates only candidates within the
envelope of the Pareto frontier.

FLAGS:
  --budget FILE    JSON budget spec (default: the paper's RTX-3090 testbed)
  --steps LIST     comma-separated steps ladder to fit across, e.g. 1,2,3
                   (default: the budget's own steps value)
  --jobs N         sweep worker threads (default: all cores)
  --out FILE       artifact path (default SURROGATE.json)
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{FIT_USAGE}");
        return Ok(());
    }
    let budget = match args.flag("budget") {
        Some(path) => Budget::from_file(path)?,
        None => Budget::rtx3090_table1(),
    };
    let jobs = args.get_usize("jobs", SweepRunner::default_jobs())?;
    let out = args.flag("out").unwrap_or("SURROGATE.json");

    let opts = match args.flag("steps") {
        Some(list) => {
            let steps = split_list(list)
                .map(|s| {
                    s.parse::<u64>()
                        .map_err(|e| format!("--steps entry '{s}': {e}"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            FitOptions { steps }
        }
        None => FitOptions::for_budget(&budget),
    };

    println!(
        "fit: budget '{}' — {} / {}, steps {:?}, {} worker{}",
        budget.name,
        budget.framework.name(),
        budget.models.policy_arch.name,
        opts.steps,
        jobs,
        if jobs == 1 { "" } else { "s" },
    );
    let model = fit(&budget, jobs, &opts)?;
    let oom_groups = model.groups.iter().filter(|g| !g.oom_steps.is_empty()).count();
    println!(
        "fitted {} groups from {} cells in {:.2}s (max rel err {:.6}, {} group(s) with OOM steps)",
        model.groups.len(),
        model.cells,
        model.wall_seconds,
        model.max_rel_err,
        oom_groups,
    );
    std::fs::write(out, model.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
    println!("wrote {out}");
    Ok(())
}
