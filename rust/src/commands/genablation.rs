//! `rlhf-mem gen-ablation` — Appendix B: the original ColossalChat
//! `generation()` keeps the cumulative [b, s, vocab] logits each step and
//! was "exceptionally high" in memory; the paper replaced it with
//! HuggingFace's implementation. This regenerates that comparison.
//!
//! The two generation variants aren't a cartesian axis, so they enter the
//! grid as explicit [`rlhf_mem::sweep::SweepGrid::push_scenario`] cells;
//! profile capture keeps the per-phase peaks the comparison needs.

use rlhf_mem::frameworks::GenerationImpl;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::table::TextTable;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 2)?;
    let jobs = args.get_usize("jobs", SweepRunner::default_jobs())?;

    // Empty the cartesian axes: only the pushed variants run.
    let mut grid = SweepGrid::new().strategies(Vec::<(&str, StrategyConfig)>::new());
    for (label, imp) in [
        ("HuggingFace (paper's fix)", GenerationImpl::HuggingFace),
        ("ColossalChat original", GenerationImpl::ColossalOriginal),
    ] {
        let mut scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.framework.generation = imp;
        scn.steps = steps;
        grid = grid.push_scenario("ColossalChat", "OPT", label, scn);
    }
    let report = SweepRunner::new(jobs).capture_profiles(true).run(grid.build()?);

    let mut t = TextTable::new(&["generation()", "Reserved", "Frag.", "Allocated", "Gen-phase peak"]);
    let mut peaks = Vec::new();
    for cell in &report.cells {
        let gen_peak = cell
            .profiler
            .as_ref()
            .and_then(|p| p.phase_peaks.get(&rlhf_mem::trace::PhaseKind::Generation))
            .map(|p| p.allocated)
            .unwrap_or(0);
        peaks.push(gen_peak);
        t.row(vec![
            cell.strategy.clone(),
            fmt_gib_paper(cell.summary.peak_reserved),
            fmt_gib_paper(cell.summary.frag),
            fmt_gib_paper(cell.summary.peak_allocated),
            fmt_gib_paper(gen_peak),
        ]);
    }
    println!("Appendix-B generation() ablation — ColossalChat/OPT (GiB)");
    println!("{}", t.render());
    if peaks[1] <= peaks[0] {
        return Err("original impl should peak higher during generation".into());
    }
    println!(
        "original generation() uses {:.1}x the generation-phase memory — why Appendix B replaced it",
        peaks[1] as f64 / peaks[0].max(1) as f64
    );
    Ok(())
}
