//! `rlhf-mem lint <config.json>` — statically verify a configuration
//! without simulating it: phase-program dataflow, sharing ownership,
//! placement collectives (`--plan`), and the abstract peak bounds
//! against the config's `capacity_gib`. Non-zero exit when any finding
//! resolves to `deny`.

use rlhf_mem::config::ExperimentConfig;
use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::lint::{lint_plan, lint_scenario, LintConfig};
use rlhf_mem::report;
use rlhf_mem::util::cli::Args;

const USAGE: &str = "usage: rlhf-mem lint <config.json> [--plan NAME] [--gpus N] \
                     [--deny LIST] [--warn LIST] [--allow LIST] [--json FILE]";

pub fn run(args: &Args) -> Result<(), String> {
    let path = args.positional.first().ok_or(USAGE)?;
    let cfg = ExperimentConfig::from_file(path)?;
    let lc = LintConfig::from_lists(
        args.get_or("deny", ""),
        args.get_or("warn", ""),
        args.get_or("allow", ""),
    )?;

    let report = if let Some(plan_name) = args.flag("plan") {
        let gpus = args.get_u64("gpus", cfg.scenario.world)?;
        let plan = PlacementPlan::by_name(plan_name, gpus)?;
        lint_plan(&cfg.scenario, &plan, cfg.capacity, &lc)
    } else {
        lint_scenario(&cfg.scenario, cfg.capacity, &lc)
    };

    print!("{}", report::lint::render(&report));
    println!(
        "lint: {} deny, {} warn",
        report.deny_count(),
        report.warn_count()
    );

    if let Some(file) = args.flag("json") {
        std::fs::write(file, report.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {file}");
    }

    if report.deny_count() > 0 {
        return Err(format!(
            "lint failed with {} deny finding(s)",
            report.deny_count()
        ));
    }
    Ok(())
}
