//! CLI subcommand implementations. The paper commands are thin grid
//! definitions over [`rlhf_mem::sweep`]; `sweep` exposes user-defined
//! grids; `train` (behind the `pjrt` feature) drives the real-compute
//! half.

pub mod ablation;
pub mod advise;
pub mod algos;
pub mod bench;
pub mod cluster;
pub mod debug;
pub mod explain;
pub mod fit;
pub mod genablation;
pub mod lint;
pub mod profile;
pub mod figure1;
pub mod overhead;
pub mod peft;
pub mod phases;
pub mod quickstart;
pub mod sweep;
pub mod table1;
pub mod table2;
#[cfg(feature = "pjrt")]
pub mod train;
