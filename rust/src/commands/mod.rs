//! CLI subcommand implementations.

pub mod ablation;
pub mod debug;
pub mod genablation;
pub mod profile;
pub mod figure1;
pub mod overhead;
pub mod phases;
pub mod quickstart;
pub mod table1;
pub mod table2;
pub mod train;
