//! CLI subcommand implementations. The paper commands are thin grid
//! definitions over [`rlhf_mem::sweep`]; `sweep` exposes user-defined
//! grids; `train` (behind the `pjrt` feature) drives the real-compute
//! half.

pub mod ablation;
pub mod advise;
pub mod algos;
pub mod bench;
pub mod cluster;
pub mod debug;
pub mod explain;
pub mod fit;
pub mod genablation;
pub mod lint;
pub mod profile;
pub mod figure1;
pub mod overhead;
pub mod peft;
pub mod phases;
pub mod quickstart;
pub mod serve;
pub mod sweep;
pub mod table1;
pub mod table2;
#[cfg(feature = "pjrt")]
pub mod train;

#[cfg(test)]
mod tests {
    /// Golden `--help` snapshots: the CLI surface of every CommonArgs
    /// command is pinned byte-for-byte. A failing case means a flag was
    /// renamed or re-spelled — update the snapshot file under
    /// `rust/src/commands/snapshots/` only when the change is deliberate.
    #[test]
    fn help_snapshots_pin_the_cli_surface() {
        for (name, usage, snapshot) in [
            (
                "sweep",
                super::sweep::SWEEP_USAGE,
                include_str!("snapshots/sweep_help.txt"),
            ),
            (
                "advise",
                super::advise::ADVISE_USAGE,
                include_str!("snapshots/advise_help.txt"),
            ),
            (
                "cluster",
                super::cluster::CLUSTER_USAGE,
                include_str!("snapshots/cluster_help.txt"),
            ),
            (
                "peft",
                super::peft::PEFT_USAGE,
                include_str!("snapshots/peft_help.txt"),
            ),
            (
                "algos",
                super::algos::ALGOS_USAGE,
                include_str!("snapshots/algos_help.txt"),
            ),
            (
                "serve",
                super::serve::SERVE_USAGE,
                include_str!("snapshots/serve_help.txt"),
            ),
        ] {
            assert_eq!(
                usage, snapshot,
                "--help surface for '{name}' drifted from \
                 rust/src/commands/snapshots/{name}_help.txt"
            );
        }
    }
}
