//! `rlhf-mem overhead` — §3.3 (E8): memory saved vs end-to-end time cost of
//! empty_cache() across the paper's bold Table-1 rows.

use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::measure_row_full;
use rlhf_mem::report::table::TextTable;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::stats::geomean;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let mut t = TextTable::new(&["Row", "Mem saved %", "Time overhead %"]);
    let mut savings = Vec::new();
    let mut overheads = Vec::new();
    let rows: Vec<(&str, SimScenario)> = vec![
        ("DS/OPT ZeRO-3", SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never)),
        ("DS/OPT ZeRO-3+Offload", SimScenario::deepspeed_opt(StrategyConfig::zero3_offload(), EmptyCachePolicy::Never)),
        ("DS/OPT All", SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never)),
        ("CC/OPT Ckpt", SimScenario::colossal_opt(StrategyConfig::checkpointing(), EmptyCachePolicy::Never)),
        ("CC/GPT2 None", SimScenario::colossal_gpt2(StrategyConfig::none(), EmptyCachePolicy::Never)),
        ("CC/GPT2 ZeRO-3", SimScenario::colossal_gpt2(StrategyConfig::zero3(), EmptyCachePolicy::Never)),
    ];
    for (label, mut scn) in rows {
        scn.steps = steps;
        let (row, orig, ec) = measure_row_full(label, &scn, RTX3090_HBM);
        let saved = 1.0 - row.with_empty_cache.peak_reserved as f64 / row.original.peak_reserved as f64;
        let overhead = ec.summary.total_time_us / orig.summary.total_time_us - 1.0;
        savings.push(f64::max(1.0 - saved, 1e-9));
        overheads.push(f64::max(1.0 + overhead, 1e-9));
        t.row(vec![
            label.to_string(),
            format!("{:.1}", saved * 100.0),
            format!("{:.2}", overhead * 100.0),
        ]);
    }
    println!("§3.3 empty_cache cost/benefit — {steps} steps");
    println!("{}", t.render());
    let mem = (1.0 - geomean(&savings)) * 100.0;
    let time = (geomean(&overheads) - 1.0) * 100.0;
    println!("geomean memory saved: {mem:.1}%   (paper: ~25% on bold rows)");
    println!("geomean time overhead: {time:.2}% (paper: ~2%)");
    Ok(())
}
