//! `rlhf-mem peft` — the model-sharing comparison: sweep the sharing
//! axis (separate replicas / shared-LoRA / hydra heads / frozen-shared)
//! against a strategy list and print peak reserved + modeled step time
//! per placement, per strategy.
//!
//! ```text
//! rlhf-mem peft --strategies none,zero3 --steps 2 --jobs 8 \
//!               --jsonl peft.jsonl --compare-paper
//! ```
//!
//! The placements come from [`rlhf_mem::rlhf::program::Sharing`]: `lora`
//! freezes one backbone per actor/reference and critic/reward pair and
//! trains per-role adapters; `hydra` hosts every role on one frozen
//! backbone with task heads. `--compare-paper` gates the run on the
//! Efficient-RLHF (arXiv 2309.00754) ordering — Hydra-PPO under
//! LoRA-PPO under full-PPO — and on the headline memory-reduction band.

use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::peft::comparison_table;
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::program::{Algo, Sharing};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{model_set_by_name, CellResult, SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::cli::{split_list, Args, CommonArgs};

pub const PEFT_USAGE: &str = "\
rlhf-mem peft — compare model-sharing placements' memory behaviour per
strategy (peak reserved + modeled step-time columns per placement)

FLAGS (comma-separated lists):
  --sharings separate,lora,hydra,frozen-shared,perl   placement columns
                                 (default separate,lora,hydra)
  --algos ppo,grpo,remax,dpo     one table per algorithm (default ppo)
  --strategies none,zero1,zero2,zero3,offload,ckpt,all   (default none,zero3)
  --framework ds|cc              framework profile (default ds)
  --models opt|gpt2|nano         model pair (default opt)
  --steps N        PPO steps per cell (default 2)
  --world N        data-parallel ranks (default 4)
  --capacity-gib N simulated HBM per GPU (default 24)
  --gpu rtx3090|a100             time-model GPU (default rtx3090)
  --seed N         response-length seed (default 0x5EED)
  --jobs N         worker threads (default: all cores)
  --jsonl FILE     write per-cell JSON-lines (index-ordered)
  --compare-paper  gate on the Efficient-RLHF ordering (hydra <= lora <
                   separate peak reserved) and reduction band; exits
                   non-zero when the reproduction drifts
";

/// The gated band for the hydra-vs-separate peak-reserved reduction on
/// the un-sharded (`None`) strategy row. Efficient-RLHF reports ~65%
/// less memory for Hydra-PPO; peak reserved also carries activations
/// and KV caches the backbone trick cannot touch, so the band is wide.
const REDUCTION_BAND: (f64, f64) = (0.30, 0.85);

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{PEFT_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, 0x5EED)?;

    let sharings: Vec<Sharing> =
        Sharing::parse_list(args.get_or("sharings", "separate,lora,hydra"))?;
    let algos: Vec<Algo> = Algo::parse_list(args.get_or("algos", "ppo"))?;

    let strategies: Vec<(&'static str, StrategyConfig)> =
        split_list(args.get_or("strategies", "none,zero3"))
            .map(|n| StrategyConfig::by_name(n).ok_or_else(|| format!("unknown strategy '{n}'")))
            .collect::<Result<_, _>>()?;

    let fw_name = args.get_or("framework", "ds");
    let kind = FrameworkKind::by_name(fw_name)
        .ok_or_else(|| format!("unknown framework '{fw_name}'"))?;

    let model_name = args.get_or("models", "opt");
    let models =
        model_set_by_name(model_name).ok_or_else(|| format!("unknown model set '{model_name}'"))?;

    let mut grid = SweepGrid::new()
        .frameworks([kind])
        .model_sets([models])
        .strategies(strategies)
        .policies([EmptyCachePolicy::Never])
        .algos(algos.clone())
        .sharings(sharings.clone())
        .steps(args.get_u64("steps", 2)?)
        .world(args.get_u64("world", 4)?)
        .capacity(args.get_u64("capacity-gib", 24)? * GIB)
        .seeds(rlhf_mem::sweep::SeedPolicy::Fixed(common.seed));
    let gpu_name = args.get_or("gpu", "rtx3090");
    grid = grid.gpu(GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?);

    let cells = grid.build()?;
    if cells.is_empty() {
        return Err("peft grid is empty (axes selected no cells)".to_string());
    }
    println!("peft: {} cells", cells.len());

    let report = SweepRunner::new(common.jobs).run(cells);

    for &algo in &algos {
        if algos.len() > 1 {
            println!("== {} ==", algo.name());
        }
        println!("{}", comparison_table(&report.cells, &sharings, algo).render());
    }
    println!("({})", report.summary_line());
    println!(
        "Expectation: shared frozen backbones (lora/hydra) reserve a fraction of\n\
         the full-replica bill — one backbone instead of four, adapter-only\n\
         optimizer state — at a small modeled step-time premium."
    );
    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if args.bool_flag("compare-paper") {
        compare_paper(&report.cells, &algos)?;
    }
    Ok(())
}

/// The `--compare-paper` gate: on the un-sharded (`None`) strategy row,
/// every algorithm must reproduce the Efficient-RLHF ordering
/// `hydra <= lora < separate` (DPO's hydra and lora placements coincide,
/// so the first comparison is not strict), and the hydra-vs-separate
/// peak-reserved reduction must land in [`REDUCTION_BAND`].
fn compare_paper(cells: &[CellResult], algos: &[Algo]) -> Result<(), String> {
    let peak = |algo: Algo, sharing: &str| -> Result<u64, String> {
        cells
            .iter()
            .find(|c| c.algo == algo.name() && c.sharing == sharing && c.strategy == "None")
            .map(|c| c.summary.peak_reserved)
            .ok_or_else(|| {
                format!(
                    "--compare-paper needs the '{sharing}' column and the 'none' strategy \
                     for algo '{}' (widen --sharings/--strategies)",
                    algo.name()
                )
            })
    };
    for &algo in algos {
        let separate = peak(algo, "separate")?;
        let lora = peak(algo, "lora")?;
        let hydra = peak(algo, "hydra")?;
        if !(hydra <= lora && lora < separate) {
            return Err(format!(
                "paper ordering violated for {}: hydra {} <= lora {} < separate {} \
                 (peak reserved bytes)",
                algo.name(),
                hydra,
                lora,
                separate
            ));
        }
        let reduction = 1.0 - hydra as f64 / separate as f64;
        println!(
            "paper anchor [{}]: hydra reserves {:.0}% less than separate \
             (Efficient-RLHF reports ~65% on persistent memory)",
            algo.name(),
            reduction * 100.0
        );
        if !(REDUCTION_BAND.0..=REDUCTION_BAND.1).contains(&reduction) {
            return Err(format!(
                "hydra reduction {:.2} for {} outside the gated band [{}, {}]",
                reduction,
                algo.name(),
                REDUCTION_BAND.0,
                REDUCTION_BAND.1
            ));
        }
    }
    println!("--compare-paper: ordering and reduction band hold");
    Ok(())
}
