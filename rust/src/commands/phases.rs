//! `rlhf-mem phases` — §3.1 (E6): compare (1) the full pipeline, (2)
//! training both models on pre-collected data, (3) training only the
//! actor. Shows that inference phases, not training, accumulate the
//! fragmentation that dominates the peak.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::table::TextTable;
use rlhf_mem::rlhf::sim::{ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_gib_paper;
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let mut t = TextTable::new(&["Scenario", "Reserved", "Frag.", "Allocated", "Peak phase"]);
    for (label, mode) in [
        ("(1) inference + training", ScenarioMode::Full),
        ("(2) train actor+critic (pre-collected)", ScenarioMode::TrainBothPrecollected),
        ("(3) train actor only (pre-collected)", ScenarioMode::TrainActorOnly),
    ] {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
        scn.steps = steps;
        scn.mode = mode;
        let res = run_scenario(&scn, RTX3090_HBM);
        let s = res.summary;
        t.row(vec![
            label.to_string(),
            fmt_gib_paper(s.peak_reserved),
            fmt_gib_paper(s.frag),
            fmt_gib_paper(s.peak_allocated),
            s.peak_phase.name().to_string(),
        ]);
    }
    println!("§3.1 phase attribution — DeepSpeed-Chat/OPT, all strategies, {steps} steps (GiB)");
    println!("{}", t.render());
    println!("Expectation (paper): scenario (1) shows the largest fragmentation and reserved;");
    println!("training-only scenarios show smaller fragmentation and reserved memory.");
    Ok(())
}
