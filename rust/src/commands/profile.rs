//! `rlhf-mem profile <config.json>` — run a user-defined experiment from a
//! JSON config (see `config/mod.rs` for the schema) and print the profile.

use rlhf_mem::config::ExperimentConfig;
use rlhf_mem::experiment::run_scenario;
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::Json;

pub fn run(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("usage: rlhf-mem profile <config.json>")?;
    let cfg = ExperimentConfig::from_file(path)?;
    let res = run_scenario(&cfg.scenario, cfg.capacity);
    let s = &res.summary;
    println!(
        "{} / {} + {} / {} / {} / world {}",
        cfg.scenario.framework.kind.name(),
        cfg.scenario.models.policy_arch.name,
        cfg.scenario.models.value_arch.name,
        cfg.scenario.strategy.label(),
        cfg.scenario.algo.name(),
        cfg.scenario.world
    );
    println!("  peak reserved : {}", fmt_bytes(s.peak_reserved));
    println!("  fragmentation : {}", fmt_bytes(s.frag));
    println!("  peak allocated: {}", fmt_bytes(s.peak_allocated));
    println!("  peak phase    : {}", s.peak_phase.name());
    println!("  sim time      : {:.2} s", s.total_time_us / 1e6);
    if s.oom {
        println!("  !! OOM — the workload does not fit the configured device");
    }
    if args.bool_flag("chart") {
        println!("\n{}", res.profiler.timeline.ascii_chart(100, 14));
    }
    if let Some(out) = args.flag("json") {
        let doc = Json::obj(vec![
            ("reserved", Json::from(s.peak_reserved)),
            ("frag", Json::from(s.frag)),
            ("allocated", Json::from(s.peak_allocated)),
            ("peak_phase", Json::str(s.peak_phase.name())),
            ("oom", Json::from(s.oom)),
        ]);
        std::fs::write(out, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}
