//! `rlhf-mem profile <config.json>` — run a user-defined experiment from a
//! JSON config (see `config/mod.rs` for the schema) and print the profile.

use rlhf_mem::alloc::AllocatorConfig;
use rlhf_mem::config::ExperimentConfig;
use rlhf_mem::experiment::run_scenario_observed;
use rlhf_mem::obs::{profile_doc, ObsStack};
use rlhf_mem::profiler::MemoryProfiler;
use rlhf_mem::rlhf::program::PhaseProgram;
use rlhf_mem::util::bytes::{fmt_bytes, MIB};
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("usage: rlhf-mem profile <config.json>")?;
    let cfg = ExperimentConfig::from_file(path)?;

    let profiler = match args.flag("timeline-resolution") {
        Some(mib) => {
            let mib: u64 = mib
                .parse()
                .map_err(|_| format!("--timeline-resolution: not a MiB count: {mib}"))?;
            MemoryProfiler::with_timeline_resolution(mib * MIB)
        }
        None => MemoryProfiler::new(),
    };
    let mut obs = ObsStack::with_profiler(profiler);
    if args.flag("trace-out").is_some() {
        obs = obs.record_perfetto(0);
    }
    let outcome = run_scenario_observed(
        &cfg.scenario,
        cfg.capacity,
        &AllocatorConfig::default(),
        &mut obs,
    );
    let s = &outcome.summary;
    println!(
        "{} / {} + {} / {} / {} / world {}",
        cfg.scenario.framework.kind.name(),
        cfg.scenario.models.policy_arch.name,
        cfg.scenario.models.value_arch.name,
        cfg.scenario.strategy.label(),
        cfg.scenario.algo.name(),
        cfg.scenario.world
    );
    println!("  peak reserved : {}", fmt_bytes(s.peak_reserved));
    println!("  fragmentation : {}", fmt_bytes(s.frag));
    println!("  peak allocated: {}", fmt_bytes(s.peak_allocated));
    println!("  peak phase    : {}", s.peak_phase.name());
    println!("  sim time      : {:.2} s", s.total_time_us / 1e6);
    if s.oom {
        println!("  !! OOM — the workload does not fit the configured device");
    }
    if args.bool_flag("chart") {
        println!("\n{}", obs.profiler.timeline.ascii_chart(100, 14));
    }
    if let Some(out) = args.flag("json") {
        let program = PhaseProgram::compile(&cfg.scenario);
        let doc = profile_doc(s, &obs.profiler, &program);
        std::fs::write(out, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    if let Some(out) = args.flag("trace-out") {
        let doc = obs
            .finish_perfetto(outcome.end_time_us)
            .expect("recorder was armed above");
        std::fs::write(out, doc.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out} (open in ui.perfetto.dev)");
    }
    Ok(())
}
