//! `rlhf-mem quickstart` — a fast smoke run: one PPO step of the
//! DeepSpeed-Chat/OPT scenario with the profiler attached, printing the
//! summary and a small timeline chart.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::fmt_bytes;
use rlhf_mem::util::cli::Args;

pub fn run(_args: &Args) -> Result<(), String> {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
    scn.steps = 1;
    let res = run_scenario(&scn, RTX3090_HBM);
    let s = &res.summary;
    println!("DeepSpeed-Chat / OPT / All-Enabled — 1 PPO step on a simulated 24 GiB GPU");
    println!("  peak reserved : {}", fmt_bytes(s.peak_reserved));
    println!("  fragmentation : {} ({:.0}% overhead)", fmt_bytes(s.frag), s.frag_overhead_ratio() * 100.0);
    println!("  peak allocated: {}", fmt_bytes(s.peak_allocated));
    println!("  peak phase    : {}", s.peak_phase.name());
    println!("  cudaMallocs   : {}", s.cuda_mallocs);
    println!("  sim time      : {:.2} s", s.total_time_us / 1e6);
    println!("\n{}", res.profiler.timeline.ascii_chart(100, 14));
    Ok(())
}
