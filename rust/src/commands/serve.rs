//! `rlhf-mem serve` — the serving-scale workload simulator: replay a
//! deterministic seeded request stream through the continuous-batching
//! scheduler under each KV-pool discipline (vLLM-style fixed pages vs
//! best-fit worst-case reservation) and report throughput, tail latency
//! and KV fragmentation per (discipline × page size × concurrency) cell.
//!
//! ```text
//! rlhf-mem serve --requests 64 --arrival-rps 20 --kv-capacity-gib 8 \
//!                --page-tokens 8,16,32 --max-concurrency 4,8,16 \
//!                --jobs 8 --jsonl serve.jsonl
//! ```
//!
//! Cells run on a worker pool under the sweep engine's contract: the
//! JSONL artifact is byte-identical for any `--jobs`.

use rlhf_mem::report;
use rlhf_mem::report::serve::summary_table;
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::serve::{run_cells, ServeSpec};
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::cli::{split_list, Args, CommonArgs};

pub const SERVE_USAGE: &str = "\
rlhf-mem serve — simulate a serving-scale generation workload: continuous
batching + paged KV cache vs best-fit reservation, per cell of a
(discipline x page size x concurrency) grid

FLAGS (comma-separated lists):
  --disciplines paged,best-fit   KV-pool disciplines (default both)
  --page-tokens 8,16,32          page sizes for 'paged', tokens (default 8,16,32)
  --max-concurrency 4,8,16       admission ceilings (default 4,8,16)
  --model NAME     model preset (default opt-1.3b)
  --gpu rtx3090|a100             time-model GPU (default rtx3090)
  --kv-capacity-gib N            KV-pool carve-out (default 8)
  --requests N     requests in the stream (default 64)
  --arrival-rps X  mean arrival rate, req/s (default 20)
  --prompt-len N   mean prompt length, tokens (default 256)
  --prompt-jitter N              +- uniform prompt jitter (default 64)
  --max-new N      mean response budget, tokens (default 128)
  --response-jitter N            +- uniform response jitter (default 32)
  --seed N         stream seed (default 0xC0FFEE)
  --jobs N         worker threads (default: all cores)
  --jsonl FILE     write the versioned per-cell JSON-lines artifact
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{SERVE_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, ServeSpec::default().seed)?;

    let mut spec = ServeSpec {
        seed: common.seed,
        ..ServeSpec::default()
    };
    if let Some(name) = args.flag("model") {
        spec.model = name.to_string();
    }
    spec.kv_capacity_bytes = args.get_u64("kv-capacity-gib", 8)? * GIB;
    spec.requests = args.get_u64("requests", spec.requests)?.max(1);
    spec.arrival_rps = args.get_f64("arrival-rps", spec.arrival_rps)?;
    if !(spec.arrival_rps.is_finite() && spec.arrival_rps > 0.0) {
        return Err("--arrival-rps must be a positive number".to_string());
    }
    spec.prompt_len = args.get_u64("prompt-len", spec.prompt_len)?.max(1);
    spec.prompt_jitter = args.get_u64("prompt-jitter", spec.prompt_jitter)?;
    spec.max_new = args.get_u64("max-new", spec.max_new)?.max(1);
    spec.response_jitter = args.get_u64("response-jitter", spec.response_jitter)?;
    if let Some(list) = args.flag("disciplines") {
        spec.disciplines = split_list(list).map(String::from).collect();
        if spec.disciplines.is_empty() {
            return Err("--disciplines must name at least one discipline".to_string());
        }
    }
    for (flag, dst) in [
        ("page-tokens", &mut spec.page_tokens),
        ("max-concurrency", &mut spec.max_concurrency),
    ] {
        if let Some(list) = args.flag(flag) {
            let xs: Vec<u64> = split_list(list)
                .map(|n| {
                    n.parse::<u64>()
                        .ok()
                        .filter(|&x| x > 0)
                        .ok_or_else(|| format!("--{flag} entries must be positive integers"))
                })
                .collect::<Result<_, _>>()?;
            if xs.is_empty() {
                return Err(format!("--{flag} must not be empty"));
            }
            *dst = xs;
        }
    }

    let gpu_name = args.get_or("gpu", "rtx3090");
    let gpu = GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?;
    let cells = spec.cells(gpu_name, gpu)?;
    println!(
        "serve: {} cells — {} requests @ {:.1} rps, {} / {}, KV budget {:.1} GiB",
        cells.len(),
        spec.requests,
        spec.arrival_rps,
        spec.model,
        gpu_name,
        spec.kv_capacity_bytes as f64 / GIB as f64,
    );

    let report = run_cells(&cells, common.jobs);
    println!("{}", summary_table(&report.cells).render());
    println!("({})", report.summary_line());
    println!("{}", report::telemetry::render_telemetry(&report.telemetry()));

    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
