//! `rlhf-mem sweep` — user-defined scenario grids over the parallel sweep
//! engine.
//!
//! ```text
//! rlhf-mem sweep --frameworks ds,cc --strategies none,zero3,all \
//!                --policies never,after_both --steps 2 --jobs 8 \
//!                --jsonl sweep.jsonl
//! ```
//!
//! Axes default to DeepSpeed-Chat / OPT / `none,zero3` / `never` /
//! `full` (two cells); every flag below widens one axis. Cells are
//! filtered by `--include`/`--exclude` substring matches on the
//! `framework/model/strategy/mode/policy` key.

use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report;
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::program::{Algo, Sharing};
use rlhf_mem::rlhf::sim::ScenarioMode;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{model_set_by_name, SeedPolicy, SweepGrid, SweepRunner};
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::cli::{split_list, Args, CommonArgs};

pub const SWEEP_USAGE: &str = "\
rlhf-mem sweep — run a user-defined scenario grid on a worker pool

FLAGS (comma-separated lists):
  --frameworks ds,cc             frameworks (default ds)
  --models opt,gpt2,nano         model pairs (default opt)
  --strategies none,zero1,zero2,zero3,offload,ckpt,all   (default none,zero3)
  --policies never,after_both,after_inference,after_training (default never)
  --modes full,train_both,train_actor                    (default full)
  --algos ppo,grpo,remax,dpo                             (default ppo)
  --sharings separate,lora,hydra,frozen-shared,perl           (default separate)
  --steps N        PPO steps per cell (default 3)
  --world N        data-parallel ranks (default 4)
  --capacity-gib N simulated HBM per GPU (default 24)
  --gpu rtx3090|a100             time-model GPU (default rtx3090)
  --jobs N         worker threads (default: all cores)
  --seed N         base seed (default 0x5EED)
  --per-cell-seeds derive a distinct deterministic seed per cell
  --include SUB[,SUB]  keep only cells whose key contains a SUB
  --exclude SUB[,SUB]  drop cells whose key contains a SUB
  --jsonl FILE     write per-cell JSON-lines (index-ordered)
";

pub fn run(args: &Args) -> Result<(), String> {
    if args.bool_flag("help") {
        println!("{SWEEP_USAGE}");
        return Ok(());
    }
    let common = CommonArgs::parse(args, 0x5EED)?;
    let mut grid = SweepGrid::new();

    let fws: Vec<FrameworkKind> = split_list(args.get_or("frameworks", "ds"))
        .map(|n| FrameworkKind::by_name(n).ok_or_else(|| format!("unknown framework '{n}'")))
        .collect::<Result<_, _>>()?;
    grid = grid.frameworks(fws);

    let models: Vec<(String, _)> = split_list(args.get_or("models", "opt"))
        .map(|n| model_set_by_name(n).ok_or_else(|| format!("unknown model set '{n}'")))
        .collect::<Result<_, _>>()?;
    grid = grid.model_sets(models);

    let strategies: Vec<(&'static str, StrategyConfig)> =
        split_list(args.get_or("strategies", "none,zero3"))
            .map(|n| StrategyConfig::by_name(n).ok_or_else(|| format!("unknown strategy '{n}'")))
            .collect::<Result<_, _>>()?;
    grid = grid.strategies(strategies);

    let policies: Vec<EmptyCachePolicy> = split_list(args.get_or("policies", "never"))
        .map(|n| EmptyCachePolicy::by_name(n).ok_or_else(|| format!("unknown policy '{n}'")))
        .collect::<Result<_, _>>()?;
    grid = grid.policies(policies);

    let modes: Vec<ScenarioMode> = split_list(args.get_or("modes", "full"))
        .map(|n| {
            ScenarioMode::by_name(n).ok_or_else(|| {
                format!("unknown mode '{n}' (valid: {})", ScenarioMode::known_names())
            })
        })
        .collect::<Result<_, _>>()?;
    grid = grid.modes(modes);

    grid = grid.algos(Algo::parse_list(args.get_or("algos", "ppo"))?);
    grid = grid.sharings(Sharing::parse_list(args.get_or("sharings", "separate"))?);

    grid = grid
        .steps(args.get_u64("steps", 3)?)
        .world(args.get_u64("world", 4)?)
        .capacity(args.get_u64("capacity-gib", 24)? * GIB);

    let gpu_name = args.get_or("gpu", "rtx3090");
    grid = grid.gpu(GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?);

    grid = grid.seeds(if args.bool_flag("per-cell-seeds") {
        SeedPolicy::PerCell(common.seed)
    } else {
        SeedPolicy::Fixed(common.seed)
    });

    if let Some(pats) = args.flag("include") {
        for p in split_list(pats) {
            grid = grid.include(p);
        }
    }
    if let Some(pats) = args.flag("exclude") {
        for p in split_list(pats) {
            grid = grid.exclude(p);
        }
    }

    let cells = grid.build()?;
    if cells.is_empty() {
        return Err("grid is empty (axes × filters selected no cells)".to_string());
    }
    println!("sweep: {} cells", cells.len());

    let report = SweepRunner::new(common.jobs).run(cells);

    println!("{}", report.to_table().render());
    println!("({})", report.summary_line());
    println!("{}", report::telemetry::render_telemetry(&report.telemetry()));
    if let Some(path) = &common.jsonl {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
