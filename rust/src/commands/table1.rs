//! `rlhf-mem table1` — regenerate Table 1: the strategy sweep over
//! DeepSpeed-Chat/OPT, ColossalChat/OPT and ColossalChat/GPT-2, with and
//! without `empty_cache()`.

use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::{paper_table1, render_rows, StrategyRow};
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::Json;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let which = args.get_or("framework", "all").to_string();
    let compare = args.bool_flag("compare-paper");
    let mut json_rows: Vec<Json> = Vec::new();

    let blocks: Vec<(&str, &str, Box<dyn Fn(StrategyConfig) -> SimScenario>)> = vec![
        (
            "DeepSpeed-Chat",
            "OPT",
            Box::new(move |s| {
                let mut scn = SimScenario::deepspeed_opt(s, EmptyCachePolicy::Never);
                scn.steps = steps;
                scn
            }),
        ),
        (
            "ColossalChat",
            "OPT",
            Box::new(move |s| {
                let mut scn = SimScenario::colossal_opt(s, EmptyCachePolicy::Never);
                scn.steps = steps;
                scn
            }),
        ),
        (
            "ColossalChat",
            "GPT-2",
            Box::new(move |s| {
                let mut scn = SimScenario::colossal_gpt2(s, EmptyCachePolicy::Never);
                scn.steps = steps;
                scn
            }),
        ),
    ];

    for (fw, model, mk) in &blocks {
        if which != "all" {
            let short = if *fw == "DeepSpeed-Chat" { "ds" } else { "cc" };
            if which != short && which != *fw {
                continue;
            }
        }
        let rows_spec = if *fw == "DeepSpeed-Chat" {
            StrategyConfig::table1_deepspeed_rows()
        } else {
            StrategyConfig::table1_colossal_rows()
        };
        let mut rows = Vec::new();
        for (label, strat) in rows_spec {
            let scn = mk(strat);
            let row = StrategyRow::measure(label, &scn, RTX3090_HBM);
            json_rows.push(row_json(fw, model, &row));
            rows.push(row);
        }
        println!("{}", render_rows(&format!("{fw} / {model}"), &rows));
        if compare {
            print_paper_block(fw, model);
        }
    }

    if let Some(path) = args.flag("json") {
        let doc = Json::obj(vec![("table1", Json::Arr(json_rows))]);
        std::fs::write(path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn row_json(fw: &str, model: &str, row: &StrategyRow) -> Json {
    Json::obj(vec![
        ("framework", Json::str(fw)),
        ("model", Json::str(model)),
        ("strategy", Json::str(row.strategy.clone())),
        ("reserved", Json::from(row.original.peak_reserved)),
        ("frag", Json::from(row.original.frag)),
        ("allocated", Json::from(row.original.peak_allocated)),
        (
            "ec_reserved",
            Json::from(row.with_empty_cache.peak_reserved),
        ),
        ("ec_frag", Json::from(row.with_empty_cache.frag)),
        ("peak_phase", Json::str(row.original.peak_phase.name())),
        ("oom", Json::from(row.original.oom)),
    ])
}

fn print_paper_block(fw: &str, model: &str) {
    println!("  paper reference ({fw}/{model}):");
    for (pfw, pmodel, strat, v) in paper_table1() {
        if pfw == fw && pmodel == model {
            println!(
                "    {strat:<28} {:>5.1} {:>5.1} {:>5.1} | {:>5.1} {:>5.1}",
                v[0], v[1], v[2], v[3], v[4]
            );
        }
    }
    println!();
}
