//! `rlhf-mem table1` — regenerate Table 1 through the sweep engine: the
//! strategy sweep over DeepSpeed-Chat/OPT, ColossalChat/OPT and
//! ColossalChat/GPT-2, each row measured with and without `empty_cache()`.
//!
//! The grid itself lives in [`rlhf_mem::sweep::presets::table1_cells`]
//! (shared with `benches/table1.rs`); this command filters it by
//! `--framework`, runs one [`SweepRunner`] pass (`--jobs N`, default all
//! cores), and groups the cells back into paper rows.
//!
//! `--compare-paper` additionally prints the published values and **exits
//! non-zero** when any reserved-scale cell deviates more than
//! `--tolerance-gib` (default 2.0) from them — a CI-usable regression
//! gate on the reproduction.

use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::report::paper::{
    gate_paper_deviation, paper_table1, render_rows, track_worst_deviation, StrategyRow,
};
use rlhf_mem::sweep::{presets, SweepRunner};
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::Json;

/// Default `--tolerance-gib` for `--compare-paper`: the gate trips when
/// any reserved-scale cell drifts more than this from the published
/// table (generous enough for modeling noise, tight enough to catch a
/// broken allocator or trace generator).
pub const DEFAULT_TOLERANCE_GIB: f64 = rlhf_mem::util::cli::DEFAULT_TOLERANCE_GIB;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let which = args.get_or("framework", "all").to_string();
    let jobs = args.get_usize("jobs", SweepRunner::default_jobs())?;
    let compare = args.bool_flag("compare-paper");
    let tolerance = args.get_f64("tolerance-gib", DEFAULT_TOLERANCE_GIB)?;

    let mut cells = presets::table1_cells(steps)?;
    if which != "all" {
        let kind = FrameworkKind::by_name(&which)
            .ok_or_else(|| format!("unknown framework '{which}'"))?;
        cells.retain(|c| c.framework == kind.name());
    }
    let report = SweepRunner::new(jobs).run(cells);

    let mut json_rows: Vec<Json> = Vec::new();
    let mut worst = (0.0f64, "-".to_string());
    let mut matched = 0usize;
    for (fw, model, rows) in report.strategy_rows() {
        for row in &rows {
            json_rows.push(row_json(&fw, &model, row));
            if compare {
                for (pfw, pmodel, strat, v) in paper_table1() {
                    if pfw == fw && pmodel == model && strat == row.strategy {
                        let label = format!("{fw}/{model}/{strat}");
                        track_worst_deviation(&mut worst, &v, row, &label);
                        matched += 1;
                    }
                }
            }
        }
        println!("{}", render_rows(&format!("{fw} / {model}"), &rows));
        if compare {
            print_paper_block(&fw, &model);
        }
    }
    println!("({})", report.summary_line());
    if compare {
        gate_paper_deviation("Table 1", &worst, matched, tolerance)?;
    }

    if let Some(path) = args.flag("json") {
        let doc = Json::obj(vec![("table1", Json::Arr(json_rows))]);
        std::fs::write(path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.flag("jsonl") {
        std::fs::write(path, report.jsonl_with_telemetry()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn row_json(fw: &str, model: &str, row: &StrategyRow) -> Json {
    Json::obj(vec![
        ("framework", Json::str(fw)),
        ("model", Json::str(model)),
        ("strategy", Json::str(row.strategy.clone())),
        ("reserved", Json::from(row.original.peak_reserved)),
        ("frag", Json::from(row.original.frag)),
        ("allocated", Json::from(row.original.peak_allocated)),
        (
            "ec_reserved",
            Json::from(row.with_empty_cache.peak_reserved),
        ),
        ("ec_frag", Json::from(row.with_empty_cache.frag)),
        ("peak_phase", Json::str(row.original.peak_phase.name())),
        ("oom", Json::from(row.original.oom)),
    ])
}

fn print_paper_block(fw: &str, model: &str) {
    println!("  paper reference ({fw}/{model}):");
    for (pfw, pmodel, strat, v) in paper_table1() {
        if pfw == fw && pmodel == model {
            println!(
                "    {strat:<28} {:>5.1} {:>5.1} {:>5.1} | {:>5.1} {:>5.1}",
                v[0], v[1], v[2], v[3], v[4]
            );
        }
    }
    println!();
}
