//! `rlhf-mem table2` — Appendix C Table 2 through the sweep engine: None
//! vs ZeRO-3 on a 4×A100-80G node for OPT-1.3b, OPT-6.7b and Llama-2-7b
//! (ColossalChat; the larger models are fully fine-tuned, which is why
//! allocated memory is much higher than Table 1). The grid lives in
//! [`rlhf_mem::sweep::presets::table2_cells`] (shared with
//! `benches/table2.rs`); one runner pass executes all twelve cells across
//! `--jobs` workers.
//!
//! `--compare-paper` prints the published values and **exits non-zero**
//! when any reserved-scale cell deviates more than `--tolerance-gib`
//! (default 2.0) from them — same CI regression gate as `table1`.

use rlhf_mem::report::paper::{
    gate_paper_deviation, paper_table2, render_rows, track_worst_deviation,
};
use rlhf_mem::sweep::{presets, SweepRunner};
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::Json;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let jobs = args.get_usize("jobs", SweepRunner::default_jobs())?;
    let compare = args.bool_flag("compare-paper");
    let tolerance = args.get_f64("tolerance-gib", super::table1::DEFAULT_TOLERANCE_GIB)?;
    let report = SweepRunner::new(jobs).run(presets::table2_cells(steps)?);

    let mut json_rows: Vec<Json> = Vec::new();
    let mut worst = (0.0f64, "-".to_string());
    let mut matched = 0usize;
    for (_fw, model, rows) in report.strategy_rows() {
        for row in &rows {
            json_rows.push(Json::obj(vec![
                ("model", Json::str(model.clone())),
                ("strategy", Json::str(row.strategy.clone())),
                ("reserved", Json::from(row.original.peak_reserved)),
                ("frag", Json::from(row.original.frag)),
                ("allocated", Json::from(row.original.peak_allocated)),
                ("ec_reserved", Json::from(row.with_empty_cache.peak_reserved)),
                ("ec_frag", Json::from(row.with_empty_cache.frag)),
            ]));
            if compare {
                for (pmodel, strat, v) in paper_table2() {
                    if pmodel.eq_ignore_ascii_case(&model) && strat == row.strategy {
                        track_worst_deviation(&mut worst, &v, row, &format!("{model}/{strat}"));
                        matched += 1;
                    }
                }
            }
        }
        println!(
            "{}",
            render_rows(&format!("ColossalChat / {model} (4xA100-80G)"), &rows)
        );
        if compare {
            println!("  paper reference ({model}):");
            for (pmodel, strat, v) in paper_table2() {
                if pmodel.eq_ignore_ascii_case(&model) {
                    println!(
                        "    {strat:<28} {:>5.1} {:>5.1} {:>5.1} | {:>5.1} {:>5.1}",
                        v[0], v[1], v[2], v[3], v[4]
                    );
                }
            }
            println!();
        }
    }
    println!("({})", report.summary_line());
    if compare {
        gate_paper_deviation("Table 2", &worst, matched, tolerance)?;
    }

    if let Some(path) = args.flag("json") {
        let doc = Json::obj(vec![("table2", Json::Arr(json_rows))]);
        std::fs::write(path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
