//! `rlhf-mem table2` — Appendix C Table 2: None vs ZeRO-3 on a 4×A100-80G
//! node for OPT-1.3b, OPT-6.7b and Llama-2-7b (ColossalChat, LoRA off —
//! the larger models are fully fine-tuned there, which is why allocated
//! memory is much higher than Table 1).

use rlhf_mem::experiment::A100_HBM;
use rlhf_mem::mem::ModelArch;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::report::paper::{render_rows, StrategyRow};
use rlhf_mem::rlhf::cost::GpuSpec;
use rlhf_mem::rlhf::models::RlhfModelSet;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::cli::Args;
use rlhf_mem::util::json::Json;

pub fn run(args: &Args) -> Result<(), String> {
    let steps = args.get_u64("steps", 3)?;
    let mut json_rows: Vec<Json> = Vec::new();
    for arch_name in ["opt-1.3b", "opt-6.7b", "llama-2-7b"] {
        let arch = ModelArch::by_name(arch_name).unwrap();
        let mut rows = Vec::new();
        for (label, strat) in [
            ("None", StrategyConfig::none()),
            ("ZeRO-3", StrategyConfig::zero3()),
        ] {
            let mut scn =
                SimScenario::colossal_opt(strat, EmptyCachePolicy::Never);
            // Table 2 pairs each larger policy with the OPT-350m scorer
            // pair (as in Table 1) and runs the A100-scale workload:
            // longer sequences and a larger rollout than the 24 GiB box.
            scn.models = RlhfModelSet {
                policy_arch: arch.clone(),
                value_arch: ModelArch::opt_350m(),
            };
            scn.framework.prompt_len = 256;
            scn.framework.gen_len = 256;
            scn.framework.rollout_batch = 64;
            scn.framework.infer_micro_batch = 8;
            scn.framework.train_micro_batch = 4;
            scn.steps = steps;
            scn.gpu = GpuSpec::a100_80g();
            let row = StrategyRow::measure(label, &scn, A100_HBM);
            json_rows.push(Json::obj(vec![
                ("model", Json::str(arch_name)),
                ("strategy", Json::str(label)),
                ("reserved", Json::from(row.original.peak_reserved)),
                ("frag", Json::from(row.original.frag)),
                ("allocated", Json::from(row.original.peak_allocated)),
                ("ec_reserved", Json::from(row.with_empty_cache.peak_reserved)),
                ("ec_frag", Json::from(row.with_empty_cache.frag)),
            ]));
            rows.push(row);
        }
        println!("{}", render_rows(&format!("ColossalChat / {arch_name} (4xA100-80G)"), &rows));
    }
    if let Some(path) = args.flag("json") {
        let doc = Json::obj(vec![("table2", Json::Arr(json_rows))]);
        std::fs::write(path, doc.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
