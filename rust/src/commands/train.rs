//! `rlhf-mem train` — real end-to-end PPO (E10): generation, scoring,
//! synthetic reward, GAE and PPO updates all through PJRT artifacts.

use rlhf_mem::rlhf::real::{PpoConfig, RealPpoTrainer};
use rlhf_mem::runtime::{KernelVariant, RlhfEngine};
use rlhf_mem::util::cli::Args;

pub fn run(args: &Args) -> Result<(), String> {
    let arch = args.get_or("model", "opt-nano").to_string();
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let iters = args.get_u64("iters", 50)?;
    let variant = if args.bool_flag("pallas") {
        KernelVariant::Pallas
    } else {
        KernelVariant::Jnp
    };
    let engine = RlhfEngine::load(&dir, &arch, variant).map_err(|e| format!("{e:#}"))?;
    println!(
        "loaded {} ({} params, batch {}, seq {}) — {} PPO iterations",
        arch, engine.manifest.num_params, engine.manifest.batch, engine.manifest.max_seq, iters
    );
    let mut trainer = RealPpoTrainer::new(engine, PpoConfig::default());
    for _ in 0..iters {
        let s = trainer.step().map_err(|e| format!("{e:#}"))?;
        println!(
            "iter {:>4}  reward {:>7.3}  kl {:>7.4}  pg {:>8.4}  vf {:>8.4}  ent {:>6.3}  ({:.1}s gen, {:.1}s train)",
            s.iter, s.mean_reward, s.mean_kl, s.policy_loss, s.value_loss, s.entropy,
            s.gen_seconds, s.train_seconds
        );
    }
    if let Some(path) = args.flag("csv") {
        std::fs::write(path, trainer.history_csv()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    // Summary: did alignment happen?
    let k = trainer.history.len().min(5);
    let first: f32 = trainer.history[..k].iter().map(|h| h.mean_reward).sum::<f32>() / k as f32;
    let last: f32 = trainer.history[trainer.history.len() - k..]
        .iter()
        .map(|h| h.mean_reward)
        .sum::<f32>()
        / k as f32;
    println!("mean reward: first-{k} {first:.3} -> last-{k} {last:.3}");
    Ok(())
}
