//! Experiment configuration: JSON files → [`SimScenario`], so users can
//! define custom sweeps without recompiling (`rlhf-mem profile cfg.json`).
//!
//! Example:
//! ```json
//! {
//!   "framework": "deepspeed-chat",
//!   "policy_model": "opt-1.3b",
//!   "value_model": "opt-350m",
//!   "strategy": {"zero": 3, "cpu_offload": true, "grad_checkpoint": false,
//!                 "lora_r": 128},
//!   "world": 4,
//!   "gpu": "rtx3090",
//!   "capacity_gib": 24,
//!   "steps": 3,
//!   "mode": "full",
//!   "algo": "ppo",
//!   "empty_cache": "after_inference",
//!   "rollout_batch": 2, "prompt_len": 256, "gen_len": 256
//! }
//! ```
//!
//! `mode` selects the §3.1 scenario (`full`, `train_both`,
//! `train_actor`); `algo` the RLHF algorithm (`ppo`, `grpo`, `remax`,
//! `dpo`); `sharing` the model-sharing placement (`separate`, `lora`,
//! `hydra`, `frozen-shared`). Unknown names error with the valid list.

use crate::frameworks::{FrameworkKind, FrameworkProfile};
use crate::mem::{LoraSpec, LoraTargets, ModelArch};
use crate::policy::EmptyCachePolicy;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::RlhfModelSet;
use crate::rlhf::program::{Algo, Sharing};
use crate::rlhf::sim::{ScenarioMode, SimScenario};
use crate::strategies::{StrategyConfig, ZeroStage};
use crate::util::bytes::GIB;
use crate::util::json::{parse, Json};

/// A fully-resolved experiment: the scenario plus device capacity.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub scenario: SimScenario,
    pub capacity: u64,
}

impl ExperimentConfig {
    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json_text(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn from_json_text(text: &str) -> Result<ExperimentConfig, String> {
        let j = parse(text)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        // Framework + its profile defaults.
        let fw_name = j.get("framework").and_then(|v| v.as_str()).unwrap_or("deepspeed-chat");
        let kind = FrameworkKind::by_name(fw_name)
            .ok_or_else(|| format!("unknown framework '{fw_name}'"))?;
        let mut framework = FrameworkProfile::by_kind(kind);
        if let Some(v) = j.get("rollout_batch").and_then(|v| v.as_u64()) {
            framework.rollout_batch = v;
        }
        if let Some(v) = j.get("infer_micro_batch").and_then(|v| v.as_u64()) {
            framework.infer_micro_batch = v;
        }
        if let Some(v) = j.get("train_micro_batch").and_then(|v| v.as_u64()) {
            framework.train_micro_batch = v;
        }
        if let Some(v) = j.get("prompt_len").and_then(|v| v.as_u64()) {
            framework.prompt_len = v;
        }
        if let Some(v) = j.get("gen_len").and_then(|v| v.as_u64()) {
            framework.gen_len = v;
        }

        // Models.
        let policy_name = j.get("policy_model").and_then(|v| v.as_str()).unwrap_or("opt-1.3b");
        let value_name = j.get("value_model").and_then(|v| v.as_str()).unwrap_or("opt-350m");
        let policy_arch = ModelArch::by_name(policy_name)
            .ok_or_else(|| format!("unknown model '{policy_name}'"))?;
        let value_arch = ModelArch::by_name(value_name)
            .ok_or_else(|| format!("unknown model '{value_name}'"))?;

        // Strategy.
        let strategy = match j.get("strategy") {
            None => StrategyConfig::none(),
            Some(s) => {
                let zero = s.get("zero").and_then(|v| v.as_u64()).unwrap_or(0);
                let zero = ZeroStage::from_stage(zero as u8)
                    .ok_or_else(|| format!("bad zero stage {zero}"))?;
                let lora = match s.get("lora_r").and_then(|v| v.as_u64()) {
                    Some(0) | None => None,
                    Some(r) => Some(LoraSpec {
                        r,
                        targets: LoraTargets::AllLinear,
                    }),
                };
                StrategyConfig {
                    zero,
                    cpu_offload: s.get("cpu_offload").and_then(|v| v.as_bool()).unwrap_or(false),
                    grad_checkpoint: s
                        .get("grad_checkpoint")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                    lora,
                }
            }
        };

        let policy_name = j.get("empty_cache").and_then(|v| v.as_str()).unwrap_or("never");
        let policy = EmptyCachePolicy::by_name(policy_name)
            .ok_or_else(|| format!("unknown empty_cache policy '{policy_name}'"))?;

        let gpu = match j.get("gpu").and_then(|v| v.as_str()).unwrap_or("rtx3090") {
            "rtx3090" => GpuSpec::rtx3090(),
            "a100" | "a100-80g" => GpuSpec::a100_80g(),
            other => return Err(format!("unknown gpu '{other}'")),
        };
        let capacity = j
            .get("capacity_gib")
            .and_then(|v| v.as_u64())
            .unwrap_or(24)
            * GIB;

        let mode_name = j.get("mode").and_then(|v| v.as_str()).unwrap_or("full");
        let mode = ScenarioMode::by_name(mode_name).ok_or_else(|| {
            format!(
                "unknown mode '{mode_name}' (valid: {})",
                ScenarioMode::known_names()
            )
        })?;

        let algo_name = j.get("algo").and_then(|v| v.as_str()).unwrap_or("ppo");
        let algo = Algo::by_name(algo_name).ok_or_else(|| {
            format!(
                "unknown algo '{algo_name}' (valid: {})",
                Algo::known_names()
            )
        })?;

        let sharing_name = j
            .get("sharing")
            .and_then(|v| v.as_str())
            .unwrap_or("separate");
        let sharing = Sharing::by_name(sharing_name).ok_or_else(|| {
            format!(
                "unknown sharing '{sharing_name}' (valid: {})",
                Sharing::known_names()
            )
        })?;

        let scenario = SimScenario {
            framework,
            models: RlhfModelSet {
                policy_arch,
                value_arch,
            },
            strategy,
            world: j.get("world").and_then(|v| v.as_u64()).unwrap_or(4),
            policy,
            steps: j.get("steps").and_then(|v| v.as_u64()).unwrap_or(3),
            mode,
            algo,
            sharing,
            gpu,
            seed: j.get("seed").and_then(|v| v.as_u64()).unwrap_or(0x5EED),
            len_jitter: j
                .get("len_jitter")
                .and_then(|v| v.as_bool())
                .unwrap_or(kind.default_len_jitter()),
            roles: crate::rlhf::models::RoleSet::ALL,
            time_shared: crate::rlhf::models::RoleSet::EMPTY,
            rank: 0,
        };
        Ok(ExperimentConfig { scenario, capacity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{
              "framework": "colossalchat",
              "policy_model": "gpt2-xl",
              "value_model": "gpt2-medium",
              "strategy": {"zero": 3, "cpu_offload": true, "lora_r": 128},
              "world": 8,
              "capacity_gib": 80,
              "gpu": "a100",
              "steps": 2,
              "empty_cache": "after_inference",
              "rollout_batch": 16
            }"#,
        )
        .unwrap();
        let s = &cfg.scenario;
        assert_eq!(s.models.policy_arch.name, "gpt2-xl");
        assert_eq!(s.world, 8);
        assert_eq!(s.strategy.zero, ZeroStage::Z3);
        assert!(s.strategy.cpu_offload);
        assert_eq!(s.framework.rollout_batch, 16);
        assert_eq!(s.policy, EmptyCachePolicy::AfterInference);
        assert_eq!(cfg.capacity, 80 * GIB);
        assert!(s.len_jitter, "colossal defaults to ragged lengths");
    }

    #[test]
    fn minimal_config_uses_defaults() {
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.scenario.models.policy_arch.name, "opt-1.3b");
        assert_eq!(cfg.scenario.world, 4);
        assert_eq!(cfg.capacity, 24 * GIB);
        assert!(!cfg.scenario.len_jitter, "deepspeed pads");
    }

    #[test]
    fn rejects_unknown_values() {
        assert!(ExperimentConfig::from_json_text(r#"{"framework": "x"}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"policy_model": "x"}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"strategy": {"zero": 9}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"empty_cache": "x"}"#).is_err());
        assert!(ExperimentConfig::from_json_text("not json").is_err());
    }

    #[test]
    fn mode_and_algo_errors_list_valid_names() {
        let err = ExperimentConfig::from_json_text(r#"{"mode": "warp"}"#).unwrap_err();
        assert!(err.contains("unknown mode 'warp'"), "{err}");
        assert!(err.contains("full, train_both, train_actor"), "{err}");
        let err = ExperimentConfig::from_json_text(r#"{"algo": "sarsa"}"#).unwrap_err();
        assert!(err.contains("unknown algo 'sarsa'"), "{err}");
        assert!(err.contains("ppo, grpo, remax, dpo"), "{err}");
        let err = ExperimentConfig::from_json_text(r#"{"sharing": "siamese"}"#).unwrap_err();
        assert!(err.contains("unknown sharing 'siamese'"), "{err}");
        assert!(err.contains("separate, lora, hydra, frozen-shared"), "{err}");
    }

    #[test]
    fn sharing_field_parses_and_defaults_to_separate() {
        let cfg =
            ExperimentConfig::from_json_text(r#"{"sharing": "hydra", "steps": 1}"#).unwrap();
        assert_eq!(cfg.scenario.sharing, Sharing::Hydra);
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.scenario.sharing, Sharing::Separate);
    }

    #[test]
    fn mode_and_algo_fields_parse() {
        use crate::rlhf::program::Algo;
        let cfg = ExperimentConfig::from_json_text(
            r#"{"mode": "train_actor", "algo": "grpo", "steps": 1}"#,
        )
        .unwrap();
        assert_eq!(cfg.scenario.mode, ScenarioMode::TrainActorOnly);
        assert_eq!(cfg.scenario.algo, Algo::Grpo);
        // Defaults: the paper's full PPO pipeline.
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.scenario.mode, ScenarioMode::Full);
        assert_eq!(cfg.scenario.algo, Algo::Ppo);
    }

    #[test]
    fn config_runs_end_to_end() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"policy_model": "opt-350m", "value_model": "opt-350m", "steps": 1}"#,
        )
        .unwrap();
        let res = crate::experiment::run_scenario(&cfg.scenario, cfg.capacity);
        assert!(!res.summary.oom);
        assert!(res.summary.peak_reserved > 0);
    }
}
