//! Ring-collective cost/byte accounting (all-gather, reduce-scatter,
//! all-reduce, broadcast) and point-to-point transfers, used by the cost
//! model and the cluster scheduler.

/// Bytes each rank RECEIVES over the wire for a ring collective moving a
/// `total`-byte tensor across `world` ranks.
///
/// Ceil-chunked accounting: the tensor is cut into `world` chunks of
/// `ceil(total / world)` bytes (the last chunk may be short) and every
/// rank forwards one chunk per hop for `world - 1` hops. Truncating
/// division here would undercount non-divisible tensors and report zero
/// wire bytes whenever `total < world` — a ring still moves every byte of
/// a small tensor through every rank.
pub fn ring_wire_bytes(total: u64, world: u64) -> u64 {
    if world <= 1 || total == 0 {
        return 0;
    }
    total.div_ceil(world) * (world - 1)
}

/// All-reduce = reduce-scatter + all-gather (2x the wire volume).
pub fn allreduce_wire_bytes(total: u64, world: u64) -> u64 {
    2 * ring_wire_bytes(total, world)
}

/// Time for a ring collective at `link_bw` bytes/s with per-hop latency.
pub fn ring_time_us(total: u64, world: u64, link_bw: f64, hop_latency_us: f64) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let wire = ring_wire_bytes(total, world) as f64;
    wire / link_bw * 1e6 + hop_latency_us * (world - 1) as f64
}

/// Time for a point-to-point transfer of `bytes` at `link_bw` bytes/s plus
/// a launch latency — how the cluster scheduler charges experience
/// shipping between GPUs that host different RLHF models.
pub fn p2p_time_us(bytes: u64, link_bw: f64, latency_us: f64) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    bytes as f64 / link_bw * 1e6 + latency_us
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_volume() {
        assert_eq!(ring_wire_bytes(1000, 1), 0);
        assert_eq!(ring_wire_bytes(1000, 4), 750);
        assert_eq!(allreduce_wire_bytes(1000, 4), 1500);
    }

    #[test]
    fn small_tensors_still_move_bytes() {
        // total < world: truncating division would say 0 — the ring still
        // forwards the (single-chunk) tensor world-1 times.
        assert_eq!(ring_wire_bytes(3, 8), 7);
        assert_eq!(ring_wire_bytes(1, 2), 1);
        // Non-divisible totals round the chunk up.
        assert_eq!(ring_wire_bytes(1000, 3), 334 * 2);
        assert_eq!(ring_wire_bytes(7, 4), 2 * 3);
    }

    #[test]
    fn wire_bytes_positive_for_any_nonempty_tensor() {
        for total in [1u64, 2, 3, 15, 16, 17, 1000, 1_000_003] {
            for world in 2u64..=16 {
                let wire = ring_wire_bytes(total, world);
                assert!(wire > 0, "total {total} world {world}");
                // Ceil chunks never undercount the exact per-rank volume.
                let exact = total as f64 * (world - 1) as f64 / world as f64;
                assert!(wire as f64 >= exact, "total {total} world {world}");
            }
        }
        assert_eq!(ring_wire_bytes(0, 8), 0);
    }

    #[test]
    fn time_scales() {
        let t4 = ring_time_us(1 << 30, 4, 12e9, 5.0);
        let t8 = ring_time_us(1 << 30, 8, 12e9, 5.0);
        assert!(t8 > t4);
        assert_eq!(ring_time_us(1 << 30, 1, 12e9, 5.0), 0.0);
        // Even a 1-byte collective takes hop latency.
        assert!(ring_time_us(1, 4, 12e9, 5.0) >= 15.0);
    }

    #[test]
    fn p2p_time_is_bandwidth_plus_latency() {
        assert_eq!(p2p_time_us(0, 12e9, 5.0), 0.0);
        let t = p2p_time_us(12_000_000_000, 12e9, 5.0);
        assert!((t - 1_000_005.0).abs() < 1e-6, "{t}");
    }
}
