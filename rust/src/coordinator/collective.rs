//! Ring-collective cost/byte accounting (all-gather, reduce-scatter,
//! all-reduce, broadcast) used by the cost model and the node scheduler.

/// Bytes each rank RECEIVES over the wire for a ring collective moving a
/// `total`-byte tensor across `world` ranks.
pub fn ring_wire_bytes(total: u64, world: u64) -> u64 {
    if world <= 1 {
        return 0;
    }
    total / world * (world - 1)
}

/// All-reduce = reduce-scatter + all-gather (2x the wire volume).
pub fn allreduce_wire_bytes(total: u64, world: u64) -> u64 {
    2 * ring_wire_bytes(total, world)
}

/// Time for a ring collective at `link_bw` bytes/s with per-hop latency.
pub fn ring_time_us(total: u64, world: u64, link_bw: f64, hop_latency_us: f64) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let wire = ring_wire_bytes(total, world) as f64;
    wire / link_bw * 1e6 + hop_latency_us * (world - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_volume() {
        assert_eq!(ring_wire_bytes(1000, 1), 0);
        assert_eq!(ring_wire_bytes(1000, 4), 750);
        assert_eq!(allreduce_wire_bytes(1000, 4), 1500);
    }

    #[test]
    fn time_scales() {
        let t4 = ring_time_us(1 << 30, 4, 12e9, 5.0);
        let t8 = ring_time_us(1 << 30, 8, 12e9, 5.0);
        assert!(t8 > t4);
        assert_eq!(ring_time_us(1 << 30, 1, 12e9, 5.0), 0.0);
    }
}
