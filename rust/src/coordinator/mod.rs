//! Multi-GPU coordination: collectives accounting, ZeRO partition maps,
//! and the lockstep simulated node.

pub mod collective;
pub mod node;
pub mod partition;

pub use node::{run_node, NodeResult};
