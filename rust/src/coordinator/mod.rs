//! Multi-GPU coordination: collectives accounting, ZeRO partition maps,
//! the lockstep simulated node, and the cluster placement simulator
//! (placement plans + the step-time scheduler behind `rlhf-mem cluster`
//! and `advise --cluster`).

pub mod collective;
pub mod node;
pub mod partition;
pub mod placement;
pub mod schedule;

pub use node::{run_node, NodeResult};
pub use placement::PlacementPlan;
pub use schedule::{ClusterRun, GpuLoad};
