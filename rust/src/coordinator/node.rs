//! Simulated multi-GPU node: `world` ranks in lockstep data parallelism.
//! Each rank gets its *own* trace from
//! [`build_trace`](crate::rlhf::sim::build_trace) — the rank index is
//! threaded through the scenario, so ZeRO flat-buffer shard remainders
//! land on the right rank instead of every rank replaying rank 0's view.
//! Placement-aware (role-subset) nodes live in [`super::schedule`]; this
//! is the symmetric-replica entry point.

use crate::experiment::{run_scenario, ExperimentResult};
use crate::profiler::ProfileSummary;
use crate::rlhf::sim::SimScenario;

/// Per-node results. [`run_node`] guarantees at least one rank.
pub struct NodeResult {
    pub ranks: Vec<ExperimentResult>,
}

/// Absolute per-rank peak divergence [`NodeResult::check_symmetry`]
/// tolerates: shard remainders are bytes inside the 16 B flat-buffer
/// padding, so symmetric ranks may differ by at most a couple of
/// allocator segment granules.
pub const SYMMETRY_TOLERANCE_BYTES: u64 = 32 * 1024 * 1024;

impl NodeResult {
    /// Rank 0's summary; `None` only for a hand-built empty rank set
    /// ([`run_node`] always returns at least one rank).
    pub fn rank0(&self) -> Option<&ProfileSummary> {
        self.ranks.first().map(|r| &r.summary)
    }

    /// Relative spread of per-rank reserved peaks: `(max - min) / max`.
    pub fn peak_spread(&self) -> f64 {
        let max = self.ranks.iter().map(|r| r.summary.peak_reserved).max();
        let min = self.ranks.iter().map(|r| r.summary.peak_reserved).min();
        match (max, min) {
            (Some(max), Some(min)) if max > 0 => (max - min) as f64 / max as f64,
            _ => 0.0,
        }
    }

    /// Symmetric-DP sanity check: per-rank traces may differ by ZeRO shard
    /// remainders, so reserved *and* allocated peaks must agree to within
    /// [`SYMMETRY_TOLERANCE_BYTES`] — anything larger means some rank ran
    /// a genuinely different workload. Errors on an empty rank set.
    pub fn check_symmetry(&self) -> Result<(), String> {
        if self.ranks.is_empty() {
            return Err("node has no ranks".to_string());
        }
        let metrics: [(&str, Vec<u64>); 2] = [
            (
                "peak_reserved",
                self.ranks.iter().map(|r| r.summary.peak_reserved).collect(),
            ),
            (
                "peak_allocated",
                self.ranks.iter().map(|r| r.summary.peak_allocated).collect(),
            ),
        ];
        for (name, vals) in metrics {
            let max = *vals.iter().max().unwrap();
            let min = *vals.iter().min().unwrap();
            if max - min > SYMMETRY_TOLERANCE_BYTES {
                return Err(format!(
                    "ranks diverged: {name} spread {} exceeds {} bytes",
                    max - min,
                    SYMMETRY_TOLERANCE_BYTES
                ));
            }
        }
        Ok(())
    }

    /// Node-wide peak reserved (Σ ranks).
    pub fn total_peak_reserved(&self) -> u64 {
        self.ranks.iter().map(|r| r.summary.peak_reserved).sum()
    }
}

/// Run `scn` on all `scn.world` ranks of a simulated node, one per-rank
/// trace each. Rejects `world == 0` instead of handing back an empty rank
/// set for downstream code to panic on.
pub fn run_node(scn: &SimScenario, per_gpu_capacity: u64) -> Result<NodeResult, String> {
    if scn.world == 0 {
        return Err("run_node: world must be >= 1 (got 0)".to_string());
    }
    let ranks = (0..scn.world)
        .map(|rank| {
            let mut per_rank = scn.clone();
            per_rank.rank = rank;
            run_scenario(&per_rank, per_gpu_capacity)
        })
        .collect();
    Ok(NodeResult { ranks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::RTX3090_HBM;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    #[test]
    fn four_rank_node_is_symmetric_within_shard_noise() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let node = run_node(&scn, RTX3090_HBM).unwrap();
        assert_eq!(node.ranks.len(), 4);
        node.check_symmetry().unwrap();
        let rank0 = node.rank0().expect("run_node returned ranks").peak_reserved;
        assert!(node.total_peak_reserved() >= 4 * rank0 * 99 / 100);
        // Each rank carried its own index.
        for (i, r) in node.ranks.iter().enumerate() {
            assert!(!r.summary.oom, "rank {i} OOMed");
        }
    }

    #[test]
    fn zero_world_is_rejected() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.world = 0;
        let err = run_node(&scn, RTX3090_HBM).unwrap_err();
        assert!(err.contains("world"), "{err}");
    }

    #[test]
    fn empty_rank_set_is_safe_everywhere() {
        let node = NodeResult { ranks: vec![] };
        assert!(node.check_symmetry().is_err());
        assert!(node.rank0().is_none());
        assert_eq!(node.total_peak_reserved(), 0);
        assert_eq!(node.peak_spread(), 0.0);
    }
}
