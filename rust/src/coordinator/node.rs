//! Simulated multi-GPU node: N ranks in lockstep data parallelism, each
//! with its own allocator + profiler. RLHF data parallelism is symmetric
//! (every rank runs the same phases on same-shaped shards), so each rank
//! replays the same op stream; the node verifies cross-rank symmetry and
//! reports per-rank and aggregate statistics.

use crate::experiment::{run_trace, ExperimentResult};
use crate::profiler::ProfileSummary;
use crate::rlhf::sim::{build_trace, SimScenario};

/// Per-node results.
pub struct NodeResult {
    pub ranks: Vec<ExperimentResult>,
}

impl NodeResult {
    pub fn rank0(&self) -> &ProfileSummary {
        &self.ranks[0].summary
    }

    /// All ranks must report identical peaks (symmetric DP).
    pub fn check_symmetry(&self) -> Result<(), String> {
        let r0 = &self.ranks[0].summary;
        for (i, r) in self.ranks.iter().enumerate().skip(1) {
            if r.summary.peak_reserved != r0.peak_reserved
                || r.summary.peak_allocated != r0.peak_allocated
            {
                return Err(format!(
                    "rank {i} diverged: {:?} vs {:?}",
                    (r.summary.peak_reserved, r.summary.peak_allocated),
                    (r0.peak_reserved, r0.peak_allocated)
                ));
            }
        }
        Ok(())
    }

    /// Node-wide peak reserved (Σ ranks).
    pub fn total_peak_reserved(&self) -> u64 {
        self.ranks.iter().map(|r| r.summary.peak_reserved).sum()
    }
}

/// Run `scn` on all `scn.world` ranks of a simulated node.
pub fn run_node(scn: &SimScenario, per_gpu_capacity: u64) -> NodeResult {
    let trace = build_trace(scn);
    let ranks = (0..scn.world)
        .map(|_| run_trace(&trace, per_gpu_capacity))
        .collect();
    NodeResult { ranks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::RTX3090_HBM;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    #[test]
    fn four_rank_node_is_symmetric() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let node = run_node(&scn, RTX3090_HBM);
        assert_eq!(node.ranks.len(), 4);
        node.check_symmetry().unwrap();
        assert_eq!(node.total_peak_reserved(), 4 * node.rank0().peak_reserved);
    }
}
