//! ZeRO partition bookkeeping: which byte range of each flat tensor every
//! rank owns. Invariants (coverage, disjointness) are property-tested.

/// Byte range [start, end) of one rank's shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub rank: u64,
    pub start: u64,
    pub end: u64,
}

impl Shard {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Even partition of `total` bytes across `world` ranks (DeepSpeed flat
/// buffer style: ceil-divided, last rank may be short).
pub fn partition(total: u64, world: u64) -> Vec<Shard> {
    assert!(world > 0);
    let per = total.div_ceil(world);
    (0..world)
        .map(|rank| {
            let start = (per * rank).min(total);
            let end = (per * (rank + 1)).min(total);
            Shard { rank, start, end }
        })
        .collect()
}

/// The rank owning byte offset `off`.
pub fn owner_of(total: u64, world: u64, off: u64) -> u64 {
    assert!(off < total);
    let per = total.div_ceil(world);
    off / per
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn covers_and_disjoint() {
        let shards = partition(1000, 4);
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0].start, 0);
        assert_eq!(shards.last().unwrap().end, 1000);
        for w in shards.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn property_coverage_random() {
        // In-repo property test (no proptest offline): random totals and
        // world sizes; shards must tile [0, total) exactly and owner_of
        // must agree with the shard table.
        let mut rng = Rng::seeded(42);
        for _ in 0..500 {
            let total = rng.gen_range(1_000_000) + 1;
            let world = rng.gen_range(16) + 1;
            let shards = partition(total, world);
            let mut covered = 0;
            for s in &shards {
                assert!(s.start <= s.end);
                covered += s.len();
            }
            assert_eq!(covered, total, "total {total} world {world}");
            for _ in 0..20 {
                let off = rng.gen_range(total);
                let owner = owner_of(total, world, off);
                let s = &shards[owner as usize];
                assert!(s.start <= off && off < s.end);
            }
        }
    }

    #[test]
    fn degenerate_cases() {
        let shards = partition(3, 8);
        let covered: u64 = shards.iter().map(|s| s.len()).sum();
        assert_eq!(covered, 3);
        assert_eq!(partition(0, 4).iter().map(|s| s.len()).sum::<u64>(), 0);
    }
}
