//! Cluster placement plans: which of the four RLHF models lives on which
//! GPU, and whether colocated frozen scorers are phase-time-shared
//! (swapped to host between the experience and training phases, the
//! Hydra-style fusion of "Efficient RLHF", Santacroce et al. 2023).
//!
//! A plan is a per-GPU [`RoleSet`] assignment plus a per-GPU time-shared
//! subset. [`PlacementPlan::scenario_for_gpu`] specializes a base
//! [`SimScenario`] for one GPU — role subset, DP world/rank — so every
//! GPU of the plan emits its *own* trace through
//! [`crate::rlhf::sim::build_trace`].

use crate::rlhf::models::{Role, RoleSet};
use crate::rlhf::sim::SimScenario;

/// How the four RLHF models are spread over a node's GPUs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlacementPlan {
    /// Stable preset name (`colocated`, `time-shared`, `dedicated`).
    pub name: String,
    /// Per-GPU hosted model sets (length = GPU count).
    pub hosted: Vec<RoleSet>,
    /// Per-GPU subset of frozen scorers swapped to host during training.
    pub time_shared: Vec<RoleSet>,
}

impl PlacementPlan {
    /// The paper's baseline: every GPU holds a full data-parallel replica
    /// of all four models.
    pub fn colocated(gpus: u64) -> PlacementPlan {
        PlacementPlan {
            name: "colocated".to_string(),
            hosted: vec![RoleSet::ALL; gpus as usize],
            time_shared: vec![RoleSet::EMPTY; gpus as usize],
        }
    }

    /// Full replicas, but the frozen reference + reward models are swapped
    /// to host memory for the whole training span of every step.
    pub fn time_shared(gpus: u64) -> PlacementPlan {
        PlacementPlan {
            name: "time-shared".to_string(),
            hosted: vec![RoleSet::ALL; gpus as usize],
            time_shared: vec![RoleSet::of(&[Role::Reference, Role::Reward]); gpus as usize],
        }
    }

    /// The training pair (actor + critic) data-parallel over the first
    /// `gpus - 1` GPUs; the frozen scorers live alone on the last GPU and
    /// score shipped sequences. Needs at least 2 GPUs.
    pub fn dedicated(gpus: u64) -> Result<PlacementPlan, String> {
        if gpus < 2 {
            return Err(format!("dedicated placement needs >= 2 GPUs (got {gpus})"));
        }
        let train = RoleSet::of(&[Role::Actor, Role::Critic]);
        let scorers = RoleSet::of(&[Role::Reference, Role::Reward]);
        let mut hosted = vec![train; gpus as usize - 1];
        hosted.push(scorers);
        PlacementPlan {
            name: "dedicated".to_string(),
            time_shared: vec![RoleSet::EMPTY; hosted.len()],
            hosted,
        }
        .validated()
    }

    /// Every preset valid at this GPU count, in stable order.
    pub fn presets(gpus: u64) -> Vec<PlacementPlan> {
        let mut out = vec![Self::colocated(gpus), Self::time_shared(gpus)];
        if let Ok(p) = Self::dedicated(gpus) {
            out.push(p);
        }
        out
    }

    /// Preset lookup by CLI name (`colocated`, `time-shared`/`time_shared`,
    /// `dedicated`).
    pub fn by_name(name: &str, gpus: u64) -> Result<PlacementPlan, String> {
        match name {
            "colocated" => Ok(Self::colocated(gpus)),
            "time-shared" | "time_shared" => Ok(Self::time_shared(gpus)),
            "dedicated" => Self::dedicated(gpus),
            other => Err(format!(
                "unknown placement '{other}' (known: colocated, time-shared, dedicated)"
            )),
        }
    }

    pub fn gpus(&self) -> u64 {
        self.hosted.len() as u64
    }

    /// Indices of the GPUs forming the training data-parallel group (those
    /// hosting the actor).
    pub fn dp_gpus(&self) -> Vec<usize> {
        (0..self.hosted.len())
            .filter(|&g| self.hosted[g].contains(Role::Actor))
            .collect()
    }

    /// GPUs hosting `role`.
    pub fn hosts_of(&self, role: Role) -> Vec<usize> {
        (0..self.hosted.len())
            .filter(|&g| self.hosted[g].contains(role))
            .collect()
    }

    /// Structural invariants: at least one GPU, nothing idle, every model
    /// hosted somewhere, time-sharing restricted to hosted frozen scorers.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_for(RoleSet::ALL)
    }

    /// [`Self::validate`] against a reduced cast: critic-free algorithms
    /// ([`crate::rlhf::program::Algo::roles`]) drop models from
    /// [`Role::ALL`], and a plan is valid for them as long as every
    /// *required* model is hosted somewhere.
    pub fn validate_for(&self, required: RoleSet) -> Result<(), String> {
        if self.hosted.is_empty() {
            return Err("placement plan has no GPUs".to_string());
        }
        if self.time_shared.len() != self.hosted.len() {
            return Err("time_shared/hosted length mismatch".to_string());
        }
        for (g, set) in self.hosted.iter().enumerate() {
            if set.is_empty() {
                return Err(format!("GPU {g} hosts no model"));
            }
        }
        for role in required.iter() {
            if self.hosts_of(role).is_empty() {
                return Err(format!("no GPU hosts the {} model", role.name()));
            }
        }
        for (g, ts) in self.time_shared.iter().enumerate() {
            if !ts.is_subset_of(self.hosted[g]) {
                return Err(format!("GPU {g} time-shares a model it does not host"));
            }
            for role in ts.iter() {
                if role.is_trainable() {
                    return Err(format!(
                        "GPU {g} cannot time-share the trainable {} model",
                        role.name()
                    ));
                }
            }
        }
        Ok(())
    }

    fn validated(self) -> Result<PlacementPlan, String> {
        self.validate()?;
        Ok(self)
    }

    /// Specialize a base (full-replica, rank-0) scenario for GPU `g`: its
    /// hosted role subset, its time-shared set, and its position in the
    /// training DP group (scorer-only GPUs hold unsharded replicas, so
    /// they run as a world of one). A GPU *outside* the DP group serves
    /// every DP rank — all `dp` ranks' rollouts fan in to it — so its
    /// per-step batch scales by the DP group size.
    pub fn scenario_for_gpu(&self, base: &SimScenario, g: usize) -> SimScenario {
        let mut s = base.clone();
        s.roles = self.hosted[g];
        s.time_shared = self.time_shared[g];
        let dp = self.dp_gpus();
        match dp.iter().position(|&x| x == g) {
            Some(r) => {
                s.world = dp.len() as u64;
                s.rank = r as u64;
            }
            None => {
                s.world = 1;
                s.rank = 0;
                s.framework.rollout_batch *= dp.len().max(1) as u64;
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    #[test]
    fn presets_validate_and_cover_every_model() {
        for gpus in [2u64, 3, 4, 8] {
            let presets = PlacementPlan::presets(gpus);
            assert!(presets.len() >= 3, "gpus {gpus}");
            for p in &presets {
                p.validate().unwrap_or_else(|e| panic!("{}: {e}", p.name));
                assert_eq!(p.gpus(), gpus);
            }
        }
    }

    #[test]
    fn dedicated_splits_training_from_scoring() {
        let p = PlacementPlan::dedicated(4).unwrap();
        assert_eq!(p.dp_gpus(), vec![0, 1, 2]);
        assert_eq!(p.hosts_of(Role::Reward), vec![3]);
        assert!(!p.hosted[3].contains(Role::Actor));
        assert!(PlacementPlan::dedicated(1).is_err());
    }

    #[test]
    fn by_name_round_trips() {
        for name in ["colocated", "time-shared", "dedicated"] {
            let p = PlacementPlan::by_name(name, 2).unwrap();
            assert_eq!(p.name, name);
        }
        assert_eq!(PlacementPlan::by_name("time_shared", 2).unwrap().name, "time-shared");
        assert!(PlacementPlan::by_name("bogus", 2).is_err());
    }

    #[test]
    fn scenario_specialization_assigns_dp_ranks() {
        let base = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        let p = PlacementPlan::dedicated(3).unwrap();
        let s0 = p.scenario_for_gpu(&base, 0);
        assert_eq!((s0.world, s0.rank), (2, 0));
        assert!(s0.roles.contains(Role::Actor));
        assert!(!s0.roles.contains(Role::Reward));
        let s1 = p.scenario_for_gpu(&base, 1);
        assert_eq!((s1.world, s1.rank), (2, 1));
        // The scorer GPU is outside the DP group: unsharded world of one.
        let s2 = p.scenario_for_gpu(&base, 2);
        assert_eq!((s2.world, s2.rank), (1, 0));
        assert!(s2.roles.contains(Role::Reference));
        assert!(!s2.roles.contains(Role::Critic));
    }

    #[test]
    fn validate_for_reduced_casts() {
        use crate::rlhf::program::Algo;
        // A plan missing the critic is invalid for PPO's full cast but
        // valid for GRPO's critic-free one.
        let mut p = PlacementPlan::colocated(2);
        p.hosted = vec![
            RoleSet::of(&[Role::Actor, Role::Reference]),
            RoleSet::of(&[Role::Reward]),
        ];
        p.time_shared = vec![RoleSet::EMPTY; 2];
        assert!(p.validate().is_err());
        assert!(p.validate_for(Algo::Grpo.roles()).is_ok());
        assert!(p.validate_for(Algo::Dpo.roles()).is_ok());
    }

    #[test]
    fn time_shared_rejects_trainables() {
        let mut p = PlacementPlan::colocated(2);
        p.time_shared[0] = RoleSet::of(&[Role::Actor]);
        assert!(p.validate().is_err());
    }
}
