//! Cluster scheduling: lower a [`PlacementPlan`] to one sweep cell per
//! GPU, then aggregate the per-GPU profiles into a [`ClusterRun`] — the
//! per-GPU peaks plus a modeled PPO step time that charges every
//! cross-GPU byte through [`super::collective`].
//!
//! The step-time model: GPUs run the phase pipeline in lockstep, so one
//! step costs the *slowest* GPU's compute, plus point-to-point experience
//! shipping for models hosted away from the actor, plus the data-parallel
//! gradient synchronisation the single-GPU traces cannot see (ZeRO-2/3
//! reduce-scatter is already charged inside each trace; ZeRO-0/1 gradients
//! all-reduce here).

use super::collective;
use super::placement::PlacementPlan;
use crate::alloc::AllocatorConfig;
use crate::experiment::run_scenario;
use crate::mem::DType;
use crate::profiler::ProfileSummary;
use crate::rlhf::models::{Role, RoleSet};
use crate::rlhf::program::{Algo, PhaseProgram, Sharing};
use crate::rlhf::sim::SimScenario;
use crate::sweep::{SweepCell, SweepRunner};
use crate::util::json::Json;

/// Per-hop launch latency charged on ring collectives and P2P copies (µs).
pub const HOP_LATENCY_US: f64 = 5.0;

/// One GPU's share of a cluster run.
#[derive(Debug, Clone)]
pub struct GpuLoad {
    pub gpu: u64,
    pub roles: RoleSet,
    pub peak_reserved: u64,
    pub peak_allocated: u64,
    pub frag: u64,
    /// This GPU's whole-run modeled time (compute + allocator), µs.
    pub compute_us: f64,
    pub oom: bool,
}

/// Aggregated outcome of running one scenario under one placement plan.
#[derive(Debug, Clone)]
pub struct ClusterRun {
    pub plan: PlacementPlan,
    pub gpus: Vec<GpuLoad>,
    /// Cross-GPU experience shipping per PPO step, µs.
    pub p2p_us: f64,
    /// Data-parallel gradient synchronisation per PPO step, µs.
    pub collective_us: f64,
    /// Modeled wall time of one PPO step, µs.
    pub step_time_us: f64,
}

impl ClusterRun {
    /// Peak reserved of the most loaded GPU — the number that must fit
    /// the per-GPU capacity.
    pub fn max_peak_reserved(&self) -> u64 {
        self.gpus.iter().map(|g| g.peak_reserved).max().unwrap_or(0)
    }

    /// Σ per-GPU peaks — the cluster's total HBM bill.
    pub fn total_peak_reserved(&self) -> u64 {
        self.gpus.iter().map(|g| g.peak_reserved).sum()
    }

    pub fn oom(&self) -> bool {
        self.gpus.iter().any(|g| g.oom)
    }

    /// Every GPU completed and fits `per_gpu_capacity`.
    pub fn fits(&self, per_gpu_capacity: u64) -> bool {
        !self.oom() && self.max_peak_reserved() <= per_gpu_capacity
    }

    /// Deterministic JSON object (per-GPU peaks + step-time breakdown; no
    /// wall-clock, no worker count).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", Json::str(self.plan.name.clone())),
            ("gpus", Json::from(self.plan.gpus())),
            (
                "per_gpu",
                Json::Arr(
                    self.gpus
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("gpu", Json::from(g.gpu)),
                                ("models", Json::str(g.roles.label())),
                                ("reserved", Json::from(g.peak_reserved)),
                                ("allocated", Json::from(g.peak_allocated)),
                                ("frag", Json::from(g.frag)),
                                ("compute_us", Json::from(g.compute_us)),
                                ("oom", Json::from(g.oom)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("max_reserved", Json::from(self.max_peak_reserved())),
            ("total_reserved", Json::from(self.total_peak_reserved())),
            ("p2p_us", Json::from(self.p2p_us)),
            ("collective_us", Json::from(self.collective_us)),
            ("step_time_us", Json::from(self.step_time_us)),
            ("oom", Json::from(self.oom())),
        ])
    }
}

/// Lower `plan` over `base` to one [`SweepCell`] per GPU, keyed
/// `{key_prefix}/gpu{g}` — the unit of work the sweep worker pool runs,
/// which is what makes `rlhf-mem cluster --jobs N` deterministic for any
/// `N` (every GPU's trace replays in isolation; aggregation is serial).
pub fn plan_cells(
    key_prefix: &str,
    strategy_label: &str,
    plan: &PlacementPlan,
    base: &SimScenario,
    capacity: u64,
) -> Vec<SweepCell> {
    (0..plan.hosted.len())
        .map(|g| {
            let scenario = plan.scenario_for_gpu(base, g);
            SweepCell {
                key: format!("{key_prefix}/gpu{g}"),
                framework: base.framework.kind.name().to_string(),
                model: base.models.policy_arch.name.clone(),
                strategy: strategy_label.to_string(),
                mode: base.mode,
                policy: base.policy,
                algo: base.algo,
                sharing: base.sharing,
                alloc_label: "default".to_string(),
                alloc_cfg: AllocatorConfig::default(),
                scenario,
                capacity,
            }
        })
        .collect()
}

/// Combine the per-GPU summaries (in GPU order) into a [`ClusterRun`].
pub fn aggregate(
    plan: &PlacementPlan,
    base: &SimScenario,
    summaries: &[ProfileSummary],
) -> Result<ClusterRun, String> {
    plan.validate_for(base.algo.roles())?;
    if summaries.len() != plan.hosted.len() {
        return Err(format!(
            "plan '{}' has {} GPUs but {} summaries",
            plan.name,
            plan.hosted.len(),
            summaries.len()
        ));
    }
    let gpus: Vec<GpuLoad> = summaries
        .iter()
        .enumerate()
        .map(|(g, s)| GpuLoad {
            gpu: g as u64,
            // Report the models that actually exist in this run: hosted
            // roles ∩ the algorithm's cast (a GRPO "actor+critic" GPU
            // instantiates no critic).
            roles: plan.hosted[g].intersect(base.algo.roles()),
            peak_reserved: s.peak_reserved,
            peak_allocated: s.peak_allocated,
            frag: s.frag,
            compute_us: s.total_time_us,
            oom: s.oom,
        })
        .collect();

    let steps = base.steps.max(1) as f64;
    let slowest = gpus.iter().map(|g| g.compute_us).fold(0.0, f64::max) / steps;
    let p2p_us = p2p_us_per_step(plan, base);
    let collective_us = collective_us_per_step(plan, base);
    Ok(ClusterRun {
        plan: plan.clone(),
        gpus,
        p2p_us,
        collective_us,
        step_time_us: slowest + p2p_us + collective_us,
    })
}

/// The stable configuration key (`cluster/w{world}/{plan}/{strategy}`,
/// with `/{algo}` appended for non-PPO algorithms and `/{sharing}` for
/// non-separate placements) shared by `rlhf-mem cluster` JSONL and the
/// planner's `ClusterCandidate::key`, so the two outputs stay
/// cross-referencable.
pub fn cluster_key(
    world: u64,
    plan_name: &str,
    strategy_label: &str,
    algo: Algo,
    sharing: Sharing,
) -> String {
    let mut key = format!("cluster/w{world}/{plan_name}/{strategy_label}");
    if algo != Algo::Ppo {
        key.push('/');
        key.push_str(algo.name());
    }
    if sharing != Sharing::Separate {
        key.push('/');
        key.push_str(sharing.name());
    }
    key
}

/// One fully-specified cluster configuration: a keyed placement plan over
/// a base scenario — the unit both `rlhf-mem cluster` and
/// `planner::plan_cluster` feed to [`run_configs`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub key: String,
    pub strategy_label: String,
    pub plan: PlacementPlan,
    pub base: SimScenario,
}

/// The outcome of running a batch of configurations through one sweep
/// pool: per-config runs in input order plus the pool's bookkeeping.
#[derive(Debug)]
pub struct ClusterBatch {
    pub runs: Vec<ClusterRun>,
    /// GPU traces executed across the batch.
    pub cells: usize,
    pub wall_seconds: f64,
    pub jobs: usize,
}

/// Run every GPU of every configuration through one [`SweepRunner`] pool
/// and aggregate per configuration. Cells execute in isolation and
/// aggregation is serial, so the runs are byte-identical for any `jobs` —
/// the shared engine behind `rlhf-mem cluster` and `advise --cluster`.
pub fn run_configs(
    configs: &[ClusterConfig],
    capacity: u64,
    jobs: usize,
) -> Result<ClusterBatch, String> {
    let mut cells = Vec::new();
    let mut slices: Vec<(usize, usize)> = Vec::with_capacity(configs.len());
    for c in configs {
        let pc = plan_cells(&c.key, &c.strategy_label, &c.plan, &c.base, capacity);
        slices.push((cells.len(), pc.len()));
        cells.extend(pc);
    }
    let cell_count = cells.len();
    let sweep = SweepRunner::new(jobs).run(cells);
    let mut runs = Vec::with_capacity(configs.len());
    for (i, c) in configs.iter().enumerate() {
        let (start, len) = slices[i];
        let summaries: Vec<ProfileSummary> = sweep.cells[start..start + len]
            .iter()
            .map(|r| r.summary.clone())
            .collect();
        runs.push(aggregate(&c.plan, &c.base, &summaries)?);
    }
    Ok(ClusterBatch {
        runs,
        cells: cell_count,
        wall_seconds: sweep.wall_seconds,
        jobs: sweep.jobs,
    })
}

/// Serial convenience: run every GPU of `plan` and aggregate (the CLI and
/// planner go through [`run_configs`] + the sweep pool instead).
pub fn run_plan(
    plan: &PlacementPlan,
    base: &SimScenario,
    per_gpu_capacity: u64,
) -> Result<ClusterRun, String> {
    plan.validate_for(base.algo.roles())?;
    let summaries: Vec<ProfileSummary> = (0..plan.hosted.len())
        .map(|g| {
            let scn = plan.scenario_for_gpu(base, g);
            run_scenario(&scn, per_gpu_capacity).summary
        })
        .collect();
    aggregate(plan, base, &summaries)
}

/// Bytes one PPO step ships between GPUs for every model hosted away from
/// the actor. Every DP rank's rollout fans in, so the shipped batch is
/// `rollout_batch × dp`. The sequences + attention mask travel **once per
/// remote GPU** (reference and reward sharing a scorer GPU share one
/// copy); each remote model's head outputs travel back, and a remote
/// critic additionally receives the advantages/returns computed on the
/// actor's GPUs. Which scorers exist at all — and which score a second
/// sequence set (DPO pairs, ReMax's greedy baseline at the reward model)
/// — comes from the scenario's compiled [`PhaseProgram`]: critic-free
/// algorithms ship less, paired scorers ship double.
fn remote_wire_bytes(plan: &PlacementPlan, base: &SimScenario) -> u64 {
    let fw = &base.framework;
    let dp = plan.dp_gpus().len().max(1) as u64;
    let b = fw.rollout_batch * dp;
    let s = fw.total_seq();
    let seq_down = 2 * b * s * DType::I64.bytes(); // sequences + mask
    let actor_gpus = plan.hosts_of(Role::Actor);
    let infers = PhaseProgram::compile(base).scorer_infers();
    let mut wire = 0;
    let mut seq_shipped_to: Vec<usize> = Vec::new();
    for &(role, pairs) in &infers {
        let hosts = plan.hosts_of(role);
        let remote = hosts.iter().all(|g| !actor_gpus.contains(g));
        if !remote {
            continue;
        }
        for &g in &hosts {
            if !seq_shipped_to.contains(&g) {
                seq_shipped_to.push(g);
                // The sequence set travels once per remote GPU — doubled
                // when *any* scorer that GPU hosts consumes a second set
                // (a shared reference+reward scorer GPU under ReMax still
                // needs the greedy rollout's sequences).
                let gpu_factor = infers
                    .iter()
                    .filter(|(r, _)| plan.hosted[g].contains(*r))
                    .map(|&(_, p)| if p { 2 } else { 1 })
                    .max()
                    .unwrap_or(1);
                wire += seq_down * gpu_factor;
            }
        }
        let outputs_up = match role {
            Role::Reference => b * s * 4, // ref logprobs
            Role::Reward => b * 4,        // sequence rewards
            Role::Critic => b * s * 4,    // values
            Role::Actor => unreachable!(),
        };
        wire += outputs_up * if pairs { 2 } else { 1 };
        if role == Role::Critic {
            // Advantages + returns stream back down for the value update.
            wire += 2 * b * s * 4;
        }
    }
    wire
}

fn p2p_us_per_step(plan: &PlacementPlan, base: &SimScenario) -> f64 {
    collective::p2p_time_us(remote_wire_bytes(plan, base), base.gpu.link_bw, HOP_LATENCY_US)
}

/// Per-step gradient synchronisation across the training DP group. The
/// single-GPU traces already charge ZeRO-2/3 reduce-scatter; ZeRO-0/1
/// all-reduce their dense gradients here instead. The set of training
/// engines comes from the compiled [`PhaseProgram`] (PPO syncs actor +
/// critic; critic-free algorithms only the actor).
fn collective_us_per_step(plan: &PlacementPlan, base: &SimScenario) -> f64 {
    let dp = plan.dp_gpus().len() as u64;
    if dp <= 1 || base.strategy.zero.partitions_gradients() {
        return 0.0;
    }
    let mut us = 0.0;
    for role in PhaseProgram::compile(base).train_roles() {
        let grads = trainable_bytes_f16(base, role);
        // All-reduce = reduce-scatter + all-gather: 2x the ring volume.
        us += 2.0 * collective::ring_time_us(grads, dp, base.gpu.link_bw, HOP_LATENCY_US);
    }
    us
}

// The gradient payload sizing (fp16 bytes of `role`'s trainable tensors
// under the scenario's strategy *and sharing*) lives with the trace
// emitter — `crate::rlhf::sim::trainable_bytes_f16` — so the collective
// model can never drift from what the traces actually train.
use crate::rlhf::sim::trainable_bytes_f16;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::RTX3090_HBM;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    fn base() -> SimScenario {
        let mut s = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        s.steps = 1;
        s
    }

    #[test]
    fn colocated_plan_loads_every_gpu_evenly() {
        let plan = PlacementPlan::colocated(2);
        let run = run_plan(&plan, &base(), RTX3090_HBM).unwrap();
        assert_eq!(run.gpus.len(), 2);
        assert!(!run.oom());
        // Symmetric replicas: identical within shard-remainder noise.
        let (a, b) = (run.gpus[0].peak_reserved, run.gpus[1].peak_reserved);
        let spread = a.abs_diff(b) as f64 / a.max(b) as f64;
        assert!(spread < 0.01, "{a} vs {b}");
        // No remote model, ZeRO-0 on 2 ranks: gradients all-reduce.
        assert_eq!(run.p2p_us, 0.0);
        assert!(run.collective_us > 0.0);
        assert!(run.step_time_us > 0.0);
    }

    #[test]
    fn dedicated_plan_unloads_the_scorer_gpu_and_ships_bytes() {
        let plan = PlacementPlan::dedicated(2).unwrap();
        let run = run_plan(&plan, &base(), RTX3090_HBM).unwrap();
        assert_eq!(run.gpus.len(), 2);
        // The scorer GPU (frozen models only, no optimizer/training) is
        // much lighter than the training GPU.
        assert!(run.gpus[1].peak_reserved < run.gpus[0].peak_reserved);
        // Remote scorers cost wire time every step.
        assert!(run.p2p_us > 0.0);
        assert_eq!(run.max_peak_reserved(), run.gpus[0].peak_reserved);
        assert_eq!(
            run.total_peak_reserved(),
            run.gpus[0].peak_reserved + run.gpus[1].peak_reserved
        );
    }

    #[test]
    fn time_sharing_cuts_the_training_peak_or_matches() {
        // Phase time-sharing frees the scorer replicas during training, so
        // its per-GPU peak never exceeds the resident colocated plan's.
        let colocated = run_plan(&PlacementPlan::colocated(2), &base(), RTX3090_HBM).unwrap();
        let shared = run_plan(&PlacementPlan::time_shared(2), &base(), RTX3090_HBM).unwrap();
        // (2% slack: the swap churn can shift segment boundaries a little.)
        let cap = colocated.max_peak_reserved() + colocated.max_peak_reserved() / 50;
        assert!(shared.max_peak_reserved() <= cap);
        // ...and pays for it in swap time.
        assert!(shared.step_time_us >= colocated.step_time_us * 0.99);
    }

    #[test]
    fn cluster_key_appends_non_default_axes() {
        assert_eq!(
            cluster_key(2, "colocated", "None", Algo::Ppo, Sharing::Separate),
            "cluster/w2/colocated/None"
        );
        assert_eq!(
            cluster_key(4, "dedicated", "ZeRO-3", Algo::Grpo, Sharing::Separate),
            "cluster/w4/dedicated/ZeRO-3/grpo"
        );
        assert_eq!(
            cluster_key(2, "colocated", "None", Algo::Ppo, Sharing::Lora),
            "cluster/w2/colocated/None/lora"
        );
        assert_eq!(
            cluster_key(4, "dedicated", "ZeRO-3", Algo::Grpo, Sharing::Hydra),
            "cluster/w4/dedicated/ZeRO-3/grpo/hydra"
        );
    }

    #[test]
    fn critic_free_algos_lighten_the_cluster() {
        // dedicated(3): two training GPUs (DP group) + one scorer GPU, so
        // the ZeRO-0 gradient all-reduce is visible.
        let plan = PlacementPlan::dedicated(3).unwrap();
        let ppo_run = run_plan(&plan, &base(), RTX3090_HBM).unwrap();
        let mut grpo = base();
        grpo.algo = Algo::Grpo;
        let grpo_run = run_plan(&plan, &grpo, RTX3090_HBM).unwrap();
        // No critic gradients in the all-reduce, and a lighter training
        // GPU (no critic engine state).
        assert!(grpo_run.collective_us < ppo_run.collective_us);
        assert!(grpo_run.gpus[0].peak_reserved < ppo_run.gpus[0].peak_reserved);
        // DPO's remote reference scores the chosen+rejected pair: double
        // the sequences down and logprobs up, so despite the smaller cast
        // it ships *more* per step than PPO's dedicated scorers.
        let mut dpo = base();
        dpo.algo = Algo::Dpo;
        let dpo_run = run_plan(&plan, &dpo, RTX3090_HBM).unwrap();
        assert!(dpo_run.p2p_us > ppo_run.p2p_us);
    }

    #[test]
    fn shared_backbones_shrink_the_gradient_allreduce() {
        // Under ZeRO-0 the critic's dense gradients dominate the
        // all-reduce; LoRA sharing shrinks its payload to adapters+head
        // and the resident footprint to one backbone per pair.
        let plan = PlacementPlan::colocated(2);
        let sep = run_plan(&plan, &base(), RTX3090_HBM).unwrap();
        let mut shared = base();
        shared.sharing = Sharing::Lora;
        let lora = run_plan(&plan, &shared, RTX3090_HBM).unwrap();
        assert!(lora.collective_us < sep.collective_us);
        assert!(lora.max_peak_reserved() < sep.max_peak_reserved());
    }

    #[test]
    fn aggregate_rejects_mismatched_summary_counts() {
        let plan = PlacementPlan::colocated(2);
        assert!(aggregate(&plan, &base(), &[]).is_err());
    }

    #[test]
    fn zero2_skips_the_allreduce_charge() {
        let mut b2 = base();
        b2.strategy = StrategyConfig::zero2();
        let run = run_plan(&PlacementPlan::colocated(2), &b2, RTX3090_HBM).unwrap();
        assert_eq!(run.collective_us, 0.0, "reduce-scatter lives in-trace");
    }

    #[test]
    fn plan_cells_key_every_gpu() {
        let plan = PlacementPlan::dedicated(3).unwrap();
        let cells = plan_cells("cluster/w3/dedicated/None", "None", &plan, &base(), RTX3090_HBM);
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].key, "cluster/w3/dedicated/None/gpu0");
        assert_eq!(cells[2].key, "cluster/w3/dedicated/None/gpu2");
        assert_eq!(cells[0].scenario.world, 2);
        assert_eq!(cells[1].scenario.rank, 1);
        assert_eq!(cells[2].scenario.world, 1);
        // Both DP ranks' rollouts fan in to the scorer GPU.
        assert_eq!(
            cells[2].scenario.framework.rollout_batch,
            2 * base().framework.rollout_batch
        );
        assert_eq!(
            cells[0].scenario.framework.rollout_batch,
            base().framework.rollout_batch
        );
    }

    #[test]
    fn run_configs_matches_serial_run_plan() {
        let plan = PlacementPlan::dedicated(2).unwrap();
        let config = ClusterConfig {
            key: "cluster/w2/dedicated/None".to_string(),
            strategy_label: "None".to_string(),
            plan: plan.clone(),
            base: base(),
        };
        let batch = run_configs(&[config], RTX3090_HBM, 2).unwrap();
        assert_eq!(batch.runs.len(), 1);
        assert_eq!(batch.cells, 2);
        let serial = run_plan(&plan, &base(), RTX3090_HBM).unwrap();
        assert_eq!(
            batch.runs[0].to_json().to_string(),
            serial.to_json().to_string(),
            "pooled and serial aggregation must agree"
        );
    }
}
