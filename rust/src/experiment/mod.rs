//! Experiment runner: scenario → trace → allocator+profiler → summary.
//! This is the API every bench, example and CLI subcommand calls — either
//! directly for one-off runs, or through [`crate::sweep`] which shards many
//! of these runs across a worker pool.
//!
//! Each run owns its whole pipeline: the profiler is a plain value passed
//! to [`replay()`] as the phase/event sink, and the allocator logs events
//! internally instead of holding a shared observer. Everything is `Send`,
//! so `run_scenario` can execute on any worker thread with zero shared
//! state between concurrent runs.

use crate::alloc::{AllocatorConfig, CachingAllocator};
use crate::obs::ObsStack;
use crate::profiler::{MemoryProfiler, ProfileSummary};
use crate::rlhf::sim::{build_trace, SimScenario};
use crate::trace::{replay, ReplayResult};
use crate::util::bytes::GIB;

/// Result of one profiled run.
pub struct ExperimentResult {
    pub summary: ProfileSummary,
    pub profiler: MemoryProfiler,
    pub replay: ReplayResult,
    pub final_reserved: u64,
    pub final_allocated: u64,
}

/// GPU capacities of the paper's two testbeds.
pub const RTX3090_HBM: u64 = 24 * GIB;
pub const A100_HBM: u64 = 80 * GIB;

/// Run one scenario on a device of `capacity` bytes and collect the
/// profile. Replay continues to completion or first OOM.
pub fn run_scenario(scn: &SimScenario, capacity: u64) -> ExperimentResult {
    run_scenario_with(scn, capacity, &AllocatorConfig::default())
}

/// [`run_scenario`] with explicit allocator tunables — how the sweep
/// engine's allocator axis and the planner's `PYTORCH_CUDA_ALLOC_CONF`
/// candidates (`max_split_size`, `expandable_segments`,
/// `garbage_collection_threshold`) reach the simulator.
pub fn run_scenario_with(
    scn: &SimScenario,
    capacity: u64,
    alloc_cfg: &AllocatorConfig,
) -> ExperimentResult {
    let trace = build_trace(scn);
    run_trace_with(&trace, capacity, alloc_cfg)
}

/// Run a pre-built trace (used by benches that sweep policies over the
/// same workload).
pub fn run_trace(trace: &crate::trace::Trace, capacity: u64) -> ExperimentResult {
    run_trace_with(trace, capacity, &AllocatorConfig::default())
}

/// [`run_trace`] with explicit allocator tunables.
pub fn run_trace_with(
    trace: &crate::trace::Trace,
    capacity: u64,
    alloc_cfg: &AllocatorConfig,
) -> ExperimentResult {
    let mut profiler = MemoryProfiler::new();
    let mut alloc = CachingAllocator::new(capacity, alloc_cfg.clone());
    let replay_res = replay(trace, &mut alloc, &mut profiler);
    debug_assert!(alloc.validate().is_ok(), "{:?}", alloc.validate());
    let final_reserved = alloc.reserved();
    let final_allocated = alloc.allocated();
    let summary = ProfileSummary::collect(&profiler, &alloc, &replay_res);
    ExperimentResult {
        summary,
        profiler,
        replay: replay_res,
        final_reserved,
        final_allocated,
    }
}

/// Result of one run under the full observability stack. The sinks
/// themselves (profiler, peak recorder, Perfetto recorder) stay in the
/// caller's [`ObsStack`]; this carries what the replay alone knows.
pub struct ObservedOutcome {
    pub summary: ProfileSummary,
    pub replay: ReplayResult,
    pub final_reserved: u64,
    pub final_allocated: u64,
    /// Final simulated time (allocator + compute), the close timestamp
    /// for [`ObsStack::finish_perfetto`].
    pub end_time_us: f64,
}

/// Run a pre-built trace feeding every sink in `obs` — the engine behind
/// `rlhf-mem explain` and `--trace-out`.
pub fn run_trace_observed(
    trace: &crate::trace::Trace,
    capacity: u64,
    alloc_cfg: &AllocatorConfig,
    obs: &mut ObsStack,
) -> ObservedOutcome {
    let mut alloc = CachingAllocator::new(capacity, alloc_cfg.clone());
    let replay_res = replay(trace, &mut alloc, obs);
    debug_assert!(alloc.validate().is_ok(), "{:?}", alloc.validate());
    let final_reserved = alloc.reserved();
    let final_allocated = alloc.allocated();
    let end_time_us = alloc.time_us() + replay_res.compute_us;
    let summary = ProfileSummary::collect(&obs.profiler, &alloc, &replay_res);
    ObservedOutcome {
        summary,
        replay: replay_res,
        final_reserved,
        final_allocated,
        end_time_us,
    }
}

/// [`run_trace_observed`] starting from a scenario.
pub fn run_scenario_observed(
    scn: &SimScenario,
    capacity: u64,
    alloc_cfg: &AllocatorConfig,
    obs: &mut ObsStack,
) -> ObservedOutcome {
    let trace = build_trace(scn);
    run_trace_observed(&trace, capacity, alloc_cfg, obs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    #[test]
    fn experiment_result_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ExperimentResult>();
    }

    #[test]
    fn observed_run_matches_plain_run() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let base = run_scenario(&scn, RTX3090_HBM);
        let mut obs = ObsStack::new();
        let observed =
            run_scenario_observed(&scn, RTX3090_HBM, &AllocatorConfig::default(), &mut obs);
        assert_eq!(base.summary, observed.summary);
        let peak = obs.recorder.peak().expect("peak must be recorded");
        assert_eq!(peak.reserved, base.summary.peak_reserved);
        assert_eq!(peak.breakdown.total(), peak.reserved);
    }

    #[test]
    fn deepspeed_none_row_runs() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 2;
        let res = run_scenario(&scn, RTX3090_HBM);
        assert!(!res.summary.oom, "must fit in 24 GiB: {:?}", res.summary);
        // Peak must be in the GiB range (sanity).
        assert!(res.summary.peak_reserved > 8 * GIB);
        assert!(res.summary.peak_reserved < 24 * GIB);
        assert!(res.summary.peak_allocated <= res.summary.peak_reserved);
        assert!(res.profiler.timeline.points().len() > 50);
    }

    #[test]
    fn allocator_knobs_thread_through() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let cfg = AllocatorConfig {
            expandable_segments: true,
            garbage_collection_threshold: Some(0.9),
            ..AllocatorConfig::default()
        };
        let res = run_scenario_with(&scn, RTX3090_HBM, &cfg);
        assert!(!res.summary.oom, "{:?}", res.summary);
        assert!(res.summary.peak_reserved > 4 * GIB);
        assert!(res.summary.peak_reserved < 24 * GIB);
        // Same scenario, default knobs: the default path is unchanged.
        let base = run_scenario(&scn, RTX3090_HBM);
        let base2 = run_trace(&crate::rlhf::sim::build_trace(&scn), RTX3090_HBM);
        assert_eq!(base.summary, base2.summary);
    }

    #[test]
    fn empty_cache_policy_reduces_peak_reserved() {
        let mk = |policy| {
            let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), policy);
            scn.steps = 2;
            run_scenario(&scn, RTX3090_HBM).summary
        };
        let never = mk(EmptyCachePolicy::Never);
        let both = mk(EmptyCachePolicy::AfterBoth);
        assert!(
            both.frag < never.frag || both.peak_reserved <= never.peak_reserved,
            "empty_cache must not increase frag: never={:?} both={:?}",
            (never.peak_reserved, never.frag),
            (both.peak_reserved, both.frag)
        );
        assert!(both.empty_cache_calls > 0);
    }
}
