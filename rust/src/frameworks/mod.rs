//! Framework profiles: what differs between DeepSpeed-Chat and
//! ColossalChat as far as memory behaviour is concerned — phase structure,
//! batching defaults, generation implementation, and quirks like
//! ColossalChat offloading the inference models to the CPU while the actor
//! and critic train (paper §3, "Workload and Setting").

use crate::strategies::{StrategyConfig, ZeroStage};

/// Which RLHF framework's behaviour to reproduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameworkKind {
    DeepSpeedChat,
    ColossalChat,
}

impl FrameworkKind {
    pub fn name(self) -> &'static str {
        match self {
            FrameworkKind::DeepSpeedChat => "DeepSpeed-Chat",
            FrameworkKind::ColossalChat => "ColossalChat",
        }
    }

    /// Case-insensitive lookup; accepts the short CLI forms (`ds`, `cc`)
    /// and the display names (`DeepSpeed-Chat`, `ColossalChat`).
    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "deepspeed-chat" | "deepspeed" | "ds" => Some(Self::DeepSpeedChat),
            "colossal-chat" | "colossalchat" | "colossal" | "cc" => Some(Self::ColossalChat),
            _ => None,
        }
    }

    /// Do rollout lengths vary step to step by default? DeepSpeed-Chat
    /// pads prompts and answers to the configured maxima, so tensor sizes
    /// repeat exactly; ColossalChat stops at EOS, and the resulting size
    /// drift is a major source of cache-reuse failure. The single source
    /// of the jitter default for presets, sweep grids, configs and the
    /// planner.
    pub fn default_len_jitter(self) -> bool {
        self == FrameworkKind::ColossalChat
    }
}

/// How `generate()` manages logits (paper Appendix B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenerationImpl {
    /// HuggingFace-style: per-step `[b, vocab]` logits, dynamic KV concat.
    HuggingFace,
    /// The original ColossalChat implementation the paper replaced: keeps
    /// the full `[b, s, vocab]` logits of every step ("exceptionally
    /// high" memory).
    ColossalOriginal,
}

/// A framework's memory-relevant configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameworkProfile {
    pub kind: FrameworkKind,
    /// Rollout (experience) batch per GPU.
    pub rollout_batch: u64,
    /// Micro-batch used for the four inference evaluations.
    pub infer_micro_batch: u64,
    /// Micro-batch used for actor/critic training.
    pub train_micro_batch: u64,
    pub prompt_len: u64,
    pub gen_len: u64,
    pub generation: GenerationImpl,
    /// ColossalChat: move reference+reward replicas to host during the
    /// training phases (re-uploaded next experience phase).
    pub offload_inference_models_during_training: bool,
    /// PPO epochs over each experience batch.
    pub ppo_epochs: u64,
    /// DeepSpeed-Chat hybrid engine: a fused inference-specialized copy of
    /// the actor's weights lives alongside the training copy (except under
    /// ZeRO-3, where generation materializes it transiently from gathers).
    pub hybrid_engine: bool,
}

impl FrameworkProfile {
    /// DeepSpeed-Chat defaults (paper: batch size 2; seqs 256 prompt +
    /// 256 generated).
    pub fn deepspeed_chat() -> Self {
        FrameworkProfile {
            kind: FrameworkKind::DeepSpeedChat,
            rollout_batch: 2,
            infer_micro_batch: 2,
            train_micro_batch: 2,
            prompt_len: 256,
            gen_len: 256,
            generation: GenerationImpl::HuggingFace,
            offload_inference_models_during_training: false,
            ppo_epochs: 1,
            hybrid_engine: true,
        }
    }

    /// ColossalChat (paper: batch size 32; it offloads inference models
    /// during training; generation replaced with HF's per Appendix B).
    /// The rollout of 32 is consumed in micro-batches — 8 for inference
    /// scoring, 2 for training — matching a 24 GB budget at OPT-1.3b the
    /// way the upstream defaults do.
    pub fn colossal_chat() -> Self {
        FrameworkProfile {
            kind: FrameworkKind::ColossalChat,
            rollout_batch: 32,
            infer_micro_batch: 8,
            train_micro_batch: 2,
            prompt_len: 128,
            gen_len: 128,
            generation: GenerationImpl::HuggingFace,
            offload_inference_models_during_training: true,
            ppo_epochs: 1,
            hybrid_engine: false,
        }
    }

    pub fn by_kind(kind: FrameworkKind) -> Self {
        match kind {
            FrameworkKind::DeepSpeedChat => Self::deepspeed_chat(),
            FrameworkKind::ColossalChat => Self::colossal_chat(),
        }
    }

    pub fn total_seq(&self) -> u64 {
        self.prompt_len + self.gen_len
    }

    /// Number of inference micro-batches per rollout.
    pub fn infer_chunks(&self) -> u64 {
        self.rollout_batch.div_ceil(self.infer_micro_batch)
    }

    /// Number of training micro-batches per rollout.
    pub fn train_chunks(&self) -> u64 {
        self.rollout_batch.div_ceil(self.train_micro_batch)
    }

    /// Does this framework support the strategy? (ColossalChat has no
    /// ZeRO-1, and the paper reports its all-enabled OPT run failing in
    /// gradient synchronization.)
    pub fn supports(&self, strategy: &StrategyConfig) -> bool {
        match self.kind {
            FrameworkKind::DeepSpeedChat => true,
            FrameworkKind::ColossalChat => strategy.zero != ZeroStage::Z1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        assert_eq!(
            FrameworkKind::by_name("deepspeed-chat"),
            Some(FrameworkKind::DeepSpeedChat)
        );
        assert_eq!(
            FrameworkKind::by_name("colossalchat"),
            Some(FrameworkKind::ColossalChat)
        );
        // Display names round-trip (what `table1 --framework` passes).
        for kind in [FrameworkKind::DeepSpeedChat, FrameworkKind::ColossalChat] {
            assert_eq!(FrameworkKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(FrameworkKind::by_name("x"), None);
        assert!(!FrameworkKind::DeepSpeedChat.default_len_jitter());
        assert!(FrameworkKind::ColossalChat.default_len_jitter());
    }

    #[test]
    fn paper_batch_settings() {
        let ds = FrameworkProfile::deepspeed_chat();
        assert_eq!(ds.rollout_batch, 2);
        assert_eq!(ds.total_seq(), 512);
        assert!(!ds.offload_inference_models_during_training);

        let cc = FrameworkProfile::colossal_chat();
        assert_eq!(cc.rollout_batch, 32);
        assert!(cc.offload_inference_models_during_training);
        assert_eq!(cc.infer_chunks(), 4);
        assert_eq!(cc.train_chunks(), 16);
    }

    #[test]
    fn colossal_rejects_zero1() {
        let cc = FrameworkProfile::colossal_chat();
        assert!(!cc.supports(&StrategyConfig::zero1()));
        assert!(cc.supports(&StrategyConfig::zero3()));
        let ds = FrameworkProfile::deepspeed_chat();
        assert!(ds.supports(&StrategyConfig::zero1()));
    }
}
