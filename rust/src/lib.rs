//! # rlhf-mem
//!
//! A three-layer (Rust coordinator + JAX model + Pallas kernels, AOT via
//! PJRT) reproduction of *"Understanding and Alleviating Memory Consumption
//! in RLHF for LLMs"* (Zhou et al., 2024).
//!
//! The library has two halves that share one RLHF PPO engine:
//!
//! * a **memory-study half** — a faithful simulator of PyTorch's CUDA
//!   caching allocator ([`alloc`]), byte-accurate model memory sizing
//!   ([`mem`]), memory-management strategies as allocation-plan transforms
//!   ([`strategies`]), framework profiles ([`frameworks`]), the profiler
//!   ([`profiler`]) and the paper's `empty_cache()` mitigation
//!   ([`policy`]) — which regenerates every table and figure in the paper;
//! * a **real-compute half** — a PJRT runtime (`runtime`, behind the
//!   `pjrt` cargo feature since it needs the `xla` FFI crate) that loads
//!   AOT-compiled JAX/Pallas artifacts and trains a small transformer with
//!   real PPO end-to-end ([`rlhf`]), proving all layers compose.
//!
//! Both halves are driven through the [`experiment`] runner; the
//! [`sweep`] engine shards many experiments across a worker pool, and is
//! what regenerates every paper table N-core fast. On top of the sweep
//! engine, the [`planner`] searches the whole mitigation space — strategy
//! × `empty_cache` placement × allocator knobs — for the cheapest
//! configuration that fits a user's GPU budget (`rlhf-mem advise`) — and
//! the [`surrogate`] makes that search two-tier: a closed-form model
//! fitted from sweep traces (`rlhf-mem fit`) screens the candidate
//! product, full simulation runs only on the candidates within the
//! model's error envelope of the Pareto frontier, and the resulting
//! frontier is byte-identical to the exhaustive search's (`advise
//! --surrogate`). The [`coordinator`] scales the simulator to a multi-GPU node: cluster
//! placement plans (colocated / time-shared / dedicated), per-GPU traces
//! that genuinely differ, and a step-time model charging cross-GPU bytes
//! through ring/P2P collectives (`rlhf-mem cluster`, `advise --cluster`).
//!
//! Structural properties of a configuration are checkable *before* any
//! simulation: the [`lint`] static verifier (`rlhf-mem lint`) runs
//! dataflow, ownership, collective-matching and abstract peak-bound
//! passes over the compiled phase program and placement plan, and its
//! lower bounds prescreen planner candidates (`advise
//! --prescreen-static`).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

#![forbid(unsafe_code)]

pub mod alloc;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod experiment;
pub mod frameworks;
pub mod lint;
pub mod mem;
pub mod obs;
pub mod planner;
pub mod policy;
pub mod profiler;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod rlhf;
pub mod serve;
pub mod strategies;
pub mod surrogate;
pub mod sweep;
pub mod trace;
pub mod util;
