//! Abstract-interpretation pass: a conservative static peak-memory
//! interval per phase, computed without generating a trace.
//!
//! The abstract domain is an interval `[lo, hi]` of ideal live bytes per
//! [`PhaseKind`]. The anchor is [`sim::init_footprint`] — the exact
//! engine-lifetime bytes `init` allocates on this rank (`P`):
//!
//! - **Lower bound.** Engine state never shrinks below `P` minus the
//!   scorers the simulator may swap out to host mid-step (ColossalChat's
//!   offload of reference/reward during training, and placement-plan
//!   phase time-sharing — both fire only from a training node under
//!   `ScenarioMode::Full`). So `lo(init) = P` and `lo(phase) = P - S`
//!   where `S` is the swappable scorers' replica bytes.
//! - **Upper bound.** `P` plus an experience envelope `E` (every tensor a
//!   step can persist across phases, at doubled batch for greedy
//!   baselines / preference pairs and jitter-free maximum length) plus,
//!   for non-init phases, a working-set envelope `W` that dominates any
//!   phase body's transient churn: per architecture, `13×` the full fp16
//!   replica (covers gathered ZeRO shards, fp16/fp32 gradients, master
//!   copies, Adam scratch and flat buffers), the training-resident
//!   activations, one layer's forward+backward transients, two logits
//!   tensors and twice the full-length KV cache — summed over both
//!   architectures and doubled once more. `init` itself can absorb a
//!   *silent* leading experience load (offline algorithms attribute the
//!   first `LoadExperience` to the init phase mark), hence `hi(init) =
//!   P + E`, not `P`.
//!
//! Soundness — `lo <= phase_peaks(trace) <= hi` for every phase of every
//! configuration — is not argued once and assumed: the `lint_soundness`
//! integration test proves it over the full algo × sharing × strategy ×
//! mode × placement battery, and pins `init`'s peak to exactly `P` where
//! no silent load exists. The planner's `--prescreen-static` relies on
//! one direction only: `lo <= ideal peak <= peak_allocated <=
//! peak_reserved`, so `lo > capacity` proves infeasibility.

use super::diag::{Finding, Span};
use crate::mem::{ActivationModel, DType, KvCacheModel, ParamInventory, SeqShape};
use crate::rlhf::models::Role;
use crate::rlhf::program::{PhaseBody, PhaseProgram};
use crate::rlhf::sim::{self, ScenarioMode, SimScenario};
use crate::trace::PhaseKind;
use crate::util::bytes::fmt_bytes;

/// The static interval for one phase: ideal live bytes stay within
/// `lo..=hi` whenever the phase runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseBound {
    pub phase: PhaseKind,
    pub lo: u64,
    pub hi: u64,
}

/// Replica bytes of the scorers the simulator may host-swap mid-step:
/// zero unless the compiled program actually trains under
/// [`ScenarioMode::Full`] (both swap paths live in the train body).
fn swappable_bytes(scn: &SimScenario, program: &PhaseProgram, fp: &sim::InitFootprint) -> u64 {
    if scn.mode != ScenarioMode::Full {
        return 0;
    }
    let trains_actor = program.nodes.iter().any(|n| {
        matches!(
            n.body,
            PhaseBody::Train {
                role: Role::Actor,
                ..
            }
        )
    });
    let trains_any = program
        .nodes
        .iter()
        .any(|n| matches!(n.body, PhaseBody::Train { .. }));
    let mut s = 0;
    for r in [Role::Reference, Role::Reward] {
        let colossal = trains_actor && scn.framework.offload_inference_models_during_training;
        let time_shared = trains_any && scn.time_shared.contains(r);
        if colossal || time_shared {
            s += fp.role_total(r);
        }
    }
    s
}

/// The experience envelope `E`: every byte one step can persist across
/// phase boundaries, at worst-case batch and length.
fn experience_envelope(scn: &SimScenario) -> u64 {
    let fw = &scn.framework;
    // Greedy baselines and preference pairs at most double the batch;
    // +2 length slack keeps the bound comfortably above any off-by-one
    // in downstream shapes.
    let b = fw.rollout_batch * 2;
    let t = fw.total_seq() + 2;
    // 4 I64 sequence/mask tensors + up to 8 per-token and 8 per-sequence
    // F32 tensors (logprobs, rewards, values, advantages, returns, ...).
    4 * b * t * DType::I64.bytes() + 8 * b * t * 4 + 8 * b * 4
}

/// The working-set envelope `W`: dominates any single phase body's
/// transient churn on top of engine state + experience.
fn working_set_envelope(scn: &SimScenario) -> u64 {
    let fw = &scn.framework;
    let b = fw.rollout_batch * 2;
    let t = fw.total_seq() + 2;
    let sh = SeqShape { batch: b, seq: t };
    let mut w = 0u64;
    for arch in [&scn.models.policy_arch, &scn.models.value_arch] {
        let inv = ParamInventory::build_with_value_head(arch);
        let c = inv.total_bytes(DType::F16);
        let act = ActivationModel::new(arch, DType::F16);
        let kv = KvCacheModel::new(arch, DType::F16);
        let transients: u64 = act.layer_transients(sh).iter().map(|a| a.bytes).sum();
        let backward: u64 = act
            .layer_backward_transients(sh)
            .iter()
            .map(|a| a.bytes)
            .sum();
        w += 13 * c
            + act.train_forward_resident(sh)
            + transients
            + backward
            + 2 * act.logits_bytes(sh)
            + 2 * kv.total_bytes(b, t);
    }
    2 * w
}

/// The exact engine-lifetime floor — `init`'s static lower bound, and
/// the planner prescreen's whole-scenario lower bound (every phase's
/// ideal peak is at least the engine bytes still resident).
pub fn static_lower_max(scn: &SimScenario) -> u64 {
    sim::init_footprint(scn).total()
}

/// Compute the static interval for every phase the compiled program can
/// mark, `init` first, then in first-appearance order.
pub fn static_bounds(scn: &SimScenario) -> Vec<PhaseBound> {
    let program = PhaseProgram::compile(scn);
    let fp = sim::init_footprint(scn);
    let p = fp.total();
    let s = swappable_bytes(scn, &program, &fp);
    let e = experience_envelope(scn);
    let w = working_set_envelope(scn);

    let mut out = vec![PhaseBound {
        phase: PhaseKind::Init,
        lo: p,
        hi: p + e,
    }];
    for node in &program.nodes {
        let Some(kind) = node.kind else { continue };
        if out.iter().any(|b| b.phase == kind) {
            continue;
        }
        out.push(PhaseBound {
            phase: kind,
            lo: p - s,
            hi: p + e + w,
        });
    }
    out
}

/// The bounds pass as lint rules: `RLHF030` (deny) per phase whose lower
/// bound alone exceeds `capacity` — the configuration is *proven*
/// infeasible — and one `RLHF031` (warn) when only upper bounds exceed
/// it, i.e. the static analysis cannot rule an OOM out. Returns the
/// computed bounds so reports can render the interval table.
pub fn check_bounds(
    scn: &SimScenario,
    capacity: u64,
    gpu: Option<u64>,
    findings: &mut Vec<Finding>,
) -> Vec<PhaseBound> {
    let bounds = static_bounds(scn);
    let mut proven_infeasible = false;
    for b in &bounds {
        if b.lo > capacity {
            proven_infeasible = true;
            findings.push(Finding::new(
                "RLHF030",
                format!(
                    "phase {} needs at least {} but capacity is {}",
                    b.phase.name(),
                    fmt_bytes(b.lo),
                    fmt_bytes(capacity)
                ),
                Span {
                    gpu,
                    phase: Some(b.phase.name().to_string()),
                    node: None,
                },
            ));
        }
    }
    if !proven_infeasible {
        if let Some(worst) = bounds.iter().max_by_key(|b| b.hi) {
            if worst.hi > capacity {
                findings.push(Finding::new(
                    "RLHF031",
                    format!(
                        "phase {} may need up to {} against capacity {}: the static \
                         bounds cannot rule an OOM out (simulate to decide)",
                        worst.phase.name(),
                        fmt_bytes(worst.hi),
                        fmt_bytes(capacity)
                    ),
                    Span {
                        gpu,
                        phase: Some(worst.phase.name().to_string()),
                        node: None,
                    },
                ));
            }
        }
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;
    use crate::trace::analysis::phase_peaks;

    #[test]
    fn intervals_are_well_formed() {
        let scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let bounds = static_bounds(&scn);
        assert_eq!(bounds[0].phase, PhaseKind::Init);
        for b in &bounds {
            assert!(b.lo <= b.hi, "{:?}", b);
            assert!(b.lo <= bounds[0].lo, "floor above init floor: {:?}", b);
        }
        // DeepSpeed never host-swaps scorers: the floor is flat.
        assert!(bounds.iter().all(|b| b.lo == bounds[0].lo));
    }

    #[test]
    fn colossal_offload_lowers_the_floor() {
        let scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let bounds = static_bounds(&scn);
        let init = bounds[0];
        let train = bounds
            .iter()
            .find(|b| b.phase == PhaseKind::TrainActor)
            .unwrap();
        assert!(train.lo < init.lo, "{} vs {}", train.lo, init.lo);
    }

    #[test]
    fn bounds_bracket_one_simulated_scenario() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        scn.steps = 2;
        let bounds = static_bounds(&scn);
        for (phase, peak) in phase_peaks(&sim::build_trace(&scn)) {
            let b = bounds.iter().find(|b| b.phase == phase).unwrap();
            assert!(
                b.lo <= peak && peak <= b.hi,
                "{}: {} outside [{}, {}]",
                phase.name(),
                peak,
                b.lo,
                b.hi
            );
        }
    }

    #[test]
    fn capacity_rules_fire_in_order() {
        let scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let floor = static_lower_max(&scn);
        // Below the floor: proven infeasible, no inconclusive warning.
        let mut f = Vec::new();
        check_bounds(&scn, floor - 1, None, &mut f);
        assert!(f.iter().any(|x| x.code == "RLHF030"), "{f:?}");
        assert!(f.iter().all(|x| x.code != "RLHF031"), "{f:?}");
        // Between floor and ceiling: inconclusive only.
        let hi = static_bounds(&scn).iter().map(|b| b.hi).max().unwrap();
        let mut f = Vec::new();
        check_bounds(&scn, hi - 1, None, &mut f);
        assert_eq!(
            f.iter().map(|x| x.code).collect::<Vec<_>>(),
            vec!["RLHF031"]
        );
        // Above the ceiling: clean.
        let mut f = Vec::new();
        check_bounds(&scn, hi, None, &mut f);
        assert!(f.is_empty(), "{f:?}");
    }
}
