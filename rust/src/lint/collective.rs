//! Cross-rank collective-matching pass over a [`PlacementPlan`]: the
//! structural rules [`PlacementPlan::validate_for`] enforces at schedule
//! time, re-expressed as diagnostics (`RLHF020`–`RLHF025`), plus the two
//! genuinely cross-rank checks the dynamic path only hits as a deadlock:
//!
//! - `RLHF026` — a trainable role's hosts partially overlap the training
//!   data-parallel group. ZeRO gradient all-reduce is a group-wide
//!   collective: ranks inside the overlap enter it, ranks outside never
//!   do, and the step deadlocks. (Disjoint hosts are fine — each trains
//!   an independent world-of-one replica; equal sets are the normal DP
//!   group.)
//! - `RLHF027` — a generating algorithm whose rollout producer (the
//!   actor) is hosted nowhere while scorer GPUs wait for shipped
//!   sequences: every P2P receive would block forever.
//!
//! `RLHF010` (warn) flags a sharing group split across GPUs: the base
//! deduplication [`Sharing`] promises exists only on GPUs hosting ≥ 2
//! group members, so a split placement silently pays full-replica cost.

use super::diag::{Finding, Span};
use crate::coordinator::PlacementPlan;
use crate::rlhf::models::{Role, RoleSet};
use crate::rlhf::program::{Algo, Sharing};

/// Run every placement/collective rule, appending findings in
/// deterministic order (structural rules first, mirroring
/// [`PlacementPlan::validate_for`], then collectives, then sharing).
///
/// Returns `false` when the plan's *shape* is broken (`RLHF020`/
/// `RLHF021`) — per-GPU passes cannot index such a plan and must be
/// skipped.
pub fn check_plan(
    plan: &PlacementPlan,
    algo: Algo,
    sharing: Sharing,
    findings: &mut Vec<Finding>,
) -> bool {
    if plan.hosted.is_empty() {
        findings.push(Finding::new(
            "RLHF020",
            "placement plan has no GPUs".to_string(),
            Span::none(),
        ));
        return false;
    }
    if plan.time_shared.len() != plan.hosted.len() {
        findings.push(Finding::new(
            "RLHF021",
            format!(
                "time_shared table covers {} GPUs but hosted covers {}",
                plan.time_shared.len(),
                plan.hosted.len()
            ),
            Span::none(),
        ));
        return false;
    }
    for (g, set) in plan.hosted.iter().enumerate() {
        if set.is_empty() {
            findings.push(Finding::new(
                "RLHF022",
                format!("GPU {g} hosts no model"),
                Span::on_gpu(g as u64),
            ));
        }
    }
    for role in algo.roles().iter() {
        if plan.hosts_of(role).is_empty() {
            findings.push(Finding::new(
                "RLHF023",
                format!("no GPU hosts the {} model", role.name()),
                Span::none(),
            ));
        }
    }
    for (g, ts) in plan.time_shared.iter().enumerate() {
        if !ts.is_subset_of(plan.hosted[g]) {
            findings.push(Finding::new(
                "RLHF024",
                format!(
                    "GPU {g} time-shares {} but hosts only {}",
                    ts.label(),
                    plan.hosted[g].label()
                ),
                Span::on_gpu(g as u64),
            ));
        }
        for role in ts.iter() {
            if role.is_trainable() {
                findings.push(Finding::new(
                    "RLHF025",
                    format!(
                        "GPU {g} time-shares the trainable {} model (its optimizer \
                         state cannot swap out mid-step)",
                        role.name()
                    ),
                    Span::on_gpu(g as u64),
                ));
            }
        }
    }

    // RLHF026: gradient all-reduce group mismatch. The DP group is the
    // actor's host set; any other trainable role must either ride the
    // whole group or live entirely outside it.
    let dp = plan.dp_gpus();
    for role in algo.roles().iter().filter(|r| r.is_trainable()) {
        let hosts = plan.hosts_of(role);
        if hosts.is_empty() || hosts == dp {
            continue;
        }
        let overlap: Vec<usize> = hosts.iter().copied().filter(|g| dp.contains(g)).collect();
        if !overlap.is_empty() {
            findings.push(Finding::new(
                "RLHF026",
                format!(
                    "{} trains on GPUs {hosts:?} but the data-parallel group is {dp:?}: \
                     ranks {overlap:?} would enter a gradient all-reduce the others never \
                     join (deadlock)",
                    role.name(),
                ),
                Span::none(),
            ));
        }
    }

    // RLHF027: P2P consumers with no producer.
    if algo.generates() && dp.is_empty() {
        let consumers: Vec<usize> = (0..plan.hosted.len())
            .filter(|&g| !plan.hosted[g].intersect(algo.roles()).is_empty())
            .collect();
        if !consumers.is_empty() {
            findings.push(Finding::new(
                "RLHF027",
                format!(
                    "no GPU hosts the generating actor, but GPUs {consumers:?} wait for \
                     shipped sequences (P2P receive with no sender)"
                ),
                Span::none(),
            ));
        }
    }

    // RLHF010: a sharing group spread over GPUs that don't all host the
    // same members loses the base deduplication on the partial hosts.
    if sharing != Sharing::Separate {
        let mut seen = RoleSet::EMPTY;
        for role in algo.roles().iter() {
            if seen.contains(role) {
                continue;
            }
            let group = sharing.group_of(role).intersect(algo.roles());
            for r in group.iter() {
                seen = seen.with(r);
            }
            let members: Vec<Role> = group.iter().collect();
            if members.len() < 2 {
                continue;
            }
            let first_hosts = plan.hosts_of(members[0]);
            if members.iter().any(|&m| plan.hosts_of(m) != first_hosts) {
                findings.push(Finding::new(
                    "RLHF010",
                    format!(
                        "sharing group {} is split across GPUs: members are hosted on \
                         different GPU sets, so the shared-base deduplication is lost",
                        group.label()
                    ),
                    Span::none(),
                ));
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.code).collect()
    }

    #[test]
    fn presets_are_clean_for_every_algo() {
        for gpus in [2u64, 4] {
            for plan in PlacementPlan::presets(gpus) {
                for algo in Algo::ALL {
                    // Presets host every role, so every reduced cast fits.
                    let mut findings = Vec::new();
                    assert!(check_plan(&plan, algo, Sharing::Separate, &mut findings));
                    assert!(
                        findings.is_empty(),
                        "{}/{}: {:?}",
                        plan.name,
                        algo.name(),
                        findings
                    );
                }
            }
        }
    }

    #[test]
    fn structural_rules_mirror_validate_for() {
        let mut plan = PlacementPlan::colocated(2);
        plan.hosted = vec![];
        plan.time_shared = vec![];
        let mut f = Vec::new();
        assert!(!check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
        assert_eq!(codes(&f), vec!["RLHF020"]);

        let mut plan = PlacementPlan::colocated(2);
        plan.time_shared.pop();
        let mut f = Vec::new();
        assert!(!check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
        assert_eq!(codes(&f), vec!["RLHF021"]);

        let mut plan = PlacementPlan::colocated(2);
        plan.hosted[1] = RoleSet::EMPTY;
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Dpo, Sharing::Separate, &mut f));
        assert!(codes(&f).contains(&"RLHF022"), "{f:?}");

        let mut plan = PlacementPlan::colocated(2);
        plan.time_shared[0] = RoleSet::of(&[Role::Actor]);
        let mut f = Vec::new();
        check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f);
        assert_eq!(codes(&f), vec!["RLHF025"]);
    }

    #[test]
    fn partial_dp_overlap_is_a_deadlock() {
        // Actor on GPUs {0,1}; critic on {1,2}: rank 1 enters the critic
        // all-reduce, rank 0 never does.
        let mut plan = PlacementPlan::colocated(3);
        plan.hosted = vec![
            RoleSet::of(&[Role::Actor, Role::Reference, Role::Reward]),
            RoleSet::of(&[Role::Actor, Role::Critic]),
            RoleSet::of(&[Role::Critic, Role::Reference, Role::Reward]),
        ];
        plan.time_shared = vec![RoleSet::EMPTY; 3];
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
        assert_eq!(codes(&f), vec!["RLHF026"]);
        // Disjoint critic hosts train independent replicas: no deadlock.
        plan.hosted[1] = RoleSet::of(&[Role::Actor]);
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn missing_generator_blocks_p2p() {
        let mut plan = PlacementPlan::colocated(2);
        plan.hosted = vec![
            RoleSet::of(&[Role::Reference, Role::Reward]),
            RoleSet::of(&[Role::Critic, Role::Reward]),
        ];
        plan.time_shared = vec![RoleSet::EMPTY; 2];
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
        // Actor unhosted fires both the structural and the P2P rule.
        assert!(codes(&f).contains(&"RLHF023"));
        assert!(codes(&f).contains(&"RLHF027"));
        // DPO loads pairs locally: no P2P, only the structural miss.
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Dpo, Sharing::Separate, &mut f));
        assert!(codes(&f).contains(&"RLHF023"));
        assert!(!codes(&f).contains(&"RLHF027"));
    }

    #[test]
    fn split_sharing_group_warns() {
        // Dedicated hosts actor+critic away from reference+reward: under
        // LoRA both pair groups are split.
        let plan = PlacementPlan::dedicated(4).unwrap();
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Ppo, Sharing::Lora, &mut f));
        assert_eq!(codes(&f), vec!["RLHF010", "RLHF010"]);
        // Colocated hosts whole groups everywhere: clean.
        let plan = PlacementPlan::colocated(4);
        let mut f = Vec::new();
        assert!(check_plan(&plan, Algo::Ppo, Sharing::Lora, &mut f));
        assert!(f.is_empty(), "{f:?}");
    }
}
