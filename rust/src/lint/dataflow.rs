//! Dataflow pass: abstract def-use analysis over a compiled
//! [`PhaseProgram`]'s experience tensors, plus the sharing-group
//! ownership rules over the static parameter allocations a scenario
//! implies.
//!
//! The abstract domain is a seven-element resource set — the experience
//! bundle one RLHF step threads between phases (sequences, pair
//! sequences, old/ref logprobs, rewards, values, advantages). Each
//! [`PhaseBody`] *defines* some resources and *requires* others; walking
//! the node list with a live set catches use-before-produce
//! (`RLHF001`), freeing nothing (`RLHF002`), leaks past the step
//! boundary (`RLHF003`), unsatisfiable role requirements (`RLHF004`),
//! redundant definitions (`RLHF005`) and phase-mark/body mismatches
//! (`RLHF006`) — statically, without generating a trace.
//!
//! Roles of the algorithm's cast that this GPU does *not* host are
//! *remote*: their scoring outputs arrive over the wire, so they
//! pre-populate the live set (the coordinator's P2P model ships them;
//! [`super::collective`] checks a producer exists).

use super::diag::{Finding, Span};
use crate::mem::{DType, ParamInventory, ParamKind};
use crate::rlhf::models::{Role, RoleSet};
use crate::rlhf::program::{ExpTensor, PhaseBody, PhaseProgram};
use crate::rlhf::sim::{self, SimScenario};

/// One element of the abstract experience bundle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// Rollout token sequences + attention masks.
    Sequences,
    /// The second sequence set of paired pipelines (DPO's rejected half,
    /// ReMax's greedy-baseline rollout).
    PairSequences,
    /// The actor's old per-token logprobs.
    OldLogprobs,
    /// The frozen reference's per-token logprobs.
    RefLogprobs,
    /// Per-sequence scalar rewards.
    Rewards,
    /// The critic's per-token values.
    Values,
    /// Computed advantages (and value targets where the estimator keeps
    /// returns).
    Advantages,
}

impl Resource {
    pub const ALL: [Resource; 7] = [
        Resource::Sequences,
        Resource::PairSequences,
        Resource::OldLogprobs,
        Resource::RefLogprobs,
        Resource::Rewards,
        Resource::Values,
        Resource::Advantages,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Resource::Sequences => "sequences",
            Resource::PairSequences => "pair_sequences",
            Resource::OldLogprobs => "old_logprobs",
            Resource::RefLogprobs => "ref_logprobs",
            Resource::Rewards => "rewards",
            Resource::Values => "values",
            Resource::Advantages => "advantages",
        }
    }

    fn bit(self) -> u8 {
        match self {
            Resource::Sequences => 1,
            Resource::PairSequences => 2,
            Resource::OldLogprobs => 4,
            Resource::RefLogprobs => 8,
            Resource::Rewards => 16,
            Resource::Values => 32,
            Resource::Advantages => 64,
        }
    }
}

/// A set of [`Resource`]s (bitset).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResSet(u8);

impl ResSet {
    pub const EMPTY: ResSet = ResSet(0);

    pub fn of(rs: &[Resource]) -> ResSet {
        rs.iter().fold(ResSet::EMPTY, |s, &r| s.with(r))
    }

    #[must_use]
    pub fn with(self, r: Resource) -> ResSet {
        ResSet(self.0 | r.bit())
    }

    #[must_use]
    pub fn union(self, other: ResSet) -> ResSet {
        ResSet(self.0 | other.0)
    }

    /// Elements of `self` not in `other`.
    #[must_use]
    pub fn minus(self, other: ResSet) -> ResSet {
        ResSet(self.0 & !other.0)
    }

    #[must_use]
    pub fn intersect(self, other: ResSet) -> ResSet {
        ResSet(self.0 & other.0)
    }

    pub fn contains(self, r: Resource) -> bool {
        self.0 & r.bit() != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub fn iter(self) -> impl Iterator<Item = Resource> {
        Resource::ALL.into_iter().filter(move |&r| self.contains(r))
    }

    /// `sequences+rewards`-style label (`-` when empty).
    pub fn label(self) -> String {
        if self.is_empty() {
            return "-".to_string();
        }
        self.iter().map(Resource::name).collect::<Vec<_>>().join("+")
    }
}

/// Resources a node produces into the step's experience bundle.
pub fn node_defs(body: &PhaseBody) -> ResSet {
    match body {
        PhaseBody::Generation { greedy_baseline }
        | PhaseBody::RemoteSequences { greedy_baseline } => {
            let mut s = ResSet::of(&[Resource::Sequences]);
            if *greedy_baseline {
                s = s.with(Resource::PairSequences);
            }
            s
        }
        PhaseBody::LoadExperience { tensors } => {
            let mut s = ResSet::EMPTY;
            let seq_sets = tensors
                .iter()
                .filter(|t| matches!(t, ExpTensor::SeqTokens))
                .count();
            if seq_sets >= 1 {
                s = s.with(Resource::Sequences);
            }
            if seq_sets >= 2 {
                s = s.with(Resource::PairSequences);
            }
            if tensors
                .iter()
                .any(|t| matches!(t, ExpTensor::PerTokenF32 | ExpTensor::PerSeqF32))
            {
                // Pre-collected scalar/per-token experience stands in for
                // the whole scored bundle.
                s = s.union(ResSet::of(&[
                    Resource::OldLogprobs,
                    Resource::RefLogprobs,
                    Resource::Rewards,
                    Resource::Values,
                    Resource::Advantages,
                ]));
            }
            s
        }
        PhaseBody::Infer { role, .. } => ResSet::of(&[scorer_output(*role)]),
        PhaseBody::Advantages { .. } => ResSet::of(&[Resource::Advantages]),
        PhaseBody::Train { .. } | PhaseBody::FreeExperience => ResSet::EMPTY,
    }
}

/// Resources a node consumes — what must be live when it runs.
pub fn node_reqs(body: &PhaseBody) -> ResSet {
    use crate::rlhf::program::{AdvantageKind, LossKind};
    match body {
        PhaseBody::Generation { .. }
        | PhaseBody::RemoteSequences { .. }
        | PhaseBody::LoadExperience { .. } => ResSet::EMPTY,
        PhaseBody::Infer { role: _, pairs } => {
            let mut s = ResSet::of(&[Resource::Sequences]);
            if *pairs {
                s = s.with(Resource::PairSequences);
            }
            s
        }
        PhaseBody::Advantages { kind } => match kind {
            AdvantageKind::Gae => ResSet::of(&[Resource::Rewards, Resource::Values]),
            AdvantageKind::GroupRelative | AdvantageKind::GreedyBaseline => {
                ResSet::of(&[Resource::Rewards])
            }
        },
        PhaseBody::Train { loss, .. } => match loss {
            LossKind::PpoClip => ResSet::of(&[
                Resource::Sequences,
                Resource::OldLogprobs,
                Resource::RefLogprobs,
                Resource::Advantages,
            ]),
            LossKind::ValueLoss => ResSet::of(&[
                Resource::Sequences,
                Resource::Values,
                Resource::Advantages,
            ]),
            LossKind::Preference => ResSet::of(&[
                Resource::Sequences,
                Resource::PairSequences,
                Resource::RefLogprobs,
            ]),
        },
        PhaseBody::FreeExperience => ResSet::EMPTY,
    }
}

/// The experience output a role's scoring pass persists.
fn scorer_output(role: Role) -> Resource {
    match role {
        Role::Actor => Resource::OldLogprobs,
        Role::Reference => Resource::RefLogprobs,
        Role::Reward => Resource::Rewards,
        Role::Critic => Resource::Values,
    }
}

/// The phase mark a body naturally carries (`None`: the body runs inside
/// the enclosing phase and must stay unmarked).
fn natural_kind(body: &PhaseBody) -> Option<crate::trace::PhaseKind> {
    use crate::trace::PhaseKind;
    match body {
        PhaseBody::Generation { .. } => Some(PhaseKind::Generation),
        PhaseBody::Infer { role, .. } => Some(PhaseProgram::infer_kind(*role)),
        PhaseBody::Train { role: Role::Actor, .. } => Some(PhaseKind::TrainActor),
        PhaseBody::Train { role: Role::Critic, .. } => Some(PhaseKind::TrainCritic),
        _ => None,
    }
}

/// Walk `program`'s nodes with a live resource set, appending findings.
/// `remote` is the set of cast roles another GPU hosts — their scoring
/// outputs are ambient (shipped in, never a local leak). `gpu` scopes
/// spans for cluster lints.
pub fn check_program(
    program: &PhaseProgram,
    remote: RoleSet,
    gpu: Option<u64>,
    findings: &mut Vec<Finding>,
) {
    let span_at = |node: usize, kind: Option<crate::trace::PhaseKind>| Span {
        gpu,
        phase: kind.map(|k| k.name().to_string()),
        node: Some(node),
    };

    let ambient = remote
        .intersect(program.algo.roles())
        .iter()
        .fold(ResSet::EMPTY, |s, r| s.with(scorer_output(r)));
    let mut live = ambient;

    for (i, node) in program.nodes.iter().enumerate() {
        let span = || span_at(i, node.kind.or_else(|| natural_kind(&node.body)));

        // RLHF004: the node needs roles this GPU does not instantiate.
        // Advantages runs wherever *either* consumer lives; every other
        // body needs its full requirement set locally.
        let hosted_ok = match node.body {
            PhaseBody::Advantages { .. } => {
                node.requires.is_empty()
                    || !node.requires.intersect(program.active_roles).is_empty()
            }
            _ => node.requires.is_subset_of(program.active_roles),
        };
        if !hosted_ok {
            findings.push(Finding::new(
                "RLHF004",
                format!(
                    "node requires role(s) {} but this GPU instantiates {}",
                    node.requires.label(),
                    program.active_roles.label()
                ),
                span(),
            ));
        }

        // RLHF006: phase-mark / body agreement.
        match (node.kind, natural_kind(&node.body)) {
            (Some(marked), Some(natural)) if marked != natural => {
                findings.push(Finding::new(
                    "RLHF006",
                    format!(
                        "node is marked '{}' but its body implies '{}'",
                        marked.name(),
                        natural.name()
                    ),
                    span(),
                ));
            }
            (Some(marked), None) => {
                findings.push(Finding::new(
                    "RLHF006",
                    format!(
                        "node is marked '{}' but its body runs inside the enclosing phase",
                        marked.name()
                    ),
                    span(),
                ));
            }
            _ => {}
        }

        if matches!(node.body, PhaseBody::FreeExperience) {
            // RLHF002: freeing when nothing locally-produced is live.
            if live.minus(ambient).is_empty() {
                findings.push(Finding::new(
                    "RLHF002",
                    "experience freed while no experience is live (double-free)".to_string(),
                    span(),
                ));
            }
            live = ResSet::EMPTY;
            continue;
        }

        // RLHF001: consumed before any producer ran.
        let missing = node_reqs(&node.body).minus(live);
        if !missing.is_empty() {
            findings.push(Finding::new(
                "RLHF001",
                format!("consumes {} before any node produces it", missing.label()),
                span(),
            ));
        }

        // RLHF005: produced again while still live.
        let defs = node_defs(&node.body);
        let redundant = defs.intersect(live);
        if !redundant.is_empty() {
            findings.push(Finding::new(
                "RLHF005",
                format!("produces {} while it is already live", redundant.label()),
                span(),
            ));
        }
        live = live.union(defs);
    }

    // RLHF003: locally-produced experience outlives the step.
    let leaked = live.minus(ambient);
    if !leaked.is_empty() {
        findings.push(Finding::new(
            "RLHF003",
            format!("{} still live after the last node (leak across step)", leaked.label()),
            Span {
                gpu,
                ..Span::default()
            },
        ));
    }
}

/// What a static parameter allocation is, for the ownership rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StaticAllocKind {
    /// The (possibly shared) backbone replica.
    SharedBase,
    /// A private value/LM head riding a shared backbone.
    Head,
    /// Trainable tensors (full replica or adapters).
    Adapter,
    /// Optimizer state (Adam moments + fp32 master).
    Optimizer,
}

impl StaticAllocKind {
    pub fn name(self) -> &'static str {
        match self {
            StaticAllocKind::SharedBase => "base",
            StaticAllocKind::Head => "head",
            StaticAllocKind::Adapter => "adapter",
            StaticAllocKind::Optimizer => "optimizer",
        }
    }
}

/// One static (init-time) parameter allocation, attributed to a role.
/// Sizes are unsharded logical bytes — the ownership rules are about
/// *who* allocates, not how ZeRO splits it.
#[derive(Debug, Clone, Copy)]
pub struct StaticAlloc {
    pub role: Role,
    pub kind: StaticAllocKind,
    pub bytes: u64,
}

/// The owner of `role`'s sharing group on this scenario's GPU: the first
/// active group member in [`Role::ALL`] order (the simulator's rule).
pub fn group_owner(scn: &SimScenario, role: Role) -> Option<Role> {
    let active = scn.roles.intersect(scn.algo.roles());
    scn.sharing.group_of(role).intersect(active).iter().next()
}

/// The static parameter allocations `scn`'s init implies, per active
/// role: the shared base (owner only), private heads (non-owners),
/// trainable tensors and optimizer state (trainable roles). Mutation
/// tests seed hand-built lists; this derivation is always clean.
pub fn derive_static_allocs(scn: &SimScenario) -> Vec<StaticAlloc> {
    let active = scn.roles.intersect(scn.algo.roles());
    let mut out = Vec::new();
    for role in active.iter() {
        let inv = role_inventory(scn, role);
        match group_owner(scn, role) {
            Some(owner) if owner == role => out.push(StaticAlloc {
                role,
                kind: StaticAllocKind::SharedBase,
                bytes: inv.total_bytes(DType::F16),
            }),
            _ => {
                let head: u64 = inv
                    .tensors
                    .iter()
                    .filter(|t| matches!(t.kind, ParamKind::Head))
                    .map(|t| t.bytes(DType::F16))
                    .sum();
                if head > 0 {
                    out.push(StaticAlloc {
                        role,
                        kind: StaticAllocKind::Head,
                        bytes: head,
                    });
                }
            }
        }
        if role.is_trainable() {
            let trainable = sim::trainable_bytes_f16(scn, role);
            out.push(StaticAlloc {
                role,
                kind: StaticAllocKind::Adapter,
                bytes: trainable,
            });
            // Adam: exp_avg + exp_avg_sq + fp32 master = 12 B/param =
            // 6 x the f16 trainable bytes.
            out.push(StaticAlloc {
                role,
                kind: StaticAllocKind::Optimizer,
                bytes: 6 * trainable,
            });
        }
    }
    out
}

/// The parameter inventory a role instantiates under the scenario's
/// sharing (Hydra collapses every role onto the policy trunk).
fn role_inventory(scn: &SimScenario, role: Role) -> ParamInventory {
    if scn.sharing.unifies_architectures() {
        if role.has_value_head() {
            ParamInventory::build_with_value_head(&scn.models.policy_arch)
        } else {
            ParamInventory::build(&scn.models.policy_arch)
        }
    } else {
        scn.models.inventory_for(role)
    }
}

/// Sharing-group ownership rules over static allocations: `RLHF012`
/// (base allocated by a non-owner) and `RLHF011` (optimizer state larger
/// than the trainable tensors justify — the frozen-backbone
/// adapter-state rule of Efficient-RLHF / PERL).
pub fn check_ownership(
    scn: &SimScenario,
    allocs: &[StaticAlloc],
    gpu: Option<u64>,
    findings: &mut Vec<Finding>,
) {
    let span = || Span {
        gpu,
        ..Span::default()
    };
    for a in allocs {
        match a.kind {
            StaticAllocKind::SharedBase => {
                let owner = group_owner(scn, a.role);
                if owner != Some(a.role) {
                    findings.push(Finding::new(
                        "RLHF012",
                        format!(
                            "role {} allocates the shared base owned by {}",
                            a.role.name(),
                            owner.map_or("nobody", Role::name),
                        ),
                        span(),
                    ));
                }
            }
            StaticAllocKind::Optimizer => {
                let budget = 6 * sim::trainable_bytes_f16(scn, a.role);
                if a.bytes > budget {
                    let why = if scn.sharing.frozen_backbone_for(a.role) {
                        "the backbone is frozen; optimizer state must cover adapters/heads only"
                    } else {
                        "optimizer state exceeds what the trainable tensors justify"
                    };
                    findings.push(Finding::new(
                        "RLHF011",
                        format!(
                            "role {} holds {} optimizer bytes but trainable tensors justify {} ({why})",
                            a.role.name(),
                            a.bytes,
                            budget,
                        ),
                        span(),
                    ));
                }
            }
            StaticAllocKind::Head | StaticAllocKind::Adapter => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::rlhf::program::Algo;
    use crate::rlhf::sim::ScenarioMode;
    use crate::strategies::StrategyConfig;

    fn scn(algo: Algo) -> SimScenario {
        let mut s = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        s.algo = algo;
        s
    }

    #[test]
    fn compiled_programs_are_dataflow_clean() {
        for algo in Algo::ALL {
            for mode in ScenarioMode::ALL {
                let mut s = scn(algo);
                s.mode = mode;
                let program = PhaseProgram::compile(&s);
                let mut findings = Vec::new();
                check_program(&program, RoleSet::EMPTY, None, &mut findings);
                assert!(
                    findings.is_empty(),
                    "{}/{}: {:?}",
                    algo.name(),
                    mode.name(),
                    findings
                );
            }
        }
    }

    #[test]
    fn scorer_only_gpu_relies_on_remote_outputs() {
        let mut s = scn(Algo::Ppo);
        s.roles = RoleSet::of(&[Role::Reference, Role::Reward]);
        let program = PhaseProgram::compile(&s);
        let remote = RoleSet::of(&[Role::Actor, Role::Critic]);
        let mut findings = Vec::new();
        check_program(&program, remote, Some(3), &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn resource_sets_behave() {
        let s = ResSet::of(&[Resource::Sequences, Resource::Rewards]);
        assert!(s.contains(Resource::Rewards));
        assert!(!s.contains(Resource::Values));
        assert_eq!(s.minus(ResSet::of(&[Resource::Rewards])).label(), "sequences");
        assert_eq!(ResSet::EMPTY.label(), "-");
        assert_eq!(s.label(), "sequences+rewards");
    }

    #[test]
    fn derived_allocs_pass_ownership() {
        use crate::rlhf::program::Sharing;
        for algo in Algo::ALL {
            for sharing in Sharing::ALL {
                let mut s = scn(algo);
                s.sharing = sharing;
                let allocs = derive_static_allocs(&s);
                let mut findings = Vec::new();
                check_ownership(&s, &allocs, None, &mut findings);
                assert!(
                    findings.is_empty(),
                    "{}/{}: {:?}",
                    algo.name(),
                    sharing.name(),
                    findings
                );
                // Every active role allocates something.
                assert!(!allocs.is_empty());
            }
        }
    }
}
