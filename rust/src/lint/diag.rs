//! Diagnostic plumbing for the static verifier: the stable code registry
//! (`RLHF001`…), severities, spans, findings, and the
//! `--deny`/`--warn`/`--allow` configuration.
//!
//! Every rule the linter can fire is registered in [`CODES`] with a
//! default severity and a one-line summary; the DESIGN.md §16 diagnostics
//! table mirrors this registry (`rust/tests/registration_audit.rs` keeps
//! the two in sync). Codes are append-only: a released code never changes
//! meaning, so scripts can match on them.

use crate::util::cli::split_list;
use crate::util::json::Json;

/// How a finding is treated: `Deny` fails the lint, `Warn` reports
/// without failing, `Allow` suppresses it entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Allow,
    Warn,
    Deny,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Allow => "allow",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Where a finding points: any of a GPU index (cluster lints), a phase
/// name, and a phase-program node index. All optional — a plan-shape
/// error has no phase, a dataflow error on a single-GPU config has no
/// GPU.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Span {
    pub gpu: Option<u64>,
    pub phase: Option<String>,
    pub node: Option<usize>,
}

impl Span {
    /// The empty span (configuration-level finding).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn on_gpu(gpu: u64) -> Self {
        Self {
            gpu: Some(gpu),
            ..Self::default()
        }
    }

    pub fn at_node(mut self, node: usize) -> Self {
        self.node = Some(node);
        self
    }

    pub fn at_phase(mut self, phase: &str) -> Self {
        self.phase = Some(phase.to_string());
        self
    }

    /// Human rendering: `gpu0 generation #3`, or `-` when empty.
    pub fn render(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(g) = self.gpu {
            parts.push(format!("gpu{g}"));
        }
        if let Some(p) = &self.phase {
            parts.push(p.clone());
        }
        if let Some(n) = self.node {
            parts.push(format!("#{n}"));
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// One registered diagnostic: stable code, default severity, one-line
/// summary (what the DESIGN.md table lists).
#[derive(Debug, Clone, Copy)]
pub struct CodeInfo {
    pub code: &'static str,
    pub default: Severity,
    pub summary: &'static str,
}

/// The diagnostic registry. Grouped: `RLHF00x` dataflow, `RLHF01x`
/// sharing/ownership, `RLHF02x` placement/collectives, `RLHF03x` static
/// peak bounds.
pub const CODES: &[CodeInfo] = &[
    CodeInfo {
        code: "RLHF001",
        default: Severity::Deny,
        summary: "experience tensor consumed before any node produces it",
    },
    CodeInfo {
        code: "RLHF002",
        default: Severity::Deny,
        summary: "experience freed while nothing is live (double-free)",
    },
    CodeInfo {
        code: "RLHF003",
        default: Severity::Warn,
        summary: "experience still live after the last node (leak across step)",
    },
    CodeInfo {
        code: "RLHF004",
        default: Severity::Deny,
        summary: "phase node requires a role this GPU does not host",
    },
    CodeInfo {
        code: "RLHF005",
        default: Severity::Warn,
        summary: "experience tensor produced again while still live",
    },
    CodeInfo {
        code: "RLHF006",
        default: Severity::Deny,
        summary: "marked phase kind does not match the node body",
    },
    CodeInfo {
        code: "RLHF010",
        default: Severity::Warn,
        summary: "sharing group split across GPUs (base deduplication lost)",
    },
    CodeInfo {
        code: "RLHF011",
        default: Severity::Deny,
        summary: "optimizer state exceeds the trainable budget on a frozen backbone",
    },
    CodeInfo {
        code: "RLHF012",
        default: Severity::Deny,
        summary: "shared base allocated by a non-owner role",
    },
    CodeInfo {
        code: "RLHF020",
        default: Severity::Deny,
        summary: "placement plan has no GPUs",
    },
    CodeInfo {
        code: "RLHF021",
        default: Severity::Deny,
        summary: "hosted/time_shared plan tables have different lengths",
    },
    CodeInfo {
        code: "RLHF022",
        default: Severity::Deny,
        summary: "GPU hosts no model",
    },
    CodeInfo {
        code: "RLHF023",
        default: Severity::Deny,
        summary: "role the algorithm requires is hosted by no GPU",
    },
    CodeInfo {
        code: "RLHF024",
        default: Severity::Deny,
        summary: "GPU time-shares a model it does not host",
    },
    CodeInfo {
        code: "RLHF025",
        default: Severity::Deny,
        summary: "GPU time-shares a trainable model",
    },
    CodeInfo {
        code: "RLHF026",
        default: Severity::Deny,
        summary: "trainable role's hosts do not match the data-parallel group",
    },
    CodeInfo {
        code: "RLHF027",
        default: Severity::Deny,
        summary: "P2P experience shipping has consumers but no producer",
    },
    CodeInfo {
        code: "RLHF030",
        default: Severity::Deny,
        summary: "statically infeasible: phase lower bound exceeds capacity",
    },
    CodeInfo {
        code: "RLHF031",
        default: Severity::Warn,
        summary: "inconclusive: phase upper bound exceeds capacity",
    },
];

/// Registry lookup by code.
pub fn code_info(code: &str) -> Option<&'static CodeInfo> {
    CODES.iter().find(|c| c.code == code)
}

/// One lint finding: a registered code at a span, with the severity the
/// active [`LintConfig`] resolved for it.
#[derive(Debug, Clone)]
pub struct Finding {
    pub code: &'static str,
    pub severity: Severity,
    pub message: String,
    pub span: Span,
}

impl Finding {
    /// A finding at its code's registry-default severity (the
    /// [`LintConfig`] re-resolves severities when the report is built).
    pub fn new(code: &'static str, message: String, span: Span) -> Self {
        let info = code_info(code).expect("finding uses a registered diagnostic code");
        Finding {
            code,
            severity: info.default,
            message,
            span,
        }
    }

    /// Deterministic JSON object for `--json` output.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("severity", Json::str(self.severity.name())),
            (
                "gpu",
                match self.span.gpu {
                    Some(g) => Json::from(g),
                    None => Json::Null,
                },
            ),
            (
                "phase",
                match &self.span.phase {
                    Some(p) => Json::str(p.clone()),
                    None => Json::Null,
                },
            ),
            (
                "node",
                match self.span.node {
                    Some(n) => Json::from(n),
                    None => Json::Null,
                },
            ),
            ("message", Json::str(self.message.clone())),
        ])
    }
}

/// The `--deny`/`--warn`/`--allow` severity overrides. Precedence:
/// a specific code entry beats an `all` entry beats the registry
/// default; listing the same code (or `all`) under two severities is an
/// error rather than an ordering puzzle.
#[derive(Debug, Clone, Default)]
pub struct LintConfig {
    all: Option<Severity>,
    specific: Vec<(&'static str, Severity)>,
}

impl LintConfig {
    /// Parse the three comma-separated lists (each entry a registered
    /// code or `all`). Empty strings mean "no overrides".
    pub fn from_lists(deny: &str, warn: &str, allow: &str) -> Result<Self, String> {
        let mut cfg = LintConfig::default();
        for (list, sev) in [
            (deny, Severity::Deny),
            (warn, Severity::Warn),
            (allow, Severity::Allow),
        ] {
            for entry in split_list(list) {
                if entry == "all" {
                    if cfg.all.is_some() {
                        return Err("'all' listed under more than one severity".to_string());
                    }
                    cfg.all = Some(sev);
                    continue;
                }
                let info = code_info(entry).ok_or_else(|| {
                    format!("unknown diagnostic code '{entry}' (codes: RLHF001..RLHF031, or 'all')")
                })?;
                if cfg.specific.iter().any(|(c, _)| *c == info.code) {
                    return Err(format!("code '{entry}' listed under more than one severity"));
                }
                cfg.specific.push((info.code, sev));
            }
        }
        Ok(cfg)
    }

    /// The severity this configuration resolves for `code`.
    pub fn severity_for(&self, code: &str) -> Severity {
        if let Some((_, sev)) = self.specific.iter().find(|(c, _)| *c == code) {
            return *sev;
        }
        if let Some(sev) = self.all {
            return sev;
        }
        code_info(code).map_or(Severity::Warn, |i| i.default)
    }

    /// Apply the configuration to a raw finding: re-resolve its severity,
    /// dropping it entirely when allowed.
    pub fn apply(&self, mut finding: Finding) -> Option<Finding> {
        let sev = self.severity_for(finding.code);
        if sev == Severity::Allow {
            return None;
        }
        finding.severity = sev;
        Some(finding)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_codes_are_unique_and_well_formed() {
        for (i, a) in CODES.iter().enumerate() {
            assert!(a.code.starts_with("RLHF") && a.code.len() == 7, "{}", a.code);
            assert!(a.code[4..].chars().all(|c| c.is_ascii_digit()));
            for b in &CODES[i + 1..] {
                assert_ne!(a.code, b.code, "duplicate diagnostic code");
            }
        }
    }

    #[test]
    fn config_precedence_specific_over_all_over_default() {
        let cfg = LintConfig::from_lists("all", "RLHF003", "RLHF031").unwrap();
        assert_eq!(cfg.severity_for("RLHF001"), Severity::Deny);
        assert_eq!(cfg.severity_for("RLHF003"), Severity::Warn);
        assert_eq!(cfg.severity_for("RLHF031"), Severity::Allow);
        // Default config: registry defaults apply.
        let def = LintConfig::default();
        assert_eq!(def.severity_for("RLHF003"), Severity::Warn);
        assert_eq!(def.severity_for("RLHF002"), Severity::Deny);
    }

    #[test]
    fn config_rejects_unknown_and_conflicting_entries() {
        assert!(LintConfig::from_lists("RLHF999", "", "").is_err());
        assert!(LintConfig::from_lists("RLHF001", "RLHF001", "").is_err());
        assert!(LintConfig::from_lists("all", "", "all").is_err());
    }

    #[test]
    fn allow_drops_findings() {
        let cfg = LintConfig::from_lists("", "", "RLHF003").unwrap();
        let f = Finding::new("RLHF003", "leak".into(), Span::none());
        assert!(cfg.apply(f).is_none());
        let f = Finding::new("RLHF002", "double free".into(), Span::on_gpu(1).at_node(3));
        let kept = cfg.apply(f).unwrap();
        assert_eq!(kept.severity, Severity::Deny);
        assert_eq!(kept.span.render(), "gpu1 #3");
    }
}
