//! Static verifier over compiled phase programs, placement plans and
//! sharing/strategy configurations — every structural property the paper
//! ties memory blowups to, checked *without generating a trace*.
//!
//! Three passes, each a module:
//!
//! - [`dataflow`] — def-use analysis over [`PhaseProgram`] nodes
//!   (use-before-produce, double-free, cross-step leaks, phase-mark
//!   mismatches) and sharing-group ownership rules over the static
//!   allocations a scenario implies (`RLHF00x`, `RLHF01x`).
//! - [`collective`] — cross-rank matching over a [`PlacementPlan`]:
//!   plan-shape rules, gradient all-reduce group mismatches, P2P
//!   consumers with no producer, split sharing groups (`RLHF02x`,
//!   `RLHF010`).
//! - [`bounds`] — abstract interpretation computing a conservative
//!   static peak interval per phase, sound against the simulator
//!   (`RLHF03x`); its lower bound also powers `advise
//!   --prescreen-static`.
//!
//! Findings carry stable diagnostic codes from [`diag::CODES`] with
//! `--deny`/`--warn`/`--allow` severity configuration; everything is
//! deterministic, so `--json` output is byte-stable.

pub mod bounds;
pub mod collective;
pub mod dataflow;
pub mod diag;

pub use bounds::{check_bounds, static_bounds, static_lower_max, PhaseBound};
pub use collective::check_plan;
pub use dataflow::{check_ownership, check_program, derive_static_allocs};
pub use diag::{code_info, CodeInfo, Finding, LintConfig, Severity, Span, CODES};

use crate::coordinator::PlacementPlan;
use crate::rlhf::models::RoleSet;
use crate::rlhf::program::PhaseProgram;
use crate::rlhf::sim::SimScenario;
use crate::util::json::Json;

/// The static peak intervals computed for one GPU (`gpu` is `None` for a
/// single-GPU lint).
#[derive(Debug, Clone)]
pub struct GpuBounds {
    pub gpu: Option<u64>,
    pub bounds: Vec<PhaseBound>,
}

/// Everything one lint run produced: configured findings (allowed codes
/// already dropped) and the per-GPU bound tables.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub bounds: Vec<GpuBounds>,
}

impl LintReport {
    pub fn deny_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Deny)
            .count()
    }

    pub fn warn_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Warn)
            .count()
    }

    /// Deterministic JSON document for `--json` output.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self.findings.iter().map(Finding::to_json).collect();
        let bounds: Vec<Json> = self
            .bounds
            .iter()
            .map(|g| {
                let phases: Vec<Json> = g
                    .bounds
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("phase", Json::str(b.phase.name())),
                            ("lo", Json::from(b.lo)),
                            ("hi", Json::from(b.hi)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    (
                        "gpu",
                        match g.gpu {
                            Some(g) => Json::from(g),
                            None => Json::Null,
                        },
                    ),
                    ("phases", Json::from(phases)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("deny", Json::from(self.deny_count())),
            ("warn", Json::from(self.warn_count())),
            ("findings", Json::from(findings)),
            ("bounds", Json::from(bounds)),
        ])
    }
}

/// The algorithm-cast roles this GPU does *not* host — their scorer
/// outputs arrive from other ranks, which the dataflow pass models as
/// ambient definitions.
fn remote_roles(scn: &SimScenario) -> RoleSet {
    let active = scn.roles.intersect(scn.algo.roles());
    let mut remote = RoleSet::EMPTY;
    for role in scn.algo.roles().iter() {
        if !active.contains(role) {
            remote = remote.with(role);
        }
    }
    remote
}

fn lint_one_gpu(
    scn: &SimScenario,
    capacity: u64,
    gpu: Option<u64>,
    findings: &mut Vec<Finding>,
) -> GpuBounds {
    let program = PhaseProgram::compile(scn);
    check_program(&program, remote_roles(scn), gpu, findings);
    let allocs = derive_static_allocs(scn);
    check_ownership(scn, &allocs, gpu, findings);
    let bounds = check_bounds(scn, capacity, gpu, findings);
    GpuBounds { gpu, bounds }
}

/// Lint a single-GPU scenario against `capacity` bytes: dataflow,
/// ownership and bounds passes, severities resolved by `cfg`.
pub fn lint_scenario(scn: &SimScenario, capacity: u64, cfg: &LintConfig) -> LintReport {
    let mut findings = Vec::new();
    let bounds = lint_one_gpu(scn, capacity, None, &mut findings);
    LintReport {
        findings: findings.into_iter().filter_map(|f| cfg.apply(f)).collect(),
        bounds: vec![bounds],
    }
}

/// Lint `base` placed over `plan`: the collective pass over the plan
/// itself, then the per-GPU passes over each GPU's derived scenario.
/// When the plan's *shape* is broken the per-GPU passes are skipped —
/// there is no coherent per-GPU scenario to check.
pub fn lint_plan(
    base: &SimScenario,
    plan: &PlacementPlan,
    capacity: u64,
    cfg: &LintConfig,
) -> LintReport {
    let mut findings = Vec::new();
    let mut bounds = Vec::new();
    if check_plan(plan, base.algo, base.sharing, &mut findings) {
        for g in 0..plan.hosted.len() {
            // A GPU hosting nothing from the cast runs nothing (RLHF022
            // already flags a fully empty GPU).
            if plan.hosted[g].intersect(base.algo.roles()).is_empty() {
                continue;
            }
            let scn = plan.scenario_for_gpu(base, g);
            bounds.push(lint_one_gpu(&scn, capacity, Some(g as u64), &mut findings));
        }
    }
    LintReport {
        findings: findings.into_iter().filter_map(|f| cfg.apply(f)).collect(),
        bounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::rlhf::program::Algo;
    use crate::rlhf::sim::SCENARIO_PRESETS;
    use crate::strategies::StrategyConfig;

    #[test]
    fn presets_lint_clean_at_ample_capacity() {
        let cfg = LintConfig::default();
        for preset in &SCENARIO_PRESETS {
            let scn = preset.build(StrategyConfig::none(), EmptyCachePolicy::Never);
            let report = lint_scenario(&scn, u64::MAX, &cfg);
            assert!(
                report.findings.is_empty(),
                "{}: {:?}",
                preset.name,
                report.findings
            );
            assert_eq!(report.bounds.len(), 1);
            assert!(!report.bounds[0].bounds.is_empty());
        }
    }

    #[test]
    fn plan_lint_covers_hosting_gpus_only() {
        use crate::rlhf::models::{Role, RoleSet};
        let mut base =
            SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        base.algo = Algo::Dpo; // cast {actor, reference}
        let mut plan = PlacementPlan::colocated(2);
        plan.hosted = vec![
            RoleSet::of(&[Role::Actor, Role::Reference]),
            RoleSet::of(&[Role::Critic]),
        ];
        let report = lint_plan(&base, &plan, u64::MAX, &LintConfig::default());
        assert!(report.findings.is_empty(), "{:?}", report.findings);
        // GPU 1 hosts nothing from the DPO cast: no per-GPU lint for it.
        let gpus: Vec<Option<u64>> = report.bounds.iter().map(|b| b.gpu).collect();
        assert_eq!(gpus, vec![Some(0)]);
    }

    #[test]
    fn every_preset_plan_lints_clean() {
        let base = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        for plan in PlacementPlan::presets(4) {
            let report = lint_plan(&base, &plan, u64::MAX, &LintConfig::default());
            assert!(
                report.findings.is_empty(),
                "{}: {:?}",
                plan.name,
                report.findings
            );
            assert_eq!(report.bounds.len(), 4, "{}", plan.name);
        }
    }

    #[test]
    fn report_json_shape_is_stable() {
        let scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let report = lint_scenario(&scn, 0, &LintConfig::default());
        assert!(report.deny_count() > 0);
        let text = report.to_json().to_string_pretty();
        assert!(text.contains("\"findings\""), "{text}");
        assert!(text.contains("RLHF030"), "{text}");
        assert!(text.contains("\"bounds\""), "{text}");
    }
}
