//! `rlhf-mem` — CLI launcher for the RLHF memory study and the real
//! end-to-end PPO trainer.
//!
//! Subcommands regenerate each paper artifact (see DESIGN.md §5).

use rlhf_mem::util::cli::Args;

mod commands;

const USAGE: &str = "\
rlhf-mem — reproduction of 'Understanding and Alleviating Memory Consumption in RLHF for LLMs'

USAGE: rlhf-mem <subcommand> [--flags]

SUBCOMMANDS:
  table1       Regenerate Table 1 (strategy sweep, both frameworks/models)
  table2       Regenerate Table 2 (A100 node, larger models)
  figure1      Regenerate Figure 1 (memory timeline; --csv FILE, --assert)
  phases       §3.1 three-scenario comparison (full / train-both / actor-only)
  ablation     §3.3 empty_cache placement ablation
  overhead     §3.3 end-to-end time overhead of empty_cache
  sweep        Run a user-defined scenario grid (see `sweep --help`)
  algos        Compare RLHF algorithms (ppo/grpo/remax/dpo): peak reserved
               + fragmentation per algorithm, per strategy (see `algos --help`)
  peft         Compare model-sharing placements (separate/lora/hydra/
               frozen-shared/perl): peak reserved + step time per placement,
               per strategy; --compare-paper gates the Efficient-RLHF
               ordering (see `peft --help`)
  cluster      Multi-GPU placement simulator: per-GPU peaks + step time
               per placement plan (see `cluster --help`)
  serve        Serving-scale workload simulator: continuous batching +
               paged KV cache vs best-fit reservation over a seeded
               request stream — throughput, p99 latency, KV fragmentation
               per (discipline x page size x concurrency) cell
               (see `serve --help`)
  advise       Search the mitigation space for the cheapest config that
               fits a GPU budget; --cluster searches placements instead;
               --prescreen-static rejects statically-infeasible candidates
               before simulating; --surrogate FILE screens with a fitted
               surrogate and simulates only near-frontier candidates, with
               a byte-identical frontier; --serve evaluates the budget's
               serving grid instead (see `advise --help`)
  fit          Fit the planner's closed-form surrogate (per-candidate
               memory/time models + error envelopes) from simulated sweep
               cells into SURROGATE.json (see `fit --help`)
  lint         Statically verify a config without simulating: dataflow,
               sharing ownership, placement collectives (--plan NAME),
               abstract peak bounds vs capacity; stable RLHF0xx codes,
               --deny/--warn/--allow LIST, --json FILE
  bench        Run the canonical perf workloads: record a BENCH_<n>.json
               trajectory point, gate against a baseline (--check), or
               run the CI smoke suite (--smoke; see `bench --help`)
  train        Real end-to-end PPO via PJRT artifacts (needs --features pjrt)
  quickstart   Tiny profiled RLHF run (fast smoke)
  profile      Run a user-defined experiment from a JSON config
               (--json FILE, --chart, --timeline-resolution MIB,
               --trace-out FILE for a Perfetto trace)
  explain      Attribute a run's reserved peak: live-tensor census, exact
               fragmentation decomposition, ranked shrink levers
               (--json FILE, --trace-out FILE, --top-peaks K)
  gen-ablation Appendix-B generation() implementation comparison
  debug        Calibration lens: peak composition + frag samples

COMMON FLAGS:
  --steps N          PPO steps to simulate (default 3)
  --framework X      deepspeed-chat | colossalchat
  --jobs N           sweep worker threads (default: all cores)
  --json FILE        also write machine-readable results
";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("table1") => commands::table1::run(&args),
        Some("table2") => commands::table2::run(&args),
        Some("figure1") => commands::figure1::run(&args),
        Some("phases") => commands::phases::run(&args),
        Some("ablation") => commands::ablation::run(&args),
        Some("overhead") => commands::overhead::run(&args),
        Some("sweep") => commands::sweep::run(&args),
        Some("algos") => commands::algos::run(&args),
        Some("peft") => commands::peft::run(&args),
        Some("cluster") => commands::cluster::run(&args),
        Some("serve") => commands::serve::run(&args),
        Some("advise") => commands::advise::run(&args),
        Some("fit") => commands::fit::run(&args),
        Some("lint") => commands::lint::run(&args),
        Some("bench") => commands::bench::run(&args),
        Some("train") => run_train(&args),
        Some("quickstart") => commands::quickstart::run(&args),
        Some("debug") => commands::debug::run(&args),
        Some("profile") => commands::profile::run(&args),
        Some("explain") => commands::explain::run(&args),
        Some("gen-ablation") => commands::genablation::run(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            Err("bad subcommand".to_string())
        }
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e: String| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

#[cfg(feature = "pjrt")]
fn run_train(args: &Args) -> Result<(), String> {
    commands::train::run(args)
}

#[cfg(not(feature = "pjrt"))]
fn run_train(_args: &Args) -> Result<(), String> {
    Err("the 'train' subcommand needs the PJRT/XLA runtime: rebuild with \
         `cargo build --features pjrt` (requires the xla crate and AOT \
         artifacts; see DESIGN.md §2)"
        .to_string())
}
