//! Activation memory model: the per-tensor transient/saved allocations a
//! PyTorch transformer makes during forward and backward, parametrized on
//! (batch, seq). These lists are what the trace generators replay, so their
//! granularity and sizes mirror the real op-by-op allocation pattern.

use super::arch::{DType, ModelArch};

/// A transient or saved activation tensor.
#[derive(Debug, Clone)]
pub struct ActTensor {
    pub label: &'static str,
    pub bytes: u64,
}

/// Shape context for one forward/backward.
#[derive(Debug, Clone, Copy)]
pub struct SeqShape {
    pub batch: u64,
    pub seq: u64,
}

/// Activation model for one architecture.
#[derive(Debug, Clone)]
pub struct ActivationModel {
    pub arch: ModelArch,
    pub dtype: DType,
}

impl ActivationModel {
    pub fn new(arch: &ModelArch, dtype: DType) -> Self {
        ActivationModel {
            arch: arch.clone(),
            dtype: dtype.clone(),
        }
    }

    fn e(&self) -> u64 {
        self.dtype.bytes()
    }

    /// `[b, s, d]` hidden-state tensor.
    pub fn hidden_bytes(&self, sh: SeqShape) -> u64 {
        sh.batch * sh.seq * self.arch.d_model * self.e()
    }

    /// `[b, s, vocab]` logits tensor (fp32 in HF generation/softmax paths).
    pub fn logits_bytes(&self, sh: SeqShape) -> u64 {
        sh.batch * sh.seq * self.arch.vocab * 4
    }

    /// `[b, vocab]` single-position logits (decode step).
    pub fn step_logits_bytes(&self, batch: u64) -> u64 {
        batch * self.arch.vocab * 4
    }

    /// Transient tensors allocated while computing ONE layer's forward.
    /// In inference these are freed as soon as the layer output exists.
    pub fn layer_transients(&self, sh: SeqShape) -> Vec<ActTensor> {
        let a = &self.arch;
        let bsd = sh.batch * sh.seq * a.d_model * self.e();
        let bsf = sh.batch * sh.seq * a.ffn_dim * self.e();
        let score = sh.batch * a.n_heads * sh.seq * sh.seq * self.e();
        // HF transformers computes the attention softmax in fp32 under
        // autocast (then casts back), so one fp32-sized score workspace is
        // live per layer regardless of the training dtype.
        let score_f32 = sh.batch * a.n_heads * sh.seq * sh.seq * 4;
        vec![
            ActTensor { label: "ln1_out", bytes: bsd },
            ActTensor { label: "q", bytes: bsd },
            ActTensor { label: "k", bytes: bsd },
            ActTensor { label: "v", bytes: bsd },
            ActTensor { label: "attn_scores", bytes: score },
            ActTensor { label: "softmax_f32", bytes: score_f32 },
            ActTensor { label: "attn_probs", bytes: score },
            ActTensor { label: "attn_ctx", bytes: bsd },
            ActTensor { label: "attn_out", bytes: bsd },
            ActTensor { label: "ln2_out", bytes: bsd },
            ActTensor { label: "fc1_out", bytes: bsf },
            ActTensor { label: "act_fn_out", bytes: bsf },
            ActTensor { label: "fc2_out", bytes: bsd },
            ActTensor { label: "residual_out", bytes: bsd },
        ]
    }

    /// Tensors SAVED for backward per layer (autograd graph inputs).
    /// Without gradient checkpointing every layer keeps these until its
    /// backward runs.
    pub fn layer_saved(&self, sh: SeqShape) -> Vec<ActTensor> {
        let a = &self.arch;
        let bsd = sh.batch * sh.seq * a.d_model * self.e();
        let bsf = sh.batch * sh.seq * a.ffn_dim * self.e();
        let score = sh.batch * a.n_heads * sh.seq * sh.seq * self.e();
        vec![
            ActTensor { label: "saved_input", bytes: bsd },
            ActTensor { label: "saved_ln1", bytes: bsd },
            ActTensor { label: "saved_q", bytes: bsd },
            ActTensor { label: "saved_k", bytes: bsd },
            ActTensor { label: "saved_v", bytes: bsd },
            ActTensor { label: "saved_attn_probs", bytes: score },
            ActTensor { label: "saved_attn_ctx", bytes: bsd },
            ActTensor { label: "saved_ln2", bytes: bsd },
            ActTensor { label: "saved_fc1", bytes: bsf },
            ActTensor { label: "saved_act", bytes: bsf },
        ]
    }

    /// With gradient checkpointing only the layer *input* is saved; the
    /// rest is recomputed (re-allocating [`Self::layer_saved`]) during
    /// backward.
    pub fn layer_checkpoint(&self, sh: SeqShape) -> Vec<ActTensor> {
        vec![ActTensor {
            label: "ckpt_input",
            bytes: self.hidden_bytes(sh),
        }]
    }

    /// Transient workspaces of one layer's BACKWARD (grad wrt activations;
    /// freed as the backward sweep proceeds).
    pub fn layer_backward_transients(&self, sh: SeqShape) -> Vec<ActTensor> {
        let a = &self.arch;
        let bsd = sh.batch * sh.seq * a.d_model * self.e();
        let bsf = sh.batch * sh.seq * a.ffn_dim * self.e();
        let score = sh.batch * a.n_heads * sh.seq * sh.seq * self.e();
        let score_f32 = sh.batch * a.n_heads * sh.seq * sh.seq * 4;
        vec![
            ActTensor { label: "d_fc2", bytes: bsd },
            ActTensor { label: "d_act", bytes: bsf },
            ActTensor { label: "d_fc1", bytes: bsf },
            ActTensor { label: "d_ln2", bytes: bsd },
            ActTensor { label: "d_attn_out", bytes: bsd },
            ActTensor { label: "d_softmax_f32", bytes: score_f32 },
            ActTensor { label: "d_attn_probs", bytes: score },
            ActTensor { label: "d_qkv", bytes: 3 * bsd },
            ActTensor { label: "d_ln1", bytes: bsd },
            ActTensor { label: "d_input", bytes: bsd },
        ]
    }

    /// Peak resident activation bytes of a full no-checkpoint training
    /// forward (all layers saved + logits), a closed-form sanity bound used
    /// in tests and DESIGN.md's capacity math.
    pub fn train_forward_resident(&self, sh: SeqShape) -> u64 {
        let per_layer: u64 = self.layer_saved(sh).iter().map(|t| t.bytes).sum();
        per_layer * self.arch.n_layers + self.logits_bytes(sh) + self.hidden_bytes(sh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GIB, MIB};

    fn model() -> ActivationModel {
        ActivationModel::new(&ModelArch::opt_1_3b(), DType::F16)
    }

    #[test]
    fn hidden_and_logits_sizes() {
        let m = model();
        let sh = SeqShape { batch: 2, seq: 512 };
        // 2*512*2048*2 = 4 MiB
        assert_eq!(m.hidden_bytes(sh), 4 * MIB);
        // 2*512*50272*4 ≈ 196 MiB
        assert_eq!(m.logits_bytes(sh), 2 * 512 * 50272 * 4);
    }

    #[test]
    fn saved_less_than_transients() {
        let m = model();
        let sh = SeqShape { batch: 2, seq: 512 };
        let trans: u64 = m.layer_transients(sh).iter().map(|t| t.bytes).sum();
        let saved: u64 = m.layer_saved(sh).iter().map(|t| t.bytes).sum();
        assert!(saved < trans);
        assert!(saved > 0);
    }

    #[test]
    fn checkpoint_saves_input_only() {
        let m = model();
        let sh = SeqShape { batch: 2, seq: 512 };
        let ckpt = m.layer_checkpoint(sh);
        assert_eq!(ckpt.len(), 1);
        assert_eq!(ckpt[0].bytes, m.hidden_bytes(sh));
        let saved: u64 = m.layer_saved(sh).iter().map(|t| t.bytes).sum();
        assert!(ckpt[0].bytes * 5 < saved, "checkpointing must save a lot");
    }

    #[test]
    fn quadratic_attention_term_scales() {
        let m = model();
        let s1 = SeqShape { batch: 1, seq: 256 };
        let s2 = SeqShape { batch: 1, seq: 512 };
        let score1 = m.layer_transients(s1).iter().find(|t| t.label == "attn_scores").unwrap().bytes;
        let score2 = m.layer_transients(s2).iter().find(|t| t.label == "attn_scores").unwrap().bytes;
        assert_eq!(score2, score1 * 4, "scores grow with s^2");
    }

    #[test]
    fn resident_bound_plausible_for_paper_config() {
        // OPT-1.3b, bs=2, seq=512, fp16, no checkpointing: resident
        // activations should land in the single-digit-GiB range.
        let m = model();
        let sh = SeqShape { batch: 2, seq: 512 };
        let r = m.train_forward_resident(sh);
        assert!((GIB / 2..8 * GIB).contains(&r), "resident {r}");
    }
}
