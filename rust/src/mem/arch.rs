//! Transformer architecture descriptions and the exact hyperparameters of
//! every model the paper evaluates (OPT-350m/1.3b/6.7b, GPT2-medium/xl,
//! Llama-2-7b) plus the small configs used for real end-to-end training.
//!
//! The allocator only ever sees byte counts, so reproducing the paper's
//! allocation traces reduces to sizing the real architectures exactly.

/// Element type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F16,
    BF16,
    F32,
    I32,
    I64,
}

impl DType {
    pub fn bytes(self) -> u64 {
        match self {
            DType::F16 | DType::BF16 => 2,
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::I64 => "i64",
        }
    }
}

/// Architectural family — drives the parameter inventory layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchFamily {
    /// OPT: learned positions (offset 2), ReLU MLP, tied LM head, biases.
    Opt,
    /// GPT-2: learned positions, fused c_attn, GELU MLP, tied head, biases.
    Gpt2,
    /// Llama-2: RoPE (no position table), SwiGLU MLP (3 mats), RMSNorm
    /// (no biases anywhere), untied LM head.
    Llama,
}

/// A concrete transformer configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArch {
    pub name: String,
    pub family: ArchFamily,
    pub n_layers: u64,
    pub d_model: u64,
    pub n_heads: u64,
    pub ffn_dim: u64,
    pub vocab: u64,
    pub max_pos: u64,
    /// OPT-350m quirk: token embeddings live in a smaller projected space
    /// (`word_embed_proj_dim = 512`) with in/out projection matrices.
    pub embed_proj_dim: Option<u64>,
}

impl ModelArch {
    pub fn head_dim(&self) -> u64 {
        self.d_model / self.n_heads
    }

    // ---- The paper's models (exact published hyperparameters) ----

    /// OPT-350m (critic/reward in the paper's DeepSpeed-Chat + ColossalChat
    /// OPT setting).
    pub fn opt_350m() -> Self {
        ModelArch {
            name: "opt-350m".into(),
            family: ArchFamily::Opt,
            n_layers: 24,
            d_model: 1024,
            n_heads: 16,
            ffn_dim: 4096,
            vocab: 50272,
            max_pos: 2048,
            embed_proj_dim: Some(512),
        }
    }

    /// OPT-1.3b (actor/reference).
    pub fn opt_1_3b() -> Self {
        ModelArch {
            name: "opt-1.3b".into(),
            family: ArchFamily::Opt,
            n_layers: 24,
            d_model: 2048,
            n_heads: 32,
            ffn_dim: 8192,
            vocab: 50272,
            max_pos: 2048,
            embed_proj_dim: None,
        }
    }

    /// OPT-6.7b (Table 2).
    pub fn opt_6_7b() -> Self {
        ModelArch {
            name: "opt-6.7b".into(),
            family: ArchFamily::Opt,
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            ffn_dim: 16384,
            vocab: 50272,
            max_pos: 2048,
            embed_proj_dim: None,
        }
    }

    /// GPT2-medium (critic/reward in the ColossalChat GPT-2 setting).
    pub fn gpt2_medium() -> Self {
        ModelArch {
            name: "gpt2-medium".into(),
            family: ArchFamily::Gpt2,
            n_layers: 24,
            d_model: 1024,
            n_heads: 16,
            ffn_dim: 4096,
            vocab: 50257,
            max_pos: 1024,
            embed_proj_dim: None,
        }
    }

    /// GPT2-xl (actor/reference in the ColossalChat GPT-2 setting).
    pub fn gpt2_xl() -> Self {
        ModelArch {
            name: "gpt2-xl".into(),
            family: ArchFamily::Gpt2,
            n_layers: 48,
            d_model: 1600,
            n_heads: 25,
            ffn_dim: 6400,
            vocab: 50257,
            max_pos: 1024,
            embed_proj_dim: None,
        }
    }

    /// Llama-2-7b (Table 2).
    pub fn llama2_7b() -> Self {
        ModelArch {
            name: "llama-2-7b".into(),
            family: ArchFamily::Llama,
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            ffn_dim: 11008,
            vocab: 32000,
            max_pos: 4096,
            embed_proj_dim: None,
        }
    }

    // ---- Small configs for the real end-to-end PPO runs (E10) ----

    /// ~3.4M params: smoke-test scale.
    pub fn opt_nano() -> Self {
        ModelArch {
            name: "opt-nano".into(),
            family: ArchFamily::Opt,
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            ffn_dim: 1024,
            vocab: 512,
            max_pos: 256,
            embed_proj_dim: None,
        }
    }

    /// ~29M params: the few-hundred-step training-curve config.
    pub fn opt_tiny() -> Self {
        ModelArch {
            name: "opt-tiny".into(),
            family: ArchFamily::Opt,
            n_layers: 8,
            d_model: 512,
            n_heads: 8,
            ffn_dim: 2048,
            vocab: 8192,
            max_pos: 512,
            embed_proj_dim: None,
        }
    }

    /// ~110M params: the short at-scale proof run.
    pub fn opt_110m() -> Self {
        ModelArch {
            name: "opt-110m".into(),
            family: ArchFamily::Opt,
            n_layers: 12,
            d_model: 768,
            n_heads: 12,
            ffn_dim: 3072,
            vocab: 32768,
            max_pos: 512,
            embed_proj_dim: None,
        }
    }

    /// Look up a preset by name (CLI / config files).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "opt-350m" => Some(Self::opt_350m()),
            "opt-1.3b" => Some(Self::opt_1_3b()),
            "opt-6.7b" => Some(Self::opt_6_7b()),
            "gpt2-medium" => Some(Self::gpt2_medium()),
            "gpt2-xl" => Some(Self::gpt2_xl()),
            "llama-2-7b" => Some(Self::llama2_7b()),
            "opt-nano" => Some(Self::opt_nano()),
            "opt-tiny" => Some(Self::opt_tiny()),
            "opt-110m" => Some(Self::opt_110m()),
            _ => None,
        }
    }

    pub fn presets() -> Vec<&'static str> {
        vec![
            "opt-350m",
            "opt-1.3b",
            "opt-6.7b",
            "gpt2-medium",
            "gpt2-xl",
            "llama-2-7b",
            "opt-nano",
            "opt-tiny",
            "opt-110m",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::params::ParamInventory;

    #[test]
    fn presets_resolve() {
        for name in ModelArch::presets() {
            let arch = ModelArch::by_name(name).unwrap();
            assert_eq!(arch.name, name);
            assert_eq!(arch.d_model % arch.n_heads, 0, "{name}: head dim");
        }
        assert!(ModelArch::by_name("nonexistent").is_none());
    }

    #[test]
    fn published_param_counts() {
        // Totals must match the published model cards within 2%.
        let cases = [
            (ModelArch::opt_350m(), 331e6),
            (ModelArch::opt_1_3b(), 1.316e9),
            (ModelArch::opt_6_7b(), 6.658e9),
            (ModelArch::gpt2_medium(), 355e6),
            (ModelArch::gpt2_xl(), 1.558e9),
            (ModelArch::llama2_7b(), 6.738e9),
        ];
        for (arch, expected) in cases {
            let total = ParamInventory::build(&arch).total_params() as f64;
            let rel = (total - expected).abs() / expected;
            assert!(
                rel < 0.02,
                "{}: got {total:.3e}, expected {expected:.3e} (rel {rel:.3})",
                arch.name
            );
        }
    }

    #[test]
    fn small_configs_scale() {
        let nano = ParamInventory::build(&ModelArch::opt_nano()).total_params();
        let tiny = ParamInventory::build(&ModelArch::opt_tiny()).total_params();
        let m110 = ParamInventory::build(&ModelArch::opt_110m()).total_params();
        assert!((2e6..6e6).contains(&(nano as f64)), "nano {nano}");
        assert!((20e6..40e6).contains(&(tiny as f64)), "tiny {tiny}");
        assert!((90e6..130e6).contains(&(m110 as f64)), "110m {m110}");
    }
}
