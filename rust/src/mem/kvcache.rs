//! KV-cache sizing for autoregressive generation.
//!
//! Two implementations are modeled, matching the paper's Appendix B:
//!
//! * **HuggingFace dynamic cache** — each decode step *concatenates*: for
//!   every layer, allocate new K/V tensors of length `s+1`, copy, free the
//!   old ones. This is the per-step odd-size alloc/free churn that seeds
//!   inference-phase fragmentation.
//! * **Original ColossalChat generation** — additionally keeps the
//!   full-sequence logits of every step (`[b, s, vocab]` grows each step),
//!   which the paper found "exceptionally high" and replaced with HF's.

use super::arch::{DType, ModelArch};

/// KV-cache size calculator.
#[derive(Debug, Clone)]
pub struct KvCacheModel {
    pub arch: ModelArch,
    pub dtype: DType,
}

impl KvCacheModel {
    pub fn new(arch: &ModelArch, dtype: DType) -> Self {
        KvCacheModel {
            arch: arch.clone(),
            dtype,
        }
    }

    /// Bytes of ONE layer's K (or V) tensor for `batch` sequences of
    /// length `seq`: `[b, n_heads, seq, head_dim]`.
    pub fn layer_kv_bytes(&self, batch: u64, seq: u64) -> u64 {
        batch * self.arch.n_heads * seq * self.arch.head_dim() * self.dtype.bytes()
    }

    /// Total cache bytes across all layers (K and V) at length `seq`.
    pub fn total_bytes(&self, batch: u64, seq: u64) -> u64 {
        2 * self.arch.n_layers * self.layer_kv_bytes(batch, seq)
    }

    /// Peak transient bytes of one decode-step concat for one layer:
    /// old (len s) and new (len s+1) K and V coexist during the copy.
    pub fn concat_step_peak(&self, batch: u64, seq: u64) -> u64 {
        2 * (self.layer_kv_bytes(batch, seq) + self.layer_kv_bytes(batch, seq + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    #[test]
    fn opt_1_3b_cache_sizes() {
        let m = KvCacheModel::new(&ModelArch::opt_1_3b(), DType::F16);
        // One layer, b=2, s=512: 2*32*512*64*2 = 4 MiB per K tensor.
        assert_eq!(m.layer_kv_bytes(2, 512), 4 * MIB);
        // Full cache: 2 (K+V) * 24 layers * 4 MiB = 192 MiB.
        assert_eq!(m.total_bytes(2, 512), 192 * MIB);
    }

    #[test]
    fn cache_grows_linearly() {
        let m = KvCacheModel::new(&ModelArch::opt_350m(), DType::F16);
        assert_eq!(m.total_bytes(2, 512), 2 * m.total_bytes(2, 256));
    }

    #[test]
    fn concat_needs_both_generations() {
        let m = KvCacheModel::new(&ModelArch::opt_1_3b(), DType::F16);
        let peak = m.concat_step_peak(2, 100);
        assert!(peak > 2 * m.layer_kv_bytes(2, 100));
        assert_eq!(
            peak,
            2 * (m.layer_kv_bytes(2, 100) + m.layer_kv_bytes(2, 101))
        );
    }
}
