//! LoRA adapter sizing (Hu et al., 2021). The paper sets the LoRA dimension
//! to 128 for both frameworks; DeepSpeed-Chat's default `lora_module_name =
//! "decoder.layers."` attaches adapters to every linear in each decoder
//! layer (attention projections *and* MLP matrices), which is what
//! [`LoraTargets::AllLinear`] reproduces.

use super::params::{ParamInventory, ParamKind, TensorSpec};

/// Which linears receive adapters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoraTargets {
    /// Attention q/k/v/o only (the original paper's default).
    AttnOnly,
    /// Every per-layer linear (DeepSpeed-Chat's `decoder.layers.` match).
    AllLinear,
}

/// LoRA configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoraSpec {
    pub r: u64,
    pub targets: LoraTargets,
}

impl LoraSpec {
    pub fn paper_default() -> Self {
        LoraSpec {
            r: 128,
            targets: LoraTargets::AllLinear,
        }
    }
}

/// Is this base tensor adapted under `spec`?
pub fn is_target(t: &TensorSpec, spec: LoraSpec) -> bool {
    if t.layer.is_none() {
        return false;
    }
    match spec.targets {
        LoraTargets::AttnOnly => t.kind == ParamKind::AttnProj,
        LoraTargets::AllLinear => matches!(t.kind, ParamKind::AttnProj | ParamKind::Mlp),
    }
}

/// Infer (in, out) dims of a weight from its numel and the arch dims. The
/// inventory stores flat numel; LoRA A/B sizing needs the factorization,
/// which is recoverable because every target is one of the known shapes.
fn factorize(t: &TensorSpec, inv: &ParamInventory) -> (u64, u64) {
    let d = inv.arch.d_model;
    let ffn = inv.arch.ffn_dim;
    let n = t.numel;
    if n == d * d {
        (d, d)
    } else if n == d * 3 * d {
        (d, 3 * d) // GPT-2 fused c_attn
    } else if n == d * ffn {
        (d, ffn)
    } else if n == ffn * d {
        (ffn, d)
    } else {
        panic!("unexpected LoRA target shape: {} ({n})", t.name)
    }
}

/// The adapter tensors (`A: [r, in]`, `B: [out, r]`) for one model.
pub fn lora_tensors(inv: &ParamInventory, spec: LoraSpec) -> Vec<TensorSpec> {
    let mut out = Vec::new();
    for t in inv.tensors.iter().filter(|t| is_target(t, spec)) {
        let (d_in, d_out) = factorize(t, inv);
        out.push(TensorSpec {
            name: format!("{}.lora_A", t.name),
            numel: spec.r * d_in,
            kind: t.kind,
            layer: t.layer,
        });
        out.push(TensorSpec {
            name: format!("{}.lora_B", t.name),
            numel: d_out * spec.r,
            kind: t.kind,
            layer: t.layer,
        });
    }
    out
}

/// Total trainable parameters under LoRA.
pub fn lora_params(inv: &ParamInventory, spec: LoraSpec) -> u64 {
    lora_tensors(inv, spec).iter().map(|t| t.numel).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::ModelArch;

    #[test]
    fn opt_1_3b_lora_counts() {
        let inv = ParamInventory::build(&ModelArch::opt_1_3b());
        let spec = LoraSpec::paper_default();
        let tensors = lora_tensors(&inv, spec);
        // 24 layers x (4 attn + 2 mlp) targets x 2 (A, B).
        assert_eq!(tensors.len(), 24 * 6 * 2);
        let total = lora_params(&inv, spec);
        // attn: 4 * (128*2048 + 2048*128) = 4 * 524288
        // mlp: (128*2048 + 8192*128) + (128*8192 + 2048*128)
        let per_layer = 4 * (128 * 2048 * 2) + 2 * (128 * 2048 + 128 * 8192);
        assert_eq!(total, 24 * per_layer);
        // ~ 113M trainable: LoRA at r=128 is a sizeable adapter.
        assert!((90e6..130e6).contains(&(total as f64)));
    }

    #[test]
    fn attn_only_is_smaller() {
        let inv = ParamInventory::build(&ModelArch::opt_1_3b());
        let all = lora_params(&inv, LoraSpec::paper_default());
        let attn = lora_params(
            &inv,
            LoraSpec {
                r: 128,
                targets: LoraTargets::AttnOnly,
            },
        );
        assert!(attn < all);
        assert_eq!(attn, 24 * 4 * 2 * 128 * 2048);
    }

    #[test]
    fn gpt2_fused_attn_factorizes() {
        let inv = ParamInventory::build(&ModelArch::gpt2_xl());
        let spec = LoraSpec::paper_default();
        // Must not panic on the fused [d, 3d] c_attn shape.
        let total = lora_params(&inv, spec);
        assert!(total > 0);
    }

    #[test]
    fn embeddings_never_targeted() {
        let inv = ParamInventory::build(&ModelArch::opt_350m());
        for t in lora_tensors(&inv, LoraSpec::paper_default()) {
            assert!(t.layer.is_some());
            assert!(t.name.contains("lora_"));
        }
    }
}
