//! Byte-accurate model memory sizing: architectures, parameter inventories,
//! activation/KV-cache/optimizer/LoRA models. Pure size calculators — the
//! trace layer turns these into allocation sequences.

pub mod activations;
pub mod arch;
pub mod kvcache;
pub mod lora;
pub mod optimizer;
pub mod params;

pub use activations::{ActTensor, ActivationModel, SeqShape};
pub use arch::{ArchFamily, DType, ModelArch};
pub use kvcache::KvCacheModel;
pub use lora::{LoraSpec, LoraTargets};
pub use optimizer::{adam_state_tensors, AdamConfig};
pub use params::{ParamInventory, ParamKind, TensorSpec};
