//! Optimizer-state sizing: DeepSpeed-style mixed-precision Adam.
//!
//! For every *trainable* fp16 parameter tensor, the optimizer holds three
//! fp32 tensors: the master copy, the first moment `m`, and the second
//! moment `v` — 12 extra bytes per parameter on top of the 2-byte weight
//! and 2-byte gradient (ZeRO's "K = 12" in Rajbhandari et al.).

use super::arch::DType;
use super::params::TensorSpec;

/// One optimizer-state tensor.
#[derive(Debug, Clone)]
pub struct OptStateTensor {
    pub name: String,
    pub bytes: u64,
}

/// Which pieces of Adam state exist (frameworks differ on master copies
/// when training is already fp32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdamConfig {
    /// Keep an fp32 master copy of each fp16 weight (mixed precision).
    pub fp32_master: bool,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { fp32_master: true }
    }
}

/// Build the optimizer-state inventory for a set of trainable tensors.
pub fn adam_state_tensors(trainable: &[&TensorSpec], cfg: AdamConfig) -> Vec<OptStateTensor> {
    let mut out = Vec::with_capacity(trainable.len() * 3);
    for t in trainable {
        let fp32 = t.numel * DType::F32.bytes();
        out.push(OptStateTensor {
            name: format!("{}.exp_avg", t.name),
            bytes: fp32,
        });
        out.push(OptStateTensor {
            name: format!("{}.exp_avg_sq", t.name),
            bytes: fp32,
        });
        if cfg.fp32_master {
            out.push(OptStateTensor {
                name: format!("{}.master", t.name),
                bytes: fp32,
            });
        }
    }
    out
}

/// Total Adam bytes for `n` trainable params (12 or 8 bytes per param).
pub fn adam_bytes_per_param(cfg: AdamConfig) -> u64 {
    if cfg.fp32_master {
        12
    } else {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::ModelArch;
    use crate::mem::params::ParamInventory;

    #[test]
    fn twelve_bytes_per_param_with_master() {
        let inv = ParamInventory::build(&ModelArch::opt_350m());
        let trainable: Vec<&TensorSpec> = inv.tensors.iter().collect();
        let states = adam_state_tensors(&trainable, AdamConfig::default());
        let total: u64 = states.iter().map(|s| s.bytes).sum();
        assert_eq!(total, inv.total_params() * 12);
        assert_eq!(states.len(), trainable.len() * 3);
    }

    #[test]
    fn eight_bytes_without_master() {
        let inv = ParamInventory::build(&ModelArch::opt_350m());
        let trainable: Vec<&TensorSpec> = inv.tensors.iter().collect();
        let cfg = AdamConfig { fp32_master: false };
        let states = adam_state_tensors(&trainable, cfg);
        let total: u64 = states.iter().map(|s| s.bytes).sum();
        assert_eq!(total, inv.total_params() * 8);
        assert_eq!(adam_bytes_per_param(cfg), 8);
    }
}
