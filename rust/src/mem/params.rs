//! Parameter inventories: the exact per-tensor shapes of each architecture.
//!
//! The inventory is what the trace generators iterate to emit per-tensor
//! allocations (model load, gradients, ZeRO gathers), so tensor granularity
//! matters: one entry per weight/bias tensor, exactly as PyTorch would
//! allocate them.

use super::arch::{ArchFamily, DType, ModelArch};

/// Where a tensor sits in the network — lets strategies treat embedding /
/// per-layer / head tensors differently (e.g. LoRA targets projections).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Embedding,
    /// Attention projection (q/k/v/o or fused c_attn).
    AttnProj,
    /// MLP matrix.
    Mlp,
    /// LayerNorm / RMSNorm weight or bias.
    Norm,
    /// Bias vector of a projection.
    Bias,
    /// Final LM head (untied) or value head.
    Head,
}

/// One parameter tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub numel: u64,
    pub kind: ParamKind,
    /// Layer index, or None for non-layer tensors (embeddings, final norm).
    pub layer: Option<u64>,
}

impl TensorSpec {
    pub fn bytes(&self, dtype: DType) -> u64 {
        self.numel * dtype.bytes()
    }
}

/// The full parameter inventory of one model.
#[derive(Debug, Clone)]
pub struct ParamInventory {
    pub arch: ModelArch,
    pub tensors: Vec<TensorSpec>,
}

impl ParamInventory {
    pub fn build(arch: &ModelArch) -> Self {
        let mut t = Vec::new();
        let d = arch.d_model;
        let ffn = arch.ffn_dim;
        let push = |t: &mut Vec<TensorSpec>, name: String, numel: u64, kind: ParamKind, layer: Option<u64>| {
            t.push(TensorSpec {
                name,
                numel,
                kind,
                layer,
            })
        };

        match arch.family {
            ArchFamily::Opt => {
                let emb_dim = arch.embed_proj_dim.unwrap_or(d);
                push(&mut t, "embed_tokens".into(), arch.vocab * emb_dim, ParamKind::Embedding, None);
                // OPT's learned positions have a +2 offset in the table.
                push(&mut t, "embed_positions".into(), (arch.max_pos + 2) * d, ParamKind::Embedding, None);
                if let Some(p) = arch.embed_proj_dim {
                    push(&mut t, "project_in".into(), p * d, ParamKind::Embedding, None);
                    push(&mut t, "project_out".into(), d * p, ParamKind::Embedding, None);
                }
                for l in 0..arch.n_layers {
                    for proj in ["q_proj", "k_proj", "v_proj", "out_proj"] {
                        push(&mut t, format!("layers.{l}.self_attn.{proj}.weight"), d * d, ParamKind::AttnProj, Some(l));
                        push(&mut t, format!("layers.{l}.self_attn.{proj}.bias"), d, ParamKind::Bias, Some(l));
                    }
                    push(&mut t, format!("layers.{l}.self_attn_layer_norm.weight"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("layers.{l}.self_attn_layer_norm.bias"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("layers.{l}.fc1.weight"), d * ffn, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("layers.{l}.fc1.bias"), ffn, ParamKind::Bias, Some(l));
                    push(&mut t, format!("layers.{l}.fc2.weight"), ffn * d, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("layers.{l}.fc2.bias"), d, ParamKind::Bias, Some(l));
                    push(&mut t, format!("layers.{l}.final_layer_norm.weight"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("layers.{l}.final_layer_norm.bias"), d, ParamKind::Norm, Some(l));
                }
                push(&mut t, "final_layer_norm.weight".into(), d, ParamKind::Norm, None);
                push(&mut t, "final_layer_norm.bias".into(), d, ParamKind::Norm, None);
                // LM head tied with embed_tokens: no extra tensor.
            }
            ArchFamily::Gpt2 => {
                push(&mut t, "wte".into(), arch.vocab * d, ParamKind::Embedding, None);
                push(&mut t, "wpe".into(), arch.max_pos * d, ParamKind::Embedding, None);
                for l in 0..arch.n_layers {
                    push(&mut t, format!("h.{l}.ln_1.weight"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("h.{l}.ln_1.bias"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("h.{l}.attn.c_attn.weight"), d * 3 * d, ParamKind::AttnProj, Some(l));
                    push(&mut t, format!("h.{l}.attn.c_attn.bias"), 3 * d, ParamKind::Bias, Some(l));
                    push(&mut t, format!("h.{l}.attn.c_proj.weight"), d * d, ParamKind::AttnProj, Some(l));
                    push(&mut t, format!("h.{l}.attn.c_proj.bias"), d, ParamKind::Bias, Some(l));
                    push(&mut t, format!("h.{l}.ln_2.weight"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("h.{l}.ln_2.bias"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("h.{l}.mlp.c_fc.weight"), d * ffn, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("h.{l}.mlp.c_fc.bias"), ffn, ParamKind::Bias, Some(l));
                    push(&mut t, format!("h.{l}.mlp.c_proj.weight"), ffn * d, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("h.{l}.mlp.c_proj.bias"), d, ParamKind::Bias, Some(l));
                }
                push(&mut t, "ln_f.weight".into(), d, ParamKind::Norm, None);
                push(&mut t, "ln_f.bias".into(), d, ParamKind::Norm, None);
            }
            ArchFamily::Llama => {
                push(&mut t, "embed_tokens".into(), arch.vocab * d, ParamKind::Embedding, None);
                for l in 0..arch.n_layers {
                    for proj in ["q_proj", "k_proj", "v_proj", "o_proj"] {
                        push(&mut t, format!("layers.{l}.self_attn.{proj}.weight"), d * d, ParamKind::AttnProj, Some(l));
                    }
                    push(&mut t, format!("layers.{l}.mlp.gate_proj.weight"), d * ffn, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("layers.{l}.mlp.up_proj.weight"), d * ffn, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("layers.{l}.mlp.down_proj.weight"), ffn * d, ParamKind::Mlp, Some(l));
                    push(&mut t, format!("layers.{l}.input_layernorm.weight"), d, ParamKind::Norm, Some(l));
                    push(&mut t, format!("layers.{l}.post_attention_layernorm.weight"), d, ParamKind::Norm, Some(l));
                }
                push(&mut t, "norm.weight".into(), d, ParamKind::Norm, None);
                push(&mut t, "lm_head".into(), arch.vocab * d, ParamKind::Head, None);
            }
        }

        ParamInventory {
            arch: arch.clone(),
            tensors: t,
        }
    }

    /// Inventory of a critic/reward variant: backbone + scalar value head
    /// (`v_head: [d_model, 1]`), as DeepSpeed-Chat and ColossalChat build
    /// them.
    pub fn build_with_value_head(arch: &ModelArch) -> Self {
        let mut inv = Self::build(arch);
        inv.tensors.push(TensorSpec {
            name: "v_head".into(),
            numel: arch.d_model,
            kind: ParamKind::Head,
            layer: None,
        });
        inv
    }

    pub fn total_params(&self) -> u64 {
        self.tensors.iter().map(|t| t.numel).sum()
    }

    pub fn total_bytes(&self, dtype: DType) -> u64 {
        self.tensors.iter().map(|t| t.bytes(dtype)).sum()
    }

    /// Tensors of one layer (for ZeRO-3 per-layer gather sizing).
    pub fn layer_tensors(&self, layer: u64) -> impl Iterator<Item = &TensorSpec> {
        self.tensors.iter().filter(move |t| t.layer == Some(layer))
    }

    /// Total bytes of one layer's parameters.
    pub fn layer_bytes(&self, layer: u64, dtype: DType) -> u64 {
        self.layer_tensors(layer).map(|t| t.bytes(dtype)).sum()
    }

    /// Non-layer (embedding/head/final-norm) tensors.
    pub fn global_tensors(&self) -> impl Iterator<Item = &TensorSpec> {
        self.tensors.iter().filter(|t| t.layer.is_none())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GIB;

    #[test]
    fn opt_1_3b_layer_structure() {
        let inv = ParamInventory::build(&ModelArch::opt_1_3b());
        // 24 layers x 16 tensors (4 attn w+b, 2 LNs w+b, 2 MLP w+b) + 2
        // embeddings + final norm w+b.
        assert_eq!(inv.tensors.len(), 24 * 16 + 4);
        // Every layer has identical byte size.
        let l0 = inv.layer_bytes(0, DType::F16);
        for l in 1..24 {
            assert_eq!(inv.layer_bytes(l, DType::F16), l0);
        }
        // 1.3b in fp16 ~ 2.6 GB.
        let total = inv.total_bytes(DType::F16);
        assert!((2 * GIB..3 * GIB).contains(&total), "{total}");
    }

    #[test]
    fn value_head_variant() {
        let base = ParamInventory::build(&ModelArch::opt_350m());
        let critic = ParamInventory::build_with_value_head(&ModelArch::opt_350m());
        assert_eq!(critic.tensors.len(), base.tensors.len() + 1);
        assert_eq!(critic.total_params(), base.total_params() + 1024);
    }

    #[test]
    fn llama_has_untied_head_and_no_biases() {
        let inv = ParamInventory::build(&ModelArch::llama2_7b());
        assert!(inv.tensors.iter().any(|t| t.name == "lm_head"));
        assert!(inv
            .tensors
            .iter()
            .all(|t| t.kind != ParamKind::Bias));
    }

    #[test]
    fn gpt2_fused_qkv() {
        let inv = ParamInventory::build(&ModelArch::gpt2_medium());
        let c_attn = inv
            .tensors
            .iter()
            .find(|t| t.name == "h.0.attn.c_attn.weight")
            .unwrap();
        assert_eq!(c_attn.numel, 1024 * 3072);
    }

    #[test]
    fn opt_350m_embed_projection() {
        let inv = ParamInventory::build(&ModelArch::opt_350m());
        let emb = inv
            .tensors
            .iter()
            .find(|t| t.name == "embed_tokens")
            .unwrap();
        assert_eq!(emb.numel, 50272 * 512);
        assert!(inv.tensors.iter().any(|t| t.name == "project_in"));
    }

    #[test]
    fn global_plus_layers_cover_everything() {
        let inv = ParamInventory::build(&ModelArch::opt_1_3b());
        let global: u64 = inv.global_tensors().map(|t| t.numel).sum();
        let layered: u64 = (0..24)
            .map(|l| inv.layer_tensors(l).map(|t| t.numel).sum::<u64>())
            .sum();
        assert_eq!(global + layered, inv.total_params());
    }
}
