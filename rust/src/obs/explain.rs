//! `rlhf-mem explain` core: run a scenario under the full observability
//! stack and turn the peak snapshot into a ranked "what to shrink first"
//! report. The command in `commands/explain.rs` is a thin wrapper so the
//! golden tests can drive everything here directly.

use crate::alloc::AllocatorConfig;
use crate::experiment::run_trace_observed;
use crate::obs::{ObsStack, PeakSnapshot, StepPeak, TraceDoc};
use crate::profiler::ProfileSummary;
use crate::report::TextTable;
use crate::rlhf::sim::{build_trace, SimScenario};
use crate::trace::Tag;
use crate::util::bytes::fmt_bytes;
use crate::util::json::Json;

/// Knobs for [`explain_scenario`].
#[derive(Debug, Clone)]
pub struct ExplainOptions {
    /// How many of the largest step peaks to keep (`TopPeaks` mode).
    pub top_k: usize,
    /// Also record a Perfetto trace for this rank.
    pub perfetto_pid: Option<u64>,
}

impl Default for ExplainOptions {
    fn default() -> Self {
        ExplainOptions {
            top_k: 5,
            perfetto_pid: None,
        }
    }
}

/// One row of the ranked shrink table.
#[derive(Debug, Clone)]
pub struct ShrinkRow {
    /// "live tensors" census class or allocator overhead class.
    pub name: &'static str,
    /// `true` for live census rows (tags), `false` for overhead rows.
    pub is_census: bool,
    pub bytes: u64,
    /// Share of the peak reserved bytes, percent.
    pub share_pct: f64,
    /// The mitigation lever that attacks this row.
    pub advice: &'static str,
}

/// The full explain result.
#[derive(Debug)]
pub struct ExplainReport {
    pub label: String,
    pub capacity: u64,
    pub summary: ProfileSummary,
    /// Composition at the global reserved peak (`None` only for a replay
    /// that never mapped device memory).
    pub peak: Option<PeakSnapshot>,
    pub top_peaks: Vec<StepPeak>,
    /// Ranked shrink rows, descending bytes.
    pub rows: Vec<ShrinkRow>,
}

/// [`ExplainReport`] plus the optional Perfetto document.
#[derive(Debug)]
pub struct ExplainOutcome {
    pub report: ExplainReport,
    pub perfetto: Option<TraceDoc>,
}

/// Which mitigation attacks a census tag (the planner's vocabulary:
/// strategy / sharing / policy / allocator knobs).
pub fn advice_for_tag(tag: Tag) -> &'static str {
    match tag {
        Tag::Param => "zero=3 partitioning; sharing=lora|hydra (frozen shared base)",
        Tag::Grad => "zero>=2 partitioning; grad_checkpoint",
        Tag::OptState => "zero>=1 partitioning; cpu_offload; sharing=lora (adapter-only Adam)",
        Tag::Activation => "grad_checkpoint; smaller train_micro_batch",
        Tag::SavedActivation => "grad_checkpoint (recompute in backward)",
        Tag::KvCache => "smaller rollout_batch / gen_len",
        Tag::Logits => "smaller infer_micro_batch",
        Tag::CommBuffer => "smaller ZeRO reduce/allgather buckets",
        Tag::Staging => "disable cpu_offload (trades memory back for time)",
        Tag::Workspace => "workload-inherent scratch",
        Tag::Experience => "smaller rollout_batch; stream experience to host",
    }
}

fn overhead_rows(peak: &PeakSnapshot) -> [ShrinkRow; 4] {
    let b = &peak.breakdown;
    let mk = |name, bytes, advice| ShrinkRow {
        name,
        is_census: false,
        bytes,
        share_pct: 0.0,
        advice,
    };
    [
        mk(
            "cached-free segments",
            b.cached_free,
            "empty_cache=after_inference|after_both; gc threshold",
        ),
        mk(
            "free-gap fragmentation",
            b.free_gaps,
            "expandable_segments; max_split_size",
        ),
        mk(
            "block slack",
            b.block_slack,
            "max_split_size (split large cached blocks)",
        ),
        mk(
            "rounding waste",
            b.rounding_waste,
            "inherent (512 B request rounding)",
        ),
    ]
}

/// Run `scn` under the observability stack and build the report.
pub fn explain_scenario(
    scn: &SimScenario,
    capacity: u64,
    alloc_cfg: &AllocatorConfig,
    opts: &ExplainOptions,
) -> ExplainOutcome {
    let trace = build_trace(scn);
    let mut obs = ObsStack::new().top_k(opts.top_k);
    if let Some(pid) = opts.perfetto_pid {
        obs = obs.record_perfetto(pid);
    }
    let outcome = run_trace_observed(&trace, capacity, alloc_cfg, &mut obs);
    let perfetto = obs.finish_perfetto(outcome.end_time_us);

    let peak = obs.recorder.peak().cloned();
    let mut rows: Vec<ShrinkRow> = Vec::new();
    if let Some(p) = &peak {
        for (tag, census) in &p.by_tag {
            rows.push(ShrinkRow {
                name: tag.name(),
                is_census: true,
                bytes: census.requested,
                share_pct: 0.0,
                advice: advice_for_tag(*tag),
            });
        }
        rows.extend(overhead_rows(p));
        rows.retain(|r| r.bytes > 0);
        rows.sort_by_key(|r| (std::cmp::Reverse(r.bytes), r.name));
        let reserved = p.reserved.max(1);
        for r in &mut rows {
            r.share_pct = r.bytes as f64 * 100.0 / reserved as f64;
        }
    }

    let label = format!(
        "{} / {} + {} / {} / {} / {} / world {}",
        scn.framework.kind.name(),
        scn.models.policy_arch.name,
        scn.models.value_arch.name,
        scn.strategy.label(),
        scn.algo.name(),
        scn.sharing.name(),
        scn.world
    );
    ExplainOutcome {
        report: ExplainReport {
            label,
            capacity,
            summary: outcome.summary,
            peak,
            top_peaks: obs.recorder.top_peaks().to_vec(),
            rows,
        },
        perfetto,
    }
}

impl ExplainReport {
    /// Fraction of the peak reserved bytes the decomposition accounts
    /// for, percent. By construction this is 100.0 (the five terms sum to
    /// reserved exactly); the golden test pins it ≥ 99.
    pub fn accounted_pct(&self) -> f64 {
        match &self.peak {
            Some(p) if p.reserved > 0 => {
                p.breakdown.total() as f64 * 100.0 / p.reserved as f64
            }
            _ => 100.0,
        }
    }

    /// The ranked shrink table plus the decomposition header, rendered.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.label));
        let Some(p) = &self.peak else {
            out.push_str("  no device memory was ever reserved\n");
            return out;
        };
        let b = &p.breakdown;
        out.push_str(&format!(
            "  peak reserved {} — set during {} (step {}), {} live tensors\n",
            fmt_bytes(p.reserved),
            p.phase.name(),
            p.step,
            p.by_tag.iter().map(|(_, c)| c.count).sum::<u64>(),
        ));
        out.push_str(&format!(
            "  = {} live + {} rounding + {} slack + {} free gaps + {} cached-free  ({:.1}% accounted)\n\n",
            fmt_bytes(b.census_requested),
            fmt_bytes(b.rounding_waste),
            fmt_bytes(b.block_slack),
            fmt_bytes(b.free_gaps),
            fmt_bytes(b.cached_free),
            self.accounted_pct(),
        ));
        let mut t = TextTable::new(&["#", "what", "class", "bytes", "share", "shrink lever"]);
        for (i, r) in self.rows.iter().enumerate() {
            t.row(vec![
                format!("{}", i + 1),
                r.name.to_string(),
                if r.is_census { "live" } else { "overhead" }.to_string(),
                fmt_bytes(r.bytes),
                format!("{:.1}%", r.share_pct),
                r.advice.to_string(),
            ]);
        }
        out.push_str(&t.render());
        if !self.top_peaks.is_empty() {
            out.push_str("\n  top step peaks:\n");
            for sp in &self.top_peaks {
                let top = sp
                    .top_tag
                    .map(|(tag, bytes)| format!("{} {}", tag.name(), fmt_bytes(bytes)))
                    .unwrap_or_else(|| "-".to_string());
                out.push_str(&format!(
                    "    step {:>3}  {:>12}  during {:<15} top live: {}\n",
                    sp.step,
                    fmt_bytes(sp.reserved),
                    sp.phase.name(),
                    top
                ));
            }
        }
        out
    }

    /// Machine-readable document (`explain --json`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("what", Json::str(r.name)),
                    (
                        "class",
                        Json::str(if r.is_census { "live" } else { "overhead" }),
                    ),
                    ("bytes", Json::from(r.bytes)),
                    ("share_pct", Json::from(r.share_pct)),
                    ("advice", Json::str(r.advice)),
                ])
            })
            .collect();
        let top_peaks: Vec<Json> = self
            .top_peaks
            .iter()
            .map(|sp| {
                Json::obj(vec![
                    ("step", Json::from(sp.step)),
                    ("reserved", Json::from(sp.reserved)),
                    ("phase", Json::str(sp.phase.name())),
                    (
                        "top_tag",
                        match sp.top_tag {
                            Some((tag, bytes)) => Json::obj(vec![
                                ("tag", Json::str(tag.name())),
                                ("bytes", Json::from(bytes)),
                            ]),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("scenario", Json::str(self.label.clone())),
            ("capacity", Json::from(self.capacity)),
            ("reserved", Json::from(self.summary.peak_reserved)),
            ("accounted_pct", Json::from(self.accounted_pct())),
            ("oom", Json::from(self.summary.oom)),
            ("rows", Json::Arr(rows)),
            (
                "peak",
                match &self.peak {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
            ("top_peaks", Json::Arr(top_peaks)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::RTX3090_HBM;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    #[test]
    fn explain_ranks_and_accounts() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let out = explain_scenario(
            &scn,
            RTX3090_HBM,
            &AllocatorConfig::default(),
            &ExplainOptions::default(),
        );
        let r = &out.report;
        assert!(!r.summary.oom);
        assert!(r.accounted_pct() >= 99.0, "{}", r.accounted_pct());
        assert!(!r.rows.is_empty());
        for w in r.rows.windows(2) {
            assert!(w[0].bytes >= w[1].bytes, "rows must be ranked");
        }
        // Rendering is total and carries the table header.
        let text = r.render();
        assert!(text.contains("shrink lever"), "{text}");
    }

    #[test]
    fn explain_json_round_trips() {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let out = explain_scenario(
            &scn,
            RTX3090_HBM,
            &AllocatorConfig::default(),
            &ExplainOptions {
                top_k: 2,
                perfetto_pid: None,
            },
        );
        let text = out.report.to_json().to_string_pretty();
        let j = crate::util::json::parse(&text).unwrap();
        assert!(j.req_f64("accounted_pct").unwrap() >= 99.0);
        assert!(j.req_arr("rows").unwrap().len() >= 3);
        assert!(out.report.top_peaks.len() <= 2);
    }
}
