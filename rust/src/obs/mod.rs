//! Observability subsystem (DESIGN.md §15): the layer that turns the
//! memory study from a scoreboard into an explainable system.
//!
//! Three pillars, all fed by the same replay event stream:
//!
//! * **Peak flight recorder** ([`PeakRecorder`]) — a live-block census
//!   keyed by tag / phase-of-origin / role / pool that snapshots the full
//!   composition of the reserved peak the moment it is set, with an exact
//!   five-way fragmentation decomposition ([`PeakBreakdown`]). Surfaced
//!   by `rlhf-mem explain`.
//! * **Trace export** ([`perfetto`]) — Chrome/Perfetto trace-event JSON
//!   (`--trace-out` on `profile` / `explain` / `cluster`): phase spans
//!   per rank, allocator instants, reserved/allocated counter tracks,
//!   collective flow events.
//! * **Run-telemetry ledger** ([`Telemetry`]) — deterministic counters
//!   (JSONL `telemetry` footers on sweep/planner artifacts) strictly
//!   separated from wall-clock spans (printed only).
//!
//! Determinism rules: every artifact-bound value is derived from
//! index-ordered results or sorted aggregations; wall-clock never enters
//! an artifact; the jobs-1 vs jobs-N byte-identical contract holds for
//! every footer and trace document.

pub mod explain;
pub mod perfetto;
pub mod recorder;
pub mod telemetry;

pub use explain::{explain_scenario, ExplainOptions, ExplainOutcome, ExplainReport, ShrinkRow};
pub use perfetto::{PerfettoRecorder, TraceDoc};
pub use recorder::{phase_role, CensusBytes, PeakBreakdown, PeakRecorder, PeakSnapshot, StepPeak};
pub use telemetry::Telemetry;

use crate::alloc::{AllocEvent, CachingAllocator, StatSnapshot};
use crate::profiler::MemoryProfiler;
use crate::trace::{PhaseKind, PhaseSink, TraceOp};
use crate::util::json::Json;

/// Fan-out sink: one replay feeds the profiler, the peak recorder, and
/// (optionally) a Perfetto recorder. This is what
/// [`run_trace_observed`](crate::experiment::run_trace_observed) drives.
#[derive(Debug)]
pub struct ObsStack {
    pub profiler: MemoryProfiler,
    pub recorder: PeakRecorder,
    pub perfetto: Option<PerfettoRecorder>,
}

impl ObsStack {
    pub fn new() -> Self {
        Self::with_profiler(MemoryProfiler::new())
    }

    /// Use a custom-configured profiler (e.g. a non-default timeline
    /// resolution).
    pub fn with_profiler(profiler: MemoryProfiler) -> Self {
        ObsStack {
            profiler,
            recorder: PeakRecorder::new(),
            perfetto: None,
        }
    }

    /// Keep the `k` largest step peaks.
    pub fn top_k(mut self, k: usize) -> Self {
        self.recorder = PeakRecorder::with_top_k(k);
        self
    }

    /// Also record a Perfetto trace for rank `pid`.
    pub fn record_perfetto(mut self, pid: u64) -> Self {
        self.perfetto = Some(PerfettoRecorder::new(pid));
        self
    }

    /// Close the Perfetto document (if recording) at `end_time_us`.
    pub fn finish_perfetto(&mut self, end_time_us: f64) -> Option<TraceDoc> {
        self.perfetto.take().map(|p| p.finish(end_time_us))
    }
}

impl Default for ObsStack {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseSink for ObsStack {
    fn on_phase(&mut self, phase: PhaseKind, alloc: &CachingAllocator, compute_us: f64) {
        self.profiler.on_phase(phase, alloc, compute_us);
        self.recorder.on_phase(phase, alloc, compute_us);
        if let Some(p) = self.perfetto.as_mut() {
            p.on_phase(phase, alloc, compute_us);
        }
    }

    fn on_step_end(&mut self, step: u64, alloc: &CachingAllocator, compute_us: f64) {
        self.profiler.on_step_end(step, alloc, compute_us);
        self.recorder.on_step_end(step, alloc, compute_us);
        if let Some(p) = self.perfetto.as_mut() {
            p.on_step_end(step, alloc, compute_us);
        }
    }

    fn on_alloc_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        self.profiler.on_alloc_event(event, state);
        self.recorder.on_alloc_event(event, state);
        if let Some(p) = self.perfetto.as_mut() {
            p.on_alloc_event(event, state);
        }
    }

    fn on_op(&mut self, op: &TraceOp) {
        self.profiler.on_op(op);
        self.recorder.on_op(op);
        if let Some(p) = self.perfetto.as_mut() {
            p.on_op(op);
        }
    }

    fn on_op_end(&mut self, alloc: &CachingAllocator) {
        self.profiler.on_op_end(alloc);
        self.recorder.on_op_end(alloc);
        if let Some(p) = self.perfetto.as_mut() {
            p.on_op_end(alloc);
        }
    }
}

/// The `profile --json` document. The first five keys are the original
/// schema and must stay stable (external consumers parse them); the
/// attribution / frag-sample / empty-cache keys extend it.
pub fn profile_doc(
    s: &crate::profiler::ProfileSummary,
    profiler: &MemoryProfiler,
    program: &crate::rlhf::program::PhaseProgram,
) -> Json {
    let attribution: Vec<Json> = profiler
        .phase_attribution(program)
        .into_iter()
        .map(|(phase, peak)| {
            Json::obj(vec![
                ("phase", Json::str(phase.name())),
                ("reserved", Json::from(peak.reserved)),
                ("allocated", Json::from(peak.allocated)),
            ])
        })
        .collect();
    Json::obj(vec![
        // Legacy keys — order and names pinned by obs_golden.rs.
        ("reserved", Json::from(s.peak_reserved)),
        ("frag", Json::from(s.frag)),
        ("allocated", Json::from(s.peak_allocated)),
        ("peak_phase", Json::str(s.peak_phase.name())),
        ("oom", Json::from(s.oom)),
        // Extensions.
        ("phase_attribution", Json::Arr(attribution)),
        ("frag_samples", Json::from(profiler.frag_samples.len())),
        ("empty_cache_calls", Json::from(s.empty_cache_calls)),
        (
            "empty_cache_released",
            Json::from(profiler.empty_cache_released),
        ),
        ("cuda_mallocs", Json::from(s.cuda_mallocs)),
    ])
}
