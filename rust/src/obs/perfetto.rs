//! Chrome/Perfetto trace-event export: any profiled run becomes a JSON
//! document `ui.perfetto.dev` opens directly.
//!
//! Schema emitted (the classic trace-event JSON, DESIGN.md §15):
//! * phase spans — `"ph": "X"` complete events, one track per rank
//!   (`pid` = rank, `tid` = 1);
//! * allocator events — `"ph": "i"` instants on a second track
//!   (`tid` = 2): cudaMalloc / cudaFree / empty_cache / OOM-retry / gc;
//! * reserved & allocated — `"ph": "C"` counter tracks sampled from the
//!   allocator's stat snapshots;
//! * cluster collective costs — `"ph": "s"` / `"ph": "f"` flow events
//!   between rank tracks.
//!
//! All timestamps are simulated microseconds (allocator time + replayed
//! compute time); nothing wall-clock enters the document, so two runs of
//! the same scenario emit byte-identical traces.

use crate::alloc::{AllocEvent, CachingAllocator, StatSnapshot};
use crate::trace::{PhaseKind, PhaseSink};
use crate::util::json::Json;

/// Builder for one trace-event document. Ranks append via
/// [`PerfettoRecorder`]; multi-rank documents merge with [`Self::merge`].
#[derive(Debug, Default)]
pub struct TraceDoc {
    events: Vec<Json>,
    next_flow_id: u64,
}

impl TraceDoc {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, ev: Json) {
        self.events.push(ev);
    }

    /// A complete ("X") span on `pid`'s phase track.
    pub fn span(&mut self, pid: u64, name: &str, ts_us: f64, dur_us: f64) {
        self.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("phase")),
            ("ph", Json::str("X")),
            ("ts", Json::from(ts_us)),
            ("dur", Json::from(dur_us)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(1u64)),
        ]));
    }

    /// An instant ("i") on `pid`'s allocator track.
    pub fn instant(&mut self, pid: u64, name: &str, ts_us: f64, arg_bytes: u64) {
        self.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("alloc")),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::from(ts_us)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(2u64)),
            ("args", Json::obj(vec![("bytes", Json::from(arg_bytes))])),
        ]));
    }

    /// A counter ("C") sample on `pid`'s `name` counter track.
    pub fn counter(&mut self, pid: u64, name: &str, ts_us: f64, value: u64) {
        self.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("C")),
            ("ts", Json::from(ts_us)),
            ("pid", Json::from(pid)),
            ("args", Json::obj(vec![("bytes", Json::from(value))])),
        ]));
    }

    /// A flow arrow from `(from_pid, from_ts)` to `(to_pid, to_ts)` —
    /// used for cluster collective/P2P costs between rank tracks.
    pub fn flow(
        &mut self,
        name: &str,
        from_pid: u64,
        from_ts_us: f64,
        to_pid: u64,
        to_ts_us: f64,
        cost_us: f64,
    ) {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        let args = Json::obj(vec![("cost_us", Json::from(cost_us))]);
        self.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("collective")),
            ("ph", Json::str("s")),
            ("id", Json::from(id)),
            ("ts", Json::from(from_ts_us)),
            ("pid", Json::from(from_pid)),
            ("tid", Json::from(1u64)),
            ("args", args.clone()),
        ]));
        self.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("collective")),
            ("ph", Json::str("f")),
            ("bp", Json::str("e")),
            ("id", Json::from(id)),
            ("ts", Json::from(to_ts_us)),
            ("pid", Json::from(to_pid)),
            ("tid", Json::from(1u64)),
            ("args", args),
        ]));
    }

    /// Name `pid`'s process track (shows as the rank label in the UI).
    pub fn name_process(&mut self, pid: u64, name: &str) {
        self.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::from(pid)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    /// Append every event of `other` (multi-rank merge). Flow ids are the
    /// merged doc's concern — callers emit flows on the merged doc only.
    pub fn merge(&mut self, other: TraceDoc) {
        self.events.extend(other.events);
    }

    pub fn event_count(&self) -> usize {
        self.events.len()
    }

    /// The final document.
    pub fn to_json(self) -> Json {
        Json::obj(vec![
            ("traceEvents", Json::Arr(self.events)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }
}

/// A [`PhaseSink`] that records one rank's replay into a [`TraceDoc`]:
/// phase spans, allocator instants, and reserved/allocated counter
/// tracks. Counter samples below `counter_resolution` bytes of change are
/// decimated (same discipline as the profiler's timeline) to keep traces
/// viewer-sized.
#[derive(Debug)]
pub struct PerfettoRecorder {
    doc: TraceDoc,
    pid: u64,
    compute_us: f64,
    open_span: Option<(PhaseKind, f64)>,
    last_reserved: u64,
    last_allocated: u64,
    counter_resolution: u64,
    emitted_first_counter: bool,
}

impl PerfettoRecorder {
    /// Record rank `pid` (single-GPU runs use rank 0).
    pub fn new(pid: u64) -> Self {
        let mut doc = TraceDoc::new();
        doc.name_process(pid, &format!("rank {pid}"));
        PerfettoRecorder {
            doc,
            pid,
            compute_us: 0.0,
            open_span: None,
            last_reserved: 0,
            last_allocated: 0,
            counter_resolution: 1 << 20,
            emitted_first_counter: false,
        }
    }

    fn now(&self, alloc_time_us: f64) -> f64 {
        alloc_time_us + self.compute_us
    }

    fn close_span(&mut self, end_us: f64) {
        if let Some((phase, start)) = self.open_span.take() {
            self.doc
                .span(self.pid, phase.name(), start, (end_us - start).max(0.0));
        }
    }

    fn sample_counters(&mut self, ts: f64, reserved: u64, allocated: u64) {
        let moved = reserved.abs_diff(self.last_reserved) >= self.counter_resolution
            || allocated.abs_diff(self.last_allocated) >= self.counter_resolution;
        if !moved && self.emitted_first_counter {
            return;
        }
        self.emitted_first_counter = true;
        self.last_reserved = reserved;
        self.last_allocated = allocated;
        self.doc.counter(self.pid, "reserved", ts, reserved);
        self.doc.counter(self.pid, "allocated", ts, allocated);
    }

    /// Close the trailing span and hand the document over. `end_time_us`
    /// is the run's final simulated time (allocator + compute).
    pub fn finish(mut self, end_time_us: f64) -> TraceDoc {
        self.close_span(end_time_us);
        self.doc
    }
}

impl PhaseSink for PerfettoRecorder {
    fn on_phase(&mut self, phase: PhaseKind, alloc: &CachingAllocator, compute_us: f64) {
        self.compute_us = compute_us;
        let t = self.now(alloc.time_us());
        self.close_span(t);
        self.open_span = Some((phase, t));
        self.sample_counters(t, alloc.reserved(), alloc.allocated());
    }

    fn on_step_end(&mut self, step: u64, alloc: &CachingAllocator, compute_us: f64) {
        self.compute_us = compute_us;
        let t = self.now(alloc.time_us());
        self.doc.instant(self.pid, &format!("step {step}"), t, 0);
    }

    fn on_alloc_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        let t = self.now(state.time_us);
        match event {
            AllocEvent::CudaMalloc { segment_bytes, .. } => {
                self.doc.instant(self.pid, "cudaMalloc", t, *segment_bytes);
            }
            AllocEvent::CudaFree { segment_bytes } => {
                self.doc.instant(self.pid, "cudaFree", t, *segment_bytes);
            }
            AllocEvent::EmptyCache { bytes, .. } => {
                self.doc.instant(self.pid, "empty_cache", t, *bytes);
            }
            AllocEvent::OomRetry { released_bytes } => {
                self.doc.instant(self.pid, "oom_retry", t, *released_bytes);
            }
            AllocEvent::GcReclaim { bytes, .. } => {
                self.doc.instant(self.pid, "gc_reclaim", t, *bytes);
            }
            _ => {}
        }
        self.sample_counters(t, state.reserved, state.allocated);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::CachingAllocator;
    use crate::trace::{replay, Tag, TraceBuilder};
    use crate::util::bytes::{GIB, MIB};
    use crate::util::json::parse;

    #[test]
    fn document_round_trips_and_has_tracks() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        b.transient([50 * MIB], Tag::KvCache);
        b.phase(PhaseKind::TrainActor);
        b.transient([80 * MIB], Tag::Grad);
        b.step_end(1);
        let trace = b.finish();

        let mut alloc = CachingAllocator::with_default_config(GIB);
        let mut rec = PerfettoRecorder::new(0);
        let res = replay(&trace, &mut alloc, &mut rec);
        let doc = rec.finish(alloc.time_us() + res.compute_us);
        let text = doc.to_json().to_string_pretty();

        let parsed = parse(&text).expect("trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let ph = |kind: &str| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some(kind))
                .count()
        };
        assert!(ph("C") >= 2, "counter samples missing");
        assert!(ph("i") >= 1, "allocator instants missing");
        let spans: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
            .filter_map(|e| e.get("name").and_then(|v| v.as_str()))
            .collect();
        assert!(spans.contains(&"generation"), "{spans:?}");
        assert!(spans.contains(&"train_actor"), "{spans:?}");
    }

    #[test]
    fn flows_pair_start_and_finish() {
        let mut doc = TraceDoc::new();
        doc.flow("p2p", 0, 10.0, 1, 20.0, 5.0);
        let j = doc.to_json();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("s"));
        assert_eq!(events[1].get("ph").unwrap().as_str(), Some("f"));
        assert_eq!(events[0].get("id"), events[1].get("id"));
    }

    #[test]
    fn identical_runs_emit_identical_traces() {
        let run = || {
            let mut b = TraceBuilder::new();
            b.phase(PhaseKind::Generation);
            b.transient([30 * MIB, 40 * MIB], Tag::Activation);
            b.step_end(1);
            let trace = b.finish();
            let mut alloc = CachingAllocator::with_default_config(GIB);
            let mut rec = PerfettoRecorder::new(0);
            let res = replay(&trace, &mut alloc, &mut rec);
            rec.finish(alloc.time_us() + res.compute_us)
                .to_json()
                .to_string_pretty()
        };
        assert_eq!(run(), run());
    }
}
