//! The peak flight recorder: a [`PhaseSink`] that maintains a live-block
//! census (tag, phase-of-origin, role, pool) from the replayed op stream
//! and, at the moment a new global reserved peak is set, snapshots the
//! full composition of that peak — what the memory *is*, not just how big
//! it got.
//!
//! The census is driven by pairing each [`TraceOp`] (which carries the
//! tag and trace handle) with the [`AllocEvent`]s the allocator emits for
//! it (which carry the requested and rounded sizes): `on_op` stages the
//! in-flight alloc, `on_alloc_event` completes the census entry, and
//! `on_op_end` — called once the op is done and its events drained —
//! checks whether the op raised the global reserved peak and, if so,
//! introspects the quiescent allocator for the segment map and cache
//! state. Reserved only rises inside an op's driver-growth path, so at
//! `on_op_end` a peak-setting op still holds `reserved() == peak`.
//!
//! Everything recorded is deterministic: census aggregations sort by
//! byte count (name-tiebroken), the segment map sorts by segment id, and
//! no wall-clock value ever enters a snapshot.

use crate::alloc::{AllocEvent, CachingAllocator, PoolKind, SegmentRecord, StatSnapshot};
use crate::trace::{PhaseKind, PhaseSink, Tag, TraceOp};
use crate::util::fasthash::FastMap;
use crate::util::json::Json;

/// Model-role attribution of a phase: which RLHF model's work allocated
/// during it. Derived purely from the phase-of-origin, so it needs no
/// extra plumbing through the emitters.
pub fn phase_role(phase: PhaseKind) -> &'static str {
    match phase {
        PhaseKind::Init => "setup",
        PhaseKind::Generation | PhaseKind::InferActor | PhaseKind::TrainActor => "actor",
        PhaseKind::InferCritic | PhaseKind::TrainCritic => "critic",
        PhaseKind::InferReference => "reference",
        PhaseKind::InferReward => "reward",
        PhaseKind::Idle => "idle",
    }
}

/// One live allocation in the census.
#[derive(Debug, Clone, Copy)]
struct CensusEntry {
    tag: Tag,
    /// Phase that performed the allocation.
    phase: PhaseKind,
    requested: u64,
    rounded: u64,
}

/// Live bytes aggregated for one census key.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CensusBytes {
    /// Bytes the callers asked for.
    pub requested: u64,
    /// After the allocator's 512 B rounding.
    pub rounded: u64,
    /// Number of live allocations.
    pub count: u64,
}

/// The five-way exact decomposition of a reserved peak. The terms are
/// disjoint and sum to `reserved` by construction:
///
/// ```text
/// reserved = census_requested   (bytes live tensors asked for)
///          + rounding_waste     (512 B-rounding inside live blocks)
///          + block_slack        (block size beyond the rounded request —
///                                unsplit-remainder bytes inside live blocks)
///          + free_gaps          (free blocks inside partially-used
///                                segments — the un-releasable gaps)
///          + cached_free        (fully-free cached segments — releasable
///                                by empty_cache)
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeakBreakdown {
    pub census_requested: u64,
    pub rounding_waste: u64,
    pub block_slack: u64,
    pub free_gaps: u64,
    pub cached_free: u64,
}

impl PeakBreakdown {
    /// Sum of all five terms — equals the reserved bytes the breakdown
    /// decomposes (the `obs_golden` tests pin this exactly).
    pub fn total(&self) -> u64 {
        self.census_requested
            + self.rounding_waste
            + self.block_slack
            + self.free_gaps
            + self.cached_free
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("census_requested", Json::from(self.census_requested)),
            ("rounding_waste", Json::from(self.rounding_waste)),
            ("block_slack", Json::from(self.block_slack)),
            ("free_gaps", Json::from(self.free_gaps)),
            ("cached_free", Json::from(self.cached_free)),
            ("total", Json::from(self.total())),
        ])
    }
}

/// Full composition captured at the moment the global reserved peak was
/// set.
#[derive(Debug, Clone)]
pub struct PeakSnapshot {
    /// Reserved bytes at the peak (== breakdown total).
    pub reserved: u64,
    /// Allocated (live block) bytes at the peak.
    pub allocated: u64,
    /// Phase executing when the peak was set.
    pub phase: PhaseKind,
    /// Step executing when the peak was set (0 = before the first
    /// `StepEnd`).
    pub step: u64,
    /// Live census by tag, descending requested bytes (name-tiebroken).
    pub by_tag: Vec<(Tag, CensusBytes)>,
    /// Live census by phase-of-origin, descending requested bytes.
    pub by_phase: Vec<(PhaseKind, CensusBytes)>,
    /// Live census by model role, descending requested bytes.
    pub by_role: Vec<(&'static str, CensusBytes)>,
    /// Live census per allocator pool: `[small, large]`.
    pub by_pool: [CensusBytes; 2],
    /// Per-segment map (sorted by segment id).
    pub segments: Vec<SegmentRecord>,
    pub breakdown: PeakBreakdown,
}

impl PeakSnapshot {
    pub fn to_json(&self) -> Json {
        let census = |b: &CensusBytes| {
            Json::obj(vec![
                ("requested", Json::from(b.requested)),
                ("rounded", Json::from(b.rounded)),
                ("count", Json::from(b.count)),
            ])
        };
        let by_tag: Vec<Json> = self
            .by_tag
            .iter()
            .map(|(t, b)| {
                let mut o = vec![("tag".to_string(), Json::str(t.name()))];
                if let Json::Obj(kvs) = census(b) {
                    o.extend(kvs);
                }
                Json::Obj(o)
            })
            .collect();
        let by_phase: Vec<Json> = self
            .by_phase
            .iter()
            .map(|(p, b)| {
                let mut o = vec![("phase".to_string(), Json::str(p.name()))];
                if let Json::Obj(kvs) = census(b) {
                    o.extend(kvs);
                }
                Json::Obj(o)
            })
            .collect();
        let by_role: Vec<Json> = self
            .by_role
            .iter()
            .map(|(r, b)| {
                let mut o = vec![("role".to_string(), Json::str(*r))];
                if let Json::Obj(kvs) = census(b) {
                    o.extend(kvs);
                }
                Json::Obj(o)
            })
            .collect();
        let segments: Vec<Json> = self
            .segments
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("segment", Json::from(u64::from(s.segment))),
                    ("pool", Json::str(s.pool.name())),
                    ("size", Json::from(s.size)),
                    ("allocated", Json::from(s.allocated)),
                    ("free", Json::from(s.free)),
                    ("blocks", Json::from(u64::from(s.blocks))),
                    (
                        "origin_phase",
                        Json::str(PhaseKind::from_tag(s.origin_phase).name()),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("reserved", Json::from(self.reserved)),
            ("allocated", Json::from(self.allocated)),
            ("phase", Json::str(self.phase.name())),
            ("step", Json::from(self.step)),
            ("breakdown", self.breakdown.to_json()),
            ("by_tag", Json::Arr(by_tag)),
            ("by_phase", Json::Arr(by_phase)),
            ("by_role", Json::Arr(by_role)),
            (
                "by_pool",
                Json::obj(vec![
                    ("small", census(&self.by_pool[0])),
                    ("large", census(&self.by_pool[1])),
                ]),
            ),
            ("segments", Json::Arr(segments)),
        ])
    }
}

/// Summary of one step's reserved peak (the `TopPeaks` mode keeps the K
/// largest of these for intra-run variance).
#[derive(Debug, Clone)]
pub struct StepPeak {
    pub step: u64,
    /// Max reserved bytes observed during the step.
    pub reserved: u64,
    /// Phase executing when the step's max was reached.
    pub phase: PhaseKind,
    /// Largest live census tag at that moment (tag, requested bytes).
    pub top_tag: Option<(Tag, u64)>,
}

/// The flight recorder. Pass it to `replay` (usually inside an
/// [`ObsStack`](crate::obs::ObsStack) alongside the profiler).
#[derive(Debug)]
pub struct PeakRecorder {
    current_phase: PhaseKind,
    current_step: u64,
    /// Trace handle → live census entry.
    live: FastMap<u64, CensusEntry>,
    /// Running totals over `live` (kept incrementally: the census is
    /// consulted at every step peak, not just the global one).
    live_requested: u64,
    live_rounded: u64,
    /// The alloc op staged by `on_op`, completed by the next Alloc event.
    pending_alloc: Option<(u64, Tag)>,
    /// Global peak reserved seen so far.
    peak_seen: u64,
    peak: Option<PeakSnapshot>,
    /// Whether the op that just ran emitted events (cheap pre-filter so
    /// `on_op_end` skips stats reads for compute/phase ops).
    op_had_events: bool,
    /// K largest step peaks (descending reserved).
    top_peaks: Vec<StepPeak>,
    top_k: usize,
    /// Current step's running max.
    step_peak: StepPeak,
}

const DEFAULT_TOP_K: usize = 5;

impl PeakRecorder {
    pub fn new() -> Self {
        Self::with_top_k(DEFAULT_TOP_K)
    }

    /// Keep the `k` largest step peaks (`TopPeaks` mode).
    pub fn with_top_k(k: usize) -> Self {
        PeakRecorder {
            current_phase: PhaseKind::Init,
            current_step: 0,
            live: FastMap::default(),
            live_requested: 0,
            live_rounded: 0,
            pending_alloc: None,
            peak_seen: 0,
            peak: None,
            op_had_events: false,
            top_peaks: Vec::new(),
            top_k: k,
            step_peak: StepPeak {
                step: 1,
                reserved: 0,
                phase: PhaseKind::Init,
                top_tag: None,
            },
        }
    }

    /// The global-peak composition (None iff the replay never reserved).
    pub fn peak(&self) -> Option<&PeakSnapshot> {
        self.peak.as_ref()
    }

    /// The K largest step peaks, descending reserved bytes.
    pub fn top_peaks(&self) -> &[StepPeak] {
        &self.top_peaks
    }

    /// Live census bytes right now (requested, rounded).
    pub fn live_bytes(&self) -> (u64, u64) {
        (self.live_requested, self.live_rounded)
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Largest live tag by requested bytes (deterministic: name-tiebroken).
    fn top_live_tag(&self) -> Option<(Tag, u64)> {
        let mut by_tag: FastMap<&'static str, (Tag, u64)> = FastMap::default();
        for e in self.live.values() {
            by_tag
                .entry(e.tag.name())
                .and_modify(|(_, b)| *b += e.requested)
                .or_insert((e.tag, e.requested));
        }
        by_tag
            .into_iter()
            .map(|(_, v)| v)
            .max_by_key(|(t, b)| (*b, std::cmp::Reverse(t.name())))
    }

    /// Aggregate the live census and introspect the allocator into a full
    /// peak snapshot.
    fn snapshot_composition(&self, alloc: &CachingAllocator) -> PeakSnapshot {
        let cfg = alloc.config();
        let mut by_tag: FastMap<&'static str, (Tag, CensusBytes)> = FastMap::default();
        let mut by_phase: FastMap<u16, (PhaseKind, CensusBytes)> = FastMap::default();
        let mut by_role: FastMap<&'static str, CensusBytes> = FastMap::default();
        let mut by_pool = [CensusBytes::default(); 2];
        for e in self.live.values() {
            let add = |b: &mut CensusBytes| {
                b.requested += e.requested;
                b.rounded += e.rounded;
                b.count += 1;
            };
            add(&mut by_tag.entry(e.tag.name()).or_insert((e.tag, CensusBytes::default())).1);
            add(&mut by_phase
                .entry(e.phase.tag())
                .or_insert((e.phase, CensusBytes::default()))
                .1);
            add(by_role.entry(phase_role(e.phase)).or_default());
            let pool = match cfg.pool_for(e.rounded) {
                PoolKind::Small => 0,
                PoolKind::Large => 1,
            };
            add(&mut by_pool[pool]);
        }
        // Deterministic orders: descending requested bytes, name-tiebroken.
        let mut by_tag: Vec<(Tag, CensusBytes)> = by_tag.into_iter().map(|(_, v)| v).collect();
        by_tag.sort_by_key(|(t, b)| (std::cmp::Reverse(b.requested), t.name()));
        let mut by_phase: Vec<(PhaseKind, CensusBytes)> =
            by_phase.into_iter().map(|(_, v)| v).collect();
        by_phase.sort_by_key(|(p, b)| (std::cmp::Reverse(b.requested), p.name()));
        let mut by_role: Vec<(&'static str, CensusBytes)> = by_role.into_iter().collect();
        by_role.sort_by_key(|(r, b)| (std::cmp::Reverse(b.requested), *r));

        let reserved = alloc.reserved();
        let allocated = alloc.allocated();
        let cached_free = alloc.cached_fully_free_bytes();
        let breakdown = PeakBreakdown {
            census_requested: self.live_requested,
            rounding_waste: self.live_rounded - self.live_requested,
            // allocated sums live *block* sizes; each live block is at
            // least its rounded request, so the slack is non-negative.
            block_slack: allocated.saturating_sub(self.live_rounded),
            // Free blocks inside partially-used segments: everything
            // reserved that is neither allocated nor releasable cache.
            free_gaps: reserved.saturating_sub(allocated + cached_free),
            cached_free,
        };
        PeakSnapshot {
            reserved,
            allocated,
            phase: self.current_phase,
            step: self.current_step,
            by_tag,
            by_phase,
            by_role,
            by_pool,
            segments: alloc.segment_map(),
            breakdown,
        }
    }
}

impl Default for PeakRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseSink for PeakRecorder {
    fn on_phase(&mut self, phase: PhaseKind, _alloc: &CachingAllocator, _compute_us: f64) {
        self.current_phase = phase;
    }

    fn on_op(&mut self, op: &TraceOp) {
        self.op_had_events = false;
        match op {
            TraceOp::Alloc { handle, tag, .. } => {
                self.pending_alloc = Some((handle.0, *tag));
            }
            TraceOp::Free { handle } => {
                if let Some(e) = self.live.remove(&handle.0) {
                    self.live_requested -= e.requested;
                    self.live_rounded -= e.rounded;
                }
            }
            _ => {}
        }
    }

    fn on_alloc_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        self.op_had_events = true;
        if let AllocEvent::Alloc {
            requested, rounded, ..
        } = event
        {
            if let Some((handle, tag)) = self.pending_alloc.take() {
                self.live.insert(
                    handle,
                    CensusEntry {
                        tag,
                        phase: self.current_phase,
                        requested: *requested,
                        rounded: *rounded,
                    },
                );
                self.live_requested += requested;
                self.live_rounded += rounded;
            }
        }
        if state.reserved > self.step_peak.reserved {
            self.step_peak.reserved = state.reserved;
            self.step_peak.phase = self.current_phase;
            self.step_peak.top_tag = self.top_live_tag();
        }
    }

    fn on_op_end(&mut self, alloc: &CachingAllocator) {
        if !self.op_had_events {
            return;
        }
        let peak = alloc.stats().peak_reserved;
        if peak > self.peak_seen {
            self.peak_seen = peak;
            self.peak = Some(self.snapshot_composition(alloc));
        }
    }

    fn on_step_end(&mut self, step: u64, _alloc: &CachingAllocator, _compute_us: f64) {
        let mut done = StepPeak {
            step: step + 1,
            reserved: 0,
            phase: self.current_phase,
            top_tag: None,
        };
        std::mem::swap(&mut done, &mut self.step_peak);
        done.step = step;
        self.top_peaks.push(done);
        // Keep the K largest, stable under ties by earliest step.
        self.top_peaks
            .sort_by_key(|p| (std::cmp::Reverse(p.reserved), p.step));
        self.top_peaks.truncate(self.top_k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::CachingAllocator;
    use crate::trace::{replay, TraceBuilder};
    use crate::util::bytes::{GIB, MIB};

    fn record(build: impl FnOnce(&mut TraceBuilder)) -> (PeakRecorder, CachingAllocator) {
        let mut b = TraceBuilder::new();
        build(&mut b);
        let trace = b.finish();
        let mut rec = PeakRecorder::new();
        let mut alloc = CachingAllocator::with_default_config(4 * GIB);
        replay(&trace, &mut alloc, &mut rec);
        (rec, alloc)
    }

    #[test]
    fn breakdown_sums_to_reserved_at_peak() {
        let (rec, alloc) = record(|b| {
            b.phase(PhaseKind::Generation);
            let h = b.alloc(15 * MIB, Tag::KvCache);
            b.transient([3 * MIB + 700], Tag::Activation);
            b.free(h);
            b.phase(PhaseKind::TrainActor);
            b.alloc(30 * MIB, Tag::Grad);
            b.step_end(1);
        });
        let peak = rec.peak().expect("reserved memory must have peaked");
        assert_eq!(peak.reserved, alloc.stats().peak_reserved);
        assert_eq!(peak.breakdown.total(), peak.reserved);
    }

    #[test]
    fn census_attributes_tags_and_phases() {
        let (rec, _alloc) = record(|b| {
            b.phase(PhaseKind::Generation);
            b.alloc(10 * MIB, Tag::KvCache);
            b.phase(PhaseKind::TrainActor);
            b.alloc(40 * MIB, Tag::Grad);
            b.step_end(1);
        });
        let peak = rec.peak().unwrap();
        assert_eq!(peak.by_tag[0].0, Tag::Grad);
        assert_eq!(peak.by_tag[0].1.requested, 40 * MIB);
        assert_eq!(peak.by_phase[0].0, PhaseKind::TrainActor);
        assert_eq!(peak.by_role[0].0, "actor");
        let census_total: u64 = peak.by_tag.iter().map(|(_, b)| b.requested).sum();
        assert_eq!(census_total, peak.breakdown.census_requested);
    }

    #[test]
    fn freed_blocks_leave_the_census() {
        let (rec, _alloc) = record(|b| {
            b.phase(PhaseKind::Generation);
            let h = b.alloc(10 * MIB, Tag::KvCache);
            b.free(h);
            b.step_end(1);
        });
        assert_eq!(rec.live_count(), 0);
        assert_eq!(rec.live_bytes(), (0, 0));
        // Peak was set while the block was live — census captured it.
        let peak = rec.peak().unwrap();
        assert_eq!(peak.breakdown.census_requested, 10 * MIB);
    }

    #[test]
    fn cached_free_recognized_after_frees() {
        let (rec, alloc) = record(|b| {
            b.phase(PhaseKind::Generation);
            let h1 = b.alloc(15 * MIB, Tag::KvCache);
            let h2 = b.alloc(15 * MIB, Tag::KvCache);
            b.free(h1);
            b.free(h2);
            b.phase(PhaseKind::TrainActor);
            // Frag-caused malloc: the two cached 16 MiB segments can't
            // serve 30 MiB — the peak snapshot must classify them.
            b.alloc(30 * MIB, Tag::Grad);
            b.step_end(1);
        });
        let peak = rec.peak().unwrap();
        assert_eq!(peak.breakdown.cached_free, 32 * MIB);
        assert_eq!(peak.breakdown.total(), peak.reserved);
        assert_eq!(alloc.cached_fully_free_bytes(), 32 * MIB);
        // Segment map agrees with the index.
        let from_map: u64 = peak
            .segments
            .iter()
            .filter(|s| s.fully_free())
            .map(|s| s.size)
            .sum();
        assert_eq!(from_map, 32 * MIB);
    }

    #[test]
    fn top_peaks_ranked_descending() {
        let (rec, _alloc) = record(|b| {
            for step in 1..=3 {
                b.phase(PhaseKind::Generation);
                b.transient([(step * 20) * MIB], Tag::KvCache);
                b.step_end(step);
            }
        });
        let tops = rec.top_peaks();
        assert_eq!(tops.len(), 3);
        assert!(tops[0].reserved >= tops[1].reserved);
        assert!(tops[1].reserved >= tops[2].reserved);
    }
}
