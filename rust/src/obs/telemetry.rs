//! Run-telemetry ledger: a lightweight counter/span registry for the
//! search subsystems (sweep, planner, cluster).
//!
//! The ledger enforces the same discipline as the bench subsystem
//! (DESIGN.md §13): **deterministic counters** — order-independent `u64`
//! sums derived from the index-ordered result cells — are the only values
//! that enter machine-readable artifacts (the `telemetry` JSONL footer),
//! while **wall-clock spans** live in a separate list that is printed by
//! `report::telemetry` but never serialized. That split is what keeps the
//! jobs-1 vs jobs-N byte-identical contract intact for every artifact
//! that carries a footer.

use crate::util::json::Json;

/// The ledger: insertion-ordered counters plus wall-clock spans.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    counters: Vec<(String, u64)>,
    wall: Vec<(String, f64)>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `delta` into the named deterministic counter (created at
    /// first touch; insertion order is the artifact order).
    pub fn add(&mut self, name: &str, delta: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v += delta,
            None => self.counters.push((name.to_string(), delta)),
        }
    }

    /// Record a wall-clock span. Wall values are for the printed table
    /// only — they never enter JSON artifacts.
    pub fn wall(&mut self, name: &str, seconds: f64) {
        self.wall.push((name.to_string(), seconds));
    }

    pub fn counters(&self) -> &[(String, u64)] {
        &self.counters
    }

    pub fn walls(&self) -> &[(String, f64)] {
        &self.wall
    }

    pub fn get(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }

    /// The deterministic counters as a JSON object (insertion order).
    pub fn counters_json(&self) -> Json {
        Json::Obj(
            self.counters
                .iter()
                .map(|(n, v)| (n.clone(), Json::from(*v)))
                .collect(),
        )
    }

    /// One compact JSONL footer line:
    /// `{"schema":"rlhf-mem-telemetry-v1","telemetry":{...}}`. Wall spans
    /// are deliberately absent — the footer must be byte-identical for
    /// any `--jobs`.
    pub fn footer_line(&self) -> String {
        Json::obj(vec![
            ("schema", Json::str(crate::util::schema::tag("telemetry"))),
            ("telemetry", self.counters_json()),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn counters_accumulate_in_insertion_order() {
        let mut t = Telemetry::new();
        t.add("cells", 3);
        t.add("oom_cells", 1);
        t.add("cells", 2);
        assert_eq!(t.get("cells"), Some(5));
        let names: Vec<&str> = t.counters().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["cells", "oom_cells"]);
    }

    #[test]
    fn footer_excludes_wall_clock() {
        let mut t = Telemetry::new();
        t.add("cells", 7);
        t.wall("sweep", 1.25);
        let line = t.footer_line();
        let j = parse(&line).unwrap();
        assert_eq!(j.req_str("schema").unwrap(), "rlhf-mem-telemetry-v1");
        let tele = j.get("telemetry").unwrap();
        assert_eq!(tele.req_u64("cells").unwrap(), 7);
        assert!(!line.contains("1.25"), "wall time leaked into the footer");
        assert!(!line.contains('\n'), "footer must be a single JSONL line");
    }
}
