//! The planner's input: a [`Budget`] describing the user's device and
//! tolerance — "this much HBM, at most this much extra time; what should I
//! configure?". Parsed from the JSON spec `rlhf-mem advise --budget FILE`
//! takes (see `examples/budget_rtx3090.json`):
//!
//! ```json
//! {
//!   "name": "rtx3090-table1",
//!   "capacity_gib": 24,
//!   "max_overhead_pct": 5.0,
//!   "framework": "deepspeed-chat",
//!   "policy_model": "opt-1.3b",
//!   "value_model": "opt-350m",
//!   "world": 4,
//!   "steps": 2,
//!   "seed": 24301,
//!   "gpu": "rtx3090",
//!   "strategies": ["none", "zero3"],
//!   "allocators": ["default", "expandable"],
//!   "algos": ["ppo", "grpo"],
//!   "sharings": ["separate", "lora", "hydra"],
//!   "worlds": [2, 4]
//! }
//! ```
//!
//! `strategies` / `allocators` optionally narrow the mitigation space (by
//! the short names [`crate::strategies::StrategyConfig::by_name`] accepts
//! and the labels of [`super::space::allocator_candidates`]); omitted, the
//! full space is searched. `algos` widens the search across RLHF
//! algorithms ([`crate::rlhf::program::Algo`] names; omitted, PPO only —
//! the paper's pipeline). `sharings` widens it across model-sharing
//! placements ([`crate::rlhf::program::Sharing`] names; omitted, separate
//! full replicas only). `worlds` lists the cluster sizes `advise
//! --cluster` searches placements over (each ≥ 2 GPUs; omitted, `{2,
//! world}`).

use crate::frameworks::FrameworkKind;
use crate::mem::ModelArch;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::RlhfModelSet;
use crate::serve::ServeSpec;
use crate::util::bytes::GIB;
use crate::util::json::{parse, Json};

/// A device + tolerance envelope the planner searches within.
#[derive(Debug, Clone)]
pub struct Budget {
    /// Display name (report headers, JSONL).
    pub name: String,
    /// Device HBM in bytes; a candidate is feasible only if it completes
    /// without OOM and its peak reserved fits.
    pub capacity: u64,
    /// Maximum tolerated mitigation time overhead, percent, relative to
    /// the same strategy with no mitigation (policy `never`, default
    /// allocator) — the paper's apples-to-apples "+2%" axis.
    pub max_overhead_pct: f64,
    pub framework: FrameworkKind,
    pub models: RlhfModelSet,
    pub world: u64,
    pub steps: u64,
    pub seed: u64,
    pub gpu: GpuSpec,
    /// Optional strategy short-names restricting the search.
    pub strategies: Option<Vec<String>>,
    /// Optional allocator-candidate labels restricting the search.
    pub allocators: Option<Vec<String>>,
    /// Optional RLHF algorithm names widening the search across the
    /// algorithm axis. Omitted, only PPO (the paper's pipeline) runs.
    pub algos: Option<Vec<String>>,
    /// Optional model-sharing placement names widening the search across
    /// the sharing axis. Omitted, only separate full replicas run.
    pub sharings: Option<Vec<String>>,
    /// Cluster sizes (GPU counts ≥ 2) `advise --cluster` searches.
    /// Omitted, the cluster planner tries `{2, world}`.
    pub worlds: Option<Vec<u64>>,
    /// Serving traffic + config grid for `advise --serve`. Omitted, the
    /// serve planner falls back to [`ServeSpec::default`].
    pub serve: Option<ServeSpec>,
}

impl Budget {
    /// The paper's Table-1 RTX-3090 testbed as a budget: 24 GiB,
    /// DeepSpeed-Chat, the OPT-1.3b/350m pair, ≤ 5% tolerated overhead —
    /// the sanity anchor `rlhf-mem advise` reproduces the §3.3 conclusion
    /// on.
    pub fn rtx3090_table1() -> Budget {
        Budget {
            name: "rtx3090-table1".to_string(),
            capacity: 24 * GIB,
            max_overhead_pct: 5.0,
            framework: FrameworkKind::DeepSpeedChat,
            models: RlhfModelSet::opt(),
            world: 4,
            steps: 2,
            seed: 0x5EED,
            gpu: GpuSpec::rtx3090(),
            strategies: None,
            allocators: None,
            algos: None,
            sharings: None,
            worlds: None,
            serve: None,
        }
    }

    pub fn from_file(path: &str) -> Result<Budget, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json_text(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn from_json_text(text: &str) -> Result<Budget, String> {
        Self::from_json(&parse(text)?)
    }

    pub fn from_json(j: &Json) -> Result<Budget, String> {
        // A typo'd field name must not silently fall back to defaults
        // (same fail-loud principle as the typed-field checks below).
        const KNOWN: [&str; 16] = [
            "name",
            "capacity_gib",
            "max_overhead_pct",
            "framework",
            "policy_model",
            "value_model",
            "world",
            "steps",
            "seed",
            "gpu",
            "strategies",
            "allocators",
            "algos",
            "sharings",
            "worlds",
            "serve",
        ];
        if let Json::Obj(kvs) = j {
            for (k, _) in kvs {
                if !KNOWN.contains(&k.as_str()) {
                    return Err(format!(
                        "unknown budget field '{k}' (known fields: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("budget must be a JSON object".to_string());
        }

        let fw_name = j
            .get("framework")
            .and_then(|v| v.as_str())
            .unwrap_or("deepspeed-chat");
        let framework = FrameworkKind::by_name(fw_name)
            .ok_or_else(|| format!("unknown framework '{fw_name}'"))?;

        let policy_name = j
            .get("policy_model")
            .and_then(|v| v.as_str())
            .unwrap_or("opt-1.3b");
        let value_name = j
            .get("value_model")
            .and_then(|v| v.as_str())
            .unwrap_or("opt-350m");
        let policy_arch = ModelArch::by_name(policy_name)
            .ok_or_else(|| format!("unknown model '{policy_name}'"))?;
        let value_arch = ModelArch::by_name(value_name)
            .ok_or_else(|| format!("unknown model '{value_name}'"))?;

        let gpu_name = j.get("gpu").and_then(|v| v.as_str()).unwrap_or("rtx3090");
        let gpu =
            GpuSpec::by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?;

        let max_overhead_pct = j
            .get("max_overhead_pct")
            .and_then(|v| v.as_f64())
            .unwrap_or(5.0);
        if max_overhead_pct.is_nan() || max_overhead_pct < 0.0 {
            return Err(format!("bad max_overhead_pct {max_overhead_pct}"));
        }

        // A present-but-mistyped field must error, never silently fall back
        // to the default — a budget planned for the wrong capacity would
        // recommend configurations that OOM on the real device.
        let opt_u64 = |key: &str| -> Result<Option<u64>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_u64()
                    .map(Some)
                    .ok_or_else(|| format!("'{key}' must be a non-negative integer")),
            }
        };

        let name_list = |key: &str| -> Result<Option<Vec<String>>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| format!("'{key}' must be an array of strings"))?;
                    let names = arr
                        .iter()
                        .map(|x| {
                            x.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| format!("'{key}' entries must be strings"))
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if names.is_empty() {
                        return Err(format!("'{key}' must not be empty"));
                    }
                    Ok(Some(names))
                }
            }
        };

        let worlds = match j.get("worlds") {
            None => None,
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| "'worlds' must be an array of integers >= 2".to_string())?;
                let ws = arr
                    .iter()
                    .map(|x| {
                        x.as_u64()
                            .filter(|&w| w >= 2)
                            .ok_or_else(|| "'worlds' entries must be integers >= 2".to_string())
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                if ws.is_empty() {
                    return Err("'worlds' must not be empty".to_string());
                }
                Some(ws)
            }
        };

        let serve = match j.get("serve") {
            None => None,
            Some(v) => Some(ServeSpec::from_json(v)?),
        };

        Ok(Budget {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("custom")
                .to_string(),
            capacity: opt_u64("capacity_gib")?.unwrap_or(24) * GIB,
            max_overhead_pct,
            framework,
            models: RlhfModelSet {
                policy_arch,
                value_arch,
            },
            world: opt_u64("world")?.unwrap_or(4),
            steps: opt_u64("steps")?.unwrap_or(2),
            seed: opt_u64("seed")?.unwrap_or(0x5EED),
            gpu,
            strategies: name_list("strategies")?,
            allocators: name_list("allocators")?,
            algos: name_list("algos")?,
            sharings: name_list("sharings")?,
            worlds,
            serve,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_budget_parses() {
        let b = Budget::from_json_text(
            r#"{
              "name": "my-box",
              "capacity_gib": 48,
              "max_overhead_pct": 3.5,
              "framework": "colossalchat",
              "policy_model": "gpt2-xl",
              "value_model": "gpt2-medium",
              "world": 8,
              "steps": 1,
              "seed": 7,
              "gpu": "a100",
              "strategies": ["none", "zero3"],
              "allocators": ["default", "expandable"]
            }"#,
        )
        .unwrap();
        assert_eq!(b.name, "my-box");
        assert_eq!(b.capacity, 48 * GIB);
        assert_eq!(b.max_overhead_pct, 3.5);
        assert_eq!(b.framework, FrameworkKind::ColossalChat);
        assert_eq!(b.models.policy_arch.name, "gpt2-xl");
        assert_eq!(b.world, 8);
        assert_eq!(b.seed, 7);
        assert_eq!(b.strategies.as_deref().unwrap().len(), 2);
        assert_eq!(b.allocators.as_deref().unwrap().len(), 2);
        assert!(b.algos.is_none(), "PPO-only unless widened");
        let b = Budget::from_json_text(r#"{"algos": ["ppo", "grpo"]}"#).unwrap();
        assert_eq!(b.algos.as_deref().unwrap().len(), 2);
        assert!(Budget::from_json_text(r#"{"algos": []}"#).is_err());
        assert!(b.sharings.is_none(), "separate-only unless widened");
        let b = Budget::from_json_text(r#"{"sharings": ["separate", "hydra"]}"#).unwrap();
        assert_eq!(b.sharings.as_deref().unwrap().len(), 2);
        assert!(Budget::from_json_text(r#"{"sharings": []}"#).is_err());
    }

    #[test]
    fn minimal_budget_matches_paper_testbed() {
        let b = Budget::from_json_text("{}").unwrap();
        let anchor = Budget::rtx3090_table1();
        assert_eq!(b.capacity, anchor.capacity);
        assert_eq!(b.framework, anchor.framework);
        assert_eq!(b.models.policy_arch.name, anchor.models.policy_arch.name);
        assert_eq!(b.steps, anchor.steps);
        assert_eq!(b.seed, anchor.seed);
        assert!(b.strategies.is_none());
    }

    #[test]
    fn serve_spec_parses_and_rejects_typos() {
        let b = Budget::from_json_text(r#"{"serve": {"requests": 8, "max_concurrency": [2, 4]}}"#)
            .unwrap();
        let s = b.serve.unwrap();
        assert_eq!(s.requests, 8);
        assert_eq!(s.max_concurrency, vec![2, 4]);
        assert!(Budget::from_json_text("{}").unwrap().serve.is_none());
        assert!(Budget::from_json_text(r#"{"serve": {"reqs": 8}}"#).is_err());
        assert!(Budget::from_json_text(r#"{"serve": 3}"#).is_err());
    }

    #[test]
    fn rejects_bad_budgets() {
        assert!(Budget::from_json_text(r#"{"framework": "x"}"#).is_err());
        assert!(Budget::from_json_text(r#"{"policy_model": "x"}"#).is_err());
        assert!(Budget::from_json_text(r#"{"gpu": "x"}"#).is_err());
        assert!(Budget::from_json_text(r#"{"max_overhead_pct": -1}"#).is_err());
        assert!(Budget::from_json_text(r#"{"strategies": []}"#).is_err());
        assert!(Budget::from_json_text(r#"{"strategies": [1]}"#).is_err());
        assert!(Budget::from_json_text("nope").is_err());
        // Mistyped numeric fields error instead of silently defaulting —
        // planning for the wrong capacity would be worse than failing.
        assert!(Budget::from_json_text(r#"{"capacity_gib": 10.5}"#).is_err());
        assert!(Budget::from_json_text(r#"{"capacity_gib": "24"}"#).is_err());
        assert!(Budget::from_json_text(r#"{"steps": true}"#).is_err());
        // ...and so do typo'd field names and non-object documents.
        assert!(Budget::from_json_text(r#"{"capacity": 48}"#).is_err());
        assert!(Budget::from_json_text(r#"{"capacity_gb": 48}"#).is_err());
        assert!(Budget::from_json_text("[1, 2]").is_err());
        // Cluster worlds: >= 2 GPUs each, non-empty when present.
        assert!(Budget::from_json_text(r#"{"worlds": []}"#).is_err());
        assert!(Budget::from_json_text(r#"{"worlds": [1]}"#).is_err());
        assert!(Budget::from_json_text(r#"{"worlds": ["2"]}"#).is_err());
        let b = Budget::from_json_text(r#"{"worlds": [2, 4]}"#).unwrap();
        assert_eq!(b.worlds.as_deref(), Some(&[2u64, 4][..]));
    }
}
