//! Dominance pruning: the memory-vs-time Pareto frontier over evaluated
//! candidates. A configuration is *dominated* when another feasible one
//! uses no more memory **and** no more time, strictly less of at least
//! one — there is never a reason to pick it.

/// One evaluated point: `(peak_reserved_bytes, total_time_us, feasible)`.
pub type Point = (u64, f64, bool);

/// Mark the Pareto-optimal points: `true` at index `i` iff point `i` is
/// feasible and no other feasible point dominates it. Infeasible points
/// are never on the frontier and never dominate. O(n²), fine for the
/// few-hundred-candidate spaces the planner searches.
pub fn pareto_frontier(points: &[Point]) -> Vec<bool> {
    let mut on = vec![false; points.len()];
    for (i, &(r_i, t_i, ok_i)) in points.iter().enumerate() {
        if !ok_i {
            continue;
        }
        let dominated = points.iter().enumerate().any(|(j, &(r_j, t_j, ok_j))| {
            j != i && ok_j && r_j <= r_i && t_j <= t_i && (r_j < r_i || t_j < t_i)
        });
        on[i] = !dominated;
    }
    on
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_dominance_prunes() {
        // (memory, time): b dominates c (less of both); a and b trade off.
        let pts = [(10, 1.0, true), (5, 2.0, true), (8, 3.0, true)];
        assert_eq!(pareto_frontier(&pts), [true, true, false]);
    }

    #[test]
    fn ties_on_one_axis() {
        // Same memory, faster wins; the slower twin is dominated.
        let pts = [(10, 1.0, true), (10, 2.0, true)];
        assert_eq!(pareto_frontier(&pts), [true, false]);
        // Exact duplicates: neither strictly better — both survive.
        let dup = [(10, 1.0, true), (10, 1.0, true)];
        assert_eq!(pareto_frontier(&dup), [true, true]);
    }

    #[test]
    fn infeasible_points_neither_appear_nor_dominate() {
        let pts = [(1, 0.5, false), (10, 1.0, true)];
        assert_eq!(pareto_frontier(&pts), [false, true]);
        assert!(pareto_frontier(&[]).is_empty());
    }
}
