//! The memory planner — a mitigation-space search engine answering the
//! user-facing question the paper's tables only sample: *"what is the
//! cheapest configuration that fits my GPU, and what does it cost me in
//! time?"*
//!
//! Given a [`Budget`] (device capacity, tolerated time overhead, workload),
//! the planner enumerates the mitigation space — strategy presets
//! (ZeRO-1/2/3, offload, checkpointing, each carrying the paper's global
//! LoRA default) × model-sharing placements
//! ([`crate::rlhf::program::Sharing`]: separate replicas, shared LoRA
//! backbones, hydra heads) × [`EmptyCachePolicy`] placements × allocator
//! knobs (`max_split_size`, `expandable_segments`,
//! `garbage_collection_threshold`) — runs every candidate through the
//! [`crate::sweep::SweepRunner`] worker pool, prunes dominated
//! configurations, and emits a ranked recommendation with a
//! memory-vs-time Pareto frontier.
//!
//! Determinism contract: same budget + seed ⇒ byte-identical
//! [`PlanReport::jsonl`] for any worker count (the same invariant
//! `rust/tests/sweep_determinism.rs` enforces for grids;
//! `rust/tests/planner_determinism.rs` enforces it here).
//!
//! The same engine also answers the *multi-GPU* question (`advise
//! --cluster`): [`plan_cluster`] searches placement plan × strategy ×
//! world-size through [`crate::coordinator`], ranks feasible
//! configurations by their most loaded GPU, and prunes to the
//! max-per-GPU-memory vs step-time Pareto frontier
//! (`rust/tests/cluster_determinism.rs` pins its `--jobs` invariance).
//!
//! # Example: advise a narrowed space
//!
//! ```
//! use rlhf_mem::planner::{plan, Budget};
//!
//! let mut budget = Budget::rtx3090_table1();
//! budget.steps = 1;
//! budget.strategies = Some(vec!["none".into()]);
//! budget.allocators = Some(vec!["default".into(), "expandable".into()]);
//! let report = plan(&budget, 2).unwrap();
//! assert_eq!(report.outcomes.len(), 8); // 1 strategy × 4 policies × 2 allocs
//! let best = report.best().expect("something fits 24 GiB");
//! assert!(best.feasible);
//! // The un-mitigated baseline is its own reference: zero overhead.
//! assert_eq!(report.outcomes[0].overhead_pct, Some(0.0));
//! ```

pub mod budget;
pub mod frontier;
pub mod space;

pub use budget::Budget;
pub use space::{allocator_candidates, Candidate, ClusterCandidate};

use crate::coordinator::schedule::{run_configs, ClusterConfig};
use crate::coordinator::ClusterRun;
use crate::obs::Telemetry;
use crate::policy::EmptyCachePolicy;
use crate::profiler::ProfileSummary;
use crate::report::table::TextTable;
use crate::sweep::{SweepReport, SweepRunner};
use crate::util::bytes::fmt_gib_paper;
use crate::util::json::Json;
use crate::util::schema;

/// One candidate's verdict.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub candidate: Candidate,
    pub summary: ProfileSummary,
    /// Completed without OOM and peak reserved fits the budget.
    pub feasible: bool,
    /// Mitigation time overhead, percent, vs the same strategy
    /// un-mitigated (policy `never`, default allocator) — the paper's
    /// "+2%" axis. `None` when that baseline is absent from the space or
    /// itself OOMed (overhead is then unmeasurable).
    pub overhead_pct: Option<f64>,
    /// On the memory-vs-time Pareto frontier of feasible candidates.
    pub on_frontier: bool,
    /// 1-based position among recommended configurations (feasible and
    /// within the budget's overhead tolerance), cheapest-memory first.
    pub rank: Option<usize>,
}

/// The planner's output: every candidate's verdict plus the ranking.
#[derive(Debug)]
pub struct PlanReport {
    pub budget: Budget,
    /// One outcome per candidate, in enumeration order.
    pub outcomes: Vec<PlanOutcome>,
    /// Wall-clock of the underlying sweep, seconds (not part of any
    /// deterministic output).
    pub wall_seconds: f64,
    pub jobs: usize,
    /// Candidates rejected by the static prescreen before simulation
    /// (`None` when the prescreen was off).
    pub static_pruned: Option<u64>,
}

/// Knobs for [`plan_with`]. [`Default`] reproduces [`plan`] exactly.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanOptions {
    /// Reject candidates whose static peak lower bound
    /// ([`crate::lint::bounds::static_lower_max`]) already exceeds the
    /// budget's capacity, *before* simulating them. Sound: the bound is
    /// below the ideal live peak, which is below the reserved peak, so a
    /// pruned candidate could never have been feasible — and because the
    /// bound depends only on the (strategy, algo, sharing) group, whole
    /// groups drop together, taking their overhead baselines with them.
    /// The surviving outcomes (frontier, ranks, overheads) are
    /// byte-identical to the unscreened search — pinned by
    /// `rust/tests/lint_soundness.rs`.
    pub prescreen_static: bool,
}

/// Search the mitigation space for `budget` on `jobs` workers.
pub fn plan(budget: &Budget, jobs: usize) -> Result<PlanReport, String> {
    plan_with(budget, jobs, PlanOptions::default())
}

/// [`plan`] with explicit [`PlanOptions`] — the two-tier entry point:
/// static lint bounds first (optional), full simulation second.
pub fn plan_with(budget: &Budget, jobs: usize, opts: PlanOptions) -> Result<PlanReport, String> {
    let mut candidates = space::enumerate(budget)?;
    let mut pruned = None;
    if opts.prescreen_static {
        let before = candidates.len();
        candidates.retain(|c| {
            let scn = space::candidate_scenario(budget, c);
            crate::lint::bounds::static_lower_max(&scn) <= budget.capacity
        });
        if candidates.is_empty() {
            return Err(format!(
                "static prescreen rejected all {before} candidates: every phase \
                 needs more than the {} GiB budget",
                fmt_gib_paper(budget.capacity)
            ));
        }
        pruned = Some((before - candidates.len()) as u64);
    }
    let cells = space::to_cells(budget, &candidates);
    let sweep = SweepRunner::new(jobs).run(cells);
    let mut report = analyze(budget.clone(), candidates, sweep);
    report.static_pruned = pruned;
    Ok(report)
}

/// Pure, serial post-processing of the sweep results — everything that
/// makes the report deterministic regardless of worker scheduling.
fn analyze(budget: Budget, candidates: Vec<Candidate>, sweep: SweepReport) -> PlanReport {
    debug_assert_eq!(candidates.len(), sweep.cells.len());
    let summaries: Vec<ProfileSummary> = sweep.cells.iter().map(|c| c.summary.clone()).collect();
    let feasible: Vec<bool> = summaries
        .iter()
        .map(|s| !s.oom && s.peak_reserved <= budget.capacity)
        .collect();

    // Per-(algorithm, strategy, sharing) un-mitigated baseline time
    // (policy `never`, default allocator, run to completion) — overheads
    // compare within one workload, never across algorithms or across
    // model-sharing placements (a hydra step is a different workload than
    // a full-replica step, not a mitigated version of it).
    let baseline_time = |of: &Candidate| -> Option<f64> {
        candidates
            .iter()
            .position(|c| {
                c.strategy_label == of.strategy_label
                    && c.algo == of.algo
                    && c.sharing == of.sharing
                    && c.policy == EmptyCachePolicy::Never
                    && c.alloc_label == "default"
            })
            .filter(|&i| !summaries[i].oom)
            .map(|i| summaries[i].total_time_us)
    };
    let overhead: Vec<Option<f64>> = candidates
        .iter()
        .zip(&summaries)
        .map(|(c, s)| {
            baseline_time(c).map(|base| (s.total_time_us - base) / base * 100.0)
        })
        .collect();

    let points: Vec<frontier::Point> = summaries
        .iter()
        .zip(&feasible)
        .map(|(s, &ok)| (s.peak_reserved, s.total_time_us, ok))
        .collect();
    let on_frontier = frontier::pareto_frontier(&points);

    // Recommendation order: feasible, within the overhead tolerance,
    // cheapest peak reserved first (time, then index break ties).
    let mut recommended: Vec<usize> = (0..candidates.len())
        .filter(|&i| {
            feasible[i]
                && match overhead[i] {
                    Some(o) => o <= budget.max_overhead_pct,
                    None => true, // unmeasurable overhead can't exceed a cap
                }
        })
        .collect();
    recommended.sort_by(|&a, &b| {
        summaries[a]
            .peak_reserved
            .cmp(&summaries[b].peak_reserved)
            .then(summaries[a].total_time_us.total_cmp(&summaries[b].total_time_us))
            .then(a.cmp(&b))
    });
    let mut rank: Vec<Option<usize>> = vec![None; candidates.len()];
    for (pos, &i) in recommended.iter().enumerate() {
        rank[i] = Some(pos + 1);
    }

    let outcomes = candidates
        .into_iter()
        .enumerate()
        .map(|(i, candidate)| PlanOutcome {
            candidate,
            summary: summaries[i].clone(),
            feasible: feasible[i],
            overhead_pct: overhead[i],
            on_frontier: on_frontier[i],
            rank: rank[i],
        })
        .collect();
    PlanReport {
        budget,
        outcomes,
        wall_seconds: sweep.wall_seconds,
        jobs: sweep.jobs,
        static_pruned: None,
    }
}

impl PlanReport {
    /// Recommended outcomes (feasible, within tolerance), best first.
    pub fn recommended(&self) -> Vec<&PlanOutcome> {
        let mut v: Vec<&PlanOutcome> = self.outcomes.iter().filter(|o| o.rank.is_some()).collect();
        v.sort_by_key(|o| o.rank);
        v
    }

    /// The single best configuration, if anything fits.
    pub fn best(&self) -> Option<&PlanOutcome> {
        self.outcomes.iter().find(|o| o.rank == Some(1))
    }

    /// The memory-vs-time Pareto frontier, cheapest memory first.
    pub fn frontier(&self) -> Vec<&PlanOutcome> {
        let mut v: Vec<&PlanOutcome> = self.outcomes.iter().filter(|o| o.on_frontier).collect();
        v.sort_by(|a, b| {
            a.summary
                .peak_reserved
                .cmp(&b.summary.peak_reserved)
                .then(a.summary.total_time_us.total_cmp(&b.summary.total_time_us))
                .then(a.candidate.index.cmp(&b.candidate.index))
        });
        v
    }

    /// The paper's §3.3 sanity anchor: the smallest measured overhead of a
    /// phase-boundary `empty_cache` placement **with the stock allocator**
    /// on the frontier (`None` if no such configuration survived pruning).
    /// Restricted to `alloc_label == "default"` so the number measures
    /// what the paper measured — `empty_cache` alone, not conflated with
    /// expandable/gc allocator savings. For the Table-1 RTX-3090 budget
    /// this should be ≈ 2%.
    pub fn empty_cache_frontier_overhead(&self) -> Option<f64> {
        self.min_frontier_empty_cache_overhead(true)
    }

    /// Like [`Self::empty_cache_frontier_overhead`], but over every
    /// allocator candidate — what the full search space actually puts on
    /// the frontier (an `empty_cache` placement combined with allocator
    /// knobs can even come out faster than the stock baseline).
    pub fn any_empty_cache_frontier_overhead(&self) -> Option<f64> {
        self.min_frontier_empty_cache_overhead(false)
    }

    fn min_frontier_empty_cache_overhead(&self, default_alloc_only: bool) -> Option<f64> {
        self.outcomes
            .iter()
            .filter(|o| {
                o.on_frontier
                    && o.candidate.policy != EmptyCachePolicy::Never
                    && (!default_alloc_only || o.candidate.alloc_label == "default")
            })
            .filter_map(|o| o.overhead_pct)
            .min_by(f64::total_cmp)
    }

    /// Deterministic JSON-lines dump: the versioned schema header, then
    /// one line per candidate, enumeration order. Byte-identical for the
    /// same budget whatever `jobs` was.
    pub fn jsonl(&self) -> String {
        let mut out = schema::header_line("planner");
        out.push('\n');
        for o in &self.outcomes {
            out.push_str(&o.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// The run-telemetry ledger of this search: counters summed over the
    /// enumeration-ordered outcomes (deterministic, `jobs`-independent);
    /// the underlying sweep's wall-clock in the never-serialized wall
    /// list.
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::new();
        t.add("candidates", self.outcomes.len() as u64);
        t.add(
            "feasible",
            self.outcomes.iter().filter(|o| o.feasible).count() as u64,
        );
        t.add(
            "frontier",
            self.outcomes.iter().filter(|o| o.on_frontier).count() as u64,
        );
        t.add(
            "oom_cells",
            self.outcomes.iter().filter(|o| o.summary.oom).count() as u64,
        );
        if let Some(p) = self.static_pruned {
            t.add("static_pruned", p);
        }
        for o in &self.outcomes {
            t.add("num_allocs", o.summary.num_allocs);
            t.add("cache_hits", o.summary.num_cache_hits);
        }
        t.wall("plan", self.wall_seconds);
        t
    }

    /// Deterministic JSON-lines dump of the *frontier outcomes only*, in
    /// enumeration order — the search-mode-invariant artifact: because a
    /// statically pruned candidate can never be feasible (and infeasible
    /// points never reach the frontier), this is byte-identical between
    /// `--prescreen-static` and unscreened runs of the same budget, and
    /// the surrogate-screened search
    /// ([`crate::surrogate::SurrogatePlanReport::frontier_jsonl`])
    /// reproduces it byte-for-byte as its identity contract. Lines are
    /// [`frontier_line_json`] (no rank — see there).
    pub fn frontier_jsonl(&self) -> String {
        let mut out = schema::header_line("planner");
        out.push('\n');
        for o in self.outcomes.iter().filter(|o| o.on_frontier) {
            out.push_str(
                &frontier_line_json(&o.candidate, &o.summary, o.overhead_pct, o.feasible, true)
                    .to_string(),
            );
            out.push('\n');
        }
        out
    }

    /// [`Self::jsonl`] plus one trailing `{"telemetry":{...}}` footer
    /// line. Still byte-identical for any `--jobs`.
    pub fn jsonl_with_telemetry(&self) -> String {
        let mut out = self.jsonl();
        out.push_str(&self.telemetry().footer_line());
        out.push('\n');
        out
    }

    /// One `--json` document: budget echo + outcomes + the winner.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("budget", Json::str(self.budget.name.clone())),
            ("capacity", Json::from(self.budget.capacity)),
            ("max_overhead_pct", Json::from(self.budget.max_overhead_pct)),
            (
                "recommendation",
                match self.best() {
                    Some(o) => Json::str(o.candidate.key()),
                    None => Json::Null,
                },
            ),
            (
                "outcomes",
                Json::Arr(self.outcomes.iter().map(|o| o.to_json()).collect()),
            ),
        ])
    }

    /// Ranked table of the top `top` recommendations.
    pub fn to_table(&self, top: usize) -> TextTable {
        let mut t = TextTable::new(&[
            "Rank", "Algo", "Sharing", "Strategy", "Policy", "Allocator", "Reserved", "Frag.",
            "Overhead", "Frontier",
        ]);
        for o in self.recommended().into_iter().take(top) {
            t.row(outcome_row(o, o.rank.map(|r| r.to_string()).unwrap_or_default()));
        }
        t
    }

    /// The whole frontier as a table (rank column shows the position in
    /// the ranking when the point is also recommended).
    pub fn frontier_table(&self) -> TextTable {
        let mut t = TextTable::new(&[
            "Rank", "Algo", "Sharing", "Strategy", "Policy", "Allocator", "Reserved", "Frag.",
            "Overhead", "Frontier",
        ]);
        for o in self.frontier() {
            let rank = o.rank.map(|r| r.to_string()).unwrap_or_else(|| "-".into());
            t.row(outcome_row(o, rank));
        }
        t
    }

    /// One-line run summary for CLI output.
    pub fn summary_line(&self) -> String {
        let feasible = self.outcomes.iter().filter(|o| o.feasible).count();
        format!(
            "{} candidates ({} feasible, {} on frontier) in {:.2}s on {} worker{}",
            self.outcomes.len(),
            feasible,
            self.outcomes.iter().filter(|o| o.on_frontier).count(),
            self.wall_seconds,
            self.jobs,
            if self.jobs == 1 { "" } else { "s" },
        )
    }
}

/// One frontier JSONL line: [`PlanOutcome::to_json`] minus `rank`. Rank
/// is a *global* ordering over every feasible candidate, which a search
/// that never simulates dominated candidates cannot know — so the shared
/// frontier artifact carries only per-candidate facts both search modes
/// compute identically. Exhaustive ([`PlanReport::frontier_jsonl`]) and
/// surrogate-screened searches both emit exactly this function's output.
pub fn frontier_line_json(
    c: &Candidate,
    s: &ProfileSummary,
    overhead_pct: Option<f64>,
    feasible: bool,
    on_frontier: bool,
) -> Json {
    Json::obj(vec![
        ("index", Json::from(c.index)),
        ("key", Json::str(c.key())),
        ("algo", Json::str(c.algo.name())),
        ("sharing", Json::str(c.sharing.name())),
        ("strategy", Json::str(c.strategy_label.clone())),
        ("policy", Json::str(c.policy.name())),
        ("alloc", Json::str(c.alloc_label.clone())),
        ("reserved", Json::from(s.peak_reserved)),
        ("frag", Json::from(s.frag)),
        ("allocated", Json::from(s.peak_allocated)),
        ("time_us", Json::from(s.total_time_us)),
        (
            "overhead_pct",
            match overhead_pct {
                Some(p) => Json::from(p),
                None => Json::Null,
            },
        ),
        ("feasible", Json::from(feasible)),
        ("frontier", Json::from(on_frontier)),
        ("oom", Json::from(s.oom)),
    ])
}

fn outcome_row(o: &PlanOutcome, rank: String) -> Vec<String> {
    vec![
        rank,
        o.candidate.algo.name().to_string(),
        o.candidate.sharing.name().to_string(),
        o.candidate.strategy_label.clone(),
        o.candidate.policy.name().to_string(),
        o.candidate.alloc_label.clone(),
        fmt_gib_paper(o.summary.peak_reserved),
        fmt_gib_paper(o.summary.frag),
        match o.overhead_pct {
            Some(p) => format!("{p:+.1}%"),
            None => "n/a".to_string(),
        },
        if o.on_frontier { "*" } else { "" }.to_string(),
    ]
}

impl PlanOutcome {
    /// The outcome's JSON object — a pure function of deterministic
    /// per-candidate data (never wall-clock or worker count).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::from(self.candidate.index)),
            ("key", Json::str(self.candidate.key())),
            ("algo", Json::str(self.candidate.algo.name())),
            ("sharing", Json::str(self.candidate.sharing.name())),
            ("strategy", Json::str(self.candidate.strategy_label.clone())),
            ("policy", Json::str(self.candidate.policy.name())),
            ("alloc", Json::str(self.candidate.alloc_label.clone())),
            ("reserved", Json::from(self.summary.peak_reserved)),
            ("frag", Json::from(self.summary.frag)),
            ("allocated", Json::from(self.summary.peak_allocated)),
            ("time_us", Json::from(self.summary.total_time_us)),
            (
                "overhead_pct",
                match self.overhead_pct {
                    Some(p) => Json::from(p),
                    None => Json::Null,
                },
            ),
            ("feasible", Json::from(self.feasible)),
            ("frontier", Json::from(self.on_frontier)),
            (
                "rank",
                match self.rank {
                    Some(r) => Json::from(r),
                    None => Json::Null,
                },
            ),
            ("oom", Json::from(self.summary.oom)),
        ])
    }
}

/// One cluster-placement candidate's verdict.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub candidate: ClusterCandidate,
    pub run: ClusterRun,
    /// Every GPU completed and the most loaded one fits the budget.
    pub feasible: bool,
    /// On the max-per-GPU-memory vs step-time Pareto frontier.
    pub on_frontier: bool,
    /// 1-based position among feasible configurations, cheapest most
    /// loaded GPU first (step time, then index break ties).
    pub rank: Option<usize>,
}

impl ClusterOutcome {
    /// Deterministic per-candidate JSON (enumeration-order identity; no
    /// wall-clock, no worker count).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::from(self.candidate.index)),
            ("key", Json::str(self.candidate.key())),
            ("world", Json::from(self.candidate.world)),
            ("plan", Json::str(self.candidate.plan.name.clone())),
            ("strategy", Json::str(self.candidate.strategy_label.clone())),
            ("algo", Json::str(self.candidate.algo.name())),
            ("sharing", Json::str(self.candidate.sharing.name())),
            (
                "per_gpu_reserved",
                Json::Arr(
                    self.run
                        .gpus
                        .iter()
                        .map(|g| Json::from(g.peak_reserved))
                        .collect(),
                ),
            ),
            ("max_reserved", Json::from(self.run.max_peak_reserved())),
            ("total_reserved", Json::from(self.run.total_peak_reserved())),
            ("step_time_us", Json::from(self.run.step_time_us)),
            ("p2p_us", Json::from(self.run.p2p_us)),
            ("collective_us", Json::from(self.run.collective_us)),
            ("feasible", Json::from(self.feasible)),
            ("frontier", Json::from(self.on_frontier)),
            (
                "rank",
                match self.rank {
                    Some(r) => Json::from(r),
                    None => Json::Null,
                },
            ),
            ("oom", Json::from(self.run.oom())),
        ])
    }
}

/// Output of the cluster placement search (`advise --cluster`).
#[derive(Debug)]
pub struct ClusterReport {
    pub budget: Budget,
    /// One outcome per candidate, in enumeration order.
    pub outcomes: Vec<ClusterOutcome>,
    pub wall_seconds: f64,
    pub jobs: usize,
}

/// Search placement × strategy × world-size for `budget` on `jobs`
/// workers: every GPU of every candidate runs as its own sweep cell
/// through the worker pool; aggregation and ranking are serial, so the
/// report is byte-identical for any `jobs`.
pub fn plan_cluster(budget: &Budget, jobs: usize) -> Result<ClusterReport, String> {
    let candidates = space::enumerate_cluster(budget)?;
    let configs: Vec<ClusterConfig> = candidates
        .iter()
        .map(|c| ClusterConfig {
            key: c.key(),
            strategy_label: c.strategy_label.clone(),
            plan: c.plan.clone(),
            base: space::cluster_base_scenario(budget, c),
        })
        .collect();
    let batch = run_configs(&configs, budget.capacity, jobs)?;
    Ok(analyze_cluster(
        budget.clone(),
        candidates,
        batch.runs,
        batch.wall_seconds,
        batch.jobs,
    ))
}

/// Pure, serial post-processing of the cluster runs.
fn analyze_cluster(
    budget: Budget,
    candidates: Vec<ClusterCandidate>,
    runs: Vec<ClusterRun>,
    wall_seconds: f64,
    jobs: usize,
) -> ClusterReport {
    debug_assert_eq!(candidates.len(), runs.len());
    let feasible: Vec<bool> = runs.iter().map(|r| r.fits(budget.capacity)).collect();
    let points: Vec<frontier::Point> = runs
        .iter()
        .zip(&feasible)
        .map(|(r, &ok)| (r.max_peak_reserved(), r.step_time_us, ok))
        .collect();
    let on_frontier = frontier::pareto_frontier(&points);

    let mut recommended: Vec<usize> = (0..candidates.len()).filter(|&i| feasible[i]).collect();
    recommended.sort_by(|&a, &b| {
        runs[a]
            .max_peak_reserved()
            .cmp(&runs[b].max_peak_reserved())
            .then(runs[a].step_time_us.total_cmp(&runs[b].step_time_us))
            .then(a.cmp(&b))
    });
    let mut rank: Vec<Option<usize>> = vec![None; candidates.len()];
    for (pos, &i) in recommended.iter().enumerate() {
        rank[i] = Some(pos + 1);
    }

    let outcomes = candidates
        .into_iter()
        .zip(runs)
        .enumerate()
        .map(|(i, (candidate, run))| ClusterOutcome {
            candidate,
            run,
            feasible: feasible[i],
            on_frontier: on_frontier[i],
            rank: rank[i],
        })
        .collect();
    ClusterReport {
        budget,
        outcomes,
        wall_seconds,
        jobs,
    }
}

impl ClusterReport {
    /// Feasible outcomes, best (lightest most-loaded GPU) first.
    pub fn recommended(&self) -> Vec<&ClusterOutcome> {
        let mut v: Vec<&ClusterOutcome> =
            self.outcomes.iter().filter(|o| o.rank.is_some()).collect();
        v.sort_by_key(|o| o.rank);
        v
    }

    /// The single best placement, if anything fits.
    pub fn best(&self) -> Option<&ClusterOutcome> {
        self.outcomes.iter().find(|o| o.rank == Some(1))
    }

    /// The memory-vs-time Pareto frontier, cheapest memory first.
    pub fn frontier(&self) -> Vec<&ClusterOutcome> {
        let mut v: Vec<&ClusterOutcome> =
            self.outcomes.iter().filter(|o| o.on_frontier).collect();
        v.sort_by(|a, b| {
            a.run
                .max_peak_reserved()
                .cmp(&b.run.max_peak_reserved())
                .then(a.run.step_time_us.total_cmp(&b.run.step_time_us))
                .then(a.candidate.index.cmp(&b.candidate.index))
        });
        v
    }

    /// Deterministic JSON-lines dump: the versioned schema header, then
    /// one line per candidate, enumeration order. Byte-identical for the
    /// same budget whatever `jobs` was.
    pub fn jsonl(&self) -> String {
        let mut out = schema::header_line("cluster");
        out.push('\n');
        for o in &self.outcomes {
            out.push_str(&o.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// The run-telemetry ledger of this placement search (same discipline
    /// as [`PlanReport::telemetry`]): enumeration-ordered counters only,
    /// wall-clock kept out of artifacts.
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::new();
        t.add("candidates", self.outcomes.len() as u64);
        t.add(
            "feasible",
            self.outcomes.iter().filter(|o| o.feasible).count() as u64,
        );
        t.add(
            "frontier",
            self.outcomes.iter().filter(|o| o.on_frontier).count() as u64,
        );
        t.add(
            "gpu_runs",
            self.outcomes.iter().map(|o| o.run.gpus.len() as u64).sum(),
        );
        t.add(
            "oom_gpus",
            self.outcomes
                .iter()
                .flat_map(|o| &o.run.gpus)
                .filter(|g| g.oom)
                .count() as u64,
        );
        t.wall("plan_cluster", self.wall_seconds);
        t
    }

    /// [`Self::jsonl`] plus one trailing `{"telemetry":{...}}` footer
    /// line. Still byte-identical for any `--jobs`.
    pub fn jsonl_with_telemetry(&self) -> String {
        let mut out = self.jsonl();
        out.push_str(&self.telemetry().footer_line());
        out.push('\n');
        out
    }

    /// One `--json` document: budget echo + outcomes + the winner.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("budget", Json::str(self.budget.name.clone())),
            ("capacity", Json::from(self.budget.capacity)),
            (
                "recommendation",
                match self.best() {
                    Some(o) => Json::str(o.candidate.key()),
                    None => Json::Null,
                },
            ),
            (
                "outcomes",
                Json::Arr(self.outcomes.iter().map(|o| o.to_json()).collect()),
            ),
        ])
    }

    /// Ranked table of the top `top` placements.
    pub fn to_table(&self, top: usize) -> TextTable {
        let mut t = cluster_table_header();
        for o in self.recommended().into_iter().take(top) {
            t.row(cluster_row(o, o.rank.map(|r| r.to_string()).unwrap_or_default()));
        }
        t
    }

    /// The whole frontier as a table.
    pub fn frontier_table(&self) -> TextTable {
        let mut t = cluster_table_header();
        for o in self.frontier() {
            let rank = o.rank.map(|r| r.to_string()).unwrap_or_else(|| "-".into());
            t.row(cluster_row(o, rank));
        }
        t
    }

    /// One-line run summary for CLI output.
    pub fn summary_line(&self) -> String {
        let feasible = self.outcomes.iter().filter(|o| o.feasible).count();
        format!(
            "{} placements ({} feasible, {} on frontier) in {:.2}s on {} worker{}",
            self.outcomes.len(),
            feasible,
            self.outcomes.iter().filter(|o| o.on_frontier).count(),
            self.wall_seconds,
            self.jobs,
            if self.jobs == 1 { "" } else { "s" },
        )
    }
}

fn cluster_table_header() -> TextTable {
    TextTable::new(&[
        "Rank", "GPUs", "Placement", "Strategy", "Algo", "Sharing", "Max GPU", "Total",
        "Step ms", "Frontier",
    ])
}

fn cluster_row(o: &ClusterOutcome, rank: String) -> Vec<String> {
    vec![
        rank,
        o.candidate.world.to_string(),
        o.candidate.plan.name.clone(),
        o.candidate.strategy_label.clone(),
        o.candidate.algo.name().to_string(),
        o.candidate.sharing.name().to_string(),
        fmt_gib_paper(o.run.max_peak_reserved()),
        fmt_gib_paper(o.run.total_peak_reserved()),
        format!("{:.1}", o.run.step_time_us / 1000.0),
        if o.on_frontier { "*" } else { "" }.to_string(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_budget() -> Budget {
        let mut b = Budget::rtx3090_table1();
        b.steps = 1;
        b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
        b.allocators = Some(vec!["default".to_string(), "expandable".to_string()]);
        b
    }

    #[test]
    fn plan_produces_one_outcome_per_candidate() {
        let budget = tiny_budget();
        let report = plan(&budget, 2).unwrap();
        assert_eq!(report.outcomes.len(), 2 * 4 * 2);
        // Schema header + one line per outcome.
        assert_eq!(report.jsonl().lines().count(), report.outcomes.len() + 1);
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.candidate.index, i);
        }
    }

    #[test]
    fn baselines_have_zero_overhead_and_ranking_is_consistent() {
        let report = plan(&tiny_budget(), 2).unwrap();
        for o in &report.outcomes {
            if o.candidate.policy == EmptyCachePolicy::Never
                && o.candidate.alloc_label == "default"
                && !o.summary.oom
            {
                assert_eq!(o.overhead_pct, Some(0.0), "{}", o.candidate.key());
            }
        }
        let rec = report.recommended();
        assert!(!rec.is_empty(), "the paper's testbed fits 24 GiB");
        // Ranking is by peak reserved, ascending.
        for w in rec.windows(2) {
            assert!(w[0].summary.peak_reserved <= w[1].summary.peak_reserved);
        }
        assert_eq!(report.best().unwrap().rank, Some(1));
        // Every recommended outcome is feasible and within tolerance.
        for o in rec {
            assert!(o.feasible);
            if let Some(p) = o.overhead_pct {
                assert!(p <= report.budget.max_overhead_pct);
            }
        }
    }

    #[test]
    fn frontier_is_internally_nondominated() {
        let report = plan(&tiny_budget(), 2).unwrap();
        let frontier = report.frontier();
        assert!(!frontier.is_empty());
        for a in &frontier {
            for b in &frontier {
                if a.candidate.index == b.candidate.index {
                    continue;
                }
                let strictly_worse = b.summary.peak_reserved <= a.summary.peak_reserved
                    && b.summary.total_time_us <= a.summary.total_time_us
                    && (b.summary.peak_reserved < a.summary.peak_reserved
                        || b.summary.total_time_us < a.summary.total_time_us);
                assert!(!strictly_worse, "frontier point dominated");
            }
        }
    }

    #[test]
    fn cluster_plan_ranks_feasible_placements() {
        let mut b = Budget::rtx3090_table1();
        b.steps = 1;
        b.strategies = Some(vec!["none".to_string()]);
        b.worlds = Some(vec![2]);
        let report = plan_cluster(&b, 2).unwrap();
        assert_eq!(report.outcomes.len(), 3, "3 plans x 1 strategy");
        assert_eq!(report.jsonl().lines().count(), 4, "header + 3 outcomes");
        let best = report.best().expect("the paper's testbed fits 24 GiB");
        assert!(best.feasible);
        // Ranking is by most-loaded-GPU peak, ascending.
        let rec = report.recommended();
        assert!(!rec.is_empty());
        for w in rec.windows(2) {
            assert!(w[0].run.max_peak_reserved() <= w[1].run.max_peak_reserved());
        }
        // Every outcome carries one reserved figure per GPU.
        for o in &report.outcomes {
            assert_eq!(o.run.gpus.len() as u64, o.candidate.world);
        }
    }

    #[test]
    fn sharing_baselines_compare_within_their_own_placement() {
        let mut b = tiny_budget();
        b.allocators = Some(vec!["default".to_string()]);
        b.sharings = Some(vec!["separate".to_string(), "lora".to_string()]);
        let report = plan(&b, 2).unwrap();
        assert_eq!(report.outcomes.len(), 2 * 2 * 4, "strategy x sharing x policy");
        // Every (strategy, sharing) pair owns its own zero-overhead
        // baseline: a lora cell is never measured against a full-replica
        // run of the same strategy.
        for o in &report.outcomes {
            if o.candidate.policy == EmptyCachePolicy::Never && !o.summary.oom {
                assert_eq!(o.overhead_pct, Some(0.0), "{}", o.candidate.key());
            }
        }
        // Shared frozen backbones strictly shrink the best feasible peak.
        let best_for = |sharing: &str| {
            report
                .outcomes
                .iter()
                .filter(|o| o.candidate.sharing.name() == sharing && o.feasible)
                .map(|o| o.summary.peak_reserved)
                .min()
                .expect("feasible cell")
        };
        assert!(best_for("lora") < best_for("separate"));
    }

    #[test]
    fn same_budget_reproduces_itself() {
        let budget = tiny_budget();
        let a = plan(&budget, 1).unwrap();
        let b = plan(&budget, 3).unwrap();
        assert_eq!(a.jsonl(), b.jsonl());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
