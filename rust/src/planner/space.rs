//! The mitigation space: every configuration the planner considers for a
//! budget — strategy presets the framework supports × `empty_cache`
//! placements × allocator-knob candidates — enumerated in a fixed,
//! deterministic order and lowered to [`SweepCell`]s for the worker pool.

use super::budget::Budget;
use crate::alloc::AllocatorConfig;
use crate::coordinator::schedule::cluster_key;
use crate::coordinator::PlacementPlan;
use crate::frameworks::FrameworkProfile;
use crate::policy::EmptyCachePolicy;
use crate::rlhf::models::RoleSet;
use crate::rlhf::program::{Algo, Sharing};
use crate::rlhf::sim::{ScenarioMode, SimScenario};
use crate::strategies::StrategyConfig;
use crate::sweep::SweepCell;
use crate::util::bytes::MIB;

/// One point of the mitigation space.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Position in enumeration order — the stable identity rankings and
    /// JSONL lines are keyed by.
    pub index: usize,
    pub algo: Algo,
    pub sharing: Sharing,
    pub strategy_label: String,
    pub strategy: StrategyConfig,
    pub policy: EmptyCachePolicy,
    pub alloc_label: String,
    pub alloc_cfg: AllocatorConfig,
}

impl Candidate {
    /// `strategy/policy[/algo][/sharing]/alloc` — unique within one plan.
    /// Non-PPO algorithms insert `/algo` and non-separate placements
    /// `/sharing` before the allocator label, matching the
    /// [`crate::sweep::SweepCell`] key component order; PPO-only
    /// full-replica budgets keep the legacy three-part keys.
    pub fn key(&self) -> String {
        let mut key = format!("{}/{}", self.strategy_label, self.policy.name());
        if self.algo != Algo::Ppo {
            key.push('/');
            key.push_str(self.algo.name());
        }
        if self.sharing != Sharing::Separate {
            key.push('/');
            key.push_str(self.sharing.name());
        }
        key.push('/');
        key.push_str(&self.alloc_label);
        key
    }
}

/// The allocator-knob candidates the planner searches: the PyTorch
/// default, `max_split_size_mb:128`, `expandable_segments`,
/// `garbage_collection_threshold:0.8`, and the expandable+gc combination.
/// Labels are what budget `allocators` lists select by.
pub fn allocator_candidates() -> Vec<(String, AllocatorConfig)> {
    let base = AllocatorConfig::default();
    let max_split = AllocatorConfig {
        max_split_size: Some(128 * MIB),
        ..base.clone()
    };
    let expandable = AllocatorConfig {
        expandable_segments: true,
        ..base.clone()
    };
    let gc = AllocatorConfig {
        garbage_collection_threshold: Some(0.8),
        ..base.clone()
    };
    let expandable_gc = AllocatorConfig {
        expandable_segments: true,
        garbage_collection_threshold: Some(0.8),
        ..base.clone()
    };
    [base, max_split, expandable, gc, expandable_gc]
        .into_iter()
        .map(|c| (c.knob_label(), c))
        .collect()
}

/// The budget's algorithm rows: its `algos` names resolved, or PPO only
/// (the paper's pipeline) when unrestricted.
fn algo_rows(budget: &Budget) -> Result<Vec<Algo>, String> {
    match &budget.algos {
        Some(names) => names
            .iter()
            .map(|n| {
                Algo::by_name(n).ok_or_else(|| {
                    format!("unknown algo '{n}' (valid: {})", Algo::known_names())
                })
            })
            .collect(),
        None => Ok(vec![Algo::Ppo]),
    }
}

/// The budget's sharing rows: its `sharings` names resolved, or separate
/// full replicas only (the paper's placement) when unrestricted.
fn sharing_rows(budget: &Budget) -> Result<Vec<Sharing>, String> {
    match &budget.sharings {
        Some(names) => names
            .iter()
            .map(|n| {
                Sharing::by_name(n).ok_or_else(|| {
                    format!("unknown sharing '{n}' (valid: {})", Sharing::known_names())
                })
            })
            .collect(),
        None => Ok(vec![Sharing::Separate]),
    }
}

/// The budget's strategy rows: its `strategies` short-names resolved, or
/// the full Table-1 sweep when unrestricted.
fn strategy_rows(budget: &Budget) -> Result<Vec<(String, StrategyConfig)>, String> {
    match &budget.strategies {
        Some(names) => names
            .iter()
            .map(|n| {
                StrategyConfig::by_name(n)
                    .map(|(label, s)| (label.to_string(), s))
                    .ok_or_else(|| format!("unknown strategy '{n}'"))
            })
            .collect::<Result<_, _>>(),
        None => Ok(StrategyConfig::table1_deepspeed_rows()
            .into_iter()
            .map(|(label, s)| (label.to_string(), s))
            .collect()),
    }
}

/// Enumerate the space for `budget` in deterministic order (algorithm →
/// sharing → strategy → policy → allocator), honouring its optional
/// `strategies`/`allocators`/`algos`/`sharings` restrictions and skipping
/// strategies the framework cannot run.
pub fn enumerate(budget: &Budget) -> Result<Vec<Candidate>, String> {
    let profile = FrameworkProfile::by_kind(budget.framework);

    let algo_rows: Vec<Algo> = algo_rows(budget)?;
    let sharing_rows: Vec<Sharing> = sharing_rows(budget)?;
    let strategy_rows: Vec<(String, StrategyConfig)> = strategy_rows(budget)?;

    let all_allocs = allocator_candidates();
    let allocs: Vec<(String, AllocatorConfig)> = match &budget.allocators {
        Some(names) => names
            .iter()
            .map(|n| {
                all_allocs
                    .iter()
                    .find(|(label, _)| label == n)
                    .cloned()
                    .ok_or_else(|| {
                        let known: Vec<&str> =
                            all_allocs.iter().map(|(l, _)| l.as_str()).collect();
                        format!("unknown allocator '{n}' (known: {})", known.join(", "))
                    })
            })
            .collect::<Result<_, _>>()?,
        None => all_allocs,
    };

    let mut out = Vec::new();
    for algo in &algo_rows {
        for sharing in &sharing_rows {
            for (slabel, strategy) in &strategy_rows {
                if !profile.supports(strategy) {
                    continue;
                }
                for policy in EmptyCachePolicy::ALL {
                    for (alabel, acfg) in &allocs {
                        out.push(Candidate {
                            index: out.len(),
                            algo: *algo,
                            sharing: *sharing,
                            strategy_label: slabel.clone(),
                            strategy: *strategy,
                            policy,
                            alloc_label: alabel.clone(),
                            alloc_cfg: acfg.clone(),
                        });
                    }
                }
            }
        }
    }
    if out.is_empty() {
        return Err(format!(
            "mitigation space is empty for framework {}",
            budget.framework.name()
        ));
    }
    Ok(out)
}

/// The exact [`SimScenario`] a candidate simulates under `budget` — the
/// single source both [`to_cells`] and the planner's static prescreen
/// (`lint::bounds` over the same scenario the simulator would run) build
/// from, so the prescreen can never diverge from the simulation.
pub fn candidate_scenario(budget: &Budget, c: &Candidate) -> SimScenario {
    SimScenario {
        framework: FrameworkProfile::by_kind(budget.framework),
        models: budget.models.clone(),
        strategy: c.strategy,
        world: budget.world,
        policy: c.policy,
        steps: budget.steps,
        mode: ScenarioMode::Full,
        algo: c.algo,
        sharing: c.sharing,
        gpu: budget.gpu,
        seed: budget.seed,
        len_jitter: budget.framework.default_len_jitter(),
        roles: RoleSet::ALL,
        time_shared: RoleSet::EMPTY,
        rank: 0,
    }
}

/// Lower candidates to [`SweepCell`]s for [`crate::sweep::SweepRunner`].
/// Every cell shares the budget's seed (the search compares mitigations on
/// the *same* workload) and runs at the budget's capacity.
pub fn to_cells(budget: &Budget, candidates: &[Candidate]) -> Vec<SweepCell> {
    candidates
        .iter()
        .map(|c| {
            let scenario = candidate_scenario(budget, c);
            SweepCell {
                key: format!("advise/{}", c.key()),
                framework: budget.framework.name().to_string(),
                model: budget.models.policy_arch.name.clone(),
                strategy: c.strategy_label.clone(),
                mode: ScenarioMode::Full,
                policy: c.policy,
                algo: c.algo,
                sharing: c.sharing,
                alloc_label: c.alloc_label.clone(),
                alloc_cfg: c.alloc_cfg.clone(),
                scenario,
                capacity: budget.capacity,
            }
        })
        .collect()
}

/// One point of the cluster placement space: a GPU count, a placement
/// plan, and a strategy — what `advise --cluster` searches.
#[derive(Debug, Clone)]
pub struct ClusterCandidate {
    /// Position in enumeration order (stable identity for JSONL/ranking).
    pub index: usize,
    /// GPUs in this configuration.
    pub world: u64,
    pub plan: PlacementPlan,
    pub strategy_label: String,
    pub strategy: StrategyConfig,
    pub algo: Algo,
    pub sharing: Sharing,
}

impl ClusterCandidate {
    /// `cluster/w{world}/{plan}/{strategy}` (plus `/{algo}` for non-PPO
    /// and `/{sharing}` for non-separate placements) — unique within one
    /// search, and identical to the `rlhf-mem cluster` JSONL key for the
    /// same configuration (both call [`cluster_key`]).
    pub fn key(&self) -> String {
        cluster_key(
            self.world,
            &self.plan.name,
            &self.strategy_label,
            self.algo,
            self.sharing,
        )
    }
}

/// Enumerate the placement space for `budget` in deterministic order
/// (world → plan preset → strategy → algorithm → sharing). Worlds come
/// from `budget.worlds` (default `{2, world}`), each ≥ 2 GPUs.
pub fn enumerate_cluster(budget: &Budget) -> Result<Vec<ClusterCandidate>, String> {
    // The cluster search varies placement × strategy × world only; every
    // cell runs policy `never` on the default allocator. A budget that
    // restricts `allocators` expects an axis this mode does not search —
    // fail loud rather than silently dropping the restriction.
    if budget.allocators.is_some() {
        return Err(
            "the cluster search does not vary allocator knobs; remove 'allocators' \
             from the budget (or run plain `advise`)"
                .to_string(),
        );
    }
    let profile = FrameworkProfile::by_kind(budget.framework);
    let rows = strategy_rows(budget)?;
    let algos = algo_rows(budget)?;
    let sharings = sharing_rows(budget)?;
    let worlds: Vec<u64> = match &budget.worlds {
        Some(ws) => ws.clone(),
        None => {
            let mut ws = vec![2, budget.world.max(2)];
            ws.sort_unstable();
            ws.dedup();
            ws
        }
    };
    for &w in &worlds {
        if w < 2 {
            return Err(format!("cluster worlds must be >= 2 GPUs (got {w})"));
        }
    }

    let mut out = Vec::new();
    for &world in &worlds {
        for plan in PlacementPlan::presets(world) {
            for (label, strategy) in &rows {
                if !profile.supports(strategy) {
                    continue;
                }
                for algo in &algos {
                    for sharing in &sharings {
                        out.push(ClusterCandidate {
                            index: out.len(),
                            world,
                            plan: plan.clone(),
                            strategy_label: label.clone(),
                            strategy: *strategy,
                            algo: *algo,
                            sharing: *sharing,
                        });
                    }
                }
            }
        }
    }
    if out.is_empty() {
        return Err(format!(
            "cluster placement space is empty for framework {}",
            budget.framework.name()
        ));
    }
    Ok(out)
}

/// The full-replica rank-0 base scenario a [`ClusterCandidate`]'s
/// placement plan specializes per GPU.
pub fn cluster_base_scenario(budget: &Budget, c: &ClusterCandidate) -> SimScenario {
    SimScenario {
        framework: FrameworkProfile::by_kind(budget.framework),
        models: budget.models.clone(),
        strategy: c.strategy,
        world: c.world,
        policy: EmptyCachePolicy::Never,
        steps: budget.steps,
        mode: ScenarioMode::Full,
        algo: c.algo,
        sharing: c.sharing,
        gpu: budget.gpu,
        seed: budget.seed,
        len_jitter: budget.framework.default_len_jitter(),
        roles: RoleSet::ALL,
        time_shared: RoleSet::EMPTY,
        rank: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::FrameworkKind;

    #[test]
    fn allocator_candidates_are_labelled_and_distinct() {
        let cands = allocator_candidates();
        assert_eq!(cands.len(), 5);
        assert_eq!(cands[0].0, "default");
        let labels: Vec<&str> = cands.iter().map(|(l, _)| l.as_str()).collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels, dedup, "labels unique");
        assert!(labels.contains(&"expandable"));
        assert!(labels.contains(&"gc:0.80"));
        assert!(labels.contains(&"max_split:128MiB"));
    }

    #[test]
    fn full_space_shape_for_deepspeed() {
        let budget = Budget::rtx3090_table1();
        let cands = enumerate(&budget).unwrap();
        // 7 strategies × 4 policies × 5 allocator configs.
        assert_eq!(cands.len(), 7 * 4 * 5);
        assert_eq!(cands[0].key(), "None/never/default");
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn colossal_drops_unsupported_zero1() {
        let mut budget = Budget::rtx3090_table1();
        budget.framework = FrameworkKind::ColossalChat;
        let cands = enumerate(&budget).unwrap();
        assert_eq!(cands.len(), 6 * 4 * 5, "ZeRO-1 filtered out");
        assert!(cands.iter().all(|c| c.strategy_label != "ZeRO-1"));
    }

    #[test]
    fn budget_restrictions_narrow_the_space() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
        budget.allocators = Some(vec!["default".to_string(), "expandable".to_string()]);
        let cands = enumerate(&budget).unwrap();
        assert_eq!(cands.len(), 2 * 4 * 2);
        budget.strategies = Some(vec!["bogus".to_string()]);
        assert!(enumerate(&budget).is_err());
    }

    #[test]
    fn algo_axis_widens_the_space_and_suffixes_keys() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["none".to_string()]);
        budget.allocators = Some(vec!["default".to_string()]);
        budget.algos = Some(vec!["ppo".to_string(), "grpo".to_string()]);
        let cands = enumerate(&budget).unwrap();
        // 2 algos × 1 strategy × 4 policies × 1 allocator.
        assert_eq!(cands.len(), 2 * 4);
        assert_eq!(cands[0].key(), "None/never/default");
        assert_eq!(cands[4].key(), "None/never/grpo/default");
        assert_eq!(cands[0].algo, Algo::Ppo);
        assert_eq!(cands[4].algo, Algo::Grpo);
        let cells = to_cells(&budget, &cands);
        assert_eq!(cells[4].scenario.algo, Algo::Grpo);
        assert_eq!(cells[4].key, "advise/None/never/grpo/default");
        budget.algos = Some(vec!["sarsa".to_string()]);
        let err = enumerate(&budget).unwrap_err();
        assert!(err.contains("unknown algo 'sarsa'"), "{err}");
        assert!(err.contains("ppo, grpo, remax, dpo"), "{err}");
    }

    #[test]
    fn sharing_axis_widens_the_space_and_suffixes_keys() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["none".to_string()]);
        budget.allocators = Some(vec!["default".to_string()]);
        budget.sharings = Some(vec!["separate".to_string(), "hydra".to_string()]);
        let cands = enumerate(&budget).unwrap();
        // 2 sharings × 1 strategy × 4 policies × 1 allocator.
        assert_eq!(cands.len(), 2 * 4);
        assert_eq!(cands[0].key(), "None/never/default");
        assert_eq!(cands[4].key(), "None/never/hydra/default");
        assert_eq!(cands[0].sharing, Sharing::Separate);
        assert_eq!(cands[4].sharing, Sharing::Hydra);
        let cells = to_cells(&budget, &cands);
        assert_eq!(cells[4].scenario.sharing, Sharing::Hydra);
        assert_eq!(cells[4].key, "advise/None/never/hydra/default");
        // Algo precedes sharing in combined keys.
        budget.algos = Some(vec!["grpo".to_string()]);
        budget.sharings = Some(vec!["lora".to_string()]);
        let cands = enumerate(&budget).unwrap();
        assert_eq!(cands[0].key(), "None/never/grpo/lora/default");
        budget.sharings = Some(vec!["siamese".to_string()]);
        let err = enumerate(&budget).unwrap_err();
        assert!(err.contains("unknown sharing 'siamese'"), "{err}");
        assert!(err.contains("separate, lora, hydra, frozen-shared"), "{err}");
    }

    #[test]
    fn cluster_space_shape_and_keys() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
        let cands = enumerate_cluster(&budget).unwrap();
        // Worlds {2, 4} × 3 plans × 2 strategies.
        assert_eq!(cands.len(), 2 * 3 * 2);
        assert_eq!(cands[0].key(), "cluster/w2/colocated/None");
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.index, i);
            c.plan.validate().unwrap();
        }
        // Explicit worlds narrow the search; world 1 is rejected.
        budget.worlds = Some(vec![2]);
        assert_eq!(enumerate_cluster(&budget).unwrap().len(), 3 * 2);
        budget.worlds = Some(vec![1]);
        assert!(enumerate_cluster(&budget).is_err());
        // An allocator restriction names an axis this mode cannot honour.
        budget.worlds = Some(vec![2]);
        budget.allocators = Some(vec!["expandable".to_string()]);
        assert!(enumerate_cluster(&budget).is_err());
        // The algorithm axis widens the placement search and its keys.
        budget.allocators = None;
        budget.algos = Some(vec!["ppo".to_string(), "grpo".to_string()]);
        let cands = enumerate_cluster(&budget).unwrap();
        assert_eq!(cands.len(), 3 * 2 * 2);
        assert_eq!(cands[0].key(), "cluster/w2/colocated/None");
        assert_eq!(cands[1].key(), "cluster/w2/colocated/None/grpo");
        let base = cluster_base_scenario(&budget, &cands[1]);
        assert_eq!(base.algo, Algo::Grpo);
        // The sharing axis widens it too, suffixing after the algo.
        budget.algos = None;
        budget.sharings = Some(vec!["separate".to_string(), "lora".to_string()]);
        let cands = enumerate_cluster(&budget).unwrap();
        assert_eq!(cands.len(), 3 * 2 * 2);
        assert_eq!(cands[0].key(), "cluster/w2/colocated/None");
        assert_eq!(cands[1].key(), "cluster/w2/colocated/None/lora");
        let base = cluster_base_scenario(&budget, &cands[1]);
        assert_eq!(base.sharing, Sharing::Lora);
    }

    #[test]
    fn cluster_base_scenario_is_a_full_replica() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["zero3".to_string()]);
        let cands = enumerate_cluster(&budget).unwrap();
        let base = cluster_base_scenario(&budget, &cands[0]);
        assert_eq!(base.world, cands[0].world);
        assert_eq!(base.rank, 0);
        assert_eq!(base.roles, crate::rlhf::models::RoleSet::ALL);
        assert_eq!(base.seed, budget.seed);
    }

    #[test]
    fn cells_share_seed_and_capacity() {
        let mut budget = Budget::rtx3090_table1();
        budget.strategies = Some(vec!["none".to_string()]);
        let cands = enumerate(&budget).unwrap();
        let cells = to_cells(&budget, &cands);
        assert_eq!(cells.len(), cands.len());
        assert!(cells.iter().all(|c| c.scenario.seed == budget.seed));
        assert!(cells.iter().all(|c| c.capacity == budget.capacity));
        assert_eq!(cells[0].key, "advise/None/never/default");
        assert!(!cells[0].scenario.len_jitter, "deepspeed pads");
    }
}
