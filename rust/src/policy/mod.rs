//! `empty_cache()` placement policies — the paper's §3.3 mitigation and its
//! ablation: after each inference and training phase, after inferences
//! only, after training only, or never.

use crate::trace::PhaseKind;

/// When to invoke `empty_cache()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmptyCachePolicy {
    /// Baseline: never (PyTorch default behaviour).
    Never,
    /// After every inference *and* training phase (the paper's headline).
    AfterBoth,
    /// Only after inference phases (§3.3: "almost as effective").
    AfterInference,
    /// Only after training phases (§3.3: "not very effective").
    AfterTraining,
}

impl EmptyCachePolicy {
    /// Should the trainer insert `empty_cache()` right after `phase` ends?
    pub fn applies_after(self, phase: PhaseKind) -> bool {
        match self {
            EmptyCachePolicy::Never => false,
            EmptyCachePolicy::AfterBoth => phase.is_inference() || phase.is_training(),
            EmptyCachePolicy::AfterInference => phase.is_inference(),
            EmptyCachePolicy::AfterTraining => phase.is_training(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EmptyCachePolicy::Never => "never",
            EmptyCachePolicy::AfterBoth => "after_both",
            EmptyCachePolicy::AfterInference => "after_inference",
            EmptyCachePolicy::AfterTraining => "after_training",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s {
            "never" => Some(Self::Never),
            "after_both" => Some(Self::AfterBoth),
            "after_inference" => Some(Self::AfterInference),
            "after_training" => Some(Self::AfterTraining),
            _ => None,
        }
    }

    pub const ALL: [EmptyCachePolicy; 4] = [
        EmptyCachePolicy::Never,
        EmptyCachePolicy::AfterBoth,
        EmptyCachePolicy::AfterInference,
        EmptyCachePolicy::AfterTraining,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_rules() {
        use PhaseKind::{Generation, InferReward, Init, TrainActor, TrainCritic};
        assert!(EmptyCachePolicy::AfterBoth.applies_after(Generation));
        assert!(EmptyCachePolicy::AfterBoth.applies_after(TrainActor));
        assert!(!EmptyCachePolicy::AfterBoth.applies_after(Init));
        assert!(EmptyCachePolicy::AfterInference.applies_after(InferReward));
        assert!(!EmptyCachePolicy::AfterInference.applies_after(TrainCritic));
        assert!(EmptyCachePolicy::AfterTraining.applies_after(TrainCritic));
        assert!(!EmptyCachePolicy::AfterTraining.applies_after(Generation));
        for p in PhaseKind::ALL {
            assert!(!EmptyCachePolicy::Never.applies_after(p));
        }
    }

    #[test]
    fn names_roundtrip() {
        for p in EmptyCachePolicy::ALL {
            assert_eq!(EmptyCachePolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(EmptyCachePolicy::by_name("bogus"), None);
    }
}
