//! Memory profiler (paper Appendix B): observes the allocator's event
//! stream and reconstructs everything the paper reports — the
//! reserved/allocated timeline of Figure 1, the fragmentation samples taken
//! at each `cudaMalloc`, per-phase peaks, and the peak-reserved /
//! "reserved w/o fragmentation" pair.

pub mod summary;
pub mod timeline;

pub use summary::ProfileSummary;
pub use timeline::{Timeline, TimelinePoint};

use crate::alloc::{AllocEvent, AllocObserver, CachingAllocator, StatSnapshot};
use crate::trace::{PhaseKind, PhaseSink};
use std::collections::HashMap;

/// One fragmentation sample (taken at a cudaMalloc).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FragSample {
    pub time_us: f64,
    pub frag: u64,
    /// The rounded request that forced the cudaMalloc.
    pub requested: u64,
    pub phase: PhaseKind,
}

/// Peak statistics of one phase kind.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhasePeak {
    pub reserved: u64,
    pub allocated: u64,
    pub visits: u64,
}

/// The profiler. Pass it to [`replay`](crate::trace::replay()) as the [`PhaseSink`]:
/// replay drains the allocator's event log after every op and feeds it
/// through [`PhaseSink::on_alloc_event`], so one owned profiler per run is
/// all the plumbing there is (the profiler is `Send`, one per sweep
/// worker).
#[derive(Debug)]
pub struct MemoryProfiler {
    pub timeline: Timeline,
    pub frag_samples: Vec<FragSample>,
    pub phase_peaks: HashMap<PhaseKind, PhasePeak>,
    /// Phase during which the global reserved peak was set.
    pub peak_phase: PhaseKind,
    peak_reserved_seen: u64,
    current_phase: PhaseKind,
    /// Compute time from the replay (advanced by PhaseSink callbacks).
    compute_us: f64,
    /// Total bytes released by empty_cache calls.
    pub empty_cache_released: u64,
    pub empty_cache_calls: u64,
    /// cudaMalloc count observed (segments mapped).
    pub cuda_mallocs: u64,
}

impl MemoryProfiler {
    pub fn new() -> Self {
        Self::with_timeline_resolution(Timeline::new().resolution())
    }

    /// A profiler whose timeline decimates at `min_delta` bytes instead of
    /// the default 16 MiB (`profile --timeline-resolution`).
    pub fn with_timeline_resolution(min_delta: u64) -> Self {
        MemoryProfiler {
            timeline: Timeline::with_resolution(min_delta),
            frag_samples: Vec::new(),
            phase_peaks: HashMap::new(),
            peak_phase: PhaseKind::Init,
            peak_reserved_seen: 0,
            current_phase: PhaseKind::Init,
            compute_us: 0.0,
            empty_cache_released: 0,
            empty_cache_calls: 0,
            cuda_mallocs: 0,
        }
    }

    fn now_us(&self, state: &StatSnapshot) -> f64 {
        state.time_us + self.compute_us
    }

    fn track_peaks(&mut self, state: &StatSnapshot) {
        let peak = self
            .phase_peaks
            .entry(self.current_phase)
            .or_default();
        peak.reserved = peak.reserved.max(state.reserved);
        peak.allocated = peak.allocated.max(state.allocated);
        if state.reserved > self.peak_reserved_seen {
            self.peak_reserved_seen = state.reserved;
            self.peak_phase = self.current_phase;
        }
    }

    pub fn current_phase(&self) -> PhaseKind {
        self.current_phase
    }

    /// Per-phase peaks in the order the compiled
    /// [`PhaseProgram`](crate::rlhf::program::PhaseProgram) runs them
    /// (`Init` first, then the program's step phases) — attribution driven
    /// by the same IR the emitter interpreted, so a phase the program
    /// never scheduled cannot appear, and consumers stop re-deriving the
    /// pipeline order privately.
    pub fn phase_attribution(
        &self,
        program: &crate::rlhf::program::PhaseProgram,
    ) -> Vec<(PhaseKind, PhasePeak)> {
        let mut order = vec![PhaseKind::Init];
        order.extend(program.step_phases());
        order
            .into_iter()
            .filter_map(|p| self.phase_peaks.get(&p).map(|peak| (p, *peak)))
            .collect()
    }
}

impl Default for MemoryProfiler {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocObserver for MemoryProfiler {
    fn on_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        let t = self.now_us(state);
        match event {
            AllocEvent::CudaMalloc { frag_sample, rounded, .. } => {
                self.cuda_mallocs += 1;
                self.frag_samples.push(FragSample {
                    time_us: t,
                    frag: *frag_sample,
                    requested: *rounded,
                    phase: self.current_phase,
                });
            }
            AllocEvent::EmptyCache { bytes, .. } => {
                self.empty_cache_calls += 1;
                self.empty_cache_released += bytes;
            }
            _ => {}
        }
        self.timeline
            .push(t, state.reserved, state.allocated, self.current_phase);
        self.track_peaks(state);
    }
}

impl PhaseSink for MemoryProfiler {
    fn on_alloc_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        self.on_event(event, state);
    }

    fn on_phase(&mut self, phase: PhaseKind, alloc: &CachingAllocator, compute_us: f64) {
        self.compute_us = compute_us;
        self.current_phase = phase;
        let snap = alloc.snapshot();
        let t = self.now_us(&snap);
        self.timeline.mark_phase(t, phase);
        self.timeline
            .push(t, snap.reserved, snap.allocated, phase);
    }

    fn on_step_end(&mut self, step: u64, alloc: &CachingAllocator, compute_us: f64) {
        self.compute_us = compute_us;
        let snap = alloc.snapshot();
        self.timeline.mark_step(self.now_us(&snap), step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::CachingAllocator;
    use crate::trace::{replay, Tag, TraceBuilder};
    use crate::util::bytes::{GIB, MIB};

    fn run_profiled(build: impl FnOnce(&mut TraceBuilder)) -> (MemoryProfiler, CachingAllocator) {
        let mut b = TraceBuilder::new();
        build(&mut b);
        let trace = b.finish();
        let mut prof = MemoryProfiler::new();
        let mut alloc = CachingAllocator::with_default_config(4 * GIB);
        replay(&trace, &mut alloc, &mut prof);
        alloc.validate().unwrap();
        (prof, alloc)
    }

    #[test]
    fn tracks_phase_peaks() {
        let (prof, _alloc) = run_profiled(|b| {
            b.phase(PhaseKind::Generation);
            b.transient([100 * MIB], Tag::KvCache);
            b.phase(PhaseKind::TrainActor);
            b.transient([300 * MIB], Tag::Grad);
        });
        let gen = prof.phase_peaks[&PhaseKind::Generation];
        let train = prof.phase_peaks[&PhaseKind::TrainActor];
        assert!(gen.allocated >= 100 * MIB);
        assert!(train.allocated >= 300 * MIB);
        assert_eq!(prof.peak_phase, PhaseKind::TrainActor);
    }

    #[test]
    fn phase_attribution_follows_the_program_order() {
        use crate::experiment::{run_scenario, RTX3090_HBM};
        use crate::policy::EmptyCachePolicy;
        use crate::rlhf::program::PhaseProgram;
        use crate::rlhf::sim::SimScenario;
        use crate::strategies::StrategyConfig;
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 1;
        let program = PhaseProgram::compile(&scn);
        let res = run_scenario(&scn, RTX3090_HBM);
        let attribution = res.profiler.phase_attribution(&program);
        let order: Vec<PhaseKind> = attribution.iter().map(|(p, _)| *p).collect();
        let mut want = vec![PhaseKind::Init];
        want.extend(program.step_phases());
        assert_eq!(order, want, "attribution follows the compiled pipeline");
        assert!(attribution.iter().all(|(_, pk)| pk.reserved > 0));
    }

    #[test]
    fn frag_samples_tagged_with_phase() {
        let (prof, _alloc) = run_profiled(|b| {
            b.phase(PhaseKind::Generation);
            // Two discontiguous cached 16 MiB segments from generation...
            let h1 = b.alloc(15 * MIB, Tag::KvCache);
            let h2 = b.alloc(15 * MIB, Tag::KvCache);
            b.free(h1);
            b.free(h2);
            b.phase(PhaseKind::TrainActor);
            // ...cannot serve training's 30 MiB request: frag-caused malloc.
            let _g = b.alloc(30 * MIB, Tag::Grad);
        });
        let train_sample = prof
            .frag_samples
            .iter()
            .find(|s| s.phase == PhaseKind::TrainActor)
            .unwrap();
        assert_eq!(train_sample.frag, 32 * MIB);
    }

    #[test]
    fn empty_cache_accounting() {
        let (prof, alloc) = run_profiled(|b| {
            b.phase(PhaseKind::Generation);
            let h = b.alloc(30 * MIB, Tag::KvCache);
            b.free(h);
            b.empty_cache();
        });
        assert_eq!(prof.empty_cache_calls, 1);
        assert_eq!(prof.empty_cache_released, 30 * MIB);
        assert_eq!(alloc.reserved(), 0);
    }

    #[test]
    fn timeline_nonempty_and_monotone() {
        let (prof, _alloc) = run_profiled(|b| {
            b.phase(PhaseKind::Generation);
            for _ in 0..10 {
                // Above the timeline's 16 MiB decimation resolution.
                b.transient([50 * MIB], Tag::Activation);
            }
        });
        let pts = prof.timeline.points();
        assert!(pts.len() >= 10, "{}", pts.len());
        for w in pts.windows(2) {
            assert!(w[1].time_us >= w[0].time_us);
        }
    }
}
