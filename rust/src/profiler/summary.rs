//! Profile summary: the numbers one paper-table cell needs.

use super::MemoryProfiler;
use crate::alloc::CachingAllocator;
use crate::trace::{PhaseKind, ReplayResult};
use crate::util::bytes::fmt_gib_paper;

/// Everything Table 1/2 and Figure 1's annotations report for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSummary {
    /// Peak reserved bytes ("Reserved" column).
    pub peak_reserved: u64,
    /// The paper's "Frag." column: the largest fragmentation-caused sample
    /// observed at any cudaMalloc (Appendix B definition).
    pub frag: u64,
    /// Peak allocated bytes ("Allocated" column).
    pub peak_allocated: u64,
    /// Fragmentation sample at the cudaMalloc that set the reserved peak —
    /// Figure 1's gap between the red and yellow crosses.
    pub frag_at_peak: u64,
    /// Phase during which the reserved peak occurred (§3.2's GPT-2
    /// diagnosis hinges on this).
    pub peak_phase: PhaseKind,
    /// Simulated end-to-end time: compute + allocator + driver, µs.
    pub total_time_us: f64,
    /// Allocator+driver share of that time, µs.
    pub allocator_time_us: f64,
    pub empty_cache_calls: u64,
    pub empty_cache_released: u64,
    pub cuda_mallocs: u64,
    /// Total allocation requests served.
    pub num_allocs: u64,
    /// Requests served from the cache (no cudaMalloc) — the telemetry
    /// ledger reports the hit ratio per search.
    pub num_cache_hits: u64,
    /// Replay hit OOM (the paper's frameworks would have crashed).
    pub oom: bool,
}

impl ProfileSummary {
    pub fn collect(
        prof: &MemoryProfiler,
        alloc: &CachingAllocator,
        replay: &ReplayResult,
    ) -> ProfileSummary {
        let stats = alloc.stats();
        ProfileSummary {
            peak_reserved: stats.peak_reserved,
            frag: stats.max_frag_sample,
            peak_allocated: stats.peak_allocated,
            frag_at_peak: stats.frag_at_peak_reserved,
            peak_phase: prof.peak_phase,
            total_time_us: replay.compute_us + alloc.time_us(),
            allocator_time_us: alloc.time_us(),
            empty_cache_calls: prof.empty_cache_calls,
            empty_cache_released: prof.empty_cache_released,
            cuda_mallocs: prof.cuda_mallocs,
            num_allocs: stats.num_allocs,
            num_cache_hits: stats.num_cache_hits,
            oom: !replay.ok(),
        }
    }

    /// "Reserved w/o fragmentation" (Figure 1's dotted yellow line at the
    /// peak). Uses the broader of the two fragmentation views so the line
    /// reflects the junk present around the peak, as the paper plots it.
    pub fn fig1_frag(&self) -> u64 {
        self.frag_at_peak.max(self.frag.min(self.peak_reserved))
    }

    pub fn reserved_wo_frag(&self) -> u64 {
        self.peak_reserved - self.fig1_frag()
    }

    /// Fragmentation overhead ratio (the paper's "+46%").
    pub fn frag_overhead_ratio(&self) -> f64 {
        let f = self.fig1_frag();
        if self.peak_reserved == f {
            return 0.0;
        }
        f as f64 / (self.peak_reserved - f) as f64
    }

    /// Paper-style row: `Reserved | Frag | Allocated` in GiB strings.
    pub fn paper_cells(&self) -> [String; 3] {
        [
            fmt_gib_paper(self.peak_reserved),
            fmt_gib_paper(self.frag),
            fmt_gib_paper(self.peak_allocated),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GIB;

    fn mk(reserved: u64, frag: u64, alloc: u64) -> ProfileSummary {
        ProfileSummary {
            peak_reserved: reserved,
            frag,
            peak_allocated: alloc,
            frag_at_peak: frag,
            peak_phase: PhaseKind::TrainActor,
            total_time_us: 1e6,
            allocator_time_us: 1e4,
            empty_cache_calls: 0,
            empty_cache_released: 0,
            cuda_mallocs: 10,
            oom: false,
        }
    }

    #[test]
    fn derived_quantities() {
        // Figure 1's numbers: 6.2 GiB frag on ~13.4 GiB base = +46%.
        let s = mk(19_593 * (1 << 20), 6_349 * (1 << 20), 5 * GIB + (1 << 29));
        assert_eq!(s.reserved_wo_frag(), (19_593 - 6_349) * (1 << 20));
        let ratio = s.frag_overhead_ratio();
        assert!((0.45..0.52).contains(&ratio), "{ratio}");
    }

    #[test]
    fn paper_cells_format() {
        let s = mk(18 * GIB + 820 * (1 << 20), 20 * (1 << 20), 18 * GIB);
        let cells = s.paper_cells();
        assert_eq!(cells[0], "18.8");
        assert_eq!(cells[1], "<0.1");
        assert_eq!(cells[2], "18.0");
    }
}
