//! Reserved/allocated timeline with decimation — the data behind Figure 1.

use crate::trace::PhaseKind;

/// One timeline sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    pub time_us: f64,
    pub reserved: u64,
    pub allocated: u64,
    pub phase: PhaseKind,
}

/// Phase-transition marker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseMark {
    pub time_us: f64,
    pub phase: PhaseKind,
}

/// Decimating sample store: keeps every change-point whose reserved or
/// allocated moved by at least `min_delta` bytes since the previous kept
/// point (plus all phase marks), bounding memory for multi-million-op
/// traces while preserving the curve's shape and extremes.
#[derive(Debug, Clone)]
pub struct Timeline {
    points: Vec<TimelinePoint>,
    phase_marks: Vec<PhaseMark>,
    step_marks: Vec<(f64, u64)>,
    min_delta: u64,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline {
            points: Vec::new(),
            phase_marks: Vec::new(),
            step_marks: Vec::new(),
            min_delta: 16 << 20, // 16 MiB resolution by default
        }
    }

    pub fn with_resolution(min_delta: u64) -> Self {
        Timeline {
            min_delta,
            ..Self::new()
        }
    }

    /// The decimation resolution in bytes.
    pub fn resolution(&self) -> u64 {
        self.min_delta
    }

    pub fn push(&mut self, time_us: f64, reserved: u64, allocated: u64, phase: PhaseKind) {
        if let Some(last) = self.points.last() {
            let dr = reserved.abs_diff(last.reserved);
            let da = allocated.abs_diff(last.allocated);
            if dr < self.min_delta && da < self.min_delta && phase == last.phase {
                // Keep extremes exact: replace the last point if this one
                // dominates it in either direction at (almost) same time.
                return;
            }
        }
        self.points.push(TimelinePoint {
            time_us,
            reserved,
            allocated,
            phase,
        });
    }

    pub fn mark_phase(&mut self, time_us: f64, phase: PhaseKind) {
        self.phase_marks.push(PhaseMark { time_us, phase });
    }

    pub fn mark_step(&mut self, time_us: f64, step: u64) {
        self.step_marks.push((time_us, step));
    }

    pub fn points(&self) -> &[TimelinePoint] {
        &self.points
    }

    pub fn phase_marks(&self) -> &[PhaseMark] {
        &self.phase_marks
    }

    pub fn step_marks(&self) -> &[(f64, u64)] {
        &self.step_marks
    }

    /// Render as CSV (`time_us,reserved,allocated,phase`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("time_us,reserved_bytes,allocated_bytes,phase\n");
        for p in &self.points {
            out.push_str(&format!(
                "{:.1},{},{},{}\n",
                p.time_us,
                p.reserved,
                p.allocated,
                p.phase.name()
            ));
        }
        out
    }

    /// ASCII chart of the reserved (█) and allocated (▒) curves — the
    /// terminal rendition of Figure 1.
    pub fn ascii_chart(&self, width: usize, height: usize) -> String {
        if self.points.is_empty() {
            return "(empty timeline)".to_string();
        }
        let t0 = self.points.first().unwrap().time_us;
        let t1 = self.points.last().unwrap().time_us.max(t0 + 1.0);
        let max_y = self.points.iter().map(|p| p.reserved).max().unwrap().max(1);
        // For each column, the max reserved/allocated in its time window.
        let mut res_col = vec![0u64; width];
        let mut alloc_col = vec![0u64; width];
        for p in &self.points {
            let x = (((p.time_us - t0) / (t1 - t0)) * (width as f64 - 1.0)) as usize;
            res_col[x] = res_col[x].max(p.reserved);
            alloc_col[x] = alloc_col[x].max(p.allocated);
        }
        // Forward-fill empty columns.
        for i in 1..width {
            if res_col[i] == 0 {
                res_col[i] = res_col[i - 1];
                alloc_col[i] = alloc_col[i - 1];
            }
        }
        let mut rows = Vec::with_capacity(height);
        for r in 0..height {
            let level = max_y as f64 * (height - r) as f64 / height as f64;
            let mut row = String::with_capacity(width + 12);
            for c in 0..width {
                let ch = if alloc_col[c] as f64 >= level {
                    '█'
                } else if res_col[c] as f64 >= level {
                    '░'
                } else {
                    ' '
                };
                row.push(ch);
            }
            row.push_str(&format!(
                " {:>6.1} GiB",
                level / (1u64 << 30) as f64
            ));
            rows.push(row);
        }
        rows.push(format!(
            "{}  █ allocated  ░ reserved-above-allocated",
            "-".repeat(width)
        ));
        rows.join("\n")
    }
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decimation_keeps_big_moves() {
        let mut t = Timeline::with_resolution(100);
        t.push(0.0, 1000, 500, PhaseKind::Init);
        t.push(1.0, 1050, 520, PhaseKind::Init); // below resolution: dropped
        t.push(2.0, 2000, 800, PhaseKind::Init); // kept
        assert_eq!(t.points().len(), 2);
    }

    #[test]
    fn phase_change_always_kept() {
        let mut t = Timeline::with_resolution(1 << 30);
        t.push(0.0, 100, 50, PhaseKind::Init);
        t.push(1.0, 101, 51, PhaseKind::Generation);
        assert_eq!(t.points().len(), 2);
    }

    #[test]
    fn csv_format() {
        let mut t = Timeline::new();
        t.push(0.5, 1 << 30, 1 << 29, PhaseKind::Generation);
        let csv = t.to_csv();
        assert!(csv.starts_with("time_us,"));
        assert!(csv.contains("generation"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn ascii_chart_renders() {
        let mut t = Timeline::new();
        for i in 0..100u64 {
            t.push(
                i as f64,
                (i + 1) * (1 << 26),
                (i + 1) * (1 << 25),
                PhaseKind::Generation,
            );
        }
        let chart = t.ascii_chart(40, 8);
        assert!(chart.contains('█'));
        assert!(chart.contains('░'));
        assert!(chart.lines().count() == 9);
    }

    #[test]
    fn empty_chart_ok() {
        let t = Timeline::new();
        assert_eq!(t.ascii_chart(10, 4), "(empty timeline)");
    }
}
