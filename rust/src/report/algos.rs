//! Algorithm-comparison renderer: one row per strategy, one
//! `Reserved`/`Frag.` column pair per RLHF algorithm — the table behind
//! `rlhf-mem algos`, showing how much of PPO's memory bill each
//! critic-free or reference-only variant forgives under each strategy.

use crate::report::table::TextTable;
use crate::rlhf::program::Algo;
use crate::sweep::CellResult;
use crate::util::bytes::fmt_gib_paper;

/// Build the comparison table from sweep cells (one cell per strategy ×
/// algorithm; extra axes collapse onto the same row/column slot, last
/// writer wins). Strategies keep first-seen order; `algos` fixes the
/// column order. Cells that OOMed render as `OOM`.
pub fn comparison_table(cells: &[CellResult], algos: &[Algo]) -> TextTable {
    let mut header: Vec<String> = vec!["Strategy".to_string()];
    for a in algos {
        header.push(format!("{} Resv", a.name()));
        header.push(format!("{} Frag", a.name()));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = TextTable::new(&header_refs);

    // strategy label -> per-algo (reserved, frag, oom) slots.
    let mut rows: Vec<(String, Vec<Option<(u64, u64, bool)>>)> = Vec::new();
    for cell in cells {
        let Some(ai) = algos.iter().position(|a| a.name() == cell.algo) else {
            continue;
        };
        let ri = match rows.iter().position(|(s, _)| *s == cell.strategy) {
            Some(i) => i,
            None => {
                rows.push((cell.strategy.clone(), vec![None; algos.len()]));
                rows.len() - 1
            }
        };
        rows[ri].1[ai] = Some((
            cell.summary.peak_reserved,
            cell.summary.frag,
            cell.summary.oom,
        ));
    }

    for (strategy, slots) in rows {
        let mut out = vec![strategy];
        for slot in slots {
            match slot {
                Some((_, _, true)) => {
                    out.push("OOM".to_string());
                    out.push("OOM".to_string());
                }
                Some((reserved, frag, false)) => {
                    out.push(fmt_gib_paper(reserved));
                    out.push(fmt_gib_paper(frag));
                }
                None => {
                    out.push("-".to_string());
                    out.push("-".to_string());
                }
            }
        }
        t.row(out);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;
    use crate::sweep::{SweepGrid, SweepRunner};

    #[test]
    fn table_has_one_row_per_strategy_and_columns_per_algo() {
        let algos = [Algo::Ppo, Algo::Grpo];
        let cells = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .policies([EmptyCachePolicy::Never])
            .algos(algos)
            .steps(1)
            .build()
            .unwrap();
        let report = SweepRunner::new(2).run(cells);
        let t = comparison_table(&report.cells, &algos);
        assert_eq!(t.header.len(), 1 + 2 * algos.len());
        assert_eq!(t.header[1], "ppo Resv");
        assert_eq!(t.header[4], "grpo Frag");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "None");
        assert_eq!(t.rows[1][0], "ZeRO-3");
        // Every slot filled (no OOM on the paper testbed at 1 step).
        for row in &t.rows {
            assert!(row.iter().all(|c| c != "-" && c != "OOM"), "{row:?}");
        }
    }
}
