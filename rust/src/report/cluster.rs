//! Cluster-report renderers: the per-configuration summary table and the
//! per-GPU detail table `rlhf-mem cluster` prints, plus the deterministic
//! JSON-lines dump (one line per configuration, input order).

use crate::coordinator::ClusterRun;
use crate::report::table::TextTable;
use crate::util::bytes::fmt_gib_paper;
use crate::util::json::Json;

/// One row per configuration: the most loaded GPU, the cluster total, and
/// the step-time breakdown.
pub fn summary_table(runs: &[(String, ClusterRun)]) -> TextTable {
    let mut t = TextTable::new(&[
        "Config", "GPUs", "Max GPU", "Total", "Step ms", "P2P ms", "Coll ms", "OOM",
    ]);
    for (key, run) in runs {
        t.row(vec![
            key.clone(),
            run.plan.gpus().to_string(),
            fmt_gib_paper(run.max_peak_reserved()),
            fmt_gib_paper(run.total_peak_reserved()),
            format!("{:.1}", run.step_time_us / 1000.0),
            format!("{:.1}", run.p2p_us / 1000.0),
            format!("{:.1}", run.collective_us / 1000.0),
            if run.oom() { "yes" } else { "" }.to_string(),
        ]);
    }
    t
}

/// One row per (configuration, GPU): which models it hosts and what they
/// cost it.
pub fn gpu_table(runs: &[(String, ClusterRun)]) -> TextTable {
    let mut t = TextTable::new(&["Config", "GPU", "Models", "Reserved", "Frag.", "OOM"]);
    for (key, run) in runs {
        for g in &run.gpus {
            t.row(vec![
                key.clone(),
                g.gpu.to_string(),
                g.roles.label(),
                fmt_gib_paper(g.peak_reserved),
                fmt_gib_paper(g.frag),
                if g.oom { "yes" } else { "" }.to_string(),
            ]);
        }
    }
    t
}

/// Deterministic JSON-lines: the versioned schema header, then one
/// `{key, ...cluster}` line per configuration, input order —
/// byte-identical whatever `--jobs` was.
pub fn jsonl(runs: &[(String, ClusterRun)]) -> String {
    let mut out = crate::util::schema::header_line("cluster");
    out.push('\n');
    for (i, (key, run)) in runs.iter().enumerate() {
        let mut line: Vec<(String, Json)> = vec![
            ("index".to_string(), Json::from(i)),
            ("key".to_string(), Json::str(key.clone())),
        ];
        if let Json::Obj(fields) = run.to_json() {
            line.extend(fields);
        }
        out.push_str(&Json::Obj(line).to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::run_plan;
    use crate::coordinator::PlacementPlan;
    use crate::experiment::RTX3090_HBM;
    use crate::policy::EmptyCachePolicy;
    use crate::rlhf::sim::SimScenario;
    use crate::strategies::StrategyConfig;

    fn one_run() -> Vec<(String, ClusterRun)> {
        let mut base = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        base.steps = 1;
        base.world = 2;
        let run = run_plan(&PlacementPlan::dedicated(2).unwrap(), &base, RTX3090_HBM).unwrap();
        vec![("cluster/w2/dedicated/None".to_string(), run)]
    }

    #[test]
    fn tables_cover_configs_and_gpus() {
        let runs = one_run();
        assert_eq!(summary_table(&runs).rows.len(), 1);
        assert_eq!(gpu_table(&runs).rows.len(), 2);
        let lines = jsonl(&runs);
        assert_eq!(lines.lines().count(), 2, "schema header + 1 config");
        assert!(lines.starts_with("{\"schema\":\"rlhf-mem-cluster-v1\"}"));
        assert!(lines.contains("\"key\":\"cluster/w2/dedicated/None\""));
        assert!(lines.contains("per_gpu"));
    }
}
