//! Rendering for `rlhf-mem lint`: the findings table and the per-GPU
//! static peak-interval table.

use crate::lint::LintReport;
use crate::report::TextTable;
use crate::util::bytes::fmt_gib;

/// The findings table (omitted when clean) followed by the static bound
/// intervals the abstract-interpretation pass computed.
pub fn render(report: &LintReport) -> String {
    let mut out = String::new();
    if report.findings.is_empty() {
        out.push_str("no findings\n");
    } else {
        let mut t = TextTable::new(&["Code", "Severity", "Span", "Message"]);
        for f in &report.findings {
            t.row(vec![
                f.code.to_string(),
                f.severity.name().to_string(),
                f.span.render(),
                f.message.clone(),
            ]);
        }
        out.push_str(&t.render());
    }
    if !report.bounds.is_empty() {
        out.push('\n');
        out.push_str("Static peak intervals (ideal live bytes, GiB):\n");
        let mut t = TextTable::new(&["GPU", "Phase", "Lower", "Upper"]);
        for g in &report.bounds {
            for b in &g.bounds {
                t.row(vec![
                    g.gpu.map_or_else(|| "-".to_string(), |x| x.to_string()),
                    b.phase.name().to_string(),
                    fmt_gib(b.lo),
                    fmt_gib(b.hi),
                ]);
            }
        }
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{lint_scenario, LintConfig};
    use crate::policy::EmptyCachePolicy;
    use crate::rlhf::sim::SimScenario;
    use crate::strategies::StrategyConfig;
    use crate::util::bytes::GIB;

    #[test]
    fn clean_and_dirty_renders() {
        let scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let clean = render(&lint_scenario(&scn, u64::MAX, &LintConfig::default()));
        assert!(clean.starts_with("no findings"), "{clean}");
        assert!(clean.contains("init"), "{clean}");
        let dirty = render(&lint_scenario(&scn, GIB, &LintConfig::default()));
        assert!(dirty.contains("RLHF030"), "{dirty}");
        assert!(dirty.contains("deny"), "{dirty}");
    }
}
