//! Report renderers: generic text tables and the paper-shaped outputs
//! (Table 1/2 rows, Figure 1 annotations).

pub mod paper;
pub mod table;

pub use paper::{render_rows, StrategyRow};
pub use table::TextTable;
