//! Report renderers: generic text tables, the paper-shaped outputs
//! (Table 1/2 rows, Figure 1 annotations), the cluster placement tables
//! behind `rlhf-mem cluster`, the per-algorithm comparison behind
//! `rlhf-mem algos`, and the model-sharing comparison behind
//! `rlhf-mem peft`.

pub mod algos;
pub mod cluster;
pub mod lint;
pub mod paper;
pub mod peft;
pub mod table;
pub mod telemetry;

pub use paper::{render_rows, StrategyRow};
pub use table::TextTable;
