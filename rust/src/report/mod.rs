//! Report renderers: generic text tables, the paper-shaped outputs
//! (Table 1/2 rows, Figure 1 annotations), and the cluster placement
//! tables behind `rlhf-mem cluster`.

pub mod cluster;
pub mod paper;
pub mod table;

pub use paper::{render_rows, StrategyRow};
pub use table::TextTable;
