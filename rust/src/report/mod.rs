//! Report renderers: generic text tables, the paper-shaped outputs
//! (Table 1/2 rows, Figure 1 annotations), the cluster placement tables
//! behind `rlhf-mem cluster`, the per-algorithm comparison behind
//! `rlhf-mem algos`, the model-sharing comparison behind
//! `rlhf-mem peft`, and the serving-cell table behind `rlhf-mem serve`.

pub mod algos;
pub mod cluster;
pub mod lint;
pub mod paper;
pub mod peft;
pub mod serve;
pub mod table;
pub mod telemetry;

pub use paper::{render_rows, StrategyRow};
pub use table::TextTable;
