//! Paper-shaped reports: Table 1, Table 2 and Figure 1 regeneration
//! helpers, including the paper's published values for side-by-side
//! comparison in EXPERIMENTS.md.

use super::table::TextTable;
use crate::experiment::{run_scenario, ExperimentResult};
use crate::policy::EmptyCachePolicy;
use crate::profiler::ProfileSummary;
use crate::rlhf::sim::SimScenario;
use crate::util::bytes::{fmt_gib_paper, GIB};

/// One rendered row of Table 1/2: the strategy label plus the six cells
/// (original reserved/frag/allocated, empty_cache reserved/frag).
#[derive(Debug, Clone)]
pub struct StrategyRow {
    pub strategy: String,
    pub original: ProfileSummary,
    pub with_empty_cache: ProfileSummary,
}

impl StrategyRow {
    /// Measure one row: the scenario runs twice, once with the policy the
    /// scenario carries (normally `Never`) and once with `AfterBoth`.
    pub fn measure(label: &str, scn: &SimScenario, capacity: u64) -> StrategyRow {
        let original = run_scenario(scn, capacity);
        let mut ec = scn.clone();
        ec.policy = EmptyCachePolicy::AfterBoth;
        let with_ec = run_scenario(&ec, capacity);
        StrategyRow {
            strategy: label.to_string(),
            original: original.summary,
            with_empty_cache: with_ec.summary,
        }
    }

    pub fn cells(&self) -> Vec<String> {
        let mut v = vec![self.strategy.clone()];
        v.extend(self.original.paper_cells());
        v.push(fmt_gib_paper(self.with_empty_cache.peak_reserved));
        v.push(fmt_gib_paper(self.with_empty_cache.frag));
        if self.original.oom || self.with_empty_cache.oom {
            v[1] = format!("{} (OOM)", v[1]);
        }
        v
    }
}

/// Assemble rows into the paper's table layout.
pub fn render_rows(title: &str, rows: &[StrategyRow]) -> String {
    let mut t = TextTable::new(&[
        "Strategy",
        "Reserved",
        "Frag.",
        "Allocated",
        "EC Reserved",
        "EC Frag.",
    ]);
    for r in rows {
        t.row(r.cells());
    }
    format!("== {title} ==\n{}", t.render())
}

/// The paper's published Table 1 values (GiB) for comparison output:
/// (framework, model, strategy) -> [reserved, frag, allocated, ec_reserved,
/// ec_frag].
pub fn paper_table1() -> Vec<(&'static str, &'static str, &'static str, [f64; 5])> {
    vec![
        ("DeepSpeed-Chat", "OPT", "None", [18.8, 0.2, 18.2, 19.4, 0.05]),
        ("DeepSpeed-Chat", "OPT", "ZeRO-1", [15.6, 0.1, 14.4, 15.9, 0.1]),
        ("DeepSpeed-Chat", "OPT", "ZeRO-2", [14.5, 0.6, 12.8, 14.3, 0.05]),
        ("DeepSpeed-Chat", "OPT", "ZeRO-3", [17.3, 3.7, 12.0, 13.7, 0.3]),
        ("DeepSpeed-Chat", "OPT", "ZeRO-3 + CPU Offloading", [15.4, 4.0, 9.8, 11.7, 0.3]),
        ("DeepSpeed-Chat", "OPT", "Gradient Checkpointing", [15.4, 0.6, 14.8, 15.4, 0.1]),
        ("DeepSpeed-Chat", "OPT", "All Enabled", [11.8, 6.2, 5.4, 5.9, 0.1]),
        ("ColossalChat", "OPT", "None", [17.5, 0.2, 17.0, 17.8, 0.4]),
        ("ColossalChat", "OPT", "ZeRO-3", [16.5, 0.5, 15.6, 16.4, 0.4]),
        ("ColossalChat", "OPT", "ZeRO-3 + CPU Offloading", [13.1, 0.4, 12.3, 13.1, 0.2]),
        ("ColossalChat", "OPT", "Gradient Checkpointng", [14.8, 0.7, 12.1, 12.5, 0.1]),
        ("ColossalChat", "GPT-2", "None", [22.9, 6.9, 14.0, 15.0, 0.1]),
        ("ColossalChat", "GPT-2", "ZeRO-3", [22.1, 7.6, 13.2, 16.6, 0.2]),
        ("ColossalChat", "GPT-2", "ZeRO-3 + CPU Offloading", [15.0, 2.6, 10.3, 11.5, 0.1]),
        ("ColossalChat", "GPT-2", "Gradient Checkpointing", [22.9, 6.9, 14.0, 15.0, 0.1]),
        ("ColossalChat", "GPT-2", "All Enabled", [15.0, 2.6, 10.3, 11.5, 0.1]),
    ]
}

/// The paper's published Table 2 values (A100 node).
pub fn paper_table2() -> Vec<(&'static str, &'static str, [f64; 5])> {
    vec![
        ("OPT-1.3b", "None", [46.4, 2.4, 43.5, 45.5, 0.3]),
        ("OPT-1.3b", "ZeRO-3", [46.4, 2.9, 43.2, 45.0, 0.3]),
        ("OPT-6.7b", "None", [53.4, 9.2, 31.4, 44.3, 0.1]),
        ("OPT-6.7b", "ZeRO-3", [55.3, 20.6, 25.6, 50.3, 0.8]),
        ("Llama-2-7b", "None", [56.2, 8.8, 39.2, 44.9, 0.2]),
        ("Llama-2-7b", "ZeRO-3", [60.5, 13.4, 32.3, 54.5, 1.7]),
    ]
}

/// Deviation of one measured row from the paper's published values, in
/// GiB: the maximum absolute difference over the capacity-scale columns —
/// reserved, allocated, and empty-cache reserved (`paper` columns 0, 2,
/// 3). The two fragmentation columns are excluded: they are an order of
/// magnitude smaller and noisier, so they would drown the gate in false
/// alarms without protecting anything the reserved columns don't.
///
/// This is what `table1`/`table2 --compare-paper --tolerance-gib T` gate
/// on: max deviation over every matched row > T ⇒ non-zero exit, so CI
/// can use the comparison as a regression guard.
pub fn row_deviation_gib(paper: &[f64; 5], row: &StrategyRow) -> f64 {
    let sim = [
        row.original.peak_reserved as f64 / GIB as f64,
        row.original.frag as f64 / GIB as f64,
        row.original.peak_allocated as f64 / GIB as f64,
        row.with_empty_cache.peak_reserved as f64 / GIB as f64,
        row.with_empty_cache.frag as f64 / GIB as f64,
    ];
    [0usize, 2, 3]
        .into_iter()
        .map(|i| (sim[i] - paper[i]).abs())
        .fold(0.0, f64::max)
}

/// Fold a row's deviation into a running `(worst, label)` maximum.
pub fn track_worst_deviation(
    worst: &mut (f64, String),
    paper: &[f64; 5],
    row: &StrategyRow,
    label: &str,
) {
    let dev = row_deviation_gib(paper, row);
    if dev > worst.0 {
        *worst = (dev, label.to_string());
    }
}

/// The shared `--compare-paper` gate: print the worst deviation, then fail
/// when nothing matched the published `table` (a gate that matched zero
/// rows is a broken gate, not a green one — label drift must fail loudly)
/// or when the worst deviation exceeds `tolerance` GiB.
pub fn gate_paper_deviation(
    table: &str,
    worst: &(f64, String),
    matched: usize,
    tolerance: f64,
) -> Result<(), String> {
    println!(
        "paper deviation: worst {:.2} GiB at {} over {matched} rows (tolerance {:.2} GiB)",
        worst.0, worst.1, tolerance
    );
    if matched == 0 {
        return Err(format!(
            "compare-paper matched no rows against the published {table} (row labels drifted?)"
        ));
    }
    if worst.0 > tolerance {
        return Err(format!(
            "deviation from published {table} exceeds tolerance: \
             {:.2} GiB at {} > {:.2} GiB (--tolerance-gib to adjust)",
            worst.0, worst.1, tolerance
        ));
    }
    Ok(())
}

/// Convenience used by benches: run + return both variants' results.
pub fn measure_row_full(
    label: &str,
    scn: &SimScenario,
    capacity: u64,
) -> (StrategyRow, ExperimentResult, ExperimentResult) {
    let original = run_scenario(scn, capacity);
    let mut ec = scn.clone();
    ec.policy = EmptyCachePolicy::AfterBoth;
    let with_ec = run_scenario(&ec, capacity);
    let row = StrategyRow {
        strategy: label.to_string(),
        original: original.summary.clone(),
        with_empty_cache: with_ec.summary.clone(),
    };
    (row, original, with_ec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values_complete() {
        assert_eq!(paper_table1().len(), 16);
        assert_eq!(paper_table2().len(), 6);
        for (_, _, _, v) in paper_table1() {
            assert!(v[0] > 0.0 && v[0] < 24.0, "3090 rows within 24 GiB");
        }
        for (_, _, v) in paper_table2() {
            assert!(v[0] > 24.0 && v[0] < 80.0, "A100 rows within 80 GiB");
        }
    }

    #[test]
    fn deviation_measures_reserved_scale_columns_only() {
        use crate::trace::PhaseKind;
        let mk = |reserved_gib: f64, frag_gib: f64, alloc_gib: f64| ProfileSummary {
            peak_reserved: (reserved_gib * GIB as f64) as u64,
            frag: (frag_gib * GIB as f64) as u64,
            peak_allocated: (alloc_gib * GIB as f64) as u64,
            frag_at_peak: 0,
            peak_phase: PhaseKind::TrainActor,
            total_time_us: 1.0,
            allocator_time_us: 0.1,
            empty_cache_calls: 0,
            empty_cache_released: 0,
            cuda_mallocs: 1,
            num_allocs: 1,
            num_cache_hits: 0,
            oom: false,
        };
        let paper = [18.8, 0.2, 18.2, 19.4, 0.05];
        // Exact match: zero deviation.
        let row = StrategyRow {
            strategy: "None".into(),
            original: mk(18.8, 0.2, 18.2),
            with_empty_cache: mk(19.4, 0.05, 18.2),
        };
        assert!(row_deviation_gib(&paper, &row) < 1e-6);
        // A 1.5 GiB reserved miss registers...
        let row2 = StrategyRow {
            strategy: "None".into(),
            original: mk(20.3, 0.2, 18.2),
            with_empty_cache: mk(19.4, 0.05, 18.2),
        };
        let dev = row_deviation_gib(&paper, &row2);
        assert!((dev - 1.5).abs() < 1e-6, "{dev}");
        // ...while a fragmentation-column miss alone does not gate.
        let row3 = StrategyRow {
            strategy: "None".into(),
            original: mk(18.8, 3.0, 18.2),
            with_empty_cache: mk(19.4, 0.05, 18.2),
        };
        assert!(row_deviation_gib(&paper, &row3) < 1e-6);
        // track_worst_deviation keeps the max.
        let mut worst = (0.0, String::new());
        track_worst_deviation(&mut worst, &paper, &row, "exact");
        track_worst_deviation(&mut worst, &paper, &row2, "off");
        assert_eq!(worst.1, "off");
        assert!((worst.0 - 1.5).abs() < 1e-6);
    }

    #[test]
    fn gate_trips_on_zero_matches_and_excess_deviation() {
        let ok = (0.5, "row".to_string());
        assert!(gate_paper_deviation("Table 1", &ok, 3, 2.0).is_ok());
        assert!(gate_paper_deviation("Table 1", &ok, 0, 2.0).is_err());
        let bad = (2.5, "worst/row".to_string());
        assert!(gate_paper_deviation("Table 2", &bad, 3, 2.0).is_err());
    }

    #[test]
    fn render_rows_shape() {
        use crate::trace::PhaseKind;
        let s = ProfileSummary {
            peak_reserved: 18 << 30,
            frag: 1 << 29,
            peak_allocated: 17 << 30,
            frag_at_peak: 1 << 29,
            peak_phase: PhaseKind::TrainActor,
            total_time_us: 1.0,
            allocator_time_us: 0.1,
            empty_cache_calls: 0,
            empty_cache_released: 0,
            cuda_mallocs: 5,
            num_allocs: 5,
            num_cache_hits: 0,
            oom: false,
        };
        let row = StrategyRow {
            strategy: "None".into(),
            original: s.clone(),
            with_empty_cache: s,
        };
        let out = render_rows("test", &[row]);
        assert!(out.contains("Strategy"));
        assert!(out.contains("None"));
        assert!(out.contains("18.0"));
    }
}
