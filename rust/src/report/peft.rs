//! Model-sharing comparison renderer: one row per strategy, one
//! `Resv`/`Time` column pair per sharing placement — the table behind
//! `rlhf-mem peft`, showing how much of the full-replica memory bill a
//! shared frozen backbone (LoRA adapters, hydra heads) forgives and what
//! it costs in modeled step time (the Efficient-RLHF trade-off).

use crate::report::table::TextTable;
use crate::rlhf::program::{Algo, Sharing};
use crate::sweep::CellResult;
use crate::util::bytes::fmt_gib_paper;

/// Build the comparison table from the `algo`'s sweep cells (one cell
/// per strategy × sharing; extra axes collapse onto the same row/column
/// slot, last writer wins; other algorithms' cells are skipped).
/// Strategies keep first-seen order; `sharings` fixes the column order.
/// Cells that OOMed render as `OOM`.
pub fn comparison_table(cells: &[CellResult], sharings: &[Sharing], algo: Algo) -> TextTable {
    let mut header: Vec<String> = vec!["Strategy".to_string()];
    for s in sharings {
        header.push(format!("{} Resv", s.name()));
        header.push(format!("{} ms", s.name()));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = TextTable::new(&header_refs);

    // strategy label -> per-sharing (reserved, time_us, oom) slots.
    let mut rows: Vec<(String, Vec<Option<(u64, f64, bool)>>)> = Vec::new();
    for cell in cells {
        if cell.algo != algo.name() {
            continue;
        }
        let Some(si) = sharings.iter().position(|s| s.name() == cell.sharing) else {
            continue;
        };
        let ri = match rows.iter().position(|(s, _)| *s == cell.strategy) {
            Some(i) => i,
            None => {
                rows.push((cell.strategy.clone(), vec![None; sharings.len()]));
                rows.len() - 1
            }
        };
        rows[ri].1[si] = Some((
            cell.summary.peak_reserved,
            cell.summary.total_time_us,
            cell.summary.oom,
        ));
    }

    for (strategy, slots) in rows {
        let mut out = vec![strategy];
        for slot in slots {
            match slot {
                Some((_, _, true)) => {
                    out.push("OOM".to_string());
                    out.push("OOM".to_string());
                }
                Some((reserved, time_us, false)) => {
                    out.push(fmt_gib_paper(reserved));
                    out.push(format!("{:.1}", time_us / 1000.0));
                }
                None => {
                    out.push("-".to_string());
                    out.push("-".to_string());
                }
            }
        }
        t.row(out);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;
    use crate::sweep::{SweepGrid, SweepRunner};

    #[test]
    fn table_has_one_row_per_strategy_and_columns_per_sharing() {
        let sharings = [Sharing::Separate, Sharing::Lora, Sharing::Hydra];
        let cells = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .policies([EmptyCachePolicy::Never])
            .sharings(sharings)
            .steps(1)
            .build()
            .unwrap();
        let report = SweepRunner::new(2).run(cells);
        let t = comparison_table(&report.cells, &sharings, Algo::Ppo);
        assert_eq!(t.header.len(), 1 + 2 * sharings.len());
        assert_eq!(t.header[1], "separate Resv");
        assert_eq!(t.header[6], "hydra ms");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "None");
        assert_eq!(t.rows[1][0], "ZeRO-3");
        // Every slot filled (no OOM on the paper testbed at 1 step).
        for row in &t.rows {
            assert!(row.iter().all(|c| c != "-" && c != "OOM"), "{row:?}");
        }
    }
}
