//! Serve-report renderer: one row per (discipline × page size ×
//! concurrency) cell of `rlhf-mem serve`, with throughput, tail latency
//! and KV-pool footprint columns.

use crate::report::table::TextTable;
use crate::serve::ServeCellResult;
use crate::util::bytes::fmt_gib_paper;

/// One row per cell, input (grid enumeration) order.
pub fn summary_table(cells: &[ServeCellResult]) -> TextTable {
    let mut t = TextTable::new(&[
        "Discipline",
        "Page",
        "Conc",
        "Done",
        "Fail",
        "Preempt",
        "tok/s",
        "p50 ms",
        "p99 ms",
        "Peak KV",
        "Frag",
        "Frag%",
    ]);
    for c in cells {
        let o = &c.outcome;
        t.row(vec![
            c.discipline.to_string(),
            if c.page_tokens == 0 {
                "-".to_string()
            } else {
                c.page_tokens.to_string()
            },
            c.max_concurrency.to_string(),
            o.completed.to_string(),
            o.failed.to_string(),
            o.preempted.to_string(),
            format!("{:.1}", o.throughput_tok_s()),
            format!("{:.1}", o.p50_latency_us as f64 / 1e3),
            format!("{:.1}", o.p99_latency_us as f64 / 1e3),
            fmt_gib_paper(c.kv_peak_held_bytes()),
            fmt_gib_paper(c.kv_frag_bytes()),
            format!("{:.1}", o.frag_frac() * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::ModelArch;
    use crate::rlhf::GpuSpec;
    use crate::serve::{run_cells, KvDiscipline, ServeScenario, ServeStream};

    #[test]
    fn table_covers_every_cell() {
        let stream = ServeStream {
            requests: 8,
            mean_interarrival_us: 5_000,
            prompt_len: 64,
            prompt_jitter: 16,
            max_new: 32,
            response_jitter: 8,
            seed: 7,
        };
        let cells = vec![
            ServeScenario {
                arch: ModelArch::opt_1_3b(),
                gpu_name: "rtx3090".into(),
                gpu: GpuSpec::rtx3090(),
                kv_capacity_bytes: 1 << 30,
                discipline: KvDiscipline::Paged { page_tokens: 16 },
                max_concurrency: 4,
                stream: stream.clone(),
            },
            ServeScenario {
                arch: ModelArch::opt_1_3b(),
                gpu_name: "rtx3090".into(),
                gpu: GpuSpec::rtx3090(),
                kv_capacity_bytes: 1 << 30,
                discipline: KvDiscipline::BestFit,
                max_concurrency: 4,
                stream,
            },
        ];
        let report = run_cells(&cells, 2);
        let t = summary_table(&report.cells);
        assert_eq!(t.rows.len(), 2);
        // Best-fit has no page size.
        assert_eq!(t.rows[1][1], "-");
    }
}
