//! Plain-text table renderer (paper-style rows in the terminal) plus CSV.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct TextTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = w
            .iter()
            .map(|n| "-".repeat(n + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["Strategy", "Reserved", "Frag."]);
        t.row(vec!["None".into(), "18.8".into(), "0.2".into()]);
        t.row(vec!["ZeRO-3 + CPU Offloading".into(), "15.4".into(), "4.0".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All rows the same width.
        assert_eq!(lines[0].chars().count(), lines[2].chars().count());
        assert!(lines[3].contains("ZeRO-3 + CPU Offloading"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = TextTable::new(&["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }
}
