//! Renderer for the run-telemetry ledger
//! ([`Telemetry`](crate::obs::Telemetry)): deterministic counters and
//! wall-clock spans as one table, clearly separated — counters are the
//! values that also land in JSONL footers, wall spans never leave the
//! terminal.

use crate::obs::Telemetry;
use crate::report::table::TextTable;

/// The telemetry ledger as a `kind | name | value` table.
pub fn telemetry_table(t: &Telemetry) -> TextTable {
    let mut table = TextTable::new(&["kind", "name", "value"]);
    for (name, v) in t.counters() {
        table.row(vec![
            "counter".to_string(),
            name.clone(),
            v.to_string(),
        ]);
    }
    for (name, secs) in t.walls() {
        table.row(vec![
            "wall".to_string(),
            name.clone(),
            format!("{secs:.2}s"),
        ]);
    }
    table
}

/// Render the ledger with its header line (the CLI's `--telemetry` view).
pub fn render_telemetry(t: &Telemetry) -> String {
    format!("telemetry:\n{}", telemetry_table(t).render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_lists_counters_then_walls() {
        let mut t = Telemetry::new();
        t.add("cells", 12);
        t.add("oom_cells", 2);
        t.wall("sweep", 0.5);
        let table = telemetry_table(&t);
        assert_eq!(table.rows.len(), 3);
        assert_eq!(table.rows[0][1], "cells");
        assert_eq!(table.rows[2][0], "wall");
        assert!(render_telemetry(&t).contains("telemetry:"));
    }
}
