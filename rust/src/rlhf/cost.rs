//! Compute-time cost model for RLHF phases.
//!
//! The paper's time claim (E8: `empty_cache()` adds ~2% end-to-end) is a
//! *ratio* of allocator/driver latency to compute latency, so phase
//! durations need to be right to within a factor of ~2, not exact. The
//! model is the standard roofline: matmul-bound phases at an effective
//! throughput, decode at weight-streaming bandwidth, ZeRO collectives at
//! interconnect bandwidth.

use crate::mem::{DType, ParamInventory};

/// Hardware envelope of one simulated GPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Effective (MFU-adjusted) half-precision throughput, FLOP/s.
    pub flops: f64,
    /// Effective HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// Inter-GPU (PCIe/NVLink) bandwidth per rank, B/s.
    pub link_bw: f64,
}

impl GpuSpec {
    /// RTX 3090 @ ~30% MFU: 71 TFLOPS fp16 -> 21 effective; 936 GB/s HBM
    /// @75%; PCIe 4.0 x16 ~12 GB/s effective.
    pub fn rtx3090() -> Self {
        GpuSpec {
            flops: 21e12,
            hbm_bw: 700e9,
            link_bw: 12e9,
        }
    }

    /// A100-80G @ ~35% MFU: 312 TFLOPS bf16 -> 109 effective; 2 TB/s HBM
    /// @75%; NVLink ~200 GB/s effective.
    pub fn a100_80g() -> Self {
        GpuSpec {
            flops: 109e12,
            hbm_bw: 1.5e12,
            link_bw: 200e9,
        }
    }

    /// Look up a preset by CLI name — the single source of truth for every
    /// subcommand's `--gpu` flag (`rtx3090`, `a100` / `a100-80g`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "rtx3090" => Some(Self::rtx3090()),
            "a100" | "a100-80g" => Some(Self::a100_80g()),
            _ => None,
        }
    }
}

/// Phase-duration calculator for one model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub gpu: GpuSpec,
    /// Total parameters of the model under evaluation.
    pub params: f64,
    /// Bytes of the fp16 replica (for decode weight-streaming).
    pub param_bytes: f64,
}

impl CostModel {
    pub fn for_inventory(inv: &ParamInventory, gpu: GpuSpec) -> Self {
        CostModel {
            gpu,
            params: inv.total_params() as f64,
            param_bytes: inv.total_bytes(DType::F16) as f64,
        }
    }

    /// Microseconds for a full-sequence forward over `tokens` tokens
    /// (prefill / scoring passes): 2·P FLOPs per token, compute-bound.
    pub fn forward_us(&self, tokens: u64) -> f64 {
        2.0 * self.params * tokens as f64 / self.gpu.flops * 1e6
    }

    /// Microseconds for ONE autoregressive decode step at batch `b`:
    /// memory-bound on streaming the weights once, plus the (small)
    /// per-token matmul work.
    pub fn decode_step_us(&self, batch: u64) -> f64 {
        let bw_bound = self.param_bytes / self.gpu.hbm_bw * 1e6;
        let flop_bound = 2.0 * self.params * batch as f64 / self.gpu.flops * 1e6;
        bw_bound.max(flop_bound)
    }

    /// Microseconds for a training step over `tokens` tokens: fwd + bwd ≈
    /// 3× forward FLOPs (6·P per token).
    pub fn train_us(&self, tokens: u64) -> f64 {
        6.0 * self.params * tokens as f64 / self.gpu.flops * 1e6
    }

    /// Microseconds for an all-gather of `bytes` across `world` ranks
    /// (ring: each rank receives bytes·(w−1)/w).
    pub fn allgather_us(&self, bytes: u64, world: u64) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        bytes as f64 * (world - 1) as f64 / world as f64 / self.gpu.link_bw * 1e6
    }

    /// Reduce-scatter cost (same wire volume as all-gather).
    pub fn reduce_scatter_us(&self, bytes: u64, world: u64) -> f64 {
        self.allgather_us(bytes, world)
    }

    /// Host transfer (offload staging) cost.
    pub fn host_copy_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.gpu.link_bw * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::ModelArch;

    fn opt13b() -> CostModel {
        let inv = ParamInventory::build(&ModelArch::opt_1_3b());
        CostModel::for_inventory(&inv, GpuSpec::rtx3090())
    }

    #[test]
    fn decode_is_bandwidth_bound_at_small_batch() {
        let c = opt13b();
        // 2.6 GB / 700 GB/s ≈ 3.7 ms.
        let us = c.decode_step_us(2);
        assert!((2_000.0..6_000.0).contains(&us), "{us}");
        // Large batch flips to compute bound.
        assert!(c.decode_step_us(4096) > c.decode_step_us(2));
    }

    #[test]
    fn train_is_3x_forward() {
        let c = opt13b();
        assert!((c.train_us(1024) / c.forward_us(1024) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn generation_dominates_step_time() {
        // Sanity for the paper's phase structure: 256 decode steps at bs=2
        // dwarf one 512-token forward.
        let c = opt13b();
        let gen = 256.0 * c.decode_step_us(2);
        let eval = c.forward_us(2 * 512);
        assert!(gen > 3.0 * eval, "gen {gen} vs eval {eval}");
    }

    #[test]
    fn allgather_scales_with_world() {
        let c = opt13b();
        let one = c.allgather_us(1 << 30, 1);
        assert_eq!(one, 0.0);
        let four = c.allgather_us(1 << 30, 4);
        let eight = c.allgather_us(1 << 30, 8);
        assert!(four > 0.0 && eight > four);
    }
}
