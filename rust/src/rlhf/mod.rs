//! The RLHF PPO engine: the four-model cast, the phase-level allocation
//! simulator used by the memory study, the compute-time cost model, and
//! (via `runtime/`) the real small-scale PPO training loop.

pub mod cost;
pub mod models;
pub mod program;
#[cfg(feature = "pjrt")]
pub mod real;
pub mod sim;

pub use cost::{CostModel, GpuSpec};
pub use models::{RlhfModelSet, Role, RoleSet};
pub use program::{Algo, PhaseProgram};
pub use sim::{build_trace, ScenarioMode, SimScenario};
