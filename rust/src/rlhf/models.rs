//! The four-model cast of RLHF stage 3 (paper §2.1): actor + frozen
//! reference sharing one architecture, critic + frozen reward sharing
//! another (critic/reward carry a scalar value head).

use crate::mem::{ModelArch, ParamInventory};

/// Role of a model in the PPO stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    /// The policy being trained (initialized from the SFT reference).
    Actor,
    /// Frozen SFT model for the KL penalty.
    Reference,
    /// Trained value function (initialized from the reward model).
    Critic,
    /// Frozen reward model.
    Reward,
}

impl Role {
    pub const ALL: [Role; 4] = [Role::Actor, Role::Reference, Role::Critic, Role::Reward];

    pub fn name(self) -> &'static str {
        match self {
            Role::Actor => "actor",
            Role::Reference => "reference",
            Role::Critic => "critic",
            Role::Reward => "reward",
        }
    }

    pub fn is_trainable(self) -> bool {
        matches!(self, Role::Actor | Role::Critic)
    }

    pub fn has_value_head(self) -> bool {
        matches!(self, Role::Critic | Role::Reward)
    }
}

/// A set of [`Role`]s — which of the four RLHF models a simulated GPU
/// hosts. The classic symmetric data-parallel replica is [`RoleSet::ALL`];
/// cluster placement plans assign subsets per GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RoleSet(u8);

impl RoleSet {
    pub const EMPTY: RoleSet = RoleSet(0);
    pub const ALL: RoleSet = RoleSet(0b1111);

    fn bit(role: Role) -> u8 {
        match role {
            Role::Actor => 1,
            Role::Reference => 2,
            Role::Critic => 4,
            Role::Reward => 8,
        }
    }

    /// The set holding exactly `roles`.
    pub fn of(roles: &[Role]) -> RoleSet {
        roles.iter().fold(RoleSet::EMPTY, |s, &r| s.with(r))
    }

    #[must_use]
    pub fn with(self, role: Role) -> RoleSet {
        RoleSet(self.0 | Self::bit(role))
    }

    pub fn contains(self, role: Role) -> bool {
        self.0 & Self::bit(role) != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn is_subset_of(self, other: RoleSet) -> bool {
        self.0 & !other.0 == 0
    }

    /// Roles in both sets (e.g. hosted roles ∩ an algorithm's cast).
    #[must_use]
    pub fn intersect(self, other: RoleSet) -> RoleSet {
        RoleSet(self.0 & other.0)
    }

    /// Member roles in [`Role::ALL`] order.
    pub fn iter(self) -> impl Iterator<Item = Role> {
        Role::ALL.into_iter().filter(move |&r| self.contains(r))
    }

    /// `actor+critic`-style display label (`-` for the empty set).
    pub fn label(self) -> String {
        if self.is_empty() {
            return "-".to_string();
        }
        self.iter().map(Role::name).collect::<Vec<_>>().join("+")
    }
}

/// The model pairing of one experiment.
#[derive(Debug, Clone)]
pub struct RlhfModelSet {
    /// Actor & reference architecture.
    pub policy_arch: ModelArch,
    /// Critic & reward architecture.
    pub value_arch: ModelArch,
}

impl RlhfModelSet {
    /// Paper's OPT setting: actor/ref OPT-1.3b, critic/reward OPT-350m.
    pub fn opt() -> Self {
        RlhfModelSet {
            policy_arch: ModelArch::opt_1_3b(),
            value_arch: ModelArch::opt_350m(),
        }
    }

    /// Paper's GPT-2 setting: actor/ref GPT2-xl, critic/reward GPT2-medium.
    pub fn gpt2() -> Self {
        RlhfModelSet {
            policy_arch: ModelArch::gpt2_xl(),
            value_arch: ModelArch::gpt2_medium(),
        }
    }

    /// Table-2 settings: same arch for both pairs scaled up.
    pub fn uniform(arch: ModelArch) -> Self {
        RlhfModelSet {
            policy_arch: arch.clone(),
            value_arch: arch,
        }
    }

    /// Tiny set for real end-to-end training.
    pub fn nano() -> Self {
        RlhfModelSet {
            policy_arch: ModelArch::opt_nano(),
            value_arch: ModelArch::opt_nano(),
        }
    }

    pub fn arch_for(&self, role: Role) -> &ModelArch {
        match role {
            Role::Actor | Role::Reference => &self.policy_arch,
            Role::Critic | Role::Reward => &self.value_arch,
        }
    }

    /// Parameter inventory for a role (value head included where present).
    pub fn inventory_for(&self, role: Role) -> ParamInventory {
        let arch = self.arch_for(role);
        if role.has_value_head() {
            ParamInventory::build_with_value_head(arch)
        } else {
            ParamInventory::build(arch)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roles() {
        assert!(Role::Actor.is_trainable());
        assert!(!Role::Reference.is_trainable());
        assert!(Role::Critic.has_value_head());
        assert!(!Role::Actor.has_value_head());
        assert_eq!(Role::ALL.len(), 4);
    }

    #[test]
    fn opt_set_shapes() {
        let set = RlhfModelSet::opt();
        assert_eq!(set.arch_for(Role::Actor).name, "opt-1.3b");
        assert_eq!(set.arch_for(Role::Reference).name, "opt-1.3b");
        assert_eq!(set.arch_for(Role::Reward).name, "opt-350m");
        // Critic has one more tensor (v_head) than reward-arch baseline.
        let critic = set.inventory_for(Role::Critic);
        assert!(critic.tensors.iter().any(|t| t.name == "v_head"));
        let actor = set.inventory_for(Role::Actor);
        assert!(!actor.tensors.iter().any(|t| t.name == "v_head"));
    }

    #[test]
    fn role_sets() {
        let scorers = RoleSet::of(&[Role::Reference, Role::Reward]);
        assert_eq!(scorers.len(), 2);
        assert!(scorers.contains(Role::Reference));
        assert!(!scorers.contains(Role::Actor));
        assert!(scorers.is_subset_of(RoleSet::ALL));
        assert!(!RoleSet::ALL.is_subset_of(scorers));
        assert_eq!(RoleSet::ALL.intersect(scorers), scorers);
        assert_eq!(
            scorers.intersect(RoleSet::of(&[Role::Actor, Role::Reference])),
            RoleSet::of(&[Role::Reference])
        );
        assert!(RoleSet::EMPTY.is_empty());
        assert_eq!(RoleSet::ALL.len(), 4);
        assert_eq!(scorers.label(), "reference+reward");
        assert_eq!(RoleSet::EMPTY.label(), "-");
        assert_eq!(
            RoleSet::ALL.iter().collect::<Vec<_>>(),
            Role::ALL.to_vec()
        );
    }

    #[test]
    fn uniform_set_for_table2() {
        let set = RlhfModelSet::uniform(ModelArch::llama2_7b());
        assert_eq!(set.policy_arch.name, set.value_arch.name);
    }
}
