//! The declarative **PhaseProgram IR**: the RLHF phase pipeline as data.
//!
//! The paper's central finding is that RLHF memory blowup comes from its
//! *phase structure* — generation, scoring inferences and training updates
//! churning differently-shaped allocations through one caching allocator.
//! This module makes that structure a first-class value: a
//! [`SimScenario`] *compiles* to an ordered list of [`PhaseNode`]s given
//! its algorithm, scenario mode and hosted-role placement, and the
//! emitter in [`crate::rlhf::sim`] is a thin interpreter over the
//! program. Every other consumer of phase knowledge — the coordinator's
//! step-time aggregation, the profiler's per-phase attribution, the
//! trace-invariant checker — reads the same compiled program instead of
//! re-deriving the pipeline privately.
//!
//! Compile pipeline:
//!
//! ```text
//! SimScenario { algo, mode, roles, framework, strategy, ... }
//!        │ PhaseProgram::compile
//!        ▼
//! PhaseProgram { active_roles, nodes: [PhaseNode...] }   (one PPO step)
//!        │ sim::build_trace_with_program (interpreter)
//!        ▼
//! Trace { Init ─ [node₁ … nodeₙ ─ StepEnd]* }
//! ```
//!
//! On top of the IR sits the **algorithm axis** ([`Algo`]): PPO's
//! four-model cast, GRPO's and ReMax's critic-free variants, and DPO's
//! reference-only preference pipeline each compile to a different node
//! list — exactly the axis the memory study sweeps.
//!
//! Orthogonal to both is the **model-sharing axis** ([`Sharing`]): how the
//! cast maps onto stored parameters (separate replicas, LoRA pairs sharing
//! frozen backbones, Hydra's single trunk). Sharing leaves the compiled
//! node list untouched — it reshapes the tensor lists the emitter
//! allocates for each role.

use crate::mem::DType;
use crate::rlhf::models::{Role, RoleSet};
use crate::rlhf::sim::{ScenarioMode, SimScenario};
use crate::trace::PhaseKind;

/// Which RLHF algorithm the stage-3 pipeline runs — decides which of the
/// four models exist and which phases a step schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// PPO with a learned critic — the paper's four-model cast.
    Ppo,
    /// Group-relative PPO: no critic model or value update; advantages
    /// are reward deviations from the rollout group's baseline.
    Grpo,
    /// ReMax: no critic; the advantage baseline is the reward of an
    /// extra *greedy* rollout, so generation churn happens twice.
    Remax,
    /// Direct preference optimization: offline preference pairs, the
    /// frozen reference as the only scorer, one preference-loss update.
    Dpo,
}

impl Algo {
    pub const ALL: [Algo; 4] = [Algo::Ppo, Algo::Grpo, Algo::Remax, Algo::Dpo];

    /// Stable name used in sweep-cell keys, JSON reports and configs.
    pub fn name(self) -> &'static str {
        match self {
            Algo::Ppo => "ppo",
            Algo::Grpo => "grpo",
            Algo::Remax => "remax",
            Algo::Dpo => "dpo",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }

    /// Parse a comma-separated algorithm list (CLI flags), with the
    /// shared unknown-name error message.
    pub fn parse_list(s: &str) -> Result<Vec<Algo>, String> {
        s.split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(|n| {
                Algo::by_name(n).ok_or_else(|| {
                    format!("unknown algo '{n}' (valid: {})", Algo::known_names())
                })
            })
            .collect()
    }

    /// Comma-separated valid names (for CLI/config error messages).
    pub fn known_names() -> String {
        Self::ALL
            .iter()
            .map(|a| a.name())
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// The model cast this algorithm instantiates. Hosted roles outside
    /// the cast never allocate engine state.
    pub fn roles(self) -> RoleSet {
        match self {
            Algo::Ppo => RoleSet::ALL,
            Algo::Grpo | Algo::Remax => {
                RoleSet::of(&[Role::Actor, Role::Reference, Role::Reward])
            }
            Algo::Dpo => RoleSet::of(&[Role::Actor, Role::Reference]),
        }
    }

    /// Does the algorithm collect experience by autoregressive rollout
    /// (vs loading offline preference pairs)?
    pub fn generates(self) -> bool {
        self != Algo::Dpo
    }

    /// The advantage estimator the full pipeline schedules, if any.
    pub fn advantage(self) -> Option<AdvantageKind> {
        match self {
            Algo::Ppo => Some(AdvantageKind::Gae),
            Algo::Grpo => Some(AdvantageKind::GroupRelative),
            Algo::Remax => Some(AdvantageKind::GreedyBaseline),
            Algo::Dpo => None,
        }
    }

    /// The actor update's loss shape.
    pub fn policy_loss(self) -> LossKind {
        match self {
            Algo::Dpo => LossKind::Preference,
            _ => LossKind::PpoClip,
        }
    }

    /// Does the pipeline score/train chosen+rejected sequence pairs
    /// (doubling the effective batch of those phases)?
    pub fn pairs(self) -> bool {
        self == Algo::Dpo
    }
}

/// How the cast shares parameter storage — the parameter-efficient
/// placements of Efficient-RLHF (arXiv 2309.00754) and PERL (arXiv
/// 2403.10704). Sharing never changes *which* phases compile (the
/// [`PhaseProgram`] is placement-invariant); it changes the tensor lists
/// the emitter allocates per role: who owns a backbone, who rides a
/// frozen one, and whether optimizer/gradient state is sized off adapter
/// parameters only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sharing {
    /// Every role loads its own full replica — the paper's testbed and
    /// the bit-identical default path.
    Separate,
    /// LoRA-PPO: actor/reference share one frozen policy backbone and
    /// critic/reward one frozen value backbone; the trainable roles train
    /// LoRA adapters (plus the critic's value head) instead of the base
    /// weights, so optimizer and gradient state shrink to adapter size.
    Lora,
    /// Hydra-PPO: a *single* frozen policy backbone hosts all four roles;
    /// value roles become scalar heads over the shared trunk. The actor
    /// trains the shared adapter set, the critic only its value head.
    Hydra,
    /// Frozen weight sharing without adapter-only training: each pair
    /// shares one stored base replica (no duplicate frozen copies), but
    /// the trainable roles keep their [`Sharing::Separate`] training
    /// state (actor LoRA-or-full, critic full fine-tune).
    FrozenShared,
    /// PERL (arXiv 2403.10704): reward-model-side LoRA only. Critic and
    /// reward share one frozen value backbone and the critic trains LoRA
    /// adapters plus its value head; the actor and reference stay
    /// separate full replicas with the actor's [`Sharing::Separate`]
    /// training state (LoRA-or-full per the strategy preset).
    Perl,
}

impl Sharing {
    pub const ALL: [Sharing; 5] = [
        Sharing::Separate,
        Sharing::Lora,
        Sharing::Hydra,
        Sharing::FrozenShared,
        Sharing::Perl,
    ];

    /// Stable name used in sweep-cell keys, JSON reports and configs.
    pub fn name(self) -> &'static str {
        match self {
            Sharing::Separate => "separate",
            Sharing::Lora => "lora",
            Sharing::Hydra => "hydra",
            Sharing::FrozenShared => "frozen-shared",
            Sharing::Perl => "perl",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|x| x.name() == s)
    }

    /// Parse a comma-separated sharing list (CLI flags), with the shared
    /// unknown-name error message.
    pub fn parse_list(s: &str) -> Result<Vec<Sharing>, String> {
        s.split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(|n| {
                Sharing::by_name(n).ok_or_else(|| {
                    format!("unknown sharing '{n}' (valid: {})", Sharing::known_names())
                })
            })
            .collect()
    }

    /// Comma-separated valid names (for CLI/config error messages).
    pub fn known_names() -> String {
        Self::ALL
            .iter()
            .map(|x| x.name())
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// The roles that share one stored backbone with `role`. The group's
    /// *owner* — the first group member (in [`Role::ALL`] order) active on
    /// a GPU — allocates the backbone; the other members allocate only
    /// their private head tensors.
    pub fn group_of(self, role: Role) -> RoleSet {
        match self {
            Sharing::Separate => RoleSet::of(&[role]),
            Sharing::Lora | Sharing::FrozenShared => match role {
                Role::Actor | Role::Reference => {
                    RoleSet::of(&[Role::Actor, Role::Reference])
                }
                Role::Critic | Role::Reward => RoleSet::of(&[Role::Critic, Role::Reward]),
            },
            Sharing::Hydra => RoleSet::ALL,
            // PERL shares the *value* side only: the policy-side roles
            // keep separate full replicas.
            Sharing::Perl => match role {
                Role::Actor | Role::Reference => RoleSet::of(&[role]),
                Role::Critic | Role::Reward => RoleSet::of(&[Role::Critic, Role::Reward]),
            },
        }
    }

    /// Do base weights stay frozen for *every* trainable role (training
    /// touches adapters/heads only)? Frozen backbones are never
    /// ZeRO-partitioned — there is nothing to re-materialize per step —
    /// and the hybrid engine's second inference copy shrinks to adapter
    /// size. Per-role placements (PERL) freeze only part of the cast;
    /// use [`Sharing::frozen_backbone_for`] wherever a specific role's
    /// backbone is sized.
    pub fn frozen_backbone(self) -> bool {
        matches!(self, Sharing::Lora | Sharing::Hydra)
    }

    /// Is `role`'s base frozen under this placement? Identical to
    /// [`Sharing::frozen_backbone`] for the uniform placements; PERL
    /// freezes the value-side backbone (critic/reward) while the actor
    /// and reference keep their separate full-training replicas.
    pub fn frozen_backbone_for(self, role: Role) -> bool {
        match self {
            Sharing::Lora | Sharing::Hydra => true,
            Sharing::Perl => role.has_value_head(),
            Sharing::Separate | Sharing::FrozenShared => false,
        }
    }

    /// Does the sharing collapse the cast onto the policy architecture
    /// (Hydra's one-trunk placement)? When true, the value roles are
    /// heads over the *policy* backbone instead of separate value models.
    pub fn unifies_architectures(self) -> bool {
        self == Sharing::Hydra
    }
}

/// Advantage/return computation scheduled between scoring and training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdvantageKind {
    /// Generalized advantage estimation over critic values: per-token
    /// advantages *and* returns persist as experience.
    Gae,
    /// Group-relative baseline: per-sequence group statistics plus
    /// per-token advantages (no returns — there is no value target).
    GroupRelative,
    /// ReMax greedy baseline: per-token advantages against the greedy
    /// rollout's rewards (persisted by the doubled reward pass).
    GreedyBaseline,
}

/// Loss workspace shape of a training phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Clipped policy surrogate (PPO/GRPO/ReMax): saved logits plus
    /// logprob/ratio/surrogate/KL temporaries.
    PpoClip,
    /// Critic value loss: value/clip/loss temporaries only.
    ValueLoss,
    /// DPO preference loss: saved logits over the pair batch plus
    /// margin/sigmoid temporaries.
    Preference,
}

/// One persisted experience tensor of a [`PhaseBody::LoadExperience`]
/// node, sized against the framework's rollout batch and full sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpTensor {
    /// Token ids over the full sequence (i64).
    SeqTokens,
    /// Attention mask over the full sequence (i64).
    Mask,
    /// One f32 per token (logprobs, values, advantages, returns).
    PerTokenF32,
    /// One f32 per sequence (scalar rewards).
    PerSeqF32,
}

impl ExpTensor {
    pub fn bytes(self, batch: u64, seq: u64) -> u64 {
        match self {
            ExpTensor::SeqTokens | ExpTensor::Mask => batch * seq * DType::I64.bytes(),
            ExpTensor::PerTokenF32 => batch * seq * 4,
            ExpTensor::PerSeqF32 => batch * 4,
        }
    }
}

/// What one node of the pipeline does — the tensor lifetimes it implies
/// (generation KV churn, scoring logits, experience buffers) are realized
/// by the interpreter in [`crate::rlhf::sim`].
#[derive(Debug, Clone, PartialEq)]
pub enum PhaseBody {
    /// Actor autoregressive rollout (prefill + decode KV churn, per-step
    /// logits, persisted sequences + masks). `greedy_baseline` adds
    /// ReMax's second argmax rollout and its persisted sequences + mask.
    Generation { greedy_baseline: bool },
    /// Sequences + attention masks received from the actor's GPU — what a
    /// scorer-only GPU of a placement plan holds instead of generating.
    /// `greedy_baseline` adds ReMax's shipped greedy-rollout set.
    RemoteSequences { greedy_baseline: bool },
    /// Experience loaded instead of generated (pre-collected modes, DPO
    /// preference pairs), sized by the tensor list.
    LoadExperience { tensors: Vec<ExpTensor> },
    /// Scoring forward of `role` over the step's sequences; persists that
    /// role's experience output (logprobs / rewards / values). `pairs`
    /// doubles the scored batch and the persisted outputs (DPO's
    /// chosen+rejected halves; ReMax's greedy-baseline rollout at the
    /// reward model).
    Infer { role: Role, pairs: bool },
    /// Advantage/return computation on experience tensors (runs inside
    /// the enclosing phase — no phase mark of its own).
    Advantages { kind: AdvantageKind },
    /// Training update of `role`: forward saving activations, loss,
    /// backward, optimizer step, plus the ZeRO collective hooks
    /// (prefetch-bucketed gathers, reduce-scatter charges).
    Train { role: Role, loss: LossKind, pairs: bool },
    /// Free the step's experience tensors (no phase mark).
    FreeExperience,
}

/// One node of the compiled pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseNode {
    /// Phase mark emitted when the node starts; `None` for bodies that
    /// run inside the current phase (advantages, experience bookkeeping).
    /// Marked nodes are also where the `empty_cache` policy applies.
    pub kind: Option<PhaseKind>,
    /// Roles whose hosting this node required at compile time (kept for
    /// analysis/diagnostics; compilation already filtered unhosted nodes).
    pub requires: RoleSet,
    pub body: PhaseBody,
}

/// One PPO step's phase pipeline, compiled from a [`SimScenario`]'s
/// algorithm × mode × placement. The trace a scenario emits is
/// `Init ─ [nodes… ─ StepEnd]*steps`.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseProgram {
    pub algo: Algo,
    /// The models this GPU instantiates: hosted roles ∩ algorithm cast.
    pub active_roles: RoleSet,
    /// Execution order of one step.
    pub nodes: Vec<PhaseNode>,
}

impl PhaseProgram {
    /// Compile `scn`'s pipeline: which phases run on this GPU, in paper
    /// order, given the algorithm's cast, the scenario mode and the
    /// hosted-role placement.
    pub fn compile(scn: &SimScenario) -> PhaseProgram {
        let algo = scn.algo;
        let active = scn.roles.intersect(algo.roles());
        let hosts = |r: Role| active.contains(r);
        let mark = |kind: PhaseKind, requires: RoleSet, body: PhaseBody| PhaseNode {
            kind: Some(kind),
            requires,
            body,
        };
        let silent = |requires: RoleSet, body: PhaseBody| PhaseNode {
            kind: None,
            requires,
            body,
        };

        let mut nodes: Vec<PhaseNode> = Vec::new();
        match scn.mode {
            ScenarioMode::Full => {
                if !algo.generates() {
                    // DPO: offline preference pairs replace the rollout.
                    nodes.push(silent(
                        RoleSet::EMPTY,
                        PhaseBody::LoadExperience {
                            tensors: vec![
                                ExpTensor::SeqTokens,
                                ExpTensor::Mask,
                                ExpTensor::SeqTokens,
                                ExpTensor::Mask,
                            ],
                        },
                    ));
                } else if hosts(Role::Actor) {
                    nodes.push(mark(
                        PhaseKind::Generation,
                        RoleSet::of(&[Role::Actor]),
                        PhaseBody::Generation {
                            greedy_baseline: algo == Algo::Remax,
                        },
                    ));
                    nodes.push(mark(
                        PhaseKind::InferActor,
                        RoleSet::of(&[Role::Actor]),
                        PhaseBody::Infer {
                            role: Role::Actor,
                            pairs: false,
                        },
                    ));
                } else {
                    nodes.push(silent(
                        RoleSet::EMPTY,
                        PhaseBody::RemoteSequences {
                            greedy_baseline: algo == Algo::Remax,
                        },
                    ));
                }
                for role in [Role::Reference, Role::Reward, Role::Critic] {
                    if hosts(role) {
                        // A second sequence set doubles a scorer's pass:
                        // DPO's rejected half everywhere, and ReMax's
                        // greedy-baseline rollout at the reward model
                        // (whose scores *are* the baseline).
                        let pairs = match role {
                            Role::Reward => algo == Algo::Remax,
                            _ => algo.pairs(),
                        };
                        nodes.push(mark(
                            Self::infer_kind(role),
                            RoleSet::of(&[role]),
                            PhaseBody::Infer { role, pairs },
                        ));
                    }
                }
                if let Some(kind) = algo.advantage() {
                    if hosts(Role::Actor) || hosts(Role::Critic) {
                        nodes.push(silent(
                            RoleSet::of(&[Role::Actor, Role::Critic]),
                            PhaseBody::Advantages { kind },
                        ));
                    }
                }
                if hosts(Role::Actor) {
                    nodes.push(mark(
                        PhaseKind::TrainActor,
                        RoleSet::of(&[Role::Actor]),
                        PhaseBody::Train {
                            role: Role::Actor,
                            loss: algo.policy_loss(),
                            pairs: algo.pairs(),
                        },
                    ));
                }
                if hosts(Role::Critic) {
                    nodes.push(mark(
                        PhaseKind::TrainCritic,
                        RoleSet::of(&[Role::Critic]),
                        PhaseBody::Train {
                            role: Role::Critic,
                            loss: LossKind::ValueLoss,
                            pairs: false,
                        },
                    ));
                }
            }
            ScenarioMode::TrainBothPrecollected | ScenarioMode::TrainActorOnly => {
                nodes.push(silent(
                    RoleSet::EMPTY,
                    PhaseBody::LoadExperience {
                        tensors: precollected_tensors(algo),
                    },
                ));
                if hosts(Role::Actor) {
                    nodes.push(mark(
                        PhaseKind::TrainActor,
                        RoleSet::of(&[Role::Actor]),
                        PhaseBody::Train {
                            role: Role::Actor,
                            loss: algo.policy_loss(),
                            pairs: algo.pairs(),
                        },
                    ));
                }
                if scn.mode == ScenarioMode::TrainBothPrecollected && hosts(Role::Critic) {
                    nodes.push(mark(
                        PhaseKind::TrainCritic,
                        RoleSet::of(&[Role::Critic]),
                        PhaseBody::Train {
                            role: Role::Critic,
                            loss: LossKind::ValueLoss,
                            pairs: false,
                        },
                    ));
                }
            }
        }
        nodes.push(silent(RoleSet::EMPTY, PhaseBody::FreeExperience));
        PhaseProgram {
            algo,
            active_roles: active,
            nodes,
        }
    }

    /// The scoring phase mark of a role.
    pub fn infer_kind(role: Role) -> PhaseKind {
        match role {
            Role::Actor => PhaseKind::InferActor,
            Role::Reference => PhaseKind::InferReference,
            Role::Reward => PhaseKind::InferReward,
            Role::Critic => PhaseKind::InferCritic,
        }
    }

    /// Phase marks one step emits, in order — the expected sequence the
    /// trace-invariant checker verifies against the actual op stream.
    pub fn step_phases(&self) -> Vec<PhaseKind> {
        self.nodes.iter().filter_map(|n| n.kind).collect()
    }

    /// Roles with a non-actor scoring node — the models whose outputs
    /// travel over the wire when a placement plan hosts them away from
    /// the actor (the coordinator's step-time model reads this instead of
    /// hardcoding the PPO scorer list).
    pub fn scorer_roles(&self) -> Vec<Role> {
        self.scorer_infers().into_iter().map(|(r, _)| r).collect()
    }

    /// Non-actor scoring nodes with their `pairs` flag — the wire model
    /// ships a second sequence set (and a second output set) for paired
    /// scorers.
    pub fn scorer_infers(&self) -> Vec<(Role, bool)> {
        self.nodes
            .iter()
            .filter_map(|n| match n.body {
                PhaseBody::Infer { role, pairs } if role != Role::Actor => {
                    Some((role, pairs))
                }
                _ => None,
            })
            .collect()
    }

    /// Roles with a training node — the data-parallel gradient
    /// synchronisation set.
    pub fn train_roles(&self) -> Vec<Role> {
        self.nodes
            .iter()
            .filter_map(|n| match n.body {
                PhaseBody::Train { role, .. } => Some(role),
                _ => None,
            })
            .collect()
    }
}

/// The experience tensors a pre-collected (train-only) step loads, per
/// algorithm: PPO's classic eight, the critic-free six (no values), and
/// DPO's preference-pair set.
fn precollected_tensors(algo: Algo) -> Vec<ExpTensor> {
    use ExpTensor::{Mask, PerSeqF32, PerTokenF32, SeqTokens};
    match algo {
        Algo::Ppo => vec![
            SeqTokens,   // sequences
            Mask,        // attention mask
            PerTokenF32, // old logprobs
            PerTokenF32, // ref logprobs
            PerSeqF32,   // rewards
            PerTokenF32, // values
            PerTokenF32, // advantages
            PerTokenF32, // returns
        ],
        Algo::Grpo | Algo::Remax => vec![
            SeqTokens,   // sequences
            Mask,        // attention mask
            PerTokenF32, // old logprobs
            PerTokenF32, // ref logprobs
            PerSeqF32,   // rewards
            PerTokenF32, // advantages
        ],
        Algo::Dpo => vec![
            SeqTokens,   // chosen sequences
            Mask,        // chosen mask
            SeqTokens,   // rejected sequences
            Mask,        // rejected mask
            PerTokenF32, // ref logprobs (chosen)
            PerTokenF32, // ref logprobs (rejected)
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    fn scn(algo: Algo, mode: ScenarioMode) -> SimScenario {
        let mut s = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        s.algo = algo;
        s.mode = mode;
        s
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::by_name(a.name()), Some(a));
        }
        assert_eq!(Algo::by_name("sarsa"), None);
        assert_eq!(Algo::known_names(), "ppo, grpo, remax, dpo");
        assert_eq!(
            Algo::parse_list("ppo, grpo,dpo").unwrap(),
            vec![Algo::Ppo, Algo::Grpo, Algo::Dpo]
        );
        let err = Algo::parse_list("ppo,sarsa").unwrap_err();
        assert!(err.contains("unknown algo 'sarsa'"), "{err}");
    }

    #[test]
    fn sharing_names_roundtrip() {
        for s in Sharing::ALL {
            assert_eq!(Sharing::by_name(s.name()), Some(s));
        }
        assert_eq!(Sharing::by_name("mega-shared"), None);
        assert_eq!(
            Sharing::known_names(),
            "separate, lora, hydra, frozen-shared, perl"
        );
        assert_eq!(
            Sharing::parse_list("separate, lora,hydra").unwrap(),
            vec![Sharing::Separate, Sharing::Lora, Sharing::Hydra]
        );
        let err = Sharing::parse_list("lora,mega").unwrap_err();
        assert!(err.contains("unknown sharing 'mega'"), "{err}");
    }

    #[test]
    fn sharing_groups_and_flags() {
        use crate::rlhf::models::Role;
        // Separate: everyone is their own group owner.
        for r in Role::ALL {
            assert_eq!(Sharing::Separate.group_of(r), RoleSet::of(&[r]));
        }
        // LoRA / frozen-shared pair the architectures.
        for s in [Sharing::Lora, Sharing::FrozenShared] {
            assert_eq!(
                s.group_of(Role::Reference),
                RoleSet::of(&[Role::Actor, Role::Reference])
            );
            assert_eq!(
                s.group_of(Role::Reward),
                RoleSet::of(&[Role::Critic, Role::Reward])
            );
        }
        // Hydra: one trunk for the whole cast.
        assert_eq!(Sharing::Hydra.group_of(Role::Critic), RoleSet::ALL);
        // PERL pairs only the value side; policy roles stay their own
        // groups.
        assert_eq!(
            Sharing::Perl.group_of(Role::Actor),
            RoleSet::of(&[Role::Actor])
        );
        assert_eq!(
            Sharing::Perl.group_of(Role::Reference),
            RoleSet::of(&[Role::Reference])
        );
        assert_eq!(
            Sharing::Perl.group_of(Role::Reward),
            RoleSet::of(&[Role::Critic, Role::Reward])
        );
        assert!(Sharing::Lora.frozen_backbone());
        assert!(Sharing::Hydra.frozen_backbone());
        assert!(!Sharing::Separate.frozen_backbone());
        assert!(!Sharing::FrozenShared.frozen_backbone());
        // PERL is a per-role freeze: not uniform, so the whole-cast
        // predicate stays false while the value side reports frozen.
        assert!(!Sharing::Perl.frozen_backbone());
        for r in Role::ALL {
            assert_eq!(
                Sharing::Perl.frozen_backbone_for(r),
                r.has_value_head(),
                "{}",
                r.name()
            );
            for s in [Sharing::Separate, Sharing::Lora, Sharing::Hydra, Sharing::FrozenShared] {
                assert_eq!(s.frozen_backbone_for(r), s.frozen_backbone());
            }
        }
        assert!(Sharing::Hydra.unifies_architectures());
        assert!(!Sharing::Lora.unifies_architectures());
        assert!(!Sharing::Perl.unifies_architectures());
    }

    #[test]
    fn sharing_never_changes_the_compiled_program() {
        // The sharing axis reshapes tensor lists, not the pipeline: every
        // sharing compiles the identical node list.
        for algo in Algo::ALL {
            for mode in ScenarioMode::ALL {
                let mut base = scn(algo, mode);
                base.sharing = Sharing::Separate;
                let reference = PhaseProgram::compile(&base);
                for sharing in Sharing::ALL {
                    base.sharing = sharing;
                    assert_eq!(
                        PhaseProgram::compile(&base),
                        reference,
                        "{}/{}/{}",
                        algo.name(),
                        mode.name(),
                        sharing.name()
                    );
                }
            }
        }
    }

    #[test]
    fn ppo_full_program_matches_paper_pipeline() {
        let p = PhaseProgram::compile(&scn(Algo::Ppo, ScenarioMode::Full));
        assert_eq!(
            p.step_phases(),
            vec![
                PhaseKind::Generation,
                PhaseKind::InferActor,
                PhaseKind::InferReference,
                PhaseKind::InferReward,
                PhaseKind::InferCritic,
                PhaseKind::TrainActor,
                PhaseKind::TrainCritic,
            ]
        );
        assert_eq!(p.active_roles, RoleSet::ALL);
        assert_eq!(p.scorer_roles(), vec![Role::Reference, Role::Reward, Role::Critic]);
        assert_eq!(p.train_roles(), vec![Role::Actor, Role::Critic]);
        // GAE advantages and the experience free run unmarked.
        assert!(p.nodes.iter().any(|n| n.kind.is_none()
            && n.body == PhaseBody::Advantages { kind: AdvantageKind::Gae }));
        assert_eq!(p.nodes.last().unwrap().body, PhaseBody::FreeExperience);
    }

    #[test]
    fn grpo_and_remax_drop_the_critic() {
        for algo in [Algo::Grpo, Algo::Remax] {
            let p = PhaseProgram::compile(&scn(algo, ScenarioMode::Full));
            assert!(!p.active_roles.contains(Role::Critic), "{:?}", algo);
            assert!(!p.step_phases().contains(&PhaseKind::InferCritic));
            assert!(!p.step_phases().contains(&PhaseKind::TrainCritic));
            assert_eq!(p.scorer_roles(), vec![Role::Reference, Role::Reward]);
            assert_eq!(p.train_roles(), vec![Role::Actor]);
        }
        // Only ReMax schedules the extra greedy rollout — and its reward
        // pass scores both rollouts (the baseline).
        let remax = PhaseProgram::compile(&scn(Algo::Remax, ScenarioMode::Full));
        assert!(remax.nodes.iter().any(|n| n.body
            == PhaseBody::Generation {
                greedy_baseline: true
            }));
        assert!(remax.nodes.iter().any(|n| n.body
            == PhaseBody::Infer {
                role: Role::Reward,
                pairs: true
            }));
        let grpo = PhaseProgram::compile(&scn(Algo::Grpo, ScenarioMode::Full));
        assert!(grpo.nodes.iter().any(|n| n.body
            == PhaseBody::Generation {
                greedy_baseline: false
            }));
        assert!(grpo.nodes.iter().any(|n| n.body
            == PhaseBody::Infer {
                role: Role::Reward,
                pairs: false
            }));
        assert!(grpo.nodes.iter().any(|n| n.body
            == PhaseBody::Advantages {
                kind: AdvantageKind::GroupRelative
            }));
    }

    #[test]
    fn dpo_collapses_to_reference_scoring_and_one_update() {
        let p = PhaseProgram::compile(&scn(Algo::Dpo, ScenarioMode::Full));
        assert_eq!(
            p.step_phases(),
            vec![PhaseKind::InferReference, PhaseKind::TrainActor]
        );
        assert_eq!(p.active_roles, RoleSet::of(&[Role::Actor, Role::Reference]));
        // Pairs load instead of generation; the update is the preference
        // loss over the doubled batch.
        assert!(matches!(
            &p.nodes[0].body,
            PhaseBody::LoadExperience { tensors } if tensors.len() == 4
        ));
        assert!(p.nodes.iter().any(|n| n.body
            == PhaseBody::Train {
                role: Role::Actor,
                loss: LossKind::Preference,
                pairs: true
            }));
        assert!(!p.nodes.iter().any(|n| matches!(n.body, PhaseBody::Advantages { .. })));
    }

    #[test]
    fn precollected_modes_shrink_with_the_algo() {
        let p = PhaseProgram::compile(&scn(Algo::Ppo, ScenarioMode::TrainBothPrecollected));
        assert_eq!(
            p.step_phases(),
            vec![PhaseKind::TrainActor, PhaseKind::TrainCritic]
        );
        assert!(matches!(
            &p.nodes[0].body,
            PhaseBody::LoadExperience { tensors } if tensors.len() == 8
        ));
        // Critic-free algos load no values and schedule no critic update,
        // even in "train both" mode.
        let g = PhaseProgram::compile(&scn(Algo::Grpo, ScenarioMode::TrainBothPrecollected));
        assert_eq!(g.step_phases(), vec![PhaseKind::TrainActor]);
        assert!(matches!(
            &g.nodes[0].body,
            PhaseBody::LoadExperience { tensors } if tensors.len() == 6
        ));
        let a = PhaseProgram::compile(&scn(Algo::Ppo, ScenarioMode::TrainActorOnly));
        assert_eq!(a.step_phases(), vec![PhaseKind::TrainActor]);
    }

    #[test]
    fn scorer_only_placement_receives_remote_sequences() {
        let mut s = scn(Algo::Ppo, ScenarioMode::Full);
        s.roles = RoleSet::of(&[Role::Reference, Role::Reward]);
        let p = PhaseProgram::compile(&s);
        assert_eq!(
            p.nodes[0].body,
            PhaseBody::RemoteSequences {
                greedy_baseline: false
            }
        );
        assert_eq!(
            p.step_phases(),
            vec![PhaseKind::InferReference, PhaseKind::InferReward]
        );
        assert!(p.train_roles().is_empty());
        // A DPO scorer GPU only ever hosts the reference.
        s.algo = Algo::Dpo;
        let p = PhaseProgram::compile(&s);
        assert_eq!(p.active_roles, RoleSet::of(&[Role::Reference]));
        assert_eq!(p.step_phases(), vec![PhaseKind::InferReference]);
    }

    #[test]
    fn exp_tensor_sizes() {
        assert_eq!(ExpTensor::SeqTokens.bytes(2, 512), 2 * 512 * 8);
        assert_eq!(ExpTensor::Mask.bytes(2, 512), 2 * 512 * 8);
        assert_eq!(ExpTensor::PerTokenF32.bytes(2, 512), 2 * 512 * 4);
        assert_eq!(ExpTensor::PerSeqF32.bytes(2, 512), 2 * 4);
    }

    #[test]
    fn kind_maps() {
        assert_eq!(PhaseProgram::infer_kind(Role::Critic), PhaseKind::InferCritic);
        assert_eq!(PhaseProgram::infer_kind(Role::Actor), PhaseKind::InferActor);
    }
}
