//! Real end-to-end PPO training (E10): the full RLHF loop — generation,
//! scoring, synthetic reward, GAE, PPO update — running through the PJRT
//! engine on AOT-compiled JAX/Pallas artifacts. No Python anywhere on this
//! path.

use crate::runtime::engine::RlhfEngine;
use crate::util::prng::Rng;
use anyhow::Result;

/// Reward configuration: the synthetic preference signal. A response token
/// `t` is "preferred" iff `t % reward_mod == reward_res`; the sequence
/// reward is `2·(preferred fraction) − 1`, so an aligned policy approaches
/// +1. KL against the frozen reference keeps the policy from collapsing.
#[derive(Debug, Clone)]
pub struct PpoConfig {
    pub reward_mod: i32,
    pub reward_res: i32,
    pub kl_beta: f32,
    pub gamma: f32,
    pub lam: f32,
    pub temperature: f32,
    pub seed: u64,
    /// Recycle the PJRT client every N iterations (see
    /// `RlhfEngine::recycle`); 0 disables.
    pub recycle_every: u64,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            reward_mod: 7,
            reward_res: 3,
            kl_beta: 0.05,
            gamma: 1.0,
            lam: 0.95,
            temperature: 1.0,
            seed: 0x0DD5EED,
            recycle_every: 4,
        }
    }
}

/// One PPO iteration's metrics.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: u64,
    pub mean_reward: f32,
    pub mean_kl: f32,
    pub policy_loss: f32,
    pub value_loss: f32,
    pub entropy: f32,
    pub gen_seconds: f64,
    pub train_seconds: f64,
}

/// The real trainer.
pub struct RealPpoTrainer {
    pub engine: RlhfEngine,
    pub cfg: PpoConfig,
    rng: Rng,
    pub history: Vec<IterStats>,
}

impl RealPpoTrainer {
    pub fn new(engine: RlhfEngine, cfg: PpoConfig) -> Self {
        let rng = Rng::seeded(cfg.seed);
        RealPpoTrainer {
            engine,
            cfg,
            rng,
            history: Vec::new(),
        }
    }

    /// Synthetic prompt: a short Markov-ish token chain (seeded), mirroring
    /// an instruction prefix.
    fn sample_prompt(&mut self, len: usize, vocab: i32) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut t = self.rng.gen_range(vocab as u64) as i32;
        for _ in 0..len {
            out.push(t);
            // biased walk through the vocab
            t = (t * 31 + 17 + self.rng.gen_range(11) as i32) % vocab;
        }
        out
    }

    fn sample_token(&mut self, logits: &[f32], temperature: f32) -> i32 {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - max) / temperature) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= sum;
        }
        self.rng.weighted_index(&probs) as i32
    }

    /// Sequence-level reward: preferred-token fraction of the response.
    pub fn reward(&self, response: &[i32]) -> f32 {
        if response.is_empty() {
            return 0.0;
        }
        let hits = response
            .iter()
            .filter(|&&t| t % self.cfg.reward_mod == self.cfg.reward_res)
            .count();
        2.0 * hits as f32 / response.len() as f32 - 1.0
    }

    /// Run one PPO iteration: rollout -> score -> GAE -> update.
    pub fn step(&mut self) -> Result<IterStats> {
        let b = self.engine.manifest.batch;
        let s = self.engine.manifest.max_seq;
        let prompt = self.engine.manifest.prompt;
        let vocab = self.engine.manifest.vocab as i32;
        let iter = self.history.len() as u64 + 1;
        if self.cfg.recycle_every > 0 && iter > 1 && (iter - 1) % self.cfg.recycle_every == 0 {
            self.engine.recycle()?;
        }

        // ---- Generation (decode loop with KV cache) ----
        let t_gen = std::time::Instant::now();
        let mut tokens = vec![0i32; b * s];
        for bi in 0..b {
            let p = self.sample_prompt(prompt, vocab);
            tokens[bi * s..bi * s + prompt].copy_from_slice(&p);
        }
        let mut kv = self.engine.init_kv()?;
        // Feed the prompt; then sample the response.
        for pos in 0..s - 1 {
            let col: Vec<i32> = (0..b).map(|bi| tokens[bi * s + pos]).collect();
            let (logits, kv_new) = self.engine.decode(&kv, &col, pos as i32)?;
            kv = kv_new;
            if pos + 1 >= prompt {
                for bi in 0..b {
                    let row = &logits[bi * vocab as usize..(bi + 1) * vocab as usize];
                    tokens[bi * s + pos + 1] = self.sample_token(row, self.cfg.temperature);
                }
            }
        }
        let gen_seconds = t_gen.elapsed().as_secs_f64();

        // ---- Scoring ----
        let (old_lp, old_values) = self.engine.score(&self.engine.params, &tokens)?;
        let (ref_lp, _) = self.engine.score(&self.engine.ref_params, &tokens)?;

        // ---- Rewards + GAE ----
        let sp = s - 1; // prediction positions
        let mut mask = vec![0f32; b * s];
        for bi in 0..b {
            for j in prompt..s {
                mask[bi * s + j] = 1.0;
            }
        }
        let mut rewards = vec![0f32; b * sp];
        let mut mean_reward = 0.0;
        let mut mean_kl = 0.0;
        for bi in 0..b {
            let response = &tokens[bi * s + prompt..bi * s + s];
            let r = self.reward(response);
            mean_reward += r / b as f32;
            for i in (prompt - 1)..sp {
                let kl = old_lp[bi * sp + i] - ref_lp[bi * sp + i];
                mean_kl += kl / (b * (sp - prompt + 1)) as f32;
                // Dense per-token preference (prediction i emits token i+1)
                // plus the KL penalty — the dense shaping is what lets a
                // 3 M-param policy align within tens of PPO iterations.
                let tok = tokens[bi * s + i + 1];
                let pref = if tok % self.cfg.reward_mod == self.cfg.reward_res {
                    1.0
                } else {
                    -1.0
                };
                rewards[bi * sp + i] = pref / (s - prompt) as f32 - self.cfg.kl_beta * kl;
            }
            rewards[bi * sp + sp - 1] += r; // terminal sequence-level bonus
        }

        // GAE over response positions; values[:, i] is the value at context i.
        let mut advantages = vec![0f32; b * sp];
        let mut returns = vec![0f32; b * sp];
        for bi in 0..b {
            let mut last_gae = 0f32;
            for i in (prompt - 1..sp).rev() {
                let v_i = old_values[bi * s + i];
                let v_next = if i + 1 < s { old_values[bi * s + i + 1] } else { 0.0 };
                let next_nonterminal = if i == sp - 1 { 0.0 } else { 1.0 };
                let delta =
                    rewards[bi * sp + i] + self.cfg.gamma * v_next * next_nonterminal - v_i;
                last_gae = delta + self.cfg.gamma * self.cfg.lam * next_nonterminal * last_gae;
                advantages[bi * sp + i] = last_gae;
                returns[bi * sp + i] = last_gae + v_i;
            }
        }
        // Advantage whitening over masked entries.
        let masked: Vec<f32> = (0..b * sp)
            .filter(|idx| {
                let i = idx % sp;
                i >= prompt - 1
            })
            .map(|idx| advantages[idx])
            .collect();
        let mean = masked.iter().sum::<f32>() / masked.len() as f32;
        let var = masked.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>()
            / masked.len() as f32;
        let std = var.sqrt().max(1e-6);
        for idx in 0..b * sp {
            if idx % sp >= prompt - 1 {
                advantages[idx] = (advantages[idx] - mean) / std;
            }
        }

        // ---- PPO update ----
        let t_train = std::time::Instant::now();
        let (pg, vf, ent) = self.engine.train(
            &tokens,
            &mask,
            &old_lp,
            &old_values,
            &advantages,
            &returns,
        )?;
        let train_seconds = t_train.elapsed().as_secs_f64();

        let stats = IterStats {
            iter,
            mean_reward,
            mean_kl,
            policy_loss: pg,
            value_loss: vf,
            entropy: ent,
            gen_seconds,
            train_seconds,
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// CSV of the training curve (EXPERIMENTS.md E10).
    pub fn history_csv(&self) -> String {
        let mut out =
            String::from("iter,mean_reward,mean_kl,policy_loss,value_loss,entropy,gen_s,train_s\n");
        for h in &self.history {
            out.push_str(&format!(
                "{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.2},{:.2}\n",
                h.iter, h.mean_reward, h.mean_kl, h.policy_loss, h.value_loss, h.entropy,
                h.gen_seconds, h.train_seconds
            ));
        }
        out
    }
}
