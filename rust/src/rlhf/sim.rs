//! The RLHF stage-3 allocation-trace generator — the heart of the memory
//! study.
//!
//! For a given framework profile, model set, strategy configuration,
//! algorithm and `empty_cache` policy, [`build_trace`] emits the op stream
//! one simulated GPU (rank `rank` of `world`) observes across PPO steps.
//! The pipeline itself is *data*: the scenario compiles to a
//! [`PhaseProgram`] (see [`crate::rlhf::program`]) and the emitter here is
//! a thin interpreter over its nodes. PPO's classic step:
//!
//! ```text
//! Init ── [ Generation → InferActor → InferReference → InferReward →
//!           InferCritic → TrainActor → TrainCritic → (step end) ]*
//! ```
//!
//! Critic-free algorithms (GRPO, ReMax) and DPO compile to shorter
//! programs — fewer models at Init, fewer phases per step.
//!
//! Nothing here hardcodes memory *outcomes*; strategies only change which
//! allocations are emitted (partitioned storage, gather/staging
//! transients, checkpointed saves...). Fragmentation and
//! reserved/allocated curves emerge when the trace replays through the
//! allocator.

use crate::frameworks::{FrameworkKind, FrameworkProfile, GenerationImpl};
use crate::mem::{
    adam_state_tensors, lora::lora_tensors, ActivationModel, AdamConfig, DType, KvCacheModel,
    LoraSpec, ParamInventory, SeqShape, TensorSpec,
};
use crate::policy::EmptyCachePolicy;
use crate::rlhf::cost::{CostModel, GpuSpec};
use crate::rlhf::models::{RlhfModelSet, Role, RoleSet};
use crate::rlhf::program::{
    AdvantageKind, Algo, ExpTensor, LossKind, PhaseBody, PhaseNode, PhaseProgram, Sharing,
};
use crate::strategies::{zero, StrategyConfig};
use crate::trace::{PhaseKind, Tag, Trace, TraceBuilder, TraceHandle};
use crate::util::prng::Rng;

/// Which parts of the pipeline run (paper §3.1's three scenarios, E6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioMode {
    /// Inference + training (the normal pipeline).
    Full,
    /// Train actor and critic on pre-collected experience.
    TrainBothPrecollected,
    /// Train only the actor on pre-collected experience.
    TrainActorOnly,
}

impl ScenarioMode {
    pub const ALL: [ScenarioMode; 3] = [
        ScenarioMode::Full,
        ScenarioMode::TrainBothPrecollected,
        ScenarioMode::TrainActorOnly,
    ];

    /// Stable name used in sweep-cell keys, JSON reports and configs.
    pub fn name(self) -> &'static str {
        match self {
            ScenarioMode::Full => "full",
            ScenarioMode::TrainBothPrecollected => "train_both",
            ScenarioMode::TrainActorOnly => "train_actor",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|m| m.name() == s)
    }

    /// Comma-separated valid names (for CLI/config error messages).
    pub fn known_names() -> String {
        Self::ALL
            .iter()
            .map(|m| m.name())
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// One simulated experiment (a row of Table 1 / Table 2).
#[derive(Debug, Clone)]
pub struct SimScenario {
    pub framework: FrameworkProfile,
    pub models: RlhfModelSet,
    pub strategy: StrategyConfig,
    pub world: u64,
    pub policy: EmptyCachePolicy,
    pub steps: u64,
    pub mode: ScenarioMode,
    /// Which RLHF algorithm the pipeline runs — decides the model cast
    /// and the compiled [`PhaseProgram`] (PPO is the paper's default).
    pub algo: Algo,
    /// How the cast shares parameter storage (LoRA-PPO pairs, Hydra's
    /// single trunk). [`Sharing::Separate`] — every role its own full
    /// replica — reproduces the paper's testbed bit-for-bit; the other
    /// placements reshape the per-role tensor lists the emitter
    /// allocates, never the compiled phase pipeline.
    pub sharing: Sharing,
    pub gpu: GpuSpec,
    /// Seed for response-length sampling.
    pub seed: u64,
    /// Model variable-length responses (EOS stopping): each step's actual
    /// generated length is sampled in [gen_len/2, gen_len]. Real RLHF
    /// rollouts vary like this, and the resulting size drift across steps
    /// is a major source of cache-reuse failure (fragmentation).
    pub len_jitter: bool,
    /// Which of the four models this GPU hosts. [`RoleSet::ALL`] is the
    /// classic symmetric data-parallel replica; cluster placement plans
    /// ([`crate::coordinator::PlacementPlan`]) assign per-GPU subsets, so
    /// ranks genuinely emit different traces. The models actually
    /// instantiated are `roles ∩ algo.roles()`.
    pub roles: RoleSet,
    /// Hosted frozen models swapped out to host memory between the
    /// experience and training phases (Hydra-style phase time-sharing).
    /// Must be a subset of `roles` containing no trainable role.
    pub time_shared: RoleSet,
    /// This GPU's index within the ZeRO data-parallel group of `world`
    /// ranks. The last rank's flat-buffer shards absorb the partition
    /// remainder and can be smaller — another way ranks differ.
    pub rank: u64,
}

/// A named scenario preset: the framework/model/jitter triple behind the
/// paper's three configurations. One table, consumed by the
/// [`SimScenario`] constructors, the sweep presets and `rlhf-mem profile`
/// configs — a row added here exists everywhere at once.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioPreset {
    /// Stable lookup name (`deepspeed-opt`, `colossal-opt`,
    /// `colossal-gpt2`).
    pub name: &'static str,
    pub framework: FrameworkKind,
    pub models: fn() -> RlhfModelSet,
}

/// The paper's three framework/model configurations.
pub const SCENARIO_PRESETS: [ScenarioPreset; 3] = [
    ScenarioPreset {
        name: "deepspeed-opt",
        framework: FrameworkKind::DeepSpeedChat,
        models: RlhfModelSet::opt,
    },
    ScenarioPreset {
        name: "colossal-opt",
        framework: FrameworkKind::ColossalChat,
        models: RlhfModelSet::opt,
    },
    ScenarioPreset {
        name: "colossal-gpt2",
        framework: FrameworkKind::ColossalChat,
        models: RlhfModelSet::gpt2,
    },
];

impl ScenarioPreset {
    pub fn by_name(name: &str) -> Option<&'static ScenarioPreset> {
        SCENARIO_PRESETS.iter().find(|p| p.name == name)
    }

    /// Materialize the preset with the paper testbed's shared defaults:
    /// world 4, 3 PPO steps, RTX-3090 time model, seed `0x5EED`, the full
    /// PPO pipeline on a full replica, and the framework's length-jitter
    /// default.
    pub fn build(&self, strategy: StrategyConfig, policy: EmptyCachePolicy) -> SimScenario {
        SimScenario {
            framework: FrameworkProfile::by_kind(self.framework),
            models: (self.models)(),
            strategy,
            world: 4,
            policy,
            steps: 3,
            mode: ScenarioMode::Full,
            algo: Algo::Ppo,
            sharing: Sharing::Separate,
            gpu: GpuSpec::rtx3090(),
            seed: 0x5EED,
            len_jitter: self.framework.default_len_jitter(),
            roles: RoleSet::ALL,
            time_shared: RoleSet::EMPTY,
            rank: 0,
        }
    }
}

impl SimScenario {
    /// DeepSpeed-Chat/OPT, the Figure-1 configuration.
    pub fn deepspeed_opt(strategy: StrategyConfig, policy: EmptyCachePolicy) -> Self {
        SCENARIO_PRESETS[0].build(strategy, policy)
    }

    /// ColossalChat/OPT.
    pub fn colossal_opt(strategy: StrategyConfig, policy: EmptyCachePolicy) -> Self {
        SCENARIO_PRESETS[1].build(strategy, policy)
    }

    /// ColossalChat/GPT-2.
    pub fn colossal_gpt2(strategy: StrategyConfig, policy: EmptyCachePolicy) -> Self {
        SCENARIO_PRESETS[2].build(strategy, policy)
    }
}

/// Per-model simulated state on this rank.
struct SimModel {
    #[allow(dead_code)] // diagnostic field (kept for Debug dumps)
    role: Role,
    inv: ParamInventory,
    act: ActivationModel,
    kv: KvCacheModel,
    cost: CostModel,
    /// Trainable tensors (LoRA adapters + value head, or everything if
    /// LoRA is off).
    trainable: Vec<TensorSpec>,
    /// Parameter tensors this role allocates *itself*: the full inventory
    /// when it owns (or doesn't share) its backbone, only its private
    /// head tensors when it rides another role's frozen replica.
    extra: Vec<TensorSpec>,
    /// Persistent handles.
    param_handles: Vec<TraceHandle>,
    adapter_handles: Vec<TraceHandle>,
    opt_handles: Vec<TraceHandle>,
    grad_handles: Vec<TraceHandle>,
    /// Whether the fp16 replica currently sits on the GPU (ColossalChat
    /// offloads ref/reward to host during training).
    resident: bool,
}

impl SimModel {
    fn build(role: Role, scn: &SimScenario) -> SimModel {
        let sharing = scn.sharing;
        // Hydra collapses the cast onto the policy trunk: value roles are
        // scalar heads over the actor architecture, not separate models.
        let inv = if sharing.unifies_architectures() && role.has_value_head() {
            ParamInventory::build_with_value_head(&scn.models.policy_arch)
        } else {
            scn.models.inventory_for(role)
        };
        let arch = if sharing.unifies_architectures() {
            &scn.models.policy_arch
        } else {
            scn.models.arch_for(role)
        };
        let act = ActivationModel::new(arch, DType::F16);
        let kv = KvCacheModel::new(arch, DType::F16);
        let cost = CostModel::for_inventory(&inv, scn.gpu);
        // DeepSpeed-Chat's reference scripts set `actor_lora_dim 128` but
        // leave `critic_lora_dim 0`: the critic is fully fine-tuned. This
        // is what makes ZeRO-1's optimizer partitioning worth ~4 GB in
        // Table 1 (the critic's full Adam state dwarfs the actor's LoRA
        // state). The LoRA/Hydra sharings are exactly the Efficient-RLHF
        // counter-move: every trainable role shrinks to adapters/heads.
        let trainable: Vec<TensorSpec> = if !role.is_trainable() {
            vec![]
        } else {
            match sharing {
                Sharing::Separate | Sharing::FrozenShared => {
                    if role == Role::Actor {
                        match scn.strategy.lora {
                            Some(spec) => lora_tensors(&inv, spec),
                            None => inv.tensors.clone(),
                        }
                    } else {
                        inv.tensors.clone()
                    }
                }
                Sharing::Lora => {
                    let spec = scn.strategy.lora.unwrap_or_else(LoraSpec::paper_default);
                    let mut t = lora_tensors(&inv, spec);
                    t.extend(
                        inv.tensors.iter().filter(|t| t.name == "v_head").cloned(),
                    );
                    t
                }
                Sharing::Hydra => {
                    if role == Role::Actor {
                        let spec =
                            scn.strategy.lora.unwrap_or_else(LoraSpec::paper_default);
                        lora_tensors(&inv, spec)
                    } else {
                        // The critic trains only its head over the trunk.
                        inv.tensors
                            .iter()
                            .filter(|t| t.name == "v_head")
                            .cloned()
                            .collect()
                    }
                }
                // PERL: the actor keeps the Separate training state
                // (LoRA-or-full per the strategy preset); the critic
                // trains adapters over the frozen value backbone plus
                // its value head — the reward-model-side LoRA rule.
                Sharing::Perl => {
                    if role == Role::Actor {
                        match scn.strategy.lora {
                            Some(spec) => lora_tensors(&inv, spec),
                            None => inv.tensors.clone(),
                        }
                    } else {
                        let spec =
                            scn.strategy.lora.unwrap_or_else(LoraSpec::paper_default);
                        let mut t = lora_tensors(&inv, spec);
                        t.extend(
                            inv.tensors.iter().filter(|t| t.name == "v_head").cloned(),
                        );
                        t
                    }
                }
            }
        };
        // Backbone ownership: the first *active* member of the role's
        // sharing group (Role::ALL order) stores the shared replica; the
        // others allocate only their private head tensors. Under
        // `Separate` every role is its own owner, so `extra` is the full
        // inventory — bit-identical to the pre-axis traces.
        let active = scn.roles.intersect(scn.algo.roles());
        let owner = sharing.group_of(role).intersect(active).iter().next();
        let extra: Vec<TensorSpec> = if owner == Some(role) || owner.is_none() {
            inv.tensors.clone()
        } else {
            inv.tensors
                .iter()
                .filter(|t| t.name == "v_head")
                .cloned()
                .collect()
        };
        SimModel {
            role,
            inv,
            act,
            kv,
            cost,
            trainable,
            extra,
            param_handles: vec![],
            adapter_handles: vec![],
            opt_handles: vec![],
            grad_handles: vec![],
            resident: false,
        }
    }

    fn trainable_bytes_f16(&self) -> u64 {
        self.trainable.iter().map(|t| t.bytes(DType::F16)).sum()
    }

    fn extra_bytes_f16(&self) -> u64 {
        self.extra.iter().map(|t| t.bytes(DType::F16)).sum()
    }
}

/// F16 bytes of `role`'s trainable tensors under `scn`'s strategy *and
/// sharing* — the gradient-synchronisation payload. The coordinator's
/// collective model charges this instead of re-deriving the trainable
/// rules privately.
pub fn trainable_bytes_f16(scn: &SimScenario, role: Role) -> u64 {
    SimModel::build(role, scn).trainable_bytes_f16()
}

/// One role's share of the engine-lifetime bytes [`Emitter::init`]
/// allocates on this rank, decomposed by what the bytes are.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoleFootprint {
    pub role: Role,
    /// fp16 replica (`extra` tensors; rank shard under ZeRO-3).
    pub params: u64,
    /// Dense adapter copies (LoRA placements).
    pub adapters: u64,
    /// Adam states (rank shard under ZeRO-1+).
    pub optimizer: u64,
    /// Gradient reduce bucket (ZeRO-2+).
    pub comm: u64,
    /// Pinned offload staging buffers.
    pub staging: u64,
}

impl RoleFootprint {
    pub fn total(&self) -> u64 {
        self.params + self.adapters + self.optimizer + self.comm + self.staging
    }
}

/// The static image of [`Emitter::init`]: every engine-lifetime byte the
/// simulator will allocate on this rank before step 1, per active role,
/// plus the hybrid-engine inference copy. Because `init` performs only
/// allocations, `total()` is *exactly* the simulated `init` phase peak —
/// the anchor of the lint subsystem's static bounds
/// (`lint::bounds::static_bounds`), pinned by the
/// `lint_soundness` integration test.
#[derive(Debug, Clone, Default)]
pub struct InitFootprint {
    pub roles: Vec<RoleFootprint>,
    /// DeepSpeed-Chat fused inference containers (actor weight copy).
    pub hybrid_engine: u64,
}

impl InitFootprint {
    /// Engine-lifetime bytes resident after `init` — the simulated `init`
    /// phase peak.
    pub fn total(&self) -> u64 {
        self.roles.iter().map(RoleFootprint::total).sum::<u64>() + self.hybrid_engine
    }

    /// `role`'s engine-lifetime bytes (0 when not active on this rank).
    pub fn role_total(&self, role: Role) -> u64 {
        self.roles
            .iter()
            .find(|r| r.role == role)
            .map_or(0, RoleFootprint::total)
    }
}

/// Compute [`InitFootprint`] for `scn` without building a trace. This
/// mirrors [`Emitter::init`] byte-for-byte — per-tensor ZeRO shard
/// round-up included — so keep the two in lockstep.
pub fn init_footprint(scn: &SimScenario) -> InitFootprint {
    let world = scn.world;
    let rank = scn.rank;
    let z = scn.strategy.zero;
    let offload = scn.strategy.cpu_offload;
    let active = scn.roles.intersect(scn.algo.roles());
    let partitioned = |role: Role| {
        scn.strategy.zero.partitions_params()
            && role.is_trainable()
            && !scn.sharing.frozen_backbone_for(role)
    };

    let mut out = InitFootprint::default();
    for role in Role::ALL {
        if !active.contains(role) {
            continue;
        }
        let m = SimModel::build(role, scn);
        let params: u64 = m
            .extra
            .iter()
            .map(|t| {
                let full = t.bytes(DType::F16);
                if partitioned(role) {
                    zero::shard_bytes(full, world, rank)
                } else {
                    full
                }
            })
            .sum();

        let adapters: u64 = match scn.sharing {
            Sharing::Separate | Sharing::FrozenShared => {
                if role == Role::Actor && scn.strategy.lora.is_some() {
                    m.trainable.iter().map(|t| t.bytes(DType::F16)).sum()
                } else {
                    0
                }
            }
            Sharing::Lora => m
                .trainable
                .iter()
                .filter(|t| t.name != "v_head")
                .map(|t| t.bytes(DType::F16))
                .sum(),
            Sharing::Hydra => {
                if role == Role::Actor {
                    m.trainable.iter().map(|t| t.bytes(DType::F16)).sum()
                } else {
                    0
                }
            }
            Sharing::Perl => {
                if role == Role::Actor {
                    if scn.strategy.lora.is_some() {
                        m.trainable.iter().map(|t| t.bytes(DType::F16)).sum()
                    } else {
                        0
                    }
                } else {
                    m.trainable
                        .iter()
                        .filter(|t| t.name != "v_head")
                        .map(|t| t.bytes(DType::F16))
                        .sum()
                }
            }
        };

        let optimizer: u64 = if role.is_trainable() && !offload {
            let trainable_refs: Vec<&TensorSpec> = m.trainable.iter().collect();
            adam_state_tensors(&trainable_refs, AdamConfig::default())
                .iter()
                .map(|s| {
                    if z.partitions_optimizer() {
                        zero::shard_bytes(s.bytes, world, rank)
                    } else {
                        s.bytes
                    }
                })
                .sum()
        } else {
            0
        };

        let comm = if role.is_trainable() && z.partitions_gradients() {
            m.trainable_bytes_f16()
                .min(zero::defaults::REDUCE_BUCKET_BYTES)
                .max(16)
        } else {
            0
        };
        let staging = if role.is_trainable() && offload {
            let cfg = crate::strategies::offload::OffloadConfig::default();
            let chunk = m.trainable_bytes_f16().min(cfg.staging_bytes).max(16);
            chunk * cfg.live_buffers()
        } else {
            0
        };

        out.roles.push(RoleFootprint {
            role,
            params,
            adapters,
            optimizer,
            comm,
            staging,
        });
    }

    if scn.framework.hybrid_engine && !partitioned(Role::Actor) && active.contains(Role::Actor) {
        let actor = SimModel::build(Role::Actor, scn);
        let layers = actor.inv.arch.n_layers;
        let mut total = 0u64;
        for l in 0..layers {
            total += if scn.sharing.frozen_backbone_for(Role::Actor) {
                actor
                    .trainable
                    .iter()
                    .filter(|t| t.layer == Some(l))
                    .map(|t| t.bytes(DType::F16))
                    .sum::<u64>()
                    .max(16)
            } else {
                actor.inv.layer_bytes(l, DType::F16)
            };
        }
        out.hybrid_engine = total;
    }
    out
}

/// Experience tensors shared across phases within one PPO step.
#[derive(Default)]
struct Experience {
    handles: Vec<TraceHandle>,
}

/// DeepSpeed `stage3_max_live_parameters` ring: gathered fp16 layer copies
/// stay live until the cap is exceeded, then the oldest are released.
struct GatherRing {
    cap: u64,
    live: std::collections::VecDeque<(TraceHandle, u64)>,
    live_bytes: u64,
}

impl GatherRing {
    fn new(cap: u64) -> Self {
        GatherRing {
            cap,
            live: std::collections::VecDeque::new(),
            live_bytes: 0,
        }
    }

    fn push(&mut self, b: &mut TraceBuilder, bytes: u64) {
        let h = b.alloc(bytes, Tag::CommBuffer);
        self.live.push_back((h, bytes));
        self.live_bytes += bytes;
        while self.live_bytes > self.cap && self.live.len() > 1 {
            let (old, ob) = self.live.pop_front().unwrap();
            b.free(old);
            self.live_bytes -= ob;
        }
    }

    fn drain(&mut self, b: &mut TraceBuilder) {
        while let Some((h, ob)) = self.live.pop_front() {
            b.free(h);
            self.live_bytes -= ob;
        }
    }
}

/// DeepSpeed stage-3 prefetch: parameters are all-gathered in buckets of
/// `stage3_prefetch_bucket_size` bytes whose boundaries cut across tensor
/// and layer edges — so the gather sizes vary bucket to bucket, and their
/// lifetimes interleave with activations. That size diversity is what
/// shreds the large pool (paper §3.2's ZeRO-3 fragmentation).
struct GatherStream {
    /// Bucket sizes in gather order.
    buckets: Vec<u64>,
    /// Cumulative parameter bytes needed *through* each layer index.
    needed_through: Vec<u64>,
    next_bucket: usize,
    gathered: u64,
}

impl GatherStream {
    fn new(inv: &ParamInventory, reverse: bool, bucket_bytes: u64) -> GatherStream {
        let n_layers = inv.arch.n_layers as usize;
        // The bucket cut is fixed at engine init (DeepSpeed's param-group
        // coalescing), so forward and backward use the SAME bucket sizes —
        // backward just consumes them in reverse. That identity is what
        // lets a backward gather reuse the cache its forward twin left.
        let globals: u64 = inv.global_tensors().map(|t| t.bytes(DType::F16)).sum();
        let mut tensor_bytes: Vec<u64> = vec![globals];
        for l in 0..n_layers as u64 {
            for t in inv.layer_tensors(l) {
                tensor_bytes.push(t.bytes(DType::F16));
            }
        }
        let mut buckets = Vec::new();
        let mut acc = 0u64;
        for b in &tensor_bytes {
            acc += b;
            if acc >= bucket_bytes {
                buckets.push(acc);
                acc = 0;
            }
        }
        if acc > 0 {
            buckets.push(acc);
        }
        // Per-traversal-step requirements.
        let layer_bytes: Vec<u64> = (0..n_layers as u64)
            .map(|l| inv.layer_bytes(l, DType::F16))
            .collect();
        let mut needed_through = Vec::with_capacity(n_layers);
        if reverse {
            buckets.reverse();
            let mut cum = 0u64;
            for l in (0..n_layers).rev() {
                cum += layer_bytes[l];
                needed_through.push(cum);
            }
        } else {
            let mut cum = globals;
            for l in 0..n_layers {
                cum += layer_bytes[l];
                needed_through.push(cum);
            }
        }
        GatherStream {
            buckets,
            needed_through,
            next_bucket: 0,
            gathered: 0,
        }
    }

    /// Gather enough buckets (into `ring`) to cover layer index `i` of the
    /// traversal. Returns bytes newly gathered (for the time model).
    fn advance(&mut self, i: usize, ring: &mut GatherRing, b: &mut TraceBuilder) -> u64 {
        let needed = self.needed_through[i];
        let mut newly = 0;
        while self.gathered < needed && self.next_bucket < self.buckets.len() {
            let bytes = self.buckets[self.next_bucket];
            ring.push(b, bytes);
            self.gathered += bytes;
            newly += bytes;
            self.next_bucket += 1;
        }
        newly
    }
}

/// The interpreter: walks a [`PhaseProgram`]'s nodes and emits each
/// body's allocation pattern.
struct Emitter<'a> {
    scn: &'a SimScenario,
    /// Hosted roles ∩ algorithm cast — the models that exist on this GPU.
    active: RoleSet,
    b: TraceBuilder,
    actor: SimModel,
    reference: SimModel,
    critic: SimModel,
    reward: SimModel,
    exp: Experience,
    rng: Rng,
    /// This step's actual generated length (≤ framework gen_len).
    cur_gen_len: u64,
}

/// Build the allocation trace one GPU of `scn` observes — rank `scn.rank`
/// of the `scn.world`-wide data-parallel group, hosting `scn.roles` —
/// by compiling the scenario's [`PhaseProgram`] and interpreting it.
pub fn build_trace(scn: &SimScenario) -> Trace {
    let program = PhaseProgram::compile(scn);
    build_trace_with_program(scn, &program)
}

/// [`build_trace`] over an explicit program — the hook the golden tests
/// use to pin compiled programs against hand-written pipelines, and the
/// escape hatch for experimenting with custom phase orders. The program
/// must have been compiled for (or be consistent with) `scn`'s roles and
/// algorithm; [`build_trace`] is the safe entry point.
pub fn build_trace_with_program(scn: &SimScenario, program: &PhaseProgram) -> Trace {
    assert!(
        scn.framework.supports(&scn.strategy),
        "{} does not support {:?}",
        scn.framework.kind.name(),
        scn.strategy
    );
    assert!(scn.world >= 1, "world must be >= 1");
    assert!(
        scn.rank < scn.world,
        "rank {} outside world {}",
        scn.rank,
        scn.world
    );
    assert!(
        scn.time_shared.is_subset_of(scn.roles),
        "time-shared roles must be hosted"
    );
    let mut e = Emitter {
        scn,
        active: program.active_roles,
        b: TraceBuilder::new(),
        actor: SimModel::build(Role::Actor, scn),
        reference: SimModel::build(Role::Reference, scn),
        critic: SimModel::build(Role::Critic, scn),
        reward: SimModel::build(Role::Reward, scn),
        exp: Experience::default(),
        rng: Rng::seeded(scn.seed),
        cur_gen_len: scn.framework.gen_len,
    };
    e.run(program);
    e.b.finish()
}

impl<'a> Emitter<'a> {
    fn run(&mut self, program: &PhaseProgram) {
        self.init();
        for step in 1..=self.scn.steps {
            // Variable-length responses: the batch's max generated length
            // this step (EOS stopping), which every downstream tensor
            // inherits. Offline algorithms (DPO) have no rollout whose
            // length could vary — their preference pairs are fixed-size,
            // so every phase sees the configured maximum.
            self.cur_gen_len = if self.scn.len_jitter && self.scn.algo.generates() {
                let g = self.scn.framework.gen_len;
                let lo = (g / 2).max(1);
                lo + self.rng.gen_range(g - lo + 1)
            } else {
                self.scn.framework.gen_len
            };
            for node in &program.nodes {
                self.exec(node);
            }
            self.b.step_end(step);
        }
    }

    /// Interpret one program node: phase mark, body, `empty_cache` hook.
    fn exec(&mut self, node: &PhaseNode) {
        if let Some(kind) = node.kind {
            self.b.phase(kind);
        }
        match &node.body {
            PhaseBody::Generation { greedy_baseline } => self.generation(*greedy_baseline),
            PhaseBody::RemoteSequences { greedy_baseline } => {
                self.remote_sequences(*greedy_baseline)
            }
            PhaseBody::LoadExperience { tensors } => self.load_experience(tensors),
            PhaseBody::Infer { role, pairs } => self.infer_body(*role, *pairs),
            PhaseBody::Advantages { kind } => self.advantages(*kind),
            PhaseBody::Train { role, loss, pairs } => self.train_body(*role, *loss, *pairs),
            PhaseBody::FreeExperience => self.free_experience(),
        }
        if let Some(kind) = node.kind {
            self.end_phase(kind);
        }
    }

    fn end_phase(&mut self, phase: PhaseKind) {
        if self.scn.policy.applies_after(phase) {
            self.b.empty_cache();
        }
    }

    /// Is `role`'s fp16 backbone stored ZeRO-3-partitioned on this rank
    /// (so forwards must gather)? Only the *training engines* shard —
    /// DeepSpeed-Chat's and ColossalChat's reference scripts leave frozen
    /// replicas unsharded — and a frozen shared backbone (LoRA/Hydra)
    /// never shards: the base weights take no optimizer step, so there is
    /// nothing to re-materialize per micro-batch.
    fn param_partitioned(&self, role: Role) -> bool {
        self.scn.strategy.zero.partitions_params()
            && role.is_trainable()
            && !self.scn.sharing.frozen_backbone_for(role)
    }

    // ---------------- Init ----------------

    fn init(&mut self) {
        self.b.phase(PhaseKind::Init);
        let world = self.scn.world;
        let z = self.scn.strategy.zero;
        let offload = self.scn.strategy.cpu_offload;

        let rank = self.scn.rank;

        for role in Role::ALL {
            // Placement × algorithm: only the models of this GPU's active
            // cast get engine state.
            if !self.active.contains(role) {
                continue;
            }
            // fp16 replica: per-tensor; partitioned under ZeRO-3, for the
            // training engines only (see `param_partitioned`). Under a
            // sharing placement a role allocates its `extra` tensors — the
            // full inventory if it owns its group's backbone, just its
            // value head if it rides another role's frozen replica.
            let partition = self.param_partitioned(role);
            let m = self.model(role);
            let sizes: Vec<u64> = m
                .extra
                .iter()
                .map(|t| {
                    let full = t.bytes(DType::F16);
                    if partition {
                        zero::shard_bytes(full, world, rank)
                    } else {
                        full
                    }
                })
                .collect();
            let handles = self.b.alloc_group(sizes, Tag::Param);
            let m = self.model_mut(role);
            m.param_handles = handles;
            m.resident = true;

            // Dense adapters. Separate/frozen-shared keep today's rule
            // (only the actor carries LoRA); the adapter-training
            // placements allocate every trainable role's adapter set (the
            // value head is already a Param above, so it is excluded).
            let adapter_sizes: Vec<u64> = match self.scn.sharing {
                Sharing::Separate | Sharing::FrozenShared => {
                    if role == Role::Actor && self.scn.strategy.lora.is_some() {
                        self.model(role)
                            .trainable
                            .iter()
                            .map(|t| t.bytes(DType::F16))
                            .collect()
                    } else {
                        vec![]
                    }
                }
                Sharing::Lora => self
                    .model(role)
                    .trainable
                    .iter()
                    .filter(|t| t.name != "v_head")
                    .map(|t| t.bytes(DType::F16))
                    .collect(),
                Sharing::Hydra => {
                    if role == Role::Actor {
                        self.model(role)
                            .trainable
                            .iter()
                            .map(|t| t.bytes(DType::F16))
                            .collect()
                    } else {
                        vec![]
                    }
                }
                Sharing::Perl => {
                    if role == Role::Actor {
                        if self.scn.strategy.lora.is_some() {
                            self.model(role)
                                .trainable
                                .iter()
                                .map(|t| t.bytes(DType::F16))
                                .collect()
                        } else {
                            vec![]
                        }
                    } else {
                        self.model(role)
                            .trainable
                            .iter()
                            .filter(|t| t.name != "v_head")
                            .map(|t| t.bytes(DType::F16))
                            .collect()
                    }
                }
            };
            if !adapter_sizes.is_empty() {
                let hs = self.b.alloc_group(adapter_sizes, Tag::Param);
                self.model_mut(role).adapter_handles = hs;
            }

            // Optimizer states (trainable models; on host when offloaded).
            if role.is_trainable() && !offload {
                let trainable_refs: Vec<&TensorSpec> =
                    self.model(role).trainable.iter().collect();
                let states = adam_state_tensors(&trainable_refs, AdamConfig::default());
                let sizes: Vec<u64> = states
                    .iter()
                    .map(|s| {
                        if z.partitions_optimizer() {
                            zero::shard_bytes(s.bytes, world, rank)
                        } else {
                            s.bytes
                        }
                    })
                    .collect();
                let hs = self.b.alloc_group(sizes, Tag::OptState);
                self.model_mut(role).opt_handles = hs;
            }

            // DeepSpeed pre-allocates its communication machinery once at
            // engine init (the `__ipg_buffer` reduce bucket; the pinned
            // staging pair for offload) — these persist across steps rather
            // than churning per micro-batch.
            if role.is_trainable() {
                if z.partitions_gradients() {
                    let gb = self.model(role).trainable_bytes_f16();
                    let bucket = gb.min(zero::defaults::REDUCE_BUCKET_BYTES).max(16);
                    let h = self.b.alloc(bucket, Tag::CommBuffer);
                    self.model_mut(role).opt_handles.push(h);
                }
                if offload {
                    let gb = self.model(role).trainable_bytes_f16();
                    let cfg = crate::strategies::offload::OffloadConfig::default();
                    let chunk = gb.min(cfg.staging_bytes).max(16);
                    for _ in 0..cfg.live_buffers() {
                        let h = self.b.alloc(chunk, Tag::Staging);
                        self.model_mut(role).opt_handles.push(h);
                    }
                }
            }
        }

        // DeepSpeed-Chat hybrid engine: fused inference containers hold a
        // second copy of the actor weights (ZeRO-3 materializes them from
        // gathers at generation time instead). With a frozen shared
        // backbone only the adapters drift from the inference copy, so
        // the duplicate shrinks to per-layer adapter bytes.
        if self.scn.framework.hybrid_engine
            && !self.param_partitioned(Role::Actor)
            && self.active.contains(Role::Actor)
        {
            let layers = self.actor.inv.arch.n_layers;
            let mut sizes: Vec<u64> = Vec::new();
            for l in 0..layers {
                let b = if self.scn.sharing.frozen_backbone_for(Role::Actor) {
                    self.actor
                        .trainable
                        .iter()
                        .filter(|t| t.layer == Some(l))
                        .map(|t| t.bytes(DType::F16))
                        .sum::<u64>()
                        .max(16)
                } else {
                    self.actor.inv.layer_bytes(l, DType::F16)
                };
                sizes.push(b);
            }
            let hs = self.b.alloc_group(sizes, Tag::Param);
            self.actor.opt_handles.extend(hs); // lifetime = engine lifetime
        }
    }

    // ---------------- Experience generation ----------------

    fn generation(&mut self, greedy_baseline: bool) {
        let fw = &self.scn.framework;
        let world = self.scn.world;
        let z3 = self.param_partitioned(Role::Actor);

        // DeepSpeed hybrid-engine style: under ZeRO-3 the actor's full
        // parameters are gathered once for the whole generation phase.
        let mut gathered: Vec<TraceHandle> = vec![];
        if z3 {
            let arch_layers = self.actor.inv.arch.n_layers;
            let mut sizes: Vec<u64> = Vec::new();
            let global: u64 = self
                .actor
                .inv
                .global_tensors()
                .map(|t| t.bytes(DType::F16))
                .sum();
            sizes.push(global);
            for l in 0..arch_layers {
                sizes.push(self.actor.inv.layer_bytes(l, DType::F16));
            }
            let total: u64 = sizes.iter().sum();
            gathered = self.b.alloc_group(sizes, Tag::CommBuffer);
            let us = self.actor.cost.allgather_us(total, world);
            self.b.compute(us);
        }

        let chunks = fw.infer_chunks();
        let mb = fw.infer_micro_batch.min(fw.rollout_batch);
        let gen_len = self.cur_gen_len;
        for _chunk in 0..chunks {
            self.generate_chunk(mb, gen_len);
        }
        // ReMax's advantage baseline: a second, *greedy* rollout of the
        // same shape — the prefill/decode KV and logits churn happens
        // twice per step.
        if greedy_baseline {
            for _chunk in 0..chunks {
                self.generate_chunk(mb, gen_len);
            }
        }

        if z3 {
            self.b.free_all(gathered);
        }

        // The generated sequences + attention masks persist as experience.
        let fw = &self.scn.framework;
        let seq_bytes = fw.rollout_batch * (fw.prompt_len + self.cur_gen_len) * DType::I64.bytes();
        let seqs = self.b.alloc(seq_bytes, Tag::Experience);
        let mask = self.b.alloc(seq_bytes, Tag::Experience);
        self.exp.handles.push(seqs);
        self.exp.handles.push(mask);
        if greedy_baseline {
            // Greedy baseline sequences + mask persist for reward scoring.
            let hs = self.b.alloc_group([seq_bytes, seq_bytes], Tag::Experience);
            self.exp.handles.extend(hs);
        }
    }

    /// One generation micro-batch: prefill + autoregressive decode with a
    /// HuggingFace-style dynamic KV cache (per-step concat churn).
    fn generate_chunk(&mut self, mb: u64, gen_len: u64) {
        let fw = self.scn.framework.clone();
        let n_layers = self.actor.inv.arch.n_layers;
        let prompt = SeqShape {
            batch: mb,
            seq: fw.prompt_len,
        };

        // Prefill: per-layer transients + initial KV tensors.
        let mut kv_handles: Vec<(TraceHandle, TraceHandle)> = Vec::with_capacity(n_layers as usize);
        for _l in 0..n_layers {
            let transients: Vec<u64> = self
                .actor
                .act
                .layer_transients(prompt)
                .iter()
                .map(|t| t.bytes)
                .collect();
            self.b.transient(transients, Tag::Activation);
            let kb = self.actor.kv.layer_kv_bytes(mb, fw.prompt_len);
            let k = self.b.alloc(kb, Tag::KvCache);
            let v = self.b.alloc(kb, Tag::KvCache);
            kv_handles.push((k, v));
        }
        self.b
            .compute(self.actor.cost.forward_us(mb * fw.prompt_len));
        // Prefill logits (full prompt) — sampled then dropped.
        let prefill_logits = self.b.alloc(
            self.actor.act.logits_bytes(prompt),
            Tag::Logits,
        );
        self.b.free(prefill_logits);

        // Decode loop.
        let mut colossal_logits: Option<TraceHandle> = None;
        for t in 0..gen_len {
            let cur = fw.prompt_len + t;
            for l in 0..n_layers as usize {
                // Per-step per-layer workspace: fused qkv/ctx temporaries
                // plus the [mb, h, 1, cur] attention row.
                let d = self.actor.inv.arch.d_model;
                let h = self.actor.inv.arch.n_heads;
                let qkv_ws = 3 * mb * d * DType::F16.bytes();
                let score_ws = mb * h * (cur + 1) * DType::F16.bytes();
                self.b.transient([qkv_ws, score_ws], Tag::Activation);

                // KV concat: allocate len+1 tensors, free the old pair.
                let new_bytes = self.actor.kv.layer_kv_bytes(mb, cur + 1);
                let nk = self.b.alloc(new_bytes, Tag::KvCache);
                let nv = self.b.alloc(new_bytes, Tag::KvCache);
                let (ok, ov) = kv_handles[l];
                self.b.free(ok);
                self.b.free(ov);
                kv_handles[l] = (nk, nv);
            }
            match fw.generation {
                GenerationImpl::HuggingFace => {
                    // [mb, vocab] fp32 step logits.
                    let lb = self.actor.act.step_logits_bytes(mb);
                    self.b.transient([lb], Tag::Logits);
                }
                GenerationImpl::ColossalOriginal => {
                    // Keeps cumulative [mb, cur+1, vocab] logits each step.
                    let lb = mb * (cur + 1) * self.actor.inv.arch.vocab * 4;
                    let nh = self.b.alloc(lb, Tag::Logits);
                    if let Some(old) = colossal_logits.take() {
                        self.b.free(old);
                    }
                    colossal_logits = Some(nh);
                }
            }
            self.b.compute(self.actor.cost.decode_step_us(mb));
        }
        if let Some(h) = colossal_logits {
            self.b.free(h);
        }
        // Free the final KV cache.
        for (k, v) in kv_handles {
            self.b.free(k);
            self.b.free(v);
        }
    }

    // ---------------- Scoring inferences ----------------

    fn infer_body(&mut self, role: Role, pairs: bool) {
        // ColossalChat re-uploads host-offloaded inference models when the
        // experience phase needs them.
        if !self.model(role).resident {
            self.upload_model(role);
        }

        let fw = self.scn.framework.clone();
        let mb = fw.infer_micro_batch.min(fw.rollout_batch);
        let sh = SeqShape {
            batch: mb,
            seq: fw.prompt_len + self.cur_gen_len,
        };
        // DPO scores chosen + rejected: twice the forward passes.
        let chunks = fw.infer_chunks() * if pairs { 2 } else { 1 };
        let per_gpu_rollout = fw.rollout_batch;

        for _c in 0..chunks {
            // Head outputs are produced while the last gathered params are
            // still live (module hooks release them after the forward), so
            // their allocation precedes the gather-ring drain.
            let head: Vec<u64> = match role {
                Role::Actor | Role::Reference => {
                    let lb = self.model(role).act.logits_bytes(sh);
                    vec![lb, lb] // logits + log-softmax workspace
                }
                Role::Reward | Role::Critic => vec![mb * sh.seq * 4],
            };
            self.forward_layers(role, sh, &head);
            let us = self.model(role).cost.forward_us(mb * sh.seq);
            self.b.compute(us);
        }

        // Persisted experience from this phase (paired scorers keep both
        // sets' outputs: DPO's chosen+rejected logprobs, ReMax's primary
        // + greedy-baseline rewards).
        let s = fw.prompt_len + self.cur_gen_len;
        let keep: Vec<u64> = match role {
            Role::Actor => vec![per_gpu_rollout * s * 4], // old logprobs
            Role::Reference => {
                let lp = per_gpu_rollout * s * 4; // ref logprobs
                if pairs {
                    vec![lp, lp]
                } else {
                    vec![lp]
                }
            }
            Role::Reward => {
                let r = per_gpu_rollout * 4; // sequence rewards
                if pairs {
                    vec![r, r]
                } else {
                    vec![r]
                }
            }
            Role::Critic => vec![per_gpu_rollout * s * 4], // values
        };
        let hs = self.b.alloc_group(keep, Tag::Experience);
        self.exp.handles.extend(hs);
    }

    /// Advantage/return computation on experience tensors.
    fn advantages(&mut self, kind: AdvantageKind) {
        let fw = &self.scn.framework;
        let s = fw.prompt_len + self.cur_gen_len;
        let b = fw.rollout_batch;
        let sizes = match kind {
            // GAE over critic values: advantages + returns.
            AdvantageKind::Gae => vec![b * s * 4, b * s * 4],
            // Per-sequence group baselines + per-token advantages.
            AdvantageKind::GroupRelative => vec![b * 4, b * s * 4],
            // Per-token advantages only: the greedy rollout's rewards
            // were already persisted by the doubled reward pass.
            AdvantageKind::GreedyBaseline => vec![b * s * 4],
        };
        let hs = self.b.alloc_group(sizes, Tag::Experience);
        self.exp.handles.extend(hs);
    }

    /// Sequences + attention masks received from the actor's GPU — what a
    /// scorer-only GPU of a placement plan holds instead of generating.
    /// Lengths follow the same jitter stream as the actor's rank, so every
    /// GPU of a plan agrees on this step's shapes. Under ReMax the greedy
    /// rollout's sequences arrive too (the reward pass scores them).
    fn remote_sequences(&mut self, greedy_baseline: bool) {
        let fw = &self.scn.framework;
        let seq_bytes = fw.rollout_batch * (fw.prompt_len + self.cur_gen_len) * DType::I64.bytes();
        let hs = self.b.alloc_group([seq_bytes, seq_bytes], Tag::Experience);
        self.exp.handles.extend(hs);
        if greedy_baseline {
            let hs = self.b.alloc_group([seq_bytes, seq_bytes], Tag::Experience);
            self.exp.handles.extend(hs);
        }
    }

    /// Experience loaded instead of generated: E6's pre-collected batches
    /// and DPO's offline preference pairs, sized by the program node's
    /// tensor list.
    fn load_experience(&mut self, tensors: &[ExpTensor]) {
        let fw = &self.scn.framework;
        let s = fw.total_seq();
        let b = fw.rollout_batch;
        let sizes: Vec<u64> = tensors.iter().map(|t| t.bytes(b, s)).collect();
        let hs = self.b.alloc_group(sizes, Tag::Experience);
        self.exp.handles.extend(hs);
    }

    fn free_experience(&mut self) {
        let hs = std::mem::take(&mut self.exp.handles);
        self.b.free_all(hs);
    }

    // ---------------- Training ----------------

    fn train_body(&mut self, role: Role, loss: LossKind, pairs: bool) {
        // ColossalChat: move the frozen scorers off-GPU while training.
        if role == Role::Actor
            && self.scn.framework.offload_inference_models_during_training
            && self.scn.mode == ScenarioMode::Full
        {
            self.offload_model(Role::Reference);
            self.offload_model(Role::Reward);
        }
        // Placement-plan phase time-sharing: swap the colocated frozen
        // scorers to host for the whole training span (they re-upload when
        // the next step's inference phases need them). Runs at whichever
        // training phase comes first on this GPU; offload_model is
        // idempotent, so the second phase is a no-op.
        if self.scn.mode == ScenarioMode::Full && !self.scn.time_shared.is_empty() {
            for r in [Role::Reference, Role::Reward] {
                if self.scn.time_shared.contains(r) {
                    self.offload_model(r);
                }
            }
        }

        let fw = self.scn.framework.clone();
        // DPO forwards chosen+rejected concatenated: double micro-batch.
        let pair_factor = if pairs { 2 } else { 1 };
        let mb = fw.train_micro_batch.min(fw.rollout_batch) * pair_factor;
        let sh = SeqShape {
            batch: mb,
            seq: fw.prompt_len + self.cur_gen_len,
        };
        let world = self.scn.world;
        let z = self.scn.strategy.zero;

        // ZeRO-2/3 partitioned gradient storage (freed after the step).
        let mut part_grads: Vec<TraceHandle> = vec![];
        if z.partitions_gradients() {
            let gb = self.model(role).trainable_bytes_f16();
            part_grads.push(
                self.b
                    .alloc(zero::shard_bytes(gb, world, self.scn.rank).max(16), Tag::Grad),
            );
        }

        for _epoch in 0..fw.ppo_epochs {
            for _chunk in 0..fw.train_chunks() {
                self.train_micro_step(role, sh, loss);
            }
        }

        self.optimizer_step(role);
        self.b.free_all(part_grads);
        // zero_grad(set_to_none=True): drop dense grads after the step.
        let ghs = std::mem::take(&mut self.model_mut(role).grad_handles);
        self.b.free_all(ghs);
    }

    /// One training micro-batch: forward (saving activations), loss,
    /// backward (consuming them), gradient production.
    fn train_micro_step(&mut self, role: Role, sh: SeqShape, loss: LossKind) {
        let z = self.scn.strategy.zero;
        let world = self.scn.world;
        let ckpt = self.scn.strategy.grad_checkpoint;
        let n_layers = self.model(role).inv.arch.n_layers;

        // ---- Forward ----
        let mut saved: Vec<Vec<TraceHandle>> = Vec::with_capacity(n_layers as usize);
        let mut ring = GatherRing::new(zero::defaults::MAX_LIVE_GATHERED_BYTES);
        let mut stream = GatherStream::new(
            &self.model(role).inv,
            false,
            zero::defaults::PREFETCH_BUCKET_BYTES,
        );
        let mut fwd_us = 0.0;
        for l in 0..n_layers {
            if self.param_partitioned(role) {
                // Prefetch-bucketed all-gather; gathered copies stay live up
                // to `stage3_max_live_parameters`, interleaving with the
                // saved activations below.
                let newly = stream.advance(l as usize, &mut ring, &mut self.b);
                fwd_us += self.model(role).cost.allgather_us(newly, world);
            }
            let m = self.model(role);
            let sizes: Vec<u64> = if ckpt {
                m.act.layer_checkpoint(sh).iter().map(|t| t.bytes).collect()
            } else {
                m.act.layer_saved(sh).iter().map(|t| t.bytes).collect()
            };
            // Transient part of the layer fwd (not saved).
            let extra: Vec<u64> = m
                .act
                .layer_transients(sh)
                .iter()
                .take(3)
                .map(|t| t.bytes)
                .collect();
            self.b.transient(extra, Tag::Activation);
            let hs = self.b.alloc_group(sizes, Tag::SavedActivation);
            saved.push(hs);
        }
        fwd_us += self.model(role).cost.forward_us(sh.batch * sh.seq);
        self.b.compute(fwd_us);

        // ---- Head + loss (before the gathered params are released) ----
        let mut head_saved: Vec<TraceHandle> = vec![];
        match loss {
            LossKind::PpoClip => {
                let lb = self.model(role).act.logits_bytes(sh);
                head_saved.push(self.b.alloc(lb, Tag::SavedActivation));
                // logprobs, ratio, clipped surrogate, KL penalty temps.
                let t = sh.batch * sh.seq * 4;
                self.b.transient([lb, t, t, t, t], Tag::Workspace);
            }
            LossKind::ValueLoss => {
                let t = sh.batch * sh.seq * 4;
                // values, clipped values, value-loss temps.
                self.b.transient([t, t, t], Tag::Workspace);
            }
            LossKind::Preference => {
                let lb = self.model(role).act.logits_bytes(sh);
                head_saved.push(self.b.alloc(lb, Tag::SavedActivation));
                // Pair logprobs, chosen−rejected margin, −logσ loss temps.
                let t = sh.batch * sh.seq * 4;
                self.b.transient([lb, t, t, t], Tag::Workspace);
            }
        }
        ring.drain(&mut self.b);
        self.b.free_all(head_saved);

        // ---- Backward (reverse layer order, reversed gather stream) ----
        let mut bwd_us = 0.0;
        let mut ring = GatherRing::new(zero::defaults::MAX_LIVE_GATHERED_BYTES);
        let mut stream = GatherStream::new(
            &self.model(role).inv,
            true,
            zero::defaults::PREFETCH_BUCKET_BYTES,
        );
        for (i, _l) in (0..n_layers).rev().enumerate() {
            if self.param_partitioned(role) {
                let newly = stream.advance(i, &mut ring, &mut self.b);
                bwd_us += self.model(role).cost.allgather_us(newly, world);
            }
            let l = n_layers - 1 - i as u64;
            let m = self.model(role);
            if ckpt {
                // Recompute the layer: transient re-materialization.
                let recompute: Vec<u64> = m.act.layer_saved(sh).iter().map(|t| t.bytes).collect();
                self.b.transient(recompute, Tag::Activation);
            }
            let bwd: Vec<u64> = self
                .model(role)
                .act
                .layer_backward_transients(sh)
                .iter()
                .map(|t| t.bytes)
                .collect();
            self.b.transient(bwd, Tag::Activation);

            // Dense per-tensor grads for this layer's trainable params
            // (ZeRO-0/1 keep them; ZeRO-2/3 reduce into the partition).
            if !self.scn.strategy.zero.partitions_gradients() {
                let first_chunk = self.model(role).grad_handles.is_empty() && l == n_layers - 1;
                if first_chunk || self.layer_grads_missing(role) {
                    let sizes: Vec<u64> = self
                        .model(role)
                        .trainable
                        .iter()
                        .filter(|t| t.layer == Some(l))
                        .map(|t| t.bytes(DType::F16))
                        .collect();
                    if !sizes.is_empty() {
                        let hs = self.b.alloc_group(sizes, Tag::Grad);
                        self.model_mut(role).grad_handles.extend(hs);
                    }
                }
            }

            // Free this layer's saved activations (consumed by backward).
            let hs = saved.pop().unwrap();
            self.b.free_all(hs);
        }
        ring.drain(&mut self.b);

        // Non-layer trainable grads (value head) once per phase.
        if !self.scn.strategy.zero.partitions_gradients() {
            let sizes: Vec<u64> = self
                .model(role)
                .trainable
                .iter()
                .filter(|t| t.layer.is_none())
                .map(|t| t.bytes(DType::F16))
                .collect();
            let missing = self.layer_grads_missing(role);
            if !sizes.is_empty() && missing {
                let hs = self.b.alloc_group(sizes, Tag::Grad);
                self.model_mut(role).grad_handles.extend(hs);
            }
        }

        // ZeRO-2/3: reduce-scatter this chunk's gradients through the
        // persistent ipg bucket (allocated at Init) — time cost only.
        if self.scn.strategy.zero.partitions_gradients() {
            let gb = self.model(role).trainable_bytes_f16();
            for bucket in zero::reduce_buckets(gb, zero::defaults::REDUCE_BUCKET_BYTES) {
                bwd_us += self.model(role).cost.reduce_scatter_us(bucket, world);
            }
        }

        bwd_us += 2.0 * self.model(role).cost.forward_us(sh.batch * sh.seq);
        self.b.compute(bwd_us);
    }

    /// Have this role's dense grads not been allocated yet this phase?
    fn layer_grads_missing(&self, role: Role) -> bool {
        self.model(role).grad_handles.len() < self.model(role).trainable.len()
    }

    fn optimizer_step(&mut self, role: Role) {
        let world = self.scn.world;
        if self.scn.strategy.cpu_offload {
            // Grads stream down / params stream up through the persistent
            // pinned staging pair allocated at Init — time cost only.
            let gb = self.model(role).trainable_bytes_f16();
            let per_rank = if self.scn.strategy.zero.partitions_gradients() {
                zero::shard_bytes(gb, world, self.scn.rank)
            } else {
                gb
            };
            let us = 2.0 * self.model(role).cost.host_copy_us(per_rank);
            self.b.compute(us);
        } else {
            // FP16_Optimizer converts fp16 gradients to fp32 *per tensor*
            // before fused Adam runs (transient, LIFO-freed).
            let part = self.scn.strategy.zero.partitions_optimizer();
            let rank = self.scn.rank;
            let sizes: Vec<u64> = self
                .model(role)
                .trainable
                .iter()
                .map(|t| {
                    let fp32 = t.numel * 4;
                    let b = if part {
                        zero::shard_bytes(fp32, world, rank)
                    } else {
                        fp32
                    };
                    b.max(512)
                })
                .collect();
            for chunk in sizes.chunks(16) {
                self.b.transient(chunk.to_vec(), Tag::Workspace);
            }
        }
    }

    // ---------------- ColossalChat host offload of scorers ----------------

    fn offload_model(&mut self, role: Role) {
        if !self.model(role).resident {
            return;
        }
        let hs = std::mem::take(&mut self.model_mut(role).param_handles);
        self.b.free_all(hs);
        self.model_mut(role).resident = false;
        // A role that rides another role's frozen replica only moves its
        // own (`extra`) tensors; the shared backbone stays on-device.
        let total = self.model(role).extra_bytes_f16();
        if total > 0 {
            let us = self.model(role).cost.host_copy_us(total);
            self.b.compute(us);
        }
    }

    fn upload_model(&mut self, role: Role) {
        // Only frozen scorers are host-offloaded, and those are unsharded.
        // With a sharing placement the role re-allocates only the tensors
        // it owns (`extra`) — a shared backbone never left the device.
        let sizes: Vec<u64> = self
            .model(role)
            .extra
            .iter()
            .map(|t| t.bytes(DType::F16))
            .collect();
        let hs = self.b.alloc_group(sizes, Tag::Param);
        let m = self.model_mut(role);
        m.param_handles = hs;
        m.resident = true;
        let total = self.model(role).extra_bytes_f16();
        if total > 0 {
            let us = self.model(role).cost.host_copy_us(total);
            self.b.compute(us);
        }
    }

    // ---------------- helpers ----------------

    fn model(&self, role: Role) -> &SimModel {
        match role {
            Role::Actor => &self.actor,
            Role::Reference => &self.reference,
            Role::Critic => &self.critic,
            Role::Reward => &self.reward,
        }
    }

    fn model_mut(&mut self, role: Role) -> &mut SimModel {
        match role {
            Role::Actor => &mut self.actor,
            Role::Reference => &mut self.reference,
            Role::Critic => &mut self.critic,
            Role::Reward => &mut self.reward,
        }
    }

    /// Forward through all layers without saving (inference).
    /// `head_sizes` are the LM/value-head tensors allocated (transiently)
    /// before the gathered parameters are released.
    fn forward_layers(&mut self, role: Role, sh: SeqShape, head_sizes: &[u64]) {
        // Only the sharded training engines (actor/critic) need gathers;
        // frozen scorers — and frozen shared backbones — hold full
        // replicas.
        let z3 = self.param_partitioned(role);
        let world = self.scn.world;
        let n_layers = self.model(role).inv.arch.n_layers;
        let mut ring = GatherRing::new(zero::defaults::MAX_LIVE_GATHERED_BYTES);
        let mut stream = GatherStream::new(
            &self.model(role).inv,
            false,
            zero::defaults::PREFETCH_BUCKET_BYTES,
        );
        let mut us = 0.0;
        for l in 0..n_layers {
            if z3 {
                let newly = stream.advance(l as usize, &mut ring, &mut self.b);
                us += self.model(role).cost.allgather_us(newly, world);
            }
            let sizes: Vec<u64> = self
                .model(role)
                .act
                .layer_transients(sh)
                .iter()
                .map(|t| t.bytes)
                .collect();
            self.b.transient(sizes, Tag::Activation);
            let hb = self.model(role).act.hidden_bytes(sh);
            let hs = self.b.alloc(hb, Tag::Activation);
            self.b.free(hs);
        }
        self.b.transient(head_sizes.to_vec(), Tag::Logits);
        ring.drain(&mut self.b);
        self.b.compute(us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;

    fn small_scn(strategy: StrategyConfig) -> SimScenario {
        let mut s = SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::Never);
        s.steps = 1;
        s
    }

    #[test]
    fn trace_is_balanced_modulo_persistents() {
        let scn = small_scn(StrategyConfig::none());
        let trace = build_trace(&scn);
        // Persistent model/optimizer state legitimately outlives the trace;
        // everything else must balance.
        let leaked = trace.check_balanced().unwrap();
        // params (4 models) + adapters (2) + opt (2 models) remain.
        assert!(!leaked.is_empty());
        assert!(trace.len() > 10_000, "trace too short: {}", trace.len());
    }

    #[test]
    fn zero3_emits_comm_buffers() {
        use crate::trace::TraceOp;
        let trace = build_trace(&small_scn(StrategyConfig::zero3()));
        let gathers = trace
            .ops
            .iter()
            .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::CommBuffer, .. }))
            .count();
        assert!(gathers > 50, "expected many gathers, got {gathers}");
        let none = build_trace(&small_scn(StrategyConfig::none()));
        let gathers_none = none
            .ops
            .iter()
            .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::CommBuffer, .. }))
            .count();
        assert_eq!(gathers_none, 0);
    }

    #[test]
    fn checkpointing_reduces_saved_bytes() {
        use crate::trace::TraceOp;
        let saved = |t: &Trace| -> u64 {
            t.ops
                .iter()
                .filter_map(|op| match op {
                    TraceOp::Alloc {
                        tag: Tag::SavedActivation,
                        bytes,
                        ..
                    } => Some(*bytes),
                    _ => None,
                })
                .sum()
        };
        let base = saved(&build_trace(&small_scn(StrategyConfig::none())));
        let ckpt = saved(&build_trace(&small_scn(StrategyConfig::checkpointing())));
        assert!(
            ckpt * 4 < base,
            "checkpointing should slash saved activations: {ckpt} vs {base}"
        );
    }

    #[test]
    fn offload_removes_opt_state_and_adds_staging() {
        use crate::trace::TraceOp;
        let count_tag = |t: &Trace, want: Tag| -> usize {
            t.ops
                .iter()
                .filter(|op| matches!(op, TraceOp::Alloc { tag, .. } if *tag == want))
                .count()
        };
        let off = build_trace(&small_scn(StrategyConfig::zero3_offload()));
        assert_eq!(count_tag(&off, Tag::OptState), 0);
        assert!(count_tag(&off, Tag::Staging) > 0);
        let on = build_trace(&small_scn(StrategyConfig::zero3()));
        assert!(count_tag(&on, Tag::OptState) > 0);
        assert_eq!(count_tag(&on, Tag::Staging), 0);
    }

    #[test]
    fn policy_inserts_empty_cache() {
        use crate::trace::TraceOp;
        let count_ec = |t: &Trace| t.ops.iter().filter(|op| matches!(op, TraceOp::EmptyCache)).count();
        let mut scn = small_scn(StrategyConfig::none());
        assert_eq!(count_ec(&build_trace(&scn)), 0);
        scn.policy = EmptyCachePolicy::AfterBoth;
        // 5 inference + 2 training phases per step.
        assert_eq!(count_ec(&build_trace(&scn)), 7);
        scn.policy = EmptyCachePolicy::AfterInference;
        assert_eq!(count_ec(&build_trace(&scn)), 5);
        scn.policy = EmptyCachePolicy::AfterTraining;
        assert_eq!(count_ec(&build_trace(&scn)), 2);
    }

    #[test]
    fn scenario_modes_shrink_pipeline() {
        use crate::trace::TraceOp;
        let phases = |t: &Trace| -> Vec<PhaseKind> {
            t.ops
                .iter()
                .filter_map(|op| match op {
                    TraceOp::Phase(p) => Some(*p),
                    _ => None,
                })
                .collect()
        };
        let mut scn = small_scn(StrategyConfig::none());
        scn.mode = ScenarioMode::TrainActorOnly;
        let ps = phases(&build_trace(&scn));
        assert!(ps.contains(&PhaseKind::TrainActor));
        assert!(!ps.contains(&PhaseKind::Generation));
        assert!(!ps.contains(&PhaseKind::TrainCritic));

        scn.mode = ScenarioMode::TrainBothPrecollected;
        let ps = phases(&build_trace(&scn));
        assert!(ps.contains(&PhaseKind::TrainCritic));
        assert!(!ps.contains(&PhaseKind::InferReward));
    }

    #[test]
    fn colossal_offloads_scorers_during_training() {
        use crate::trace::TraceOp;
        let scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let trace = build_trace(&scn);
        // Params are freed (offload) and re-allocated (upload) mid-trace:
        // count Param allocations beyond Init's 4 models + adapters.
        let param_allocs = trace
            .ops
            .iter()
            .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::Param, .. }))
            .count();
        let ds = build_trace(&SimScenario::deepspeed_opt(
            StrategyConfig::none(),
            EmptyCachePolicy::Never,
        ));
        let ds_param_allocs = ds
            .ops
            .iter()
            .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::Param, .. }))
            .count();
        // ColossalChat re-uploads ref+reward each of 3 steps... with steps=3
        // in the preset; both presets share steps, so colossal must exceed.
        assert!(param_allocs > ds_param_allocs);
    }

    #[test]
    fn role_subsets_shrink_the_trace() {
        use crate::rlhf::models::RoleSet;
        use crate::trace::TraceOp;
        let phases = |t: &Trace| -> Vec<PhaseKind> {
            t.ops
                .iter()
                .filter_map(|op| match op {
                    TraceOp::Phase(p) => Some(*p),
                    _ => None,
                })
                .collect()
        };
        let full = build_trace(&small_scn(StrategyConfig::none()));
        let mut scn = small_scn(StrategyConfig::none());
        scn.roles = RoleSet::of(&[Role::Reference, Role::Reward]);
        let scorer = build_trace(&scn);
        // A scorer-only GPU skips generation and both training phases —
        // its trace is a fraction of the full replica's.
        assert!(scorer.len() < full.len() / 2, "{} vs {}", scorer.len(), full.len());
        let ps = phases(&scorer);
        assert!(!ps.contains(&PhaseKind::Generation));
        assert!(!ps.contains(&PhaseKind::TrainActor));
        assert!(!ps.contains(&PhaseKind::TrainCritic));
        assert!(ps.contains(&PhaseKind::InferReference));
        assert!(ps.contains(&PhaseKind::InferReward));
    }

    #[test]
    fn time_shared_scorers_cycle_param_allocations() {
        use crate::rlhf::models::RoleSet;
        use crate::trace::TraceOp;
        let count_params = |t: &Trace| {
            t.ops
                .iter()
                .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::Param, .. }))
                .count()
        };
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 2;
        let resident = count_params(&build_trace(&scn));
        scn.time_shared = RoleSet::of(&[Role::Reference, Role::Reward]);
        let shared = count_params(&build_trace(&scn));
        // Swap-out during training forces a re-upload (fresh Param allocs)
        // each subsequent step.
        assert!(shared > resident, "{shared} vs {resident}");
    }

    #[test]
    #[should_panic(expected = "outside world")]
    fn rank_outside_world_panics() {
        let mut scn = small_scn(StrategyConfig::none());
        scn.rank = 4; // world is 4: ranks 0..=3
        build_trace(&scn);
    }

    #[test]
    fn per_rank_traces_have_identical_shape() {
        // Ranks of a symmetric replica differ only in flat-buffer shard
        // remainders (bytes, inside the 16 B padding) — never in op count.
        let mut a = small_scn(StrategyConfig::zero3());
        a.steps = 1;
        let t0 = build_trace(&a);
        let mut b = a.clone();
        b.rank = 3;
        let t3 = build_trace(&b);
        assert_eq!(t0.len(), t3.len());
    }

    #[test]
    fn multi_step_trace_scales_linearly() {
        let mut scn = small_scn(StrategyConfig::none());
        let one = build_trace(&scn).len();
        scn.steps = 3;
        let three = build_trace(&scn).len();
        assert!(three > 2 * one && three < 4 * one, "one={one} three={three}");
    }

    #[test]
    fn preset_table_backs_the_constructors() {
        let a = ScenarioPreset::by_name("deepspeed-opt").unwrap().build(
            StrategyConfig::none(),
            EmptyCachePolicy::Never,
        );
        assert_eq!(a.framework.kind, FrameworkKind::DeepSpeedChat);
        assert!(!a.len_jitter);
        assert_eq!(a.algo, Algo::Ppo);
        let b = ScenarioPreset::by_name("colossal-gpt2").unwrap().build(
            StrategyConfig::none(),
            EmptyCachePolicy::Never,
        );
        assert_eq!(b.models.policy_arch.name, "gpt2-xl");
        assert!(b.len_jitter, "colossal presets jitter");
        assert!(ScenarioPreset::by_name("nope").is_none());
        // Constructor == table row, field for field.
        let c = SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterBoth);
        assert_eq!(c.framework.kind, FrameworkKind::ColossalChat);
        assert_eq!(c.models.policy_arch.name, "opt-1.3b");
        assert!(c.len_jitter);
    }

    #[test]
    fn critic_free_algos_drop_critic_state_and_phases() {
        use crate::trace::TraceOp;
        let phases = |t: &Trace| -> Vec<PhaseKind> {
            t.ops
                .iter()
                .filter_map(|op| match op {
                    TraceOp::Phase(p) => Some(*p),
                    _ => None,
                })
                .collect()
        };
        for algo in [Algo::Grpo, Algo::Remax] {
            let mut scn = small_scn(StrategyConfig::none());
            scn.algo = algo;
            let t = build_trace(&scn);
            let ps = phases(&t);
            assert!(!ps.contains(&PhaseKind::InferCritic), "{:?}", algo);
            assert!(!ps.contains(&PhaseKind::TrainCritic), "{:?}", algo);
            assert!(ps.contains(&PhaseKind::Generation));
            assert!(ps.contains(&PhaseKind::InferReward));
            // Three models at Init instead of four: fewer Param allocs.
            let ppo = build_trace(&small_scn(StrategyConfig::none()));
            let count = |t: &Trace| {
                t.ops
                    .iter()
                    .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::Param, .. }))
                    .count()
            };
            assert!(count(&t) < count(&ppo));
        }
    }

    #[test]
    fn remax_doubles_generation_churn() {
        use crate::trace::TraceOp;
        let kv_allocs = |t: &Trace| {
            t.ops
                .iter()
                .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::KvCache, .. }))
                .count()
        };
        let ppo = build_trace(&small_scn(StrategyConfig::none()));
        let mut scn = small_scn(StrategyConfig::none());
        scn.algo = Algo::Remax;
        let remax = build_trace(&scn);
        assert_eq!(kv_allocs(&remax), 2 * kv_allocs(&ppo));
    }

    #[test]
    fn dpo_runs_reference_scoring_and_one_update_only() {
        use crate::trace::TraceOp;
        let mut scn = small_scn(StrategyConfig::none());
        scn.algo = Algo::Dpo;
        let t = build_trace(&scn);
        let ps: Vec<PhaseKind> = t
            .ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::Phase(p) => Some(*p),
                _ => None,
            })
            .collect();
        assert_eq!(
            ps,
            vec![PhaseKind::Init, PhaseKind::InferReference, PhaseKind::TrainActor]
        );
        // No rollout: no KV-cache churn at all.
        assert!(!t
            .ops
            .iter()
            .any(|op| matches!(op, TraceOp::Alloc { tag: Tag::KvCache, .. })));
    }

    fn alloc_bytes(t: &Trace, want: Tag) -> u64 {
        use crate::trace::TraceOp;
        t.ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::Alloc { tag, bytes, .. } if *tag == want => Some(*bytes),
                _ => None,
            })
            .sum()
    }

    #[test]
    fn shared_backbones_shrink_param_footprint() {
        let traced = |sharing: Sharing| {
            let mut scn = small_scn(StrategyConfig::none());
            scn.sharing = sharing;
            alloc_bytes(&build_trace(&scn), Tag::Param)
        };
        let separate = traced(Sharing::Separate);
        let lora = traced(Sharing::Lora);
        let hydra = traced(Sharing::Hydra);
        let frozen = traced(Sharing::FrozenShared);
        assert!(hydra < lora, "hydra {hydra} !< lora {lora}");
        assert!(lora < separate, "lora {lora} !< separate {separate}");
        assert!(frozen < separate, "frozen {frozen} !< separate {separate}");
    }

    #[test]
    fn adapter_only_optimizer_state_shrinks() {
        let opt = |sharing: Sharing| {
            let mut scn = small_scn(StrategyConfig::none());
            scn.sharing = sharing;
            alloc_bytes(&build_trace(&scn), Tag::OptState)
        };
        let separate = opt(Sharing::Separate);
        let lora = opt(Sharing::Lora);
        let hydra = opt(Sharing::Hydra);
        // Separate is dominated by the critic's *full* Adam state; the
        // sharing placements keep only adapter/head moments.
        assert!(
            lora * 2 < separate,
            "lora Adam state {lora} vs full fine-tune {separate}"
        );
        assert!(hydra < lora, "hydra {hydra} !< lora {lora}");
    }

    #[test]
    fn sharing_traces_stay_balanced() {
        for sharing in Sharing::ALL {
            for algo in Algo::ALL {
                let mut scn = small_scn(StrategyConfig::zero3());
                scn.sharing = sharing;
                scn.algo = algo;
                let trace = build_trace(&scn);
                trace
                    .check_balanced()
                    .unwrap_or_else(|e| panic!("{}/{}: {e}", sharing.name(), algo.name()));
            }
        }
    }

    #[test]
    fn frozen_backbones_skip_zero3_gathers() {
        use crate::trace::TraceOp;
        let gathers = |sharing: Sharing| {
            let mut scn = small_scn(StrategyConfig::zero3());
            scn.sharing = sharing;
            build_trace(&scn)
                .ops
                .iter()
                .filter(|op| matches!(op, TraceOp::Alloc { tag: Tag::CommBuffer, .. }))
                .count()
        };
        let separate = gathers(Sharing::Separate);
        let lora = gathers(Sharing::Lora);
        // A frozen backbone holds a full replica — no per-layer gather
        // churn, only the persistent reduce buckets survive.
        assert!(
            lora * 10 < separate,
            "lora gathers {lora} vs separate {separate}"
        );
    }

    #[test]
    fn colossal_offload_only_moves_owned_tensors_under_sharing() {
        let mut scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 2;
        let separate = alloc_bytes(&build_trace(&scn), Tag::Param);
        scn.sharing = Sharing::Lora;
        let lora = alloc_bytes(&build_trace(&scn), Tag::Param);
        // Ref/reward re-uploads shrink to their private heads, so the
        // cumulative Param traffic collapses alongside the Init footprint.
        assert!(lora < separate / 2, "{lora} vs {separate}");
    }
}
