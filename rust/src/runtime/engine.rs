//! The PJRT engine: loads the AOT HLO artifacts and exposes typed
//! score/decode/train calls over flat `Literal` parameter lists.
//!
//! This is the only place Python's output is consumed; after `make
//! artifacts` the binary is self-contained.

use super::manifest::Manifest;
use super::npz;
use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Which artifact family to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelVariant {
    /// XLA-fused jnp path (fast on CPU; default for long runs).
    Jnp,
    /// Pallas interpret path (numerics-identical; exercised by tests).
    Pallas,
}

impl KernelVariant {
    fn score_name(self) -> &'static str {
        match self {
            KernelVariant::Jnp => "score.jnp",
            KernelVariant::Pallas => "score.pallas",
        }
    }
}

/// Loaded engine with mutable actor state.
pub struct RlhfEngine {
    #[allow(dead_code)]
    client: PjRtClient,
    dir: String,
    variant: KernelVariant,
    pub manifest: Manifest,
    score_exe: PjRtLoadedExecutable,
    decode_exe: PjRtLoadedExecutable,
    train_exe: PjRtLoadedExecutable,
    /// Actor parameters (flat leaf order).
    pub params: Vec<Literal>,
    /// Frozen reference copy (KL baseline).
    pub ref_params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    pub train_steps_done: u64,
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

impl RlhfEngine {
    /// Load artifacts for `arch` from `dir` and compile all executables.
    pub fn load(dir: &str, arch: &str, variant: KernelVariant) -> Result<RlhfEngine> {
        let manifest = Manifest::load(&format!("{dir}/{arch}.manifest.json"))?;
        let client = PjRtClient::cpu()?;

        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            let file = manifest
                .artifact_file(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = format!("{dir}/{file}");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };

        let score_exe = compile(variant.score_name())?;
        let decode_exe = compile("decode.jnp")?;
        let train_exe = compile("train.jnp")?;

        // Initial parameters.
        let arrays = npz::load_npz(&format!("{dir}/{arch}.init.npz"))?;
        let mut params = Vec::with_capacity(manifest.leaves.len());
        let mut ref_params = Vec::with_capacity(manifest.leaves.len());
        let mut m = Vec::new();
        let mut v = Vec::new();
        for leaf in &manifest.leaves {
            let arr = arrays
                .get(&leaf.name)
                .ok_or_else(|| anyhow!("leaf {} missing from init.npz", leaf.name))?;
            if arr.numel() != leaf.numel() {
                bail!("leaf {} shape mismatch", leaf.name);
            }
            params.push(lit_f32(&arr.data, &leaf.shape)?);
            ref_params.push(lit_f32(&arr.data, &leaf.shape)?);
            let zeros = vec![0f32; leaf.numel()];
            m.push(lit_f32(&zeros, &leaf.shape)?);
            v.push(lit_f32(&zeros, &leaf.shape)?);
        }

        Ok(RlhfEngine {
            client,
            dir: dir.to_string(),
            variant,
            manifest,
            score_exe,
            decode_exe,
            train_exe,
            params,
            ref_params,
            m,
            v,
            train_steps_done: 0,
        })
    }

    /// Rebuild the PJRT client + executables, keeping all model state.
    ///
    /// The image's xla_extension 0.5.1 CPU client accumulates per-execution
    /// bookkeeping that makes call latency grow with the total number of
    /// executions; recycling the client every few hundred calls keeps the
    /// long end-to-end runs at steady throughput (EXPERIMENTS.md §Perf).
    pub fn recycle(&mut self) -> Result<()> {
        let fresh = Self::load(&self.dir, &self.manifest.arch, self.variant)?;
        self.client = fresh.client;
        self.score_exe = fresh.score_exe;
        self.decode_exe = fresh.decode_exe;
        self.train_exe = fresh.train_exe;
        Ok(())
    }

    fn run(
        exe: &PjRtLoadedExecutable,
        args: &[&Literal],
        expect_outputs: usize,
    ) -> Result<Vec<Literal>> {
        let result = exe.execute::<&Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != expect_outputs {
            bail!("expected {expect_outputs} outputs, got {}", outs.len());
        }
        Ok(outs)
    }

    /// Scoring pass with arbitrary parameters (actor or reference):
    /// returns (logprobs [b, s-1], values [b, s]) flattened.
    pub fn score(&self, with_params: &[Literal], tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, s) = (self.manifest.batch, self.manifest.max_seq);
        assert_eq!(tokens.len(), b * s);
        let tok = lit_i32(tokens, &[b, s])?;
        let mut args: Vec<&Literal> = with_params.iter().collect();
        args.push(&tok);
        let outs = Self::run(&self.score_exe, &args, 2)?;
        Ok((outs[0].to_vec::<f32>()?, outs[1].to_vec::<f32>()?))
    }

    /// Zeroed KV cache literal.
    pub fn init_kv(&self) -> Result<Literal> {
        let numel: usize = self.manifest.kv_shape.iter().product();
        lit_f32(&vec![0f32; numel], &self.manifest.kv_shape)
    }

    /// One decode step: (logits [b, vocab], new kv).
    pub fn decode(&self, kv: &Literal, token: &[i32], pos: i32) -> Result<(Vec<f32>, Literal)> {
        let b = self.manifest.batch;
        assert_eq!(token.len(), b);
        let tok = lit_i32(token, &[b])?;
        let pos_lit = Literal::scalar(pos);
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(kv);
        args.push(&tok);
        args.push(&pos_lit);
        let mut outs = Self::run(&self.decode_exe, &args, 2)?;
        let kv_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, kv_new))
    }

    /// One PPO train step; updates the actor in place. Returns
    /// (policy_loss, value_loss, entropy).
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &mut self,
        tokens: &[i32],
        mask: &[f32],
        old_logprobs: &[f32],
        old_values: &[f32],
        advantages: &[f32],
        returns: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let (b, s) = (self.manifest.batch, self.manifest.max_seq);
        let n = self.manifest.leaves.len();
        assert_eq!(tokens.len(), b * s);
        assert_eq!(old_logprobs.len(), b * (s - 1));
        self.train_steps_done += 1;
        let step = Literal::scalar(self.train_steps_done as f32);
        let tok = lit_i32(tokens, &[b, s])?;
        let mask_l = lit_f32(mask, &[b, s])?;
        let olp = lit_f32(old_logprobs, &[b, s - 1])?;
        let ov = lit_f32(old_values, &[b, s])?;
        let adv = lit_f32(advantages, &[b, s - 1])?;
        let ret = lit_f32(returns, &[b, s - 1])?;

        let mut args: Vec<&Literal> = Vec::with_capacity(3 * n + 7);
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.extend([&step, &tok, &mask_l, &olp, &ov, &adv, &ret]);

        let mut outs = Self::run(&self.train_exe, &args, 3 * n + 3)?;
        let ent = outs.pop().unwrap().to_vec::<f32>()?[0];
        let vf = outs.pop().unwrap().to_vec::<f32>()?[0];
        let pg = outs.pop().unwrap().to_vec::<f32>()?[0];
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        Ok((pg, vf, ent))
    }
}
