//! Artifact manifest: the shapes/arg-order contract `python/compile/aot.py`
//! writes and the engine obeys.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, Context, Result};

#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub arch: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub prompt: usize,
    pub num_params: u64,
    pub leaves: Vec<LeafSpec>,
    pub kv_shape: Vec<usize>,
    /// artifact name -> file name
    pub artifacts: Vec<(String, String)>,
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let j = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_json(&j).map_err(|e| anyhow!("{path}: {e}"))
    }

    pub fn from_json(j: &Json) -> Result<Manifest, String> {
        let cfg = j.req("config")?;
        let leaves = j
            .req_arr("leaves")?
            .iter()
            .map(|l| {
                Ok(LeafSpec {
                    name: l.req_str("name")?.to_string(),
                    shape: l
                        .req_arr("shape")?
                        .iter()
                        .map(|d| d.as_usize().ok_or("bad dim".to_string()))
                        .collect::<Result<Vec<_>, _>>()?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let artifacts = match j.req("artifacts")? {
            Json::Obj(kvs) => kvs
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        v.as_str().ok_or("artifact not a string")?.to_string(),
                    ))
                })
                .collect::<Result<Vec<_>, String>>()?,
            _ => return Err("artifacts not an object".into()),
        };
        Ok(Manifest {
            arch: j.req_str("arch")?.to_string(),
            vocab: cfg.req_u64("vocab")? as usize,
            d_model: cfg.req_u64("d_model")? as usize,
            n_layers: cfg.req_u64("n_layers")? as usize,
            n_heads: cfg.req_u64("n_heads")? as usize,
            max_seq: cfg.req_u64("max_seq")? as usize,
            batch: j.req_u64("batch")? as usize,
            prompt: j.req_u64("prompt")? as usize,
            num_params: j.req_u64("num_params")?,
            leaves,
            kv_shape: j
                .req_arr("kv_shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad kv dim".to_string()))
                .collect::<Result<Vec<_>, _>>()?,
            artifacts,
        })
    }

    pub fn artifact_file(&self, name: &str) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn total_leaf_elems(&self) -> usize {
        self.leaves.iter().map(|l| l.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "arch": "opt-nano",
      "config": {"vocab": 512, "d_model": 256, "n_layers": 4, "n_heads": 8,
                 "ffn": 1024, "max_seq": 96},
      "batch": 4, "prompt": 32, "num_params": 3407616,
      "leaves": [{"name": "tok_emb", "shape": [512, 256], "dtype": "float32"}],
      "kv_shape": [4, 2, 4, 8, 96, 32],
      "artifacts": {"score.jnp": "opt-nano.score.jnp.hlo.txt"},
      "signatures": {}
    }"#;

    #[test]
    fn parses_sample() {
        let j = parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.arch, "opt-nano");
        assert_eq!(m.vocab, 512);
        assert_eq!(m.batch, 4);
        assert_eq!(m.leaves[0].numel(), 512 * 256);
        assert_eq!(m.kv_shape.len(), 6);
        assert_eq!(
            m.artifact_file("score.jnp"),
            Some("opt-nano.score.jnp.hlo.txt")
        );
        assert_eq!(m.artifact_file("missing"), None);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/opt-nano.manifest.json"
        );
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert_eq!(m.arch, "opt-nano");
            assert!(m.num_params > 1_000_000);
            assert_eq!(m.total_leaf_elems() as u64, m.num_params);
        }
    }
}
