//! PJRT runtime: load AOT HLO artifacts, hold model state, execute
//! score/decode/train from the Rust hot path (Python never runs here).

pub mod engine;
pub mod manifest;
pub mod npz;

pub use engine::{KernelVariant, RlhfEngine};
pub use manifest::Manifest;
