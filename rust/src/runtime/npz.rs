//! Minimal `.npz`/`.npy` reader for the initial-parameter archive emitted
//! by `python/compile/aot.py`. Supports the subset numpy writes for plain
//! C-contiguous float32/int32 arrays (format version 1.0).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;

/// One loaded array.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyArray {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parse one `.npy` byte stream (f32 little-endian, C order).
pub fn parse_npy(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let major = bytes[6];
    let (header_len, header_start) = if major == 1 {
        (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10)
    } else {
        (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12,
        )
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
        .context("npy header not utf-8")?;
    if !header.contains("'descr': '<f4'") && !header.contains("'descr': '|f4'") {
        bail!("unsupported npy dtype (want <f4): {header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("fortran-order npy not supported");
    }
    let shape = parse_shape(header)?;
    let numel: usize = shape.iter().product();
    let payload = &bytes[header_start + header_len..];
    if payload.len() < numel * 4 {
        bail!("npy payload too short: {} < {}", payload.len(), numel * 4);
    }
    let mut data = Vec::with_capacity(numel);
    for chunk in payload[..numel * 4].chunks_exact(4) {
        data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(NpyArray { shape, data })
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let start = header.find("'shape':").context("no shape")? + 8;
    let open = header[start..].find('(').context("no (")? + start;
    let close = header[open..].find(')').context("no )")? + open;
    let inner = &header[open + 1..close];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if !t.is_empty() {
            out.push(t.parse::<usize>().with_context(|| format!("bad dim {t}"))?);
        }
    }
    Ok(out)
}

/// Load every array in an `.npz` (zip of `.npy` members).
pub fn load_npz(path: &str) -> Result<HashMap<String, NpyArray>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let mut zip = zip::ZipArchive::new(file).context("read npz zip")?;
    let mut out = HashMap::new();
    for i in 0..zip.len() {
        let mut member = zip.by_index(i)?;
        let name = member
            .name()
            .trim_end_matches(".npy")
            .to_string();
        let mut bytes = Vec::with_capacity(member.size() as usize);
        member.read_to_end(&mut bytes)?;
        out.insert(name, parse_npy(&bytes)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn npy_bytes(shape_str: &str, values: &[f32]) -> Vec<u8> {
        let header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
        );
        let mut header = header.into_bytes();
        // Pad so (magic+len+header) % 64 == 0 like numpy does; end with \n.
        let base = 10 + header.len() + 1;
        let pad = (64 - base % 64) % 64;
        header.extend(std::iter::repeat(b' ').take(pad));
        header.push(b'\n');
        let mut out = Vec::new();
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(&header);
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parse_simple_npy() {
        let bytes = npy_bytes("(2, 3)", &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, vec![2, 3]);
        assert_eq!(arr.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn parse_scalar_and_1d() {
        let arr = parse_npy(&npy_bytes("()", &[7.5])).unwrap();
        assert_eq!(arr.shape, Vec::<usize>::new());
        assert_eq!(arr.data, vec![7.5]);
        let arr = parse_npy(&npy_bytes("(4,)", &[1.0, 2.0, 3.0, 4.0])).unwrap();
        assert_eq!(arr.shape, vec![4]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not numpy").is_err());
        // Truncated payload.
        let mut bytes = npy_bytes("(10,)", &[1.0]);
        bytes.truncate(bytes.len());
        assert!(parse_npy(&bytes).is_err());
    }

    #[test]
    fn loads_real_artifact_npz_if_present() {
        // Integration against the real AOT output when artifacts exist.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/opt-nano.init.npz");
        if std::path::Path::new(path).exists() {
            let arrays = load_npz(path).unwrap();
            assert!(arrays.contains_key("tok_emb"));
            let emb = &arrays["tok_emb"];
            assert_eq!(emb.shape, vec![512, 256]);
            assert_eq!(emb.numel(), emb.data.len());
        }
    }
}
