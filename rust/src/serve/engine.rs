//! Continuous-batching engine: a discrete-event simulation of one serve
//! cell (DESIGN.md §18).
//!
//! The loop alternates admission and decode. Admission is FIFO by arrival
//! time and charges a prefill pass per admitted request; decode advances
//! every running request by one token per step at the batched decode cost.
//! When the paged KV pool runs out of pages mid-decode, the engine
//! preempts the *latest-admitted* other request (vLLM's recompute-style
//! preemption: its KV is dropped and the request re-queues with its
//! original arrival priority). A request whose KV alone exceeds the pool
//! fails permanently. All state is integer µs / integer tokens, so a cell
//! replays byte-identically.

use super::scenario::{KvDiscipline, Request, ServeScenario};
use crate::alloc::paged::{BestFitKvPool, KvLease, KvPool, PagedKvPool};
use crate::mem::ParamInventory;
use crate::rlhf::CostModel;

/// Deterministic outcome of one serve cell. Token/µs units; the report
/// layer converts KV tokens to bytes.
#[derive(Debug, Clone, Default)]
pub struct ServeOutcome {
    pub requests: u64,
    pub completed: u64,
    /// Requests dropped because their KV footprint can never fit.
    pub failed: u64,
    /// OOM preemptions (a running request's KV dropped + re-queued).
    pub preempted: u64,
    /// Admissions (> completed when preempted requests re-enter).
    pub admissions: u64,
    pub decode_steps: u64,
    pub generated_tokens: u64,
    /// End of the last event, µs.
    pub makespan_us: u64,
    /// Completion latencies (arrival → last token), µs.
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub mean_latency_us: f64,
    /// Peak token slots held by the pool, and tokens actually written at
    /// the moment the peak was first reached. held − used = fragmentation
    /// (internal page slack for paged; unwritten reservation tails and
    /// holes for best-fit).
    pub peak_held_tokens: u64,
    pub used_at_peak_tokens: u64,
    pub capacity_tokens: u64,
}

impl ServeOutcome {
    /// Fragmentation at the held-peak, token slots.
    pub fn frag_tokens(&self) -> u64 {
        self.peak_held_tokens - self.used_at_peak_tokens
    }

    /// Fragmentation as a fraction of the peak held footprint.
    pub fn frag_frac(&self) -> f64 {
        if self.peak_held_tokens == 0 {
            0.0
        } else {
            self.frag_tokens() as f64 / self.peak_held_tokens as f64
        }
    }

    /// Generated tokens per second over the makespan.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_us == 0 {
            0.0
        } else {
            self.generated_tokens as f64 * 1e6 / self.makespan_us as f64
        }
    }
}

struct Active {
    req: Request,
    lease: KvLease,
    generated: u64,
    /// Monotone admission sequence number; highest = latest admitted =
    /// first preemption victim.
    seq: u64,
}

/// Run one serve cell to completion.
pub fn simulate(scn: &ServeScenario) -> ServeOutcome {
    let cost = CostModel::for_inventory(&ParamInventory::build(&scn.arch), scn.gpu);
    let capacity_tokens = scn.capacity_tokens();
    let mut pool = match scn.discipline {
        KvDiscipline::Paged { page_tokens } => {
            KvPool::Paged(PagedKvPool::new(capacity_tokens, page_tokens))
        }
        KvDiscipline::BestFit => KvPool::BestFit(BestFitKvPool::new(capacity_tokens)),
    };

    let reqs = scn.stream.generate();
    let mut out = ServeOutcome {
        requests: reqs.len() as u64,
        capacity_tokens,
        ..ServeOutcome::default()
    };

    // Waiting queue kept sorted by (arrival, id): FIFO admission, and a
    // preempted request re-enters at its original priority.
    let mut waiting: Vec<Request> = Vec::new();
    let mut running: Vec<Active> = Vec::new();
    let mut latencies: Vec<u64> = Vec::new();
    let mut next_arrival = 0usize;
    let mut next_seq = 0u64;
    let mut used_tokens = 0u64; // Σ (prompt + generated) over running
    let mut t = 0u64;

    let prefill_us = |tokens: u64| (cost.forward_us(tokens).round() as u64).max(1);
    let decode_us = |batch: u64| (cost.decode_step_us(batch).round() as u64).max(1);

    // Peak tracking: first moment the held footprint reaches a new high.
    macro_rules! note_peak {
        () => {
            if pool.held_tokens() > out.peak_held_tokens {
                out.peak_held_tokens = pool.held_tokens();
                out.used_at_peak_tokens = used_tokens;
            }
        };
    }

    loop {
        // Pull due arrivals into the waiting queue.
        while next_arrival < reqs.len() && reqs[next_arrival].arrival_us <= t {
            insert_by_priority(&mut waiting, reqs[next_arrival].clone());
            next_arrival += 1;
        }

        // Admit FIFO while capacity and the concurrency ceiling allow.
        while (running.len() as u64) < scn.max_concurrency && !waiting.is_empty() {
            let head = &waiting[0];
            match pool.try_admit(head.prompt, head.target_new) {
                Some(lease) => {
                    let req = waiting.remove(0);
                    t += prefill_us(req.prompt);
                    used_tokens += req.prompt;
                    out.admissions += 1;
                    note_peak!();
                    running.push(Active {
                        req,
                        lease,
                        generated: 0,
                        seq: next_seq,
                    });
                    next_seq += 1;
                }
                None if running.is_empty() => {
                    // The pool is fully drained (leases live only on
                    // running requests), yet this request does not fit:
                    // it never will.
                    waiting.remove(0);
                    out.failed += 1;
                }
                None => break,
            }
        }

        if running.is_empty() {
            if next_arrival < reqs.len() {
                // Idle until the next arrival.
                t = t.max(reqs[next_arrival].arrival_us);
                continue;
            }
            break; // waiting is drained too (admit-or-fail above)
        }

        // One batched decode step: every running request gains one token.
        t += decode_us(running.len() as u64);
        out.decode_steps += 1;
        let mut i = 0;
        while i < running.len() {
            let mut extend_failed = false;
            while !pool.try_extend(&mut running[i].lease) {
                // Out of pages: preempt the latest-admitted other request
                // (recompute-style — its KV is dropped, it re-queues).
                let victim = running
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .max_by_key(|(_, a)| a.seq)
                    .map(|(j, _)| j);
                match victim {
                    Some(j) => {
                        let v = running.remove(j);
                        used_tokens -= v.req.prompt + v.generated;
                        pool.release(v.lease);
                        out.preempted += 1;
                        insert_by_priority(&mut waiting, v.req);
                        if j < i {
                            i -= 1;
                        }
                    }
                    None => {
                        // Alone and still cannot grow: the request's own
                        // KV exceeds the pool.
                        extend_failed = true;
                        break;
                    }
                }
            }
            if extend_failed {
                let a = running.remove(i);
                used_tokens -= a.req.prompt + a.generated;
                pool.release(a.lease);
                out.failed += 1;
                continue; // same i now names the next request
            }
            running[i].generated += 1;
            used_tokens += 1;
            out.generated_tokens += 1;
            note_peak!();
            if running[i].generated >= running[i].req.target_new {
                let a = running.remove(i);
                used_tokens -= a.req.prompt + a.generated;
                pool.release(a.lease);
                latencies.push(t - a.req.arrival_us);
                out.completed += 1;
            } else {
                i += 1;
            }
        }
    }

    out.makespan_us = t;
    latencies.sort_unstable();
    if !latencies.is_empty() {
        let n = latencies.len();
        out.p50_latency_us = latencies[(n - 1) * 50 / 100];
        out.p99_latency_us = latencies[(n - 1) * 99 / 100];
        out.mean_latency_us = latencies.iter().sum::<u64>() as f64 / n as f64;
    }
    debug_assert_eq!(out.completed + out.failed, out.requests);
    debug_assert_eq!(pool.held_tokens(), 0, "leaked KV leases");
    out
}

/// Insert keeping `(arrival_us, id)` order — the admission priority.
fn insert_by_priority(waiting: &mut Vec<Request>, req: Request) {
    let key = (req.arrival_us, req.id);
    let pos = waiting
        .binary_search_by_key(&key, |r| (r.arrival_us, r.id))
        .unwrap_or_else(|p| p);
    waiting.insert(pos, req);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::ModelArch;
    use crate::rlhf::GpuSpec;
    use crate::serve::scenario::ServeStream;

    fn scenario(discipline: KvDiscipline, max_concurrency: u64, kv_gib: u64) -> ServeScenario {
        ServeScenario {
            arch: ModelArch::opt_1_3b(),
            gpu_name: "rtx3090".into(),
            gpu: GpuSpec::rtx3090(),
            kv_capacity_bytes: kv_gib << 30,
            discipline,
            max_concurrency,
            stream: ServeStream {
                requests: 48,
                mean_interarrival_us: 5_000,
                prompt_len: 128,
                prompt_jitter: 32,
                max_new: 64,
                response_jitter: 16,
                seed: 0xC0FFEE,
            },
        }
    }

    #[test]
    fn every_request_is_accounted_for() {
        for disc in [KvDiscipline::Paged { page_tokens: 16 }, KvDiscipline::BestFit] {
            let out = simulate(&scenario(disc, 8, 4));
            assert_eq!(out.completed + out.failed, 48);
            assert_eq!(out.failed, 0, "4 GiB fits these requests");
            assert!(out.generated_tokens > 0);
            assert!(out.p99_latency_us >= out.p50_latency_us);
            assert!(out.throughput_tok_s() > 0.0);
            assert!(out.peak_held_tokens >= out.used_at_peak_tokens);
            assert!(out.peak_held_tokens <= out.capacity_tokens);
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let scn = scenario(KvDiscipline::Paged { page_tokens: 16 }, 8, 4);
        let a = simulate(&scn);
        let b = simulate(&scn);
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.p99_latency_us, b.p99_latency_us);
        assert_eq!(a.peak_held_tokens, b.peak_held_tokens);
        assert_eq!(a.preempted, b.preempted);
    }

    #[test]
    fn tiny_pool_preempts_under_pressure() {
        // ~0.06 GiB ≈ 341 token slots: two mid-size requests cannot both
        // hold their full sequences -> the paged engine must preempt.
        let mut scn = scenario(KvDiscipline::Paged { page_tokens: 16 }, 8, 1);
        scn.kv_capacity_bytes = 64 << 20;
        scn.stream.requests = 12;
        scn.stream.mean_interarrival_us = 100;
        let out = simulate(&scn);
        assert_eq!(out.completed + out.failed, 12);
        assert!(out.completed > 0);
        assert!(out.preempted > 0, "pressure must trigger preemption");
        assert!(out.admissions > out.completed);
    }

    #[test]
    fn impossible_request_fails_not_hangs() {
        // Pool smaller than a single prompt: every request fails.
        let mut scn = scenario(KvDiscipline::BestFit, 4, 1);
        scn.kv_capacity_bytes = scn.kv_token_bytes() * 8; // 8 token slots
        scn.stream.requests = 5;
        let out = simulate(&scn);
        assert_eq!(out.failed, 5);
        assert_eq!(out.completed, 0);
    }

    #[test]
    fn paged_wastes_less_than_best_fit_under_load() {
        let paged = simulate(&scenario(KvDiscipline::Paged { page_tokens: 16 }, 16, 4));
        let best = simulate(&scenario(KvDiscipline::BestFit, 16, 4));
        assert!(
            paged.frag_tokens() <= best.frag_tokens(),
            "paged {} vs best-fit {}",
            paged.frag_tokens(),
            best.frag_tokens()
        );
    }
}
