//! Serving-scale workload axis (ROADMAP item 1, DESIGN.md §18).
//!
//! The paper studies training-time memory; the "millions of users" story
//! runs through the *generation* phase serving heavy traffic. This module
//! simulates exactly that: a seeded request stream ([`scenario`]) against
//! a continuous-batching scheduler with per-request admission/eviction
//! ([`engine`]) and a choice of KV-pool disciplines
//! ([`crate::alloc::paged`]): vLLM-style fixed pages vs. classic best-fit
//! worst-case reservation. [`run_cells`] shards a (discipline × page size
//! × concurrency) grid across a worker pool under the same jobs-1 vs
//! jobs-N byte-identical contract as the sweep engine, and [`plan`]
//! threads a serving budget through `advise`.

pub mod engine;
pub mod plan;
pub mod scenario;

pub use engine::{simulate, ServeOutcome};
pub use plan::{plan_serve, ServePlanReport, ServeSpec};
pub use scenario::{KvDiscipline, Request, ServeScenario, ServeStream};

use crate::obs::Telemetry;
use crate::util::json::Json;
use crate::util::schema;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One evaluated serve cell: the scenario's identity plus its outcome.
#[derive(Debug, Clone)]
pub struct ServeCellResult {
    pub index: usize,
    pub model: String,
    pub gpu: String,
    pub discipline: &'static str,
    /// Page size in tokens (0 for best-fit).
    pub page_tokens: u64,
    pub max_concurrency: u64,
    /// Bytes of KV per token — converts the outcome's token counts.
    pub kv_token_bytes: u64,
    pub kv_capacity_bytes: u64,
    pub outcome: ServeOutcome,
}

impl ServeCellResult {
    fn new(index: usize, scn: &ServeScenario, outcome: ServeOutcome) -> Self {
        ServeCellResult {
            index,
            model: scn.arch.name.clone(),
            gpu: scn.gpu_name.clone(),
            discipline: scn.discipline.name(),
            page_tokens: scn.discipline.page_tokens(),
            max_concurrency: scn.max_concurrency,
            kv_token_bytes: scn.kv_token_bytes(),
            kv_capacity_bytes: scn.kv_capacity_bytes,
            outcome,
        }
    }

    pub fn kv_peak_held_bytes(&self) -> u64 {
        self.outcome.peak_held_tokens * self.kv_token_bytes
    }

    pub fn kv_frag_bytes(&self) -> u64 {
        self.outcome.frag_tokens() * self.kv_token_bytes
    }

    /// The cell as a JSON object — every value deterministic (counters
    /// and integer-µs times only, no wall clock).
    pub fn to_json(&self) -> Json {
        let o = &self.outcome;
        Json::obj(vec![
            ("cell", Json::from(self.index)),
            ("model", Json::str(&*self.model)),
            ("gpu", Json::str(&*self.gpu)),
            ("discipline", Json::str(self.discipline)),
            ("page_tokens", Json::from(self.page_tokens)),
            ("max_concurrency", Json::from(self.max_concurrency)),
            ("requests", Json::from(o.requests)),
            ("completed", Json::from(o.completed)),
            ("failed", Json::from(o.failed)),
            ("preempted", Json::from(o.preempted)),
            ("admissions", Json::from(o.admissions)),
            ("decode_steps", Json::from(o.decode_steps)),
            ("generated_tokens", Json::from(o.generated_tokens)),
            ("throughput_tok_s", Json::from(o.throughput_tok_s())),
            ("p50_latency_us", Json::from(o.p50_latency_us)),
            ("p99_latency_us", Json::from(o.p99_latency_us)),
            ("mean_latency_us", Json::from(o.mean_latency_us)),
            ("makespan_us", Json::from(o.makespan_us)),
            ("kv_capacity_bytes", Json::from(self.kv_capacity_bytes)),
            ("kv_token_bytes", Json::from(self.kv_token_bytes)),
            ("kv_peak_held_bytes", Json::from(self.kv_peak_held_bytes())),
            (
                "kv_used_at_peak_bytes",
                Json::from(o.used_at_peak_tokens * self.kv_token_bytes),
            ),
            ("kv_frag_bytes", Json::from(self.kv_frag_bytes())),
            ("kv_frag_pct", Json::from(o.frag_frac() * 100.0)),
        ])
    }

    pub fn jsonl_line(&self) -> String {
        self.to_json().to_string()
    }
}

/// A completed serve grid: index-ordered cells plus run metadata.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub cells: Vec<ServeCellResult>,
    pub wall_seconds: f64,
    pub jobs: usize,
}

impl ServeReport {
    /// The versioned JSONL artifact: schema header, then one line per
    /// cell in index order. Byte-identical for any `--jobs`.
    pub fn jsonl(&self) -> String {
        let mut out = schema::header_line("serve");
        out.push('\n');
        for c in &self.cells {
            out.push_str(&c.jsonl_line());
            out.push('\n');
        }
        out
    }

    /// Deterministic run counters (order-independent sums over cells).
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::new();
        t.add("cells", self.cells.len() as u64);
        for c in &self.cells {
            let o = &c.outcome;
            t.add("requests", o.requests);
            t.add("completed", o.completed);
            t.add("failed", o.failed);
            t.add("preempted", o.preempted);
            t.add("admissions", o.admissions);
            t.add("decode_steps", o.decode_steps);
            t.add("generated_tokens", o.generated_tokens);
        }
        t.wall("serve", self.wall_seconds);
        t
    }

    /// The artifact plus the telemetry footer line.
    pub fn jsonl_with_telemetry(&self) -> String {
        let mut out = self.jsonl();
        out.push_str(&self.telemetry().footer_line());
        out.push('\n');
        out
    }

    /// One-line run summary for stdout.
    pub fn summary_line(&self) -> String {
        let t = self.telemetry();
        format!(
            "serve: {} cells, {} requests ({} completed, {} failed, {} preempted) \
             in {:.2}s with {} jobs",
            self.cells.len(),
            t.get("requests").unwrap_or(0),
            t.get("completed").unwrap_or(0),
            t.get("failed").unwrap_or(0),
            t.get("preempted").unwrap_or(0),
            self.wall_seconds,
            self.jobs
        )
    }
}

/// Run every cell across `jobs` workers. Results land in index-ordered
/// slots, so the report is byte-identical regardless of worker count or
/// completion order — the sweep engine's contract, upheld here.
pub fn run_cells(cells: &[ServeScenario], jobs: usize) -> ServeReport {
    let t0 = Instant::now();
    let jobs = jobs.max(1);
    let n = cells.len();
    let slots: Mutex<Vec<Option<ServeCellResult>>> = Mutex::new((0..n).map(|_| None).collect());
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs.min(n.max(1)) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let outcome = engine::simulate(&cells[i]);
                let result = ServeCellResult::new(i, &cells[i], outcome);
                slots.lock().unwrap()[i] = Some(result);
            });
        }
    });
    let cells = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|c| c.expect("every cell filled"))
        .collect();
    ServeReport {
        cells,
        wall_seconds: t0.elapsed().as_secs_f64(),
        jobs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::ModelArch;
    use crate::rlhf::GpuSpec;

    fn grid() -> Vec<ServeScenario> {
        let stream = ServeStream {
            requests: 24,
            mean_interarrival_us: 5_000,
            prompt_len: 96,
            prompt_jitter: 32,
            max_new: 48,
            response_jitter: 16,
            seed: 42,
        };
        let mut cells = Vec::new();
        for disc in [
            KvDiscipline::Paged { page_tokens: 16 },
            KvDiscipline::Paged { page_tokens: 32 },
            KvDiscipline::BestFit,
        ] {
            for conc in [4u64, 8] {
                cells.push(ServeScenario {
                    arch: ModelArch::opt_1_3b(),
                    gpu_name: "rtx3090".into(),
                    gpu: GpuSpec::rtx3090(),
                    kv_capacity_bytes: 2 << 30,
                    discipline: disc,
                    max_concurrency: conc,
                    stream: stream.clone(),
                });
            }
        }
        cells
    }

    #[test]
    fn jobs_one_and_many_agree_byte_for_byte() {
        let a = run_cells(&grid(), 1);
        let b = run_cells(&grid(), 4);
        assert_eq!(a.jsonl_with_telemetry(), b.jsonl_with_telemetry());
        assert_eq!(a.cells.len(), 6);
    }

    #[test]
    fn artifact_opens_with_serve_header_and_covers_cells() {
        let r = run_cells(&grid(), 2);
        let text = r.jsonl();
        schema::check_jsonl("serve", &text).unwrap();
        // Header + one line per cell.
        assert_eq!(text.lines().count(), r.cells.len() + 1);
        for line in text.lines().skip(1) {
            assert!(line.contains("\"discipline\":"), "{line}");
        }
    }
}
