//! The serving budget threaded through `advise`: given traffic (request
//! rate, length distributions) and a KV budget, search the (discipline ×
//! page size × max concurrency) grid and answer "what batch + page-size
//! config fits this GPU under this traffic" — a Pareto frontier over
//! (peak KV footprint, p99 latency) plus a throughput-ranked
//! recommendation. See DESIGN.md §18.

use super::scenario::{KvDiscipline, ServeScenario, ServeStream};
use super::{run_cells, ServeCellResult, ServeReport};
use crate::mem::ModelArch;
use crate::planner::budget::Budget;
use crate::planner::frontier::pareto_frontier;
use crate::rlhf::GpuSpec;
use crate::util::bytes::GIB;
use crate::util::json::Json;
use crate::util::schema;

/// The `"serve"` object of a budget file: traffic plus the config grid to
/// search. Every field optional; defaults describe a moderate chat-style
/// load on an 8 GiB KV carve-out.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Model whose KV/compute costs the cells use.
    pub model: String,
    /// Bytes of GPU memory dedicated to the KV cache.
    pub kv_capacity_bytes: u64,
    pub requests: u64,
    /// Mean request arrival rate, requests/second.
    pub arrival_rps: f64,
    pub prompt_len: u64,
    pub prompt_jitter: u64,
    pub max_new: u64,
    pub response_jitter: u64,
    pub seed: u64,
    /// Disciplines to search: any of `"paged"`, `"best-fit"`.
    pub disciplines: Vec<String>,
    /// Page sizes (tokens) for the paged discipline.
    pub page_tokens: Vec<u64>,
    /// Concurrency ceilings to search.
    pub max_concurrency: Vec<u64>,
    /// Optional p99-latency ceiling, ms: cells above it are infeasible.
    pub p99_budget_ms: Option<f64>,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            model: "opt-1.3b".to_string(),
            kv_capacity_bytes: 8 * GIB,
            requests: 64,
            arrival_rps: 20.0,
            prompt_len: 256,
            prompt_jitter: 64,
            max_new: 128,
            response_jitter: 32,
            seed: 0xC0FFEE,
            disciplines: vec!["paged".to_string(), "best-fit".to_string()],
            page_tokens: vec![8, 16, 32],
            max_concurrency: vec![4, 8, 16],
            p99_budget_ms: None,
        }
    }
}

impl ServeSpec {
    /// Parse the budget file's `"serve"` object. Unknown fields fail loud,
    /// like the budget itself.
    pub fn from_json(j: &Json) -> Result<ServeSpec, String> {
        const KNOWN: [&str; 13] = [
            "model",
            "kv_capacity_gib",
            "requests",
            "arrival_rps",
            "prompt_len",
            "prompt_jitter",
            "max_new",
            "response_jitter",
            "seed",
            "disciplines",
            "page_tokens",
            "max_concurrency",
            "p99_budget_ms",
        ];
        let Json::Obj(kvs) = j else {
            return Err("'serve' must be a JSON object".to_string());
        };
        for (k, _) in kvs {
            if !KNOWN.contains(&k.as_str()) {
                return Err(format!(
                    "unknown serve field '{k}' (known fields: {})",
                    KNOWN.join(", ")
                ));
            }
        }
        let mut spec = ServeSpec::default();

        let opt_u64 = |key: &str| -> Result<Option<u64>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_u64()
                    .map(Some)
                    .ok_or_else(|| format!("serve '{key}' must be a non-negative integer")),
            }
        };
        let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x > 0.0)
                    .map(Some)
                    .ok_or_else(|| format!("serve '{key}' must be a positive number")),
            }
        };
        let u64_list = |key: &str| -> Result<Option<Vec<u64>>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => {
                    let arr = v.as_arr().ok_or_else(|| {
                        format!("serve '{key}' must be an array of positive integers")
                    })?;
                    let xs = arr
                        .iter()
                        .map(|x| {
                            x.as_u64().filter(|&n| n > 0).ok_or_else(|| {
                                format!("serve '{key}' entries must be positive integers")
                            })
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if xs.is_empty() {
                        return Err(format!("serve '{key}' must not be empty"));
                    }
                    Ok(Some(xs))
                }
            }
        };

        if let Some(model) = j.get("model") {
            let name = model
                .as_str()
                .ok_or_else(|| "serve 'model' must be a string".to_string())?;
            ModelArch::by_name(name).ok_or_else(|| format!("unknown model '{name}'"))?;
            spec.model = name.to_string();
        }
        if let Some(gib) = opt_u64("kv_capacity_gib")? {
            spec.kv_capacity_bytes = gib * GIB;
        }
        if let Some(v) = opt_u64("requests")? {
            spec.requests = v.max(1);
        }
        if let Some(v) = opt_f64("arrival_rps")? {
            spec.arrival_rps = v;
        }
        if let Some(v) = opt_u64("prompt_len")? {
            spec.prompt_len = v.max(1);
        }
        if let Some(v) = opt_u64("prompt_jitter")? {
            spec.prompt_jitter = v;
        }
        if let Some(v) = opt_u64("max_new")? {
            spec.max_new = v.max(1);
        }
        if let Some(v) = opt_u64("response_jitter")? {
            spec.response_jitter = v;
        }
        if let Some(v) = opt_u64("seed")? {
            spec.seed = v;
        }
        if let Some(names) = j.get("disciplines") {
            let arr = names
                .as_arr()
                .ok_or_else(|| "serve 'disciplines' must be an array of strings".to_string())?;
            let mut ds = Vec::new();
            for x in arr {
                match x.as_str() {
                    Some(d @ ("paged" | "best-fit")) => ds.push(d.to_string()),
                    Some(other) => {
                        return Err(format!(
                            "unknown discipline '{other}' (known: paged, best-fit)"
                        ))
                    }
                    None => return Err("serve 'disciplines' entries must be strings".to_string()),
                }
            }
            if ds.is_empty() {
                return Err("serve 'disciplines' must not be empty".to_string());
            }
            spec.disciplines = ds;
        }
        if let Some(xs) = u64_list("page_tokens")? {
            spec.page_tokens = xs;
        }
        if let Some(xs) = u64_list("max_concurrency")? {
            spec.max_concurrency = xs;
        }
        spec.p99_budget_ms = opt_f64("p99_budget_ms")?;
        Ok(spec)
    }

    /// The seeded stream this spec describes.
    pub fn stream(&self) -> ServeStream {
        ServeStream {
            requests: self.requests,
            mean_interarrival_us: ((1e6 / self.arrival_rps).round() as u64).max(1),
            prompt_len: self.prompt_len,
            prompt_jitter: self.prompt_jitter,
            max_new: self.max_new,
            response_jitter: self.response_jitter,
            seed: self.seed,
        }
    }

    /// Materialize the (discipline × page size × concurrency) grid. The
    /// page axis collapses for best-fit (it has no pages).
    pub fn cells(&self, gpu_name: &str, gpu: GpuSpec) -> Result<Vec<ServeScenario>, String> {
        let arch = ModelArch::by_name(&self.model)
            .ok_or_else(|| format!("unknown model '{}'", self.model))?;
        let stream = self.stream();
        let mut disciplines = Vec::new();
        for d in &self.disciplines {
            match d.as_str() {
                "paged" => {
                    for &p in &self.page_tokens {
                        disciplines.push(KvDiscipline::Paged { page_tokens: p });
                    }
                }
                "best-fit" => disciplines.push(KvDiscipline::BestFit),
                other => return Err(format!("unknown discipline '{other}'")),
            }
        }
        let mut cells = Vec::new();
        for disc in &disciplines {
            for &conc in &self.max_concurrency {
                cells.push(ServeScenario {
                    arch: arch.clone(),
                    gpu_name: gpu_name.to_string(),
                    gpu,
                    kv_capacity_bytes: self.kv_capacity_bytes,
                    discipline: *disc,
                    max_concurrency: conc,
                    stream: stream.clone(),
                });
            }
        }
        Ok(cells)
    }
}

/// Planner verdict for one serve cell.
#[derive(Debug, Clone)]
pub struct ServeVerdict {
    /// No dropped requests, and p99 within the budget (when set).
    pub feasible: bool,
    /// On the (peak KV bytes, p99 latency) Pareto frontier.
    pub on_frontier: bool,
    /// Throughput rank among feasible cells (0 = recommended).
    pub rank: Option<usize>,
}

/// The serve-planner result: the evaluated grid plus per-cell verdicts.
#[derive(Debug, Clone)]
pub struct ServePlanReport {
    pub budget_name: String,
    pub spec: ServeSpec,
    pub report: ServeReport,
    pub verdicts: Vec<ServeVerdict>,
}

/// Evaluate the serving budget's grid and rank configurations.
pub fn plan_serve(budget: &Budget, jobs: usize) -> Result<ServePlanReport, String> {
    let spec = budget.serve.clone().unwrap_or_default();
    let cells = spec.cells(gpu_label(&budget.gpu), budget.gpu)?;
    let report = run_cells(&cells, jobs);

    let feasible: Vec<bool> = report
        .cells
        .iter()
        .map(|c| {
            c.outcome.failed == 0
                && spec
                    .p99_budget_ms
                    .map(|ms| c.outcome.p99_latency_us as f64 <= ms * 1e3)
                    .unwrap_or(true)
        })
        .collect();
    let points: Vec<(u64, f64, bool)> = report
        .cells
        .iter()
        .zip(&feasible)
        .map(|(c, &ok)| (c.kv_peak_held_bytes(), c.outcome.p99_latency_us as f64, ok))
        .collect();
    let on_frontier = pareto_frontier(&points);

    // Throughput ranking over feasible cells; deterministic tie-breaks on
    // (smaller peak KV, lower index).
    let mut order: Vec<usize> = (0..report.cells.len()).filter(|&i| feasible[i]).collect();
    order.sort_by(|&a, &b| {
        let (ca, cb) = (&report.cells[a], &report.cells[b]);
        cb.outcome
            .throughput_tok_s()
            .partial_cmp(&ca.outcome.throughput_tok_s())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(ca.kv_peak_held_bytes().cmp(&cb.kv_peak_held_bytes()))
            .then(a.cmp(&b))
    });
    let mut verdicts: Vec<ServeVerdict> = feasible
        .iter()
        .zip(&on_frontier)
        .map(|(&feasible, &on_frontier)| ServeVerdict {
            feasible,
            on_frontier,
            rank: None,
        })
        .collect();
    for (rank, &i) in order.iter().enumerate() {
        verdicts[i].rank = Some(rank);
    }

    Ok(ServePlanReport {
        budget_name: budget.name.clone(),
        spec,
        report,
        verdicts,
    })
}

impl ServePlanReport {
    /// The recommended cell (rank 0), if any cell is feasible.
    pub fn recommendation(&self) -> Option<&ServeCellResult> {
        self.verdicts
            .iter()
            .position(|v| v.rank == Some(0))
            .map(|i| &self.report.cells[i])
    }

    /// Versioned JSONL: the serve header, one line per cell (cell fields
    /// plus the planner verdict), and the telemetry footer.
    pub fn jsonl(&self) -> String {
        let mut out = schema::header_line("serve");
        out.push('\n');
        for (cell, v) in self.report.cells.iter().zip(&self.verdicts) {
            let Json::Obj(mut kvs) = cell.to_json() else {
                unreachable!("cell json is an object");
            };
            kvs.push(("feasible".to_string(), Json::from(v.feasible)));
            kvs.push(("on_frontier".to_string(), Json::from(v.on_frontier)));
            if let Some(rank) = v.rank {
                kvs.push(("rank".to_string(), Json::from(rank)));
            }
            out.push_str(&Json::Obj(kvs).to_string());
            out.push('\n');
        }
        out
    }

    pub fn jsonl_with_telemetry(&self) -> String {
        let mut t = self.report.telemetry();
        t.add("feasible", self.verdicts.iter().filter(|v| v.feasible).count() as u64);
        t.add(
            "frontier",
            self.verdicts.iter().filter(|v| v.on_frontier).count() as u64,
        );
        let mut out = self.jsonl();
        out.push_str(&t.footer_line());
        out.push('\n');
        out
    }

    /// Human summary: the frontier plus the recommendation.
    pub fn to_table(&self) -> String {
        let mut out = format!(
            "serve plan for '{}': {} cells, traffic {} req @ {:.1} rps, \
             KV budget {:.1} GiB\n",
            self.budget_name,
            self.report.cells.len(),
            self.spec.requests,
            self.spec.arrival_rps,
            self.spec.kv_capacity_bytes as f64 / GIB as f64,
        );
        out.push_str(
            "  rank  discipline  page  conc  tok/s     p99 ms    peak KV GiB  frag%  frontier\n",
        );
        let mut rows: Vec<(usize, &ServeCellResult, &ServeVerdict)> = self
            .report
            .cells
            .iter()
            .zip(&self.verdicts)
            .enumerate()
            .filter(|(_, (_, v))| v.feasible)
            .map(|(i, (c, v))| (i, c, v))
            .collect();
        rows.sort_by_key(|(i, _, v)| (v.rank.unwrap_or(usize::MAX), *i));
        for (_, c, v) in &rows {
            out.push_str(&format!(
                "  {:>4}  {:<10}  {:>4}  {:>4}  {:>8.1}  {:>8.1}  {:>11.2}  {:>5.1}  {}\n",
                v.rank.map(|r| r.to_string()).unwrap_or_default(),
                c.discipline,
                c.page_tokens,
                c.max_concurrency,
                c.outcome.throughput_tok_s(),
                c.outcome.p99_latency_us as f64 / 1e3,
                c.kv_peak_held_bytes() as f64 / GIB as f64,
                c.outcome.frag_frac() * 100.0,
                if v.on_frontier { "*" } else { "" },
            ));
        }
        let infeasible = self.verdicts.iter().filter(|v| !v.feasible).count();
        if infeasible > 0 {
            out.push_str(&format!(
                "  ({infeasible} infeasible cells omitted: dropped requests or p99 over budget)\n"
            ));
        }
        match self.recommendation() {
            Some(c) => out.push_str(&format!(
                "recommended: {} page_tokens={} max_concurrency={} — {:.1} tok/s, \
                 p99 {:.1} ms, peak KV {:.2} GiB\n",
                c.discipline,
                c.page_tokens,
                c.max_concurrency,
                c.outcome.throughput_tok_s(),
                c.outcome.p99_latency_us as f64 / 1e3,
                c.kv_peak_held_bytes() as f64 / GIB as f64,
            )),
            None => out.push_str("recommended: none — no feasible cell under this traffic\n"),
        }
        out
    }
}

/// Stable display label for a GPU preset (budgets carry the spec, not the
/// CLI name).
fn gpu_label(gpu: &GpuSpec) -> &'static str {
    if *gpu == GpuSpec::rtx3090() {
        "rtx3090"
    } else if *gpu == GpuSpec::a100_80g() {
        "a100-80g"
    } else {
        "custom"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_budget() -> Budget {
        let mut b = Budget::rtx3090_table1();
        b.serve = Some(ServeSpec {
            requests: 24,
            max_concurrency: vec![4, 8],
            page_tokens: vec![16],
            ..ServeSpec::default()
        });
        b
    }

    #[test]
    fn plan_ranks_and_marks_a_frontier() {
        let plan = plan_serve(&small_budget(), 2).unwrap();
        // paged×1 page size + best-fit, × 2 concurrencies.
        assert_eq!(plan.report.cells.len(), 4);
        assert_eq!(plan.verdicts.len(), 4);
        assert!(plan.verdicts.iter().any(|v| v.on_frontier));
        let rec = plan.recommendation().expect("some cell is feasible");
        assert!(rec.outcome.failed == 0);
        // The recommendation has the best feasible throughput.
        for (c, v) in plan.report.cells.iter().zip(&plan.verdicts) {
            if v.feasible {
                assert!(c.outcome.throughput_tok_s() <= rec.outcome.throughput_tok_s() + 1e-9);
            }
        }
        let table = plan.to_table();
        assert!(table.contains("recommended:"), "{table}");
    }

    #[test]
    fn plan_jsonl_is_versioned_and_jobs_invariant() {
        let a = plan_serve(&small_budget(), 1).unwrap();
        let b = plan_serve(&small_budget(), 4).unwrap();
        assert_eq!(a.jsonl_with_telemetry(), b.jsonl_with_telemetry());
        schema::check_jsonl("serve", &a.jsonl()).unwrap();
        assert!(a.jsonl().lines().skip(1).all(|l| l.contains("\"feasible\":")));
    }

    #[test]
    fn spec_parsing_rejects_typos_and_bad_values() {
        use crate::util::json::parse;
        let ok = ServeSpec::from_json(
            &parse(r#"{"requests": 8, "page_tokens": [8, 64], "p99_budget_ms": 250}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(ok.requests, 8);
        assert_eq!(ok.page_tokens, vec![8, 64]);
        assert_eq!(ok.p99_budget_ms, Some(250.0));
        for bad in [
            r#"{"request": 8}"#,
            r#"{"requests": -1}"#,
            r#"{"model": "nope"}"#,
            r#"{"disciplines": ["slab"]}"#,
            r#"{"disciplines": []}"#,
            r#"{"page_tokens": [0]}"#,
            r#"{"p99_budget_ms": 0}"#,
            r#"[1]"#,
        ] {
            assert!(ServeSpec::from_json(&parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn p99_budget_gates_feasibility() {
        let mut b = small_budget();
        if let Some(s) = &mut b.serve {
            s.p99_budget_ms = Some(0.001); // nothing clears 1µs
        }
        let plan = plan_serve(&b, 2).unwrap();
        assert!(plan.verdicts.iter().all(|v| !v.feasible));
        assert!(plan.recommendation().is_none());
        assert!(plan.to_table().contains("none"), "{}", plan.to_table());
    }
}
