//! Serving workload description: a deterministic seeded request stream
//! plus the KV budget and scheduling knobs for one serve cell.
//!
//! Everything here is integer-valued (token counts, microseconds) and
//! driven by the library [`Rng`], so a scenario replays byte-identically
//! for a given seed — the property the jobs-1 vs jobs-N contract and the
//! CI serve gate rest on.

use crate::mem::{DType, KvCacheModel, ModelArch};
use crate::rlhf::GpuSpec;
use crate::util::prng::Rng;

/// Seeded request-stream spec: arrival process and length distributions.
#[derive(Debug, Clone)]
pub struct ServeStream {
    /// Total requests in the stream.
    pub requests: u64,
    /// Mean inter-arrival gap, µs. Arrivals are uniformly jittered in
    /// `[0, 2·mean]` — integer-only (no libm), same mean as Poisson.
    pub mean_interarrival_us: u64,
    /// Prompt length, tokens, uniformly jittered by ±`prompt_jitter`.
    pub prompt_len: u64,
    pub prompt_jitter: u64,
    /// Response budget, tokens, uniformly jittered by ±`response_jitter`.
    pub max_new: u64,
    pub response_jitter: u64,
    pub seed: u64,
}

/// One request materialized from the stream.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub arrival_us: u64,
    /// Prompt tokens (KV written at admission by the prefill pass).
    pub prompt: u64,
    /// Tokens this request will generate before completing.
    pub target_new: u64,
}

impl ServeStream {
    /// Materialize the stream. Same seed → same vector, always sorted by
    /// arrival time (arrivals are generated as a running sum).
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::seeded(self.seed);
        let mut t = 0u64;
        let mut out = Vec::with_capacity(self.requests as usize);
        for id in 0..self.requests {
            t += rng.gen_range(2 * self.mean_interarrival_us + 1);
            let prompt = jittered(&mut rng, self.prompt_len, self.prompt_jitter);
            let target_new = jittered(&mut rng, self.max_new, self.response_jitter);
            out.push(Request {
                id,
                arrival_us: t,
                prompt,
                target_new,
            });
        }
        out
    }
}

/// `base ± jitter`, uniform, clamped to ≥ 1 token.
fn jittered(rng: &mut Rng, base: u64, jitter: u64) -> u64 {
    (base + rng.gen_range(2 * jitter + 1)).saturating_sub(jitter).max(1)
}

/// How the KV budget is carved among concurrent requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvDiscipline {
    /// vLLM-style on-demand fixed-size pages of `page_tokens` slots.
    Paged { page_tokens: u64 },
    /// Contiguous worst-case reservation from a best-fit free list.
    BestFit,
}

impl KvDiscipline {
    pub fn name(&self) -> &'static str {
        match self {
            KvDiscipline::Paged { .. } => "paged",
            KvDiscipline::BestFit => "best-fit",
        }
    }

    /// Page size in tokens; 0 for the (page-less) best-fit discipline.
    pub fn page_tokens(&self) -> u64 {
        match self {
            KvDiscipline::Paged { page_tokens } => *page_tokens,
            KvDiscipline::BestFit => 0,
        }
    }
}

/// One serve cell: a request stream against one (discipline, concurrency)
/// configuration of one model on one GPU.
#[derive(Debug, Clone)]
pub struct ServeScenario {
    pub arch: ModelArch,
    pub gpu_name: String,
    pub gpu: GpuSpec,
    /// Bytes of GPU memory dedicated to the KV cache.
    pub kv_capacity_bytes: u64,
    pub discipline: KvDiscipline,
    /// Admission ceiling: running requests never exceed this.
    pub max_concurrency: u64,
    pub stream: ServeStream,
}

impl ServeScenario {
    /// Bytes of KV cache per token for this model (both K and V, all
    /// layers, fp16) — the token-slot/byte exchange rate.
    pub fn kv_token_bytes(&self) -> u64 {
        KvCacheModel::new(&self.arch, DType::F16).total_bytes(1, 1)
    }

    /// The KV budget expressed in token slots.
    pub fn capacity_tokens(&self) -> u64 {
        self.kv_capacity_bytes / self.kv_token_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(seed: u64) -> ServeStream {
        ServeStream {
            requests: 32,
            mean_interarrival_us: 10_000,
            prompt_len: 64,
            prompt_jitter: 16,
            max_new: 32,
            response_jitter: 8,
            seed,
        }
    }

    #[test]
    fn stream_replays_exactly_for_a_seed() {
        let a = stream(7).generate();
        let b = stream(7).generate();
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                (x.id, x.arrival_us, x.prompt, x.target_new),
                (y.id, y.arrival_us, y.prompt, y.target_new)
            );
        }
        // A different seed genuinely changes the stream.
        let c = stream(8).generate();
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| (x.arrival_us, x.prompt) != (y.arrival_us, y.prompt)));
    }

    #[test]
    fn stream_is_sorted_and_lengths_in_band() {
        let reqs = stream(11).generate();
        for w in reqs.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        for r in &reqs {
            assert!((48..=80).contains(&r.prompt), "prompt {}", r.prompt);
            assert!((24..=40).contains(&r.target_new), "new {}", r.target_new);
        }
    }

    #[test]
    fn token_bytes_matches_kv_model() {
        let scn = ServeScenario {
            arch: ModelArch::opt_1_3b(),
            gpu_name: "rtx3090".into(),
            gpu: GpuSpec::rtx3090(),
            kv_capacity_bytes: 8 << 30,
            discipline: KvDiscipline::Paged { page_tokens: 16 },
            max_concurrency: 8,
            stream: stream(1),
        };
        // opt-1.3b: 2 (K+V) · 24 layers · 2048 d_model · 2 bytes = 192 KiB.
        assert_eq!(scn.kv_token_bytes(), 2 * 24 * 2048 * 2);
        assert_eq!(scn.capacity_tokens(), (8 << 30) / (2 * 24 * 2048 * 2));
    }
}
