//! Memory-management strategies (§2.2 of the paper): ZeRO stages 1–3, CPU
//! offloading, gradient checkpointing, and LoRA.
//!
//! A strategy here is *not* a lookup table of memory savings — it is a
//! transformation of the allocation behaviour of the RLHF phase generators
//! (`rlhf::phases`). This module defines the configuration surface plus the
//! partitioning/bucketing arithmetic the generators consult; the actual op
//! streams are emitted by the generators.

pub mod offload;
pub mod zero;

pub use zero::ZeroStage;

use crate::mem::LoraSpec;

/// The strategy knobs of one experiment row (paper Table 1 "Strategy").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyConfig {
    pub zero: ZeroStage,
    /// ZeRO-Offload: optimizer states (+ their update) live in host memory;
    /// the GPU sees only transient staging buffers during the step.
    pub cpu_offload: bool,
    /// Gradient (activation) checkpointing.
    pub grad_checkpoint: bool,
    /// LoRA adapters (the paper sets r=128 everywhere).
    pub lora: Option<LoraSpec>,
}

impl StrategyConfig {
    /// Paper row "None" (LoRA is still on — the paper applies it globally).
    pub fn none() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z0,
            cpu_offload: false,
            grad_checkpoint: false,
            lora: Some(LoraSpec::paper_default()),
        }
    }

    pub fn zero1() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z1,
            ..Self::none()
        }
    }

    pub fn zero2() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z2,
            ..Self::none()
        }
    }

    pub fn zero3() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z3,
            ..Self::none()
        }
    }

    pub fn zero3_offload() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z3,
            cpu_offload: true,
            ..Self::none()
        }
    }

    pub fn checkpointing() -> Self {
        StrategyConfig {
            grad_checkpoint: true,
            ..Self::none()
        }
    }

    /// Paper row "All Enabled": ZeRO-3 + CPU offloading + checkpointing.
    pub fn all_enabled() -> Self {
        StrategyConfig {
            zero: ZeroStage::Z3,
            cpu_offload: true,
            grad_checkpoint: true,
            ..Self::none()
        }
    }

    /// The paper's Table-1 DeepSpeed-Chat sweep, in row order.
    pub fn table1_deepspeed_rows() -> Vec<(&'static str, StrategyConfig)> {
        vec![
            ("None", Self::none()),
            ("ZeRO-1", Self::zero1()),
            ("ZeRO-2", Self::zero2()),
            ("ZeRO-3", Self::zero3()),
            ("ZeRO-3 + CPU Offloading", Self::zero3_offload()),
            ("Gradient Checkpointing", Self::checkpointing()),
            ("All Enabled", Self::all_enabled()),
        ]
    }

    /// The ColossalChat sweep (no ZeRO-1 support; "All Enabled" fails in
    /// gradient sync upstream, so the paper's table ends at ZeRO-3+offload
    /// and checkpointing — except GPT-2 which has an All row).
    pub fn table1_colossal_rows() -> Vec<(&'static str, StrategyConfig)> {
        vec![
            ("None", Self::none()),
            ("ZeRO-3", Self::zero3()),
            ("ZeRO-3 + CPU Offloading", Self::zero3_offload()),
            ("Gradient Checkpointing", Self::checkpointing()),
            ("All Enabled", Self::all_enabled()),
        ]
    }

    /// Preset lookup by CLI short name: `none`, `zero1`, `zero2`, `zero3`,
    /// `offload` (ZeRO-3 + CPU offload), `ckpt` (gradient checkpointing),
    /// `all` (everything on). Returns the preset and its paper-row label.
    pub fn by_name(name: &str) -> Option<(&'static str, StrategyConfig)> {
        match name {
            "none" => Some(("None", Self::none())),
            "zero1" => Some(("ZeRO-1", Self::zero1())),
            "zero2" => Some(("ZeRO-2", Self::zero2())),
            "zero3" => Some(("ZeRO-3", Self::zero3())),
            "offload" | "zero3_offload" => {
                Some(("ZeRO-3 + CPU Offloading", Self::zero3_offload()))
            }
            "ckpt" | "checkpointing" => Some(("Gradient Checkpointing", Self::checkpointing())),
            "all" => Some(("All Enabled", Self::all_enabled())),
            _ => None,
        }
    }

    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        match self.zero {
            ZeroStage::Z0 => {}
            z => parts.push(format!("ZeRO-{}", z.stage())),
        }
        if self.cpu_offload {
            parts.push("Offload".into());
        }
        if self.grad_checkpoint {
            parts.push("Ckpt".into());
        }
        if parts.is_empty() {
            "None".into()
        } else {
            parts.join("+")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_expected_knobs() {
        assert_eq!(StrategyConfig::none().zero, ZeroStage::Z0);
        assert!(StrategyConfig::none().lora.is_some());
        assert!(StrategyConfig::all_enabled().cpu_offload);
        assert!(StrategyConfig::all_enabled().grad_checkpoint);
        assert_eq!(StrategyConfig::all_enabled().zero, ZeroStage::Z3);
    }

    #[test]
    fn table1_rows_match_paper_layout() {
        let ds = StrategyConfig::table1_deepspeed_rows();
        assert_eq!(ds.len(), 7);
        assert_eq!(ds[0].0, "None");
        assert_eq!(ds[6].0, "All Enabled");
        let cc = StrategyConfig::table1_colossal_rows();
        assert!(cc.iter().all(|(n, _)| *n != "ZeRO-1"), "ColossalChat has no ZeRO-1");
    }

    #[test]
    fn by_name_covers_every_table1_row() {
        for (label, strat) in StrategyConfig::table1_deepspeed_rows() {
            let found = [
                "none", "zero1", "zero2", "zero3", "offload", "ckpt", "all",
            ]
            .iter()
            .find_map(|n| StrategyConfig::by_name(n).filter(|(l, _)| *l == label));
            assert_eq!(found.map(|(_, s)| s), Some(strat), "{label}");
        }
        assert!(StrategyConfig::by_name("bogus").is_none());
    }

    #[test]
    fn labels() {
        assert_eq!(StrategyConfig::none().label(), "None");
        assert_eq!(StrategyConfig::zero3_offload().label(), "ZeRO-3+Offload");
        assert_eq!(StrategyConfig::all_enabled().label(), "ZeRO-3+Offload+Ckpt");
    }
}
