//! CPU-offloading arithmetic (ZeRO-Offload, Ren et al., ATC'21).
//!
//! With optimizer offload the Adam states and the update computation live
//! in host memory; the GPU's involvement in the optimizer step reduces to
//! streaming gradient/parameter buckets through *staging buffers* — the
//! transient allocations this module sizes. ColossalChat additionally
//! offloads the *inference models* (reference + reward) to the CPU during
//! the training phases, moving their whole fp16 replicas off-GPU.

/// Staging-buffer configuration for host<->device streaming.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffloadConfig {
    /// Size of one GPU-side staging buffer for grad-down / param-up
    /// streaming (DeepSpeed pins ~the reduce bucket; we default to 100 M
    /// fp16 elements = 200 MB).
    pub staging_bytes: u64,
    /// Double buffering (compute/copy overlap) — two staging buffers live
    /// at once.
    pub double_buffer: bool,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        OffloadConfig {
            staging_bytes: 200_000_000,
            double_buffer: true,
        }
    }
}

impl OffloadConfig {
    /// The sequence of staging-buffer sizes needed to stream `total` bytes.
    pub fn staging_chunks(&self, total: u64) -> Vec<u64> {
        if total == 0 {
            return vec![];
        }
        let n = total / self.staging_bytes;
        let mut out = vec![self.staging_bytes; n as usize];
        let rem = total - n * self.staging_bytes;
        if rem > 0 {
            out.push(rem);
        }
        out
    }

    /// Number of staging buffers resident at once.
    pub fn live_buffers(&self) -> u64 {
        if self.double_buffer {
            2
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_total() {
        let cfg = OffloadConfig {
            staging_bytes: 100,
            double_buffer: false,
        };
        let chunks = cfg.staging_chunks(250);
        assert_eq!(chunks, [100, 100, 50]);
        assert!(cfg.staging_chunks(0).is_empty());
        assert_eq!(cfg.live_buffers(), 1);
    }

    #[test]
    fn default_double_buffers() {
        assert_eq!(OffloadConfig::default().live_buffers(), 2);
    }
}
