//! ZeRO partitioning arithmetic (Rajbhandari et al., SC'20).
//!
//! * **Stage 1** partitions optimizer states across the data-parallel
//!   world; * **Stage 2** adds gradients (reduce-scattered in buckets);
//! * **Stage 3** adds parameters (per-layer all-gathered on demand).
//!
//! The bucket/gather sizes below are DeepSpeed's defaults, because the
//! transient buffers they imply are exactly the allocations that seed
//! ZeRO-3's fragmentation (paper §3.2).

/// ZeRO stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ZeroStage {
    Z0,
    Z1,
    Z2,
    Z3,
}

impl ZeroStage {
    pub fn stage(self) -> u8 {
        match self {
            ZeroStage::Z0 => 0,
            ZeroStage::Z1 => 1,
            ZeroStage::Z2 => 2,
            ZeroStage::Z3 => 3,
        }
    }

    pub fn from_stage(n: u8) -> Option<Self> {
        match n {
            0 => Some(ZeroStage::Z0),
            1 => Some(ZeroStage::Z1),
            2 => Some(ZeroStage::Z2),
            3 => Some(ZeroStage::Z3),
            _ => None,
        }
    }

    pub fn partitions_optimizer(self) -> bool {
        self >= ZeroStage::Z1
    }
    pub fn partitions_gradients(self) -> bool {
        self >= ZeroStage::Z2
    }
    pub fn partitions_params(self) -> bool {
        self >= ZeroStage::Z3
    }
}

/// DeepSpeed defaults (bytes). DeepSpeed's config expresses bucket sizes
/// in *elements*; everything here is the byte size of those buckets at
/// fp16 (elements × 2 B) — `tests::bucket_defaults_pin_element_counts`
/// pins that identity.
pub mod defaults {
    /// `reduce_bucket_size` (5e8 elements) × 2 B fp16 — the transient
    /// gradient reduce-scatter bucket.
    pub const REDUCE_BUCKET_BYTES: u64 = 500_000_000 * 2;
    /// `allgather_bucket_size` (5e8 elements): ZeRO-3 parameter all-gather
    /// granularity.
    pub const ALLGATHER_BUCKET_BYTES: u64 = 500_000_000 * 2;
    /// `stage3_prefetch_bucket_size` ~ 5e7 elements.
    pub const PREFETCH_BUCKET_BYTES: u64 = 50_000_000 * 2;
    /// `stage3_max_live_parameters` = 1e9 params: gathered fp16 copies are
    /// kept resident until this many bytes are live, then the oldest are
    /// released — the ring that interleaves gather lifetimes with
    /// activations and shreds the large pool (paper §3.2).
    pub const MAX_LIVE_GATHERED_BYTES: u64 = 1_000_000_000 * 2;
}

/// Per-rank share of a partitioned tensor: ceil(bytes / world), with each
/// rank padded to an even element boundary like DeepSpeed's flat buffers.
/// This is rank 0's (largest) shard; rank-aware callers should use
/// [`shard_bytes`], which models the short last-rank remainder.
pub fn partitioned_bytes(total: u64, world: u64) -> u64 {
    assert!(world > 0);
    let per = total.div_ceil(world);
    // Pad to 16 B so flat partitions stay aligned.
    per.div_ceil(16) * 16
}

/// Rank `rank`'s share of a partitioned tensor, DeepSpeed flat-buffer
/// style: the buffer is cut into `world` ceil-divided chunks and the last
/// rank's shard absorbs the remainder, so it can be shorter than the
/// others (down to empty, floored here at one 16 B alignment unit so the
/// trace still carries the rank's stub allocation). `shard_bytes(t, w, 0)`
/// equals [`partitioned_bytes`] for any non-empty tensor.
pub fn shard_bytes(total: u64, world: u64, rank: u64) -> u64 {
    assert!(world > 0 && rank < world, "rank {rank} outside world {world}");
    let per = total.div_ceil(world);
    let start = (per * rank).min(total);
    let end = (per * (rank + 1)).min(total);
    (end - start).max(1).div_ceil(16) * 16
}

/// Sizes of the transient reduce-scatter buckets covering `grad_bytes` of
/// gradients (ZeRO-2/3 backward).
pub fn reduce_buckets(grad_bytes: u64, bucket: u64) -> Vec<u64> {
    split_buckets(grad_bytes, bucket)
}

/// Sizes of the transient all-gather buffers covering `param_bytes`
/// (ZeRO-3 forward/backward). Each buffer materializes the *full* tensor
/// group on every rank.
pub fn gather_buffers(param_bytes: u64, bucket: u64) -> Vec<u64> {
    split_buckets(param_bytes, bucket)
}

fn split_buckets(total: u64, bucket: u64) -> Vec<u64> {
    assert!(bucket > 0);
    if total == 0 {
        return vec![];
    }
    let n = total / bucket;
    let mut out = vec![bucket; n as usize];
    let rem = total - n * bucket;
    if rem > 0 {
        out.push(rem);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    #[test]
    fn stage_predicates() {
        assert!(!ZeroStage::Z0.partitions_optimizer());
        assert!(ZeroStage::Z1.partitions_optimizer());
        assert!(!ZeroStage::Z1.partitions_gradients());
        assert!(ZeroStage::Z2.partitions_gradients());
        assert!(!ZeroStage::Z2.partitions_params());
        assert!(ZeroStage::Z3.partitions_params());
        assert_eq!(ZeroStage::from_stage(3), Some(ZeroStage::Z3));
        assert_eq!(ZeroStage::from_stage(4), None);
    }

    #[test]
    fn partition_rounds_up_and_aligns() {
        assert_eq!(partitioned_bytes(100, 4), 32); // 25 -> pad 32
        assert_eq!(partitioned_bytes(1024, 4), 256);
        assert_eq!(partitioned_bytes(1, 4), 16);
        // Sum over ranks covers the total.
        assert!(partitioned_bytes(1000, 3) * 3 >= 1000);
    }

    #[test]
    fn bucket_defaults_pin_element_counts() {
        // DeepSpeed configures buckets in elements; the byte constants
        // must be elems × dtype size (fp16 = 2 B), not raw element counts.
        use crate::mem::DType;
        assert_eq!(defaults::REDUCE_BUCKET_BYTES, 500_000_000 * DType::F16.bytes());
        assert_eq!(
            defaults::ALLGATHER_BUCKET_BYTES,
            500_000_000 * DType::F16.bytes()
        );
        assert_eq!(defaults::PREFETCH_BUCKET_BYTES, 50_000_000 * DType::F16.bytes());
        assert_eq!(
            defaults::MAX_LIVE_GATHERED_BYTES,
            1_000_000_000 * DType::F16.bytes()
        );
    }

    #[test]
    fn shard_bytes_models_the_short_last_rank() {
        // Divisible: every rank identical, equal to partitioned_bytes.
        for rank in 0..4 {
            assert_eq!(shard_bytes(1024, 4, rank), 256);
        }
        // Non-divisible: earlier ranks take the ceil chunk, the last rank
        // absorbs the remainder.
        assert_eq!(shard_bytes(100, 4, 0), partitioned_bytes(100, 4));
        assert_eq!(shard_bytes(100, 4, 0), 32); // 25 -> pad 32
        assert_eq!(shard_bytes(100, 4, 3), 32); // 100 - 3*25 = 25 -> 32
        assert_eq!(shard_bytes(65, 4, 0), 32); // ceil chunk 17 -> pad 32
        assert_eq!(shard_bytes(65, 4, 3), 16); // remainder 65 - 3*17 = 14 -> 16
        // Tiny tensors: trailing ranks get the 16 B stub floor.
        assert_eq!(shard_bytes(3, 8, 0), 16);
        assert_eq!(shard_bytes(3, 8, 7), 16);
        // Shards tile the tensor: unpadded lengths sum to the total.
        for (total, world) in [(1_000u64, 3u64), (7, 4), (1 << 20, 6)] {
            let per = total.div_ceil(world);
            let sum: u64 = (0..world)
                .map(|r| (per * (r + 1)).min(total) - (per * r).min(total))
                .sum();
            assert_eq!(sum, total);
            for r in 0..world {
                assert!(shard_bytes(total, world, r) >= 16);
                assert!(shard_bytes(total, world, r) <= partitioned_bytes(total, world).max(16));
            }
        }
    }

    #[test]
    fn buckets_cover_exactly() {
        let bs = reduce_buckets(25 * MIB, 10 * MIB);
        assert_eq!(bs, [10 * MIB, 10 * MIB, 5 * MIB]);
        assert_eq!(bs.iter().sum::<u64>(), 25 * MIB);
        assert!(reduce_buckets(0, MIB).is_empty());
        assert_eq!(gather_buffers(MIB, 10 * MIB), [MIB]);
    }
}
