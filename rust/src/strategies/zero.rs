//! ZeRO partitioning arithmetic (Rajbhandari et al., SC'20).
//!
//! * **Stage 1** partitions optimizer states across the data-parallel
//!   world; * **Stage 2** adds gradients (reduce-scattered in buckets);
//! * **Stage 3** adds parameters (per-layer all-gathered on demand).
//!
//! The bucket/gather sizes below are DeepSpeed's defaults, because the
//! transient buffers they imply are exactly the allocations that seed
//! ZeRO-3's fragmentation (paper §3.2).

/// ZeRO stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ZeroStage {
    Z0,
    Z1,
    Z2,
    Z3,
}

impl ZeroStage {
    pub fn stage(self) -> u8 {
        match self {
            ZeroStage::Z0 => 0,
            ZeroStage::Z1 => 1,
            ZeroStage::Z2 => 2,
            ZeroStage::Z3 => 3,
        }
    }

    pub fn from_stage(n: u8) -> Option<Self> {
        match n {
            0 => Some(ZeroStage::Z0),
            1 => Some(ZeroStage::Z1),
            2 => Some(ZeroStage::Z2),
            3 => Some(ZeroStage::Z3),
            _ => None,
        }
    }

    pub fn partitions_optimizer(self) -> bool {
        self >= ZeroStage::Z1
    }
    pub fn partitions_gradients(self) -> bool {
        self >= ZeroStage::Z2
    }
    pub fn partitions_params(self) -> bool {
        self >= ZeroStage::Z3
    }
}

/// DeepSpeed defaults (bytes).
pub mod defaults {
    /// `reduce_bucket_size` (elements) × 2 B fp16 — the transient gradient
    /// reduce-scatter bucket.
    pub const REDUCE_BUCKET_BYTES: u64 = 500_000_000 * 2 / 2; // 5e8 elems fp16
    /// `allgather_bucket_size`: ZeRO-3 parameter all-gather granularity.
    pub const ALLGATHER_BUCKET_BYTES: u64 = 500_000_000 * 2 / 2;
    /// `stage3_prefetch_bucket_size` ~ 5e7 elements.
    pub const PREFETCH_BUCKET_BYTES: u64 = 50_000_000 * 2;
    /// `stage3_max_live_parameters` = 1e9 params: gathered fp16 copies are
    /// kept resident until this many bytes are live, then the oldest are
    /// released — the ring that interleaves gather lifetimes with
    /// activations and shreds the large pool (paper §3.2).
    pub const MAX_LIVE_GATHERED_BYTES: u64 = 1_000_000_000 * 2;
}

/// Per-rank share of a partitioned tensor: ceil(bytes / world), with each
/// rank padded to an even element boundary like DeepSpeed's flat buffers.
pub fn partitioned_bytes(total: u64, world: u64) -> u64 {
    assert!(world > 0);
    let per = total.div_ceil(world);
    // Pad to 16 B so flat partitions stay aligned.
    per.div_ceil(16) * 16
}

/// Sizes of the transient reduce-scatter buckets covering `grad_bytes` of
/// gradients (ZeRO-2/3 backward).
pub fn reduce_buckets(grad_bytes: u64, bucket: u64) -> Vec<u64> {
    split_buckets(grad_bytes, bucket)
}

/// Sizes of the transient all-gather buffers covering `param_bytes`
/// (ZeRO-3 forward/backward). Each buffer materializes the *full* tensor
/// group on every rank.
pub fn gather_buffers(param_bytes: u64, bucket: u64) -> Vec<u64> {
    split_buckets(param_bytes, bucket)
}

fn split_buckets(total: u64, bucket: u64) -> Vec<u64> {
    assert!(bucket > 0);
    if total == 0 {
        return vec![];
    }
    let n = total / bucket;
    let mut out = vec![bucket; n as usize];
    let rem = total - n * bucket;
    if rem > 0 {
        out.push(rem);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    #[test]
    fn stage_predicates() {
        assert!(!ZeroStage::Z0.partitions_optimizer());
        assert!(ZeroStage::Z1.partitions_optimizer());
        assert!(!ZeroStage::Z1.partitions_gradients());
        assert!(ZeroStage::Z2.partitions_gradients());
        assert!(!ZeroStage::Z2.partitions_params());
        assert!(ZeroStage::Z3.partitions_params());
        assert_eq!(ZeroStage::from_stage(3), Some(ZeroStage::Z3));
        assert_eq!(ZeroStage::from_stage(4), None);
    }

    #[test]
    fn partition_rounds_up_and_aligns() {
        assert_eq!(partitioned_bytes(100, 4), 32); // 25 -> pad 32
        assert_eq!(partitioned_bytes(1024, 4), 256);
        assert_eq!(partitioned_bytes(1, 4), 16);
        // Sum over ranks covers the total.
        assert!(partitioned_bytes(1000, 3) * 3 >= 1000);
    }

    #[test]
    fn buckets_cover_exactly() {
        let bs = reduce_buckets(25 * MIB, 10 * MIB);
        assert_eq!(bs, [10 * MIB, 10 * MIB, 5 * MIB]);
        assert_eq!(bs.iter().sum::<u64>(), 25 * MIB);
        assert!(reduce_buckets(0, MIB).is_empty());
        assert_eq!(gather_buffers(MIB, 10 * MIB), [MIB]);
    }
}
