//! Fitting the surrogate: one sweep over the budget's candidate product
//! (optionally at several `steps` values), then per-group per-target
//! affine regression with residual envelopes.
//!
//! The fit is deliberately conservative about its own quality: the
//! envelope of every target is sized from the *worst* in-sample residual
//! (× [`super::ENVELOPE_SLACK`], + [`super::ENVELOPE_FLOOR`]), so a
//! target the affine form fits poorly simply gets a wide envelope — the
//! screen then keeps more candidates for full simulation instead of
//! trusting a bad prediction. Soundness never depends on fit quality,
//! only speed does.

use super::{
    features, GroupModel, SurrogateModel, TargetModel, ENVELOPE_FLOOR, ENVELOPE_SLACK,
    NUM_FEATURES, PEAK_TARGET, PHASE_TARGET_PREFIX, TIME_TARGET,
};
use crate::planner::space;
use crate::planner::Budget;
use crate::rlhf::program::PhaseProgram;
use crate::sweep::SweepRunner;

/// Knobs of [`fit`].
#[derive(Debug, Clone)]
pub struct FitOptions {
    /// The `steps` values to simulate every candidate at. More values
    /// give the regression a real `steps` axis (the only feature that
    /// varies within one budget); the default is the budget's own
    /// `steps`, which yields exact intercept-only models for it.
    pub steps: Vec<u64>,
}

impl FitOptions {
    /// Fit exactly at the budget's configured `steps`.
    pub fn for_budget(budget: &Budget) -> FitOptions {
        FitOptions {
            steps: vec![budget.steps],
        }
    }
}

/// One observed sweep cell of one group: feature vector + observed
/// targets (name → value), in stable target order.
struct Row {
    x: [f64; NUM_FEATURES],
    y: Vec<(String, f64)>,
}

/// Run the budget's sweep cells at every `opts.steps` value and fit a
/// [`SurrogateModel`]. The sweep shards over `jobs` worker threads; the
/// fitted artifact is byte-identical for any `jobs` (cells are keyed by
/// position, regression order is fixed).
pub fn fit(budget: &Budget, jobs: usize, opts: &FitOptions) -> Result<SurrogateModel, String> {
    let mut steps_fit = opts.steps.clone();
    steps_fit.sort_unstable();
    steps_fit.dedup();
    if steps_fit.is_empty() {
        return Err("fit needs at least one steps value".to_string());
    }
    if steps_fit.contains(&0) {
        return Err("fit steps must be >= 1 (a 0-step cell observes no phases)".to_string());
    }

    let candidates = space::enumerate(budget)?;
    let n = candidates.len();
    if n == 0 {
        return Err(format!("budget '{}' enumerates no candidates", budget.name));
    }

    // Steps-major cell list: block si holds every candidate at steps
    // steps_fit[si], so cell (si, ci) sits at index si*n + ci.
    let mut cells = Vec::with_capacity(steps_fit.len() * n);
    for &s in &steps_fit {
        let mut block = space::to_cells(budget, &candidates);
        for cell in &mut block {
            cell.scenario.steps = s;
        }
        cells.append(&mut block);
    }
    let report = SweepRunner::new(jobs).capture_profiles(true).run(cells);

    let mut groups = Vec::with_capacity(n);
    let mut max_rel_err = 0.0f64;
    for (ci, cand) in candidates.iter().enumerate() {
        let mut oom_steps = Vec::new();
        let mut rows: Vec<Row> = Vec::with_capacity(steps_fit.len());
        for (si, &s) in steps_fit.iter().enumerate() {
            let cell = &report.cells[si * n + ci];
            if cell.summary.oom {
                oom_steps.push(s);
                continue;
            }
            let mut y = vec![
                (PEAK_TARGET.to_string(), cell.summary.peak_reserved as f64),
                (TIME_TARGET.to_string(), cell.summary.total_time_us),
            ];
            if let Some(profiler) = &cell.profiler {
                let mut scn = space::candidate_scenario(budget, cand);
                scn.steps = s;
                let program = PhaseProgram::compile(&scn);
                for (kind, peak) in profiler.phase_attribution(&program) {
                    y.push((
                        format!("{PHASE_TARGET_PREFIX}{}", kind.name()),
                        peak.reserved as f64,
                    ));
                }
            }
            rows.push(Row {
                x: features(budget, s),
                y,
            });
        }

        // Stable target order: first-seen across rows (peak, time, then
        // phases in program order).
        let mut names: Vec<String> = Vec::new();
        for row in &rows {
            for (name, _) in &row.y {
                if !names.contains(name) {
                    names.push(name.clone());
                }
            }
        }
        let mut targets = Vec::with_capacity(names.len());
        for name in names {
            let samples: Vec<([f64; NUM_FEATURES], f64)> = rows
                .iter()
                .filter_map(|r| {
                    r.y.iter()
                        .find(|(n, _)| *n == name)
                        .map(|(_, v)| (r.x, *v))
                })
                .collect();
            let model = fit_target(&samples);
            for (x, y) in &samples {
                let resid = (y - model.predict(x)).abs();
                let rel = resid / y.abs().max(1.0);
                if rel > max_rel_err {
                    max_rel_err = rel;
                }
            }
            targets.push((name, model));
        }
        groups.push(GroupModel {
            key: cand.key(),
            oom_steps,
            targets,
        });
    }

    Ok(SurrogateModel {
        budget_name: budget.name.clone(),
        framework: budget.framework.name().to_string(),
        policy_model: budget.models.policy_arch.name.clone(),
        value_model: budget.models.value_arch.name.clone(),
        world: budget.world,
        seed: budget.seed,
        capacity: budget.capacity,
        gpu: budget.gpu,
        steps_fit,
        cells: report.cells.len() as u64,
        max_rel_err,
        groups,
        wall_seconds: report.wall_seconds,
    })
}

/// Fit one target over its sample rows. The ladder degrades gracefully
/// with sample count and conditioning:
///
/// 1. one row → intercept-only (exact, zero residual);
/// 2. otherwise try the full [`super::FEATURES`] basis via normal
///    equations — within a single budget most features are constant and
///    collinear with the intercept, so this usually fails the pivot
///    check and falls through;
/// 3. the `[1, steps]` sub-basis (the only axis that varies in-budget);
/// 4. the mean (intercept-only) as the unconditional fallback.
///
/// Whatever rung lands, the envelope covers the residuals — rung choice
/// affects envelope width (speed), never soundness.
fn fit_target(rows: &[([f64; NUM_FEATURES], f64)]) -> TargetModel {
    let coefs = if rows.len() == 1 {
        let mut c = [0.0; NUM_FEATURES];
        c[0] = rows[0].1;
        c
    } else {
        let full: Vec<usize> = (0..NUM_FEATURES).collect();
        solve_least_squares(rows, &full)
            .or_else(|| solve_least_squares(rows, &[0, 1]))
            .unwrap_or_else(|| {
                let mut c = [0.0; NUM_FEATURES];
                c[0] = rows.iter().map(|(_, y)| *y).sum::<f64>() / rows.len() as f64;
                c
            })
    };
    let probe = TargetModel {
        coefs,
        envelope: 0.0,
    };
    let mut worst = 0.0f64;
    for (x, y) in rows {
        let resid = (y - probe.predict(x)).abs();
        if resid > worst {
            worst = resid;
        }
    }
    TargetModel {
        coefs,
        envelope: ENVELOPE_SLACK * worst + ENVELOPE_FLOOR,
    }
}

/// Least squares over the feature columns `cols` via normal equations +
/// Gaussian elimination with partial pivoting. Returns `None` when the
/// system is singular (pivot below `1e-9 ×` the matrix's initial scale)
/// — the caller drops to a smaller basis. Coefficients come back in the
/// full [`NUM_FEATURES`]-wide frame, zero for unused columns.
fn solve_least_squares(
    rows: &[([f64; NUM_FEATURES], f64)],
    cols: &[usize],
) -> Option<[f64; NUM_FEATURES]> {
    let k = cols.len();
    // Augmented normal system [XᵀX | Xᵀy].
    let mut m = vec![vec![0.0f64; k + 1]; k];
    for (x, y) in rows {
        for (i, &ci) in cols.iter().enumerate() {
            for (j, &cj) in cols.iter().enumerate() {
                m[i][j] += x[ci] * x[cj];
            }
            m[i][k] += x[ci] * y;
        }
    }
    let mut scale = 0.0f64;
    for row in &m {
        for &v in &row[..k] {
            if v.abs() > scale {
                scale = v.abs();
            }
        }
    }
    let threshold = 1e-9 * scale.max(1.0);

    for col in 0..k {
        let pivot_row = (col..k)
            .max_by(|&a, &b| m[a][col].abs().total_cmp(&m[b][col].abs()))
            .unwrap();
        if m[pivot_row][col].abs() < threshold {
            return None;
        }
        m.swap(col, pivot_row);
        let pivot = m[col][col];
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = m[r][col] / pivot;
            for c in col..=k {
                m[r][c] -= f * m[col][c];
            }
        }
    }
    let mut out = [0.0f64; NUM_FEATURES];
    for (i, &ci) in cols.iter().enumerate() {
        out[ci] = m[i][k] / m[i][i];
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(steps: f64, y: f64) -> ([f64; NUM_FEATURES], f64) {
        ([1.0, steps, 1024.0, 2.6e9, 6.6e8, 4.0], y)
    }

    #[test]
    fn exact_affine_data_is_recovered() {
        // y = 100 + 7·steps, three samples: the [1, steps] rung solves it
        // exactly (every other column is constant ⇒ full basis singular).
        let rows = [row(1.0, 107.0), row(2.0, 114.0), row(4.0, 128.0)];
        let t = fit_target(&rows);
        for (x, y) in &rows {
            assert!(
                (t.predict(x) - y).abs() < 1e-6,
                "pred {} vs {}",
                t.predict(x),
                y
            );
        }
        // Exact fit ⇒ floor-only envelope, still strictly positive.
        assert!(t.envelope >= ENVELOPE_FLOOR);
        assert!(t.envelope < ENVELOPE_FLOOR + 1e-3);
    }

    #[test]
    fn single_sample_is_pinned_by_the_intercept() {
        let rows = [row(2.0, 5.5e9)];
        let t = fit_target(&rows);
        assert_eq!(t.predict(&rows[0].0), 5.5e9);
        assert_eq!(t.envelope, ENVELOPE_FLOOR);
    }

    #[test]
    fn envelope_strictly_brackets_every_sample() {
        // Non-affine data (quadratic in steps): the fit can't be exact,
        // the envelope must still strictly contain every residual.
        let rows = [
            row(1.0, 1.0),
            row(2.0, 4.0),
            row(3.0, 9.0),
            row(5.0, 25.0),
        ];
        let t = fit_target(&rows);
        for (x, y) in &rows {
            let p = t.predict(x);
            assert!(
                p - t.envelope < *y && *y < p + t.envelope,
                "sample {y} escapes [{}, {}]",
                p - t.envelope,
                p + t.envelope
            );
        }
    }

    #[test]
    fn singular_systems_fall_back_instead_of_exploding() {
        // Identical feature rows with different y: no basis separates
        // them; the mean fallback lands and the envelope covers both.
        let rows = [row(2.0, 10.0), row(2.0, 20.0)];
        let t = fit_target(&rows);
        assert_eq!(t.coefs[1], 0.0, "steps coefficient must be dropped");
        for (x, y) in &rows {
            let p = t.predict(x);
            assert!(p - t.envelope < *y && *y < p + t.envelope);
        }
    }

    #[test]
    fn fit_rejects_bad_step_ladders() {
        let budget = Budget::rtx3090_table1();
        assert!(fit(&budget, 1, &FitOptions { steps: vec![] })
            .unwrap_err()
            .contains("at least one"));
        assert!(fit(&budget, 1, &FitOptions { steps: vec![0, 1] })
            .unwrap_err()
            .contains(">= 1"));
    }
}
