//! Trace-mined surrogate of the planner's simulation: a closed-form
//! memory/step-time model fitted from sweep cells, used to screen the
//! mitigation space before any full simulation runs.
//!
//! The planner's exhaustive search ([`crate::planner::plan`]) simulates
//! every candidate of the strategy × sharing × `empty_cache` × allocator
//! product, at milliseconds per cell. Most of those cells exist only to
//! be dominated: their peak and time land strictly inside another
//! configuration's, so the Pareto frontier — the artifact the search is
//! for — never contains them. The surrogate makes that screening decision
//! in microseconds per candidate:
//!
//! * [`fit`] runs the budget's sweep cells once (optionally over a ladder
//!   of `steps` values), groups them by their full discrete configuration
//!   (`strategy/policy[/algo][/sharing]/alloc`), and fits one affine
//!   model per group per target over the [`FEATURES`] basis — per-phase
//!   reserved peaks (from [`crate::profiler::MemoryProfiler::
//!   phase_attribution`]), the overall reserved peak and the modeled step
//!   time. Every model carries a fitted **residual envelope**: by
//!   construction strictly wider than every in-sample residual
//!   ([`ENVELOPE_SLACK`] × the largest absolute residual, plus
//!   [`ENVELOPE_FLOOR`]). The result serializes as the versioned
//!   `SURROGATE.json` artifact (`rlhf-mem fit`).
//! * [`plan_surrogate`] screens the candidate product against the model:
//!   a candidate is dropped only when its *optimistic* corner
//!   (prediction − envelope) is strictly dominated by the *pessimistic*
//!   corner (prediction + envelope) of a certainly-feasible witness, or
//!   when the artifact certifies it infeasible outright. Survivors — the
//!   candidates within the surrogate's error envelope of the Pareto
//!   frontier — go to full simulation, and the frontier computed over
//!   that simulated subset is **byte-identical** to the exhaustive
//!   search's ([`crate::planner::PlanReport::frontier_jsonl`] vs
//!   [`SurrogatePlanReport::frontier_jsonl`], pinned by
//!   `rust/tests/surrogate_soundness.rs`).
//!
//! Why the identity holds: envelopes strictly contain in-sample
//! residuals, so for an artifact fitted on this exact budget (provenance
//! and `steps` match) the corners bracket the true simulated values. A
//! dominated-and-dropped candidate is then *truly* strictly dominated, in
//! both dimensions, by its witness's true values; chains of witnesses
//! terminate at an unscreened (hence simulated) configuration, and
//! dominance is transitive, so no dropped candidate can be on the true
//! frontier or shield another candidate's membership. When provenance
//! does not match — different capacity, seed, model pair, or a group the
//! fit never saw — the planner falls back to simulating those candidates
//! (counted in telemetry), trading speed for the same guarantee, never
//! correctness. DESIGN.md §17 carries the full argument and the refit
//! policy.

pub mod fit;
pub mod screen;

pub use fit::{fit, FitOptions};
pub use screen::{plan_surrogate, SurrogateOutcome, SurrogatePlanReport};

use crate::frameworks::FrameworkProfile;
use crate::mem::DType;
use crate::planner::Budget;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::Role;
use crate::util::json::{parse, Json};

/// Artifact schema tag (`SURROGATE.json`). Bump on any change to the
/// fitted form, the feature basis or the envelope semantics — a stale
/// artifact must fail loudly, not screen unsoundly.
pub const SCHEMA: &str = "rlhf-mem-surrogate-v1";

/// The affine feature basis, in coefficient order: intercept, simulated
/// PPO steps, rollout tokens per step (batch × (prompt + generated)),
/// fp16 bytes of the policy-side and value-side inventories, and the
/// data-parallel world size. Within a single budget only `steps` varies
/// (via the fit ladder), so the fit degenerates gracefully — see
/// [`fit::fit_target`]'s ladder.
pub const FEATURES: [&str; 6] = [
    "1",
    "steps",
    "tokens",
    "policy_bytes",
    "value_bytes",
    "world",
];

/// Number of features in [`FEATURES`].
pub const NUM_FEATURES: usize = 6;

/// Target name of the overall reserved peak (bytes).
pub const PEAK_TARGET: &str = "peak_reserved";
/// Target name of the modeled total step time (µs).
pub const TIME_TARGET: &str = "total_time_us";
/// Prefix of the per-phase reserved-peak targets (`phase:init`,
/// `phase:generation`, ...).
pub const PHASE_TARGET_PREFIX: &str = "phase:";

/// Multiplier on the largest in-sample absolute residual when sizing a
/// target's envelope. > 1 so the envelope *strictly* contains every
/// residual the fit saw.
pub const ENVELOPE_SLACK: f64 = 1.5;
/// Additive envelope floor (1 byte / 1 µs): keeps the bracketing strict
/// even for exact fits (zero residual), which is what makes tie-peak
/// candidates survive screening instead of being dropped on a guess.
pub const ENVELOPE_FLOOR: f64 = 1.0;

/// The feature vector for `budget` at `steps` simulated PPO steps, in
/// [`FEATURES`] order. A pure function of the budget — both the fit and
/// the screen call this, so they can never disagree on the basis.
pub fn features(budget: &Budget, steps: u64) -> [f64; NUM_FEATURES] {
    let profile = FrameworkProfile::by_kind(budget.framework);
    let tokens = profile.rollout_batch * (profile.prompt_len + profile.gen_len);
    let policy_bytes = budget
        .models
        .inventory_for(Role::Actor)
        .total_bytes(DType::F16);
    let value_bytes = budget
        .models
        .inventory_for(Role::Critic)
        .total_bytes(DType::F16);
    [
        1.0,
        steps as f64,
        tokens as f64,
        policy_bytes as f64,
        value_bytes as f64,
        budget.world as f64,
    ]
}

/// One fitted affine model for one target of one group: coefficients
/// over [`FEATURES`] (zero for features the fit ladder dropped) plus the
/// residual envelope.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetModel {
    pub coefs: [f64; NUM_FEATURES],
    /// Strictly wider than every in-sample |residual|:
    /// [`ENVELOPE_SLACK`] × max |residual| + [`ENVELOPE_FLOOR`]. For an
    /// in-sample prediction, `pred − envelope < truth < pred + envelope`.
    pub envelope: f64,
}

impl TargetModel {
    /// Predict the target at feature vector `x` (fixed evaluation order —
    /// the prediction is bit-reproducible).
    pub fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        let mut y = 0.0;
        for (c, v) in self.coefs.iter().zip(x.iter()) {
            y += c * v;
        }
        y
    }
}

/// The fitted models of one discrete configuration (one planner
/// [`crate::planner::Candidate`] key).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupModel {
    /// The candidate key (`strategy/policy[/algo][/sharing]/alloc`).
    pub key: String,
    /// `steps` values at which this configuration's fit cell OOMed at the
    /// artifact's capacity. Deterministic simulation makes this a
    /// certificate: replaying the same cell would OOM again.
    pub oom_steps: Vec<u64>,
    /// Fitted targets in stable order: [`PEAK_TARGET`], [`TIME_TARGET`],
    /// then the `phase:*` reserved peaks in program order. Empty when
    /// every fit cell of the group OOMed (nothing to fit).
    pub targets: Vec<(String, TargetModel)>,
}

impl GroupModel {
    pub fn target(&self, name: &str) -> Option<&TargetModel> {
        self.targets.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// The fitted surrogate: provenance (what the fit simulated) + one
/// [`GroupModel`] per discrete configuration, in enumeration order.
/// Serializes as `SURROGATE.json` ([`Self::to_json`]).
#[derive(Debug, Clone)]
pub struct SurrogateModel {
    /// Name of the budget the fit ran (display only).
    pub budget_name: String,
    // Provenance: screening trusts the artifact's certificates only when
    // every one of these matches the budget being planned.
    pub framework: String,
    pub policy_model: String,
    pub value_model: String,
    pub world: u64,
    pub seed: u64,
    pub capacity: u64,
    pub gpu: GpuSpec,
    /// The `steps` ladder the fit simulated (sorted, deduplicated).
    pub steps_fit: Vec<u64>,
    /// Sweep cells simulated by the fit.
    pub cells: u64,
    /// Largest in-sample relative error across every group and target:
    /// max |residual| / max(|observed|, 1). The CI gate holds this under
    /// a committed bound (see `.github/workflows/ci.yml`).
    pub max_rel_err: f64,
    pub groups: Vec<GroupModel>,
    /// Wall-clock of the fit sweep, seconds. Never serialized (the
    /// artifact must be machine-independent); 0 after a parse.
    pub wall_seconds: f64,
}

impl SurrogateModel {
    pub fn group(&self, key: &str) -> Option<&GroupModel> {
        self.groups.iter().find(|g| g.key == key)
    }

    /// Does this artifact's provenance match `budget` exactly? Only then
    /// are its OOM flags and envelopes certificates about the cells the
    /// planner would simulate (the simulator is deterministic, so same
    /// provenance ⇒ same cell results). The `steps` axis is checked
    /// separately ([`Self::in_sample`]) because the fit ladder may cover
    /// several values.
    pub fn applies_to(&self, budget: &Budget) -> bool {
        self.framework == budget.framework.name()
            && self.policy_model == budget.models.policy_arch.name
            && self.value_model == budget.models.value_arch.name
            && self.world == budget.world
            && self.seed == budget.seed
            && self.capacity == budget.capacity
            && self.gpu == budget.gpu
    }

    /// Was `steps` one of the fitted ladder values? In-sample predictions
    /// are bracketed by the envelopes *by construction*; out-of-sample
    /// ones are extrapolations and must not drop candidates.
    pub fn in_sample(&self, steps: u64) -> bool {
        self.steps_fit.contains(&steps)
    }

    /// The `SURROGATE.json` document (deterministic field order; no
    /// wall-clock).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("budget", Json::str(self.budget_name.clone())),
            ("framework", Json::str(self.framework.clone())),
            ("policy_model", Json::str(self.policy_model.clone())),
            ("value_model", Json::str(self.value_model.clone())),
            ("world", Json::from(self.world)),
            ("seed", Json::from(self.seed)),
            ("capacity", Json::from(self.capacity)),
            (
                "gpu",
                Json::obj(vec![
                    ("flops", Json::from(self.gpu.flops)),
                    ("hbm_bw", Json::from(self.gpu.hbm_bw)),
                    ("link_bw", Json::from(self.gpu.link_bw)),
                ]),
            ),
            (
                "steps_fit",
                Json::Arr(self.steps_fit.iter().map(|&s| Json::from(s)).collect()),
            ),
            (
                "features",
                Json::Arr(FEATURES.iter().map(|f| Json::str(*f)).collect()),
            ),
            ("cells", Json::from(self.cells)),
            ("max_rel_err", Json::from(self.max_rel_err)),
            (
                "groups",
                Json::Arr(self.groups.iter().map(group_json).collect()),
            ),
        ])
    }

    pub fn from_json_text(text: &str) -> Result<SurrogateModel, String> {
        Self::from_json(&parse(text)?)
    }

    pub fn from_file(path: &str) -> Result<SurrogateModel, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json_text(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn from_json(j: &Json) -> Result<SurrogateModel, String> {
        let schema = j.req_str("schema")?;
        if schema != SCHEMA {
            return Err(format!(
                "surrogate schema '{schema}' != '{SCHEMA}': refit with `rlhf-mem fit`"
            ));
        }
        let feats: Vec<&str> = j
            .req_arr("features")?
            .iter()
            .filter_map(|f| f.as_str())
            .collect();
        if feats != FEATURES {
            return Err(format!(
                "surrogate feature basis {feats:?} does not match this build's \
                 {FEATURES:?}: refit with `rlhf-mem fit`"
            ));
        }
        let gpu = j.req("gpu")?;
        let gpu = GpuSpec {
            flops: gpu.req_f64("flops")?,
            hbm_bw: gpu.req_f64("hbm_bw")?,
            link_bw: gpu.req_f64("link_bw")?,
        };
        let steps_fit = j
            .req_arr("steps_fit")?
            .iter()
            .map(|s| s.as_u64().ok_or("steps_fit entries must be u64".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        let groups = j
            .req_arr("groups")?
            .iter()
            .map(group_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SurrogateModel {
            budget_name: j.req_str("budget")?.to_string(),
            framework: j.req_str("framework")?.to_string(),
            policy_model: j.req_str("policy_model")?.to_string(),
            value_model: j.req_str("value_model")?.to_string(),
            world: j.req_u64("world")?,
            seed: j.req_u64("seed")?,
            capacity: j.req_u64("capacity")?,
            gpu,
            steps_fit,
            cells: j.req_u64("cells")?,
            max_rel_err: j.req_f64("max_rel_err")?,
            groups,
            wall_seconds: 0.0,
        })
    }
}

fn group_json(g: &GroupModel) -> Json {
    Json::obj(vec![
        ("key", Json::str(g.key.clone())),
        (
            "oom_steps",
            Json::Arr(g.oom_steps.iter().map(|&s| Json::from(s)).collect()),
        ),
        (
            "targets",
            Json::Obj(
                g.targets
                    .iter()
                    .map(|(name, t)| {
                        (
                            name.clone(),
                            Json::obj(vec![
                                (
                                    "coefs",
                                    Json::Arr(t.coefs.iter().map(|&c| Json::from(c)).collect()),
                                ),
                                ("envelope", Json::from(t.envelope)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

fn group_from_json(j: &Json) -> Result<GroupModel, String> {
    let oom_steps = j
        .req_arr("oom_steps")?
        .iter()
        .map(|s| s.as_u64().ok_or("oom_steps entries must be u64".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let Json::Obj(target_kvs) = j.req("targets")? else {
        return Err("group 'targets' must be an object".to_string());
    };
    let mut targets = Vec::with_capacity(target_kvs.len());
    for (name, t) in target_kvs {
        let coef_arr = t.req_arr("coefs")?;
        if coef_arr.len() != NUM_FEATURES {
            return Err(format!(
                "target '{name}' has {} coefficients, expected {NUM_FEATURES}",
                coef_arr.len()
            ));
        }
        let mut coefs = [0.0; NUM_FEATURES];
        for (i, c) in coef_arr.iter().enumerate() {
            coefs[i] = c
                .as_f64()
                .ok_or_else(|| format!("target '{name}' coefficient {i} is not a number"))?;
        }
        targets.push((
            name.clone(),
            TargetModel {
                coefs,
                envelope: t.req_f64("envelope")?,
            },
        ));
    }
    Ok(GroupModel {
        key: j.req_str("key")?.to_string(),
        oom_steps,
        targets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_are_the_documented_basis() {
        let budget = Budget::rtx3090_table1();
        let x = features(&budget, 3);
        assert_eq!(x[0], 1.0);
        assert_eq!(x[1], 3.0);
        // DeepSpeed-Chat: batch 2 × (256 prompt + 256 generated).
        assert_eq!(x[2], 1024.0);
        assert!(x[3] > x[4], "policy (1.3b) outweighs value (350m)");
        assert_eq!(x[5], budget.world as f64);
    }

    #[test]
    fn target_model_predicts_in_fixed_order() {
        let t = TargetModel {
            coefs: [10.0, 2.0, 0.0, 0.0, 0.0, 0.0],
            envelope: 1.0,
        };
        let budget = Budget::rtx3090_table1();
        assert_eq!(t.predict(&features(&budget, 5)), 20.0);
    }

    #[test]
    fn artifact_roundtrips_and_rejects_drift() {
        let model = SurrogateModel {
            budget_name: "rt".to_string(),
            framework: "DeepSpeed-Chat".to_string(),
            policy_model: "opt-1.3b".to_string(),
            value_model: "opt-350m".to_string(),
            world: 4,
            seed: 0x5EED,
            capacity: 24 * crate::util::bytes::GIB,
            gpu: GpuSpec::rtx3090(),
            steps_fit: vec![1, 2],
            cells: 280,
            max_rel_err: 0.001,
            groups: vec![GroupModel {
                key: "None/never/default".to_string(),
                oom_steps: vec![],
                targets: vec![(
                    PEAK_TARGET.to_string(),
                    TargetModel {
                        coefs: [1.5e10, 2.25, 0.0, 0.0, 0.0, 0.0],
                        envelope: 1.0,
                    },
                )],
            }],
            wall_seconds: 9.0,
        };
        let text = model.to_json().to_string_pretty();
        let back = SurrogateModel::from_json_text(&text).unwrap();
        assert_eq!(back.to_json().to_string(), model.to_json().to_string());
        assert_eq!(back.wall_seconds, 0.0, "wall never enters the artifact");
        assert!(back.applies_to(&Budget::rtx3090_table1()));
        assert!(back.in_sample(2));
        assert!(!back.in_sample(3));
        let mut other = Budget::rtx3090_table1();
        other.seed = 7;
        assert!(!back.applies_to(&other));

        let bad = text.replace(SCHEMA, "rlhf-mem-surrogate-v0");
        assert!(SurrogateModel::from_json_text(&bad)
            .unwrap_err()
            .contains("refit"));
        let bad = text.replace("\"steps\"", "\"epochs\"");
        assert!(SurrogateModel::from_json_text(&bad)
            .unwrap_err()
            .contains("feature basis"));
    }
}
