//! The two-tier search: surrogate screening first, full simulation only
//! for candidates the model cannot certify away.
//!
//! # Screening verdicts
//!
//! Per candidate, from the artifact's group models:
//!
//! * **Fallback** — the artifact does not cover this candidate (wrong
//!   provenance, out-of-sample `steps`, unknown group, missing targets).
//!   Always simulated; the surrogate buys nothing here but costs nothing
//!   in correctness.
//! * **Certain OOM** — the fit observed this exact cell OOM at this
//!   exact `steps`/provenance; deterministic simulation would OOM again.
//!   Excluded without simulation (an OOM cell is infeasible and the
//!   frontier never contains infeasible points).
//! * **Certified** — the group's peak/time models predict with their
//!   envelopes. Because the envelope strictly contains every in-sample
//!   residual and an applicable artifact makes this cell in-sample, the
//!   true simulated values lie strictly inside
//!   `(prediction − envelope, prediction + envelope)`. A certified
//!   candidate is excluded only when
//!   1. its optimistic peak corner already exceeds capacity (truly
//!      infeasible), or
//!   2. some *certainly feasible* certified witness's pessimistic corner
//!      is ≤ its optimistic corner in **both** dimensions — strict
//!      bracketing then forces strict true dominance in both dimensions,
//!      so the candidate can be on no frontier and can shield nothing.
//!
//! Survivors are simulated (pass A), their overhead baselines next
//! (pass B, the `refined` counter), and the Pareto frontier over the
//! simulated subset is byte-identical to the exhaustive search's — the
//! module doc of [`crate::surrogate`] sketches why, DESIGN.md §17 has
//! the full argument, and [`plan_surrogate`] additionally *checks* the
//! dominance certificates against the simulated results, erroring on a
//! stale artifact instead of returning a silently wrong frontier.

use super::{features, SurrogateModel, PEAK_TARGET, TIME_TARGET};
use crate::obs::Telemetry;
use crate::planner::{frontier, frontier_line_json, space, Budget, Candidate};
use crate::policy::EmptyCachePolicy;
use crate::profiler::ProfileSummary;
use crate::report::table::TextTable;
use crate::sweep::SweepRunner;
use crate::util::bytes::fmt_gib_paper;
use crate::util::schema;

/// One simulated candidate's verdict — the surrogate search only ever
/// materializes outcomes it actually simulated.
#[derive(Debug, Clone)]
pub struct SurrogateOutcome {
    pub candidate: Candidate,
    pub summary: ProfileSummary,
    /// Completed without OOM and peak reserved fits the budget.
    pub feasible: bool,
    /// Same semantics (and same value) as the exhaustive search's
    /// [`crate::planner::PlanOutcome::overhead_pct`]: pass B simulates
    /// every simulated candidate's un-mitigated baseline, so the numbers
    /// agree line-for-line.
    pub overhead_pct: Option<f64>,
    /// On the memory-vs-time Pareto frontier (computed over the
    /// simulated subset; identical membership to the exhaustive search).
    pub on_frontier: bool,
}

/// The surrogate-screened planner's output.
#[derive(Debug)]
pub struct SurrogatePlanReport {
    pub budget: Budget,
    /// Simulated candidates only, enumeration order.
    pub outcomes: Vec<SurrogateOutcome>,
    /// Candidates screened (the full enumeration).
    pub screened: u64,
    /// Candidates excluded without simulation.
    pub screened_out: u64,
    /// Candidates simulated in total (pass A survivors + pass B
    /// baselines) — the headline denominator vs `screened`.
    pub simulated: u64,
    /// Pass-B cells: overhead baselines the screen excluded but the
    /// report needs for its `overhead_pct` columns.
    pub refined: u64,
    /// Candidates the artifact could not certify (simulated in pass A).
    pub fallback: u64,
    /// Wall-clock of both sweeps, seconds (never serialized).
    pub wall_seconds: f64,
    pub jobs: usize,
    /// Echo of the artifact's fit quality.
    pub max_rel_err: f64,
}

/// A candidate's screening prediction.
enum Pred {
    /// Artifact certifies this cell OOMs at the planned `steps`.
    CertainOom,
    /// In-sample prediction with strict-bracketing corners.
    Certified {
        opt_peak: f64,
        pess_peak: f64,
        opt_time: f64,
        pess_time: f64,
        /// Pessimistic peak fits capacity ⇒ truly feasible.
        certainly_feasible: bool,
    },
    /// Artifact has no certified prediction — simulate.
    Fallback,
}

#[derive(Clone, Copy, PartialEq)]
enum Verdict {
    Simulate,
    /// Optimistic peak ≥ capacity: truly infeasible.
    InfeasibleBound,
    /// Strictly dominated (both dims) by a certainly-feasible witness.
    Dominated,
    /// Certified OOM.
    Oom,
}

/// Screen `budget`'s candidate product against `model`, simulate the
/// survivors and their overhead baselines, and return a report whose
/// [`SurrogatePlanReport::frontier_jsonl`] is byte-identical to the
/// exhaustive [`crate::planner::plan`]'s
/// [`crate::planner::PlanReport::frontier_jsonl`] — or an error if the
/// simulated results refute the artifact's dominance certificates (a
/// stale artifact: refit, don't guess).
pub fn plan_surrogate(
    budget: &Budget,
    jobs: usize,
    model: &SurrogateModel,
) -> Result<SurrogatePlanReport, String> {
    let candidates = space::enumerate(budget)?;
    let applicable = model.applies_to(budget) && model.in_sample(budget.steps);
    let x = features(budget, budget.steps);
    let cap = budget.capacity as f64;

    let preds: Vec<Pred> = candidates
        .iter()
        .map(|c| {
            if !applicable {
                return Pred::Fallback;
            }
            let Some(g) = model.group(&c.key()) else {
                return Pred::Fallback;
            };
            if g.oom_steps.contains(&budget.steps) {
                return Pred::CertainOom;
            }
            let (Some(pk), Some(tm)) = (g.target(PEAK_TARGET), g.target(TIME_TARGET)) else {
                return Pred::Fallback;
            };
            let peak = pk.predict(&x);
            let time = tm.predict(&x);
            Pred::Certified {
                opt_peak: (peak - pk.envelope).max(0.0),
                pess_peak: peak + pk.envelope,
                opt_time: (time - tm.envelope).max(0.0),
                pess_time: time + tm.envelope,
                certainly_feasible: peak + pk.envelope <= cap,
            }
        })
        .collect();

    let verdicts: Vec<Verdict> = preds
        .iter()
        .enumerate()
        .map(|(i, p)| match p {
            Pred::Fallback => Verdict::Simulate,
            Pred::CertainOom => Verdict::Oom,
            Pred::Certified {
                opt_peak, opt_time, ..
            } => {
                if *opt_peak >= cap {
                    return Verdict::InfeasibleBound;
                }
                let dominated = preds.iter().enumerate().any(|(j, w)| {
                    j != i
                        && matches!(
                            w,
                            Pred::Certified {
                                certainly_feasible: true,
                                pess_peak,
                                pess_time,
                                ..
                            } if *pess_peak <= *opt_peak && *pess_time <= *opt_time
                        )
                });
                if dominated {
                    Verdict::Dominated
                } else {
                    Verdict::Simulate
                }
            }
        })
        .collect();

    // Pass A: simulate the survivors.
    let survivors: Vec<Candidate> = candidates
        .iter()
        .zip(&verdicts)
        .filter(|(_, v)| **v == Verdict::Simulate)
        .map(|(c, _)| c.clone())
        .collect();
    let fallback = preds.iter().filter(|p| matches!(p, Pred::Fallback)).count() as u64;
    let sweep_a = SweepRunner::new(jobs).run(space::to_cells(budget, &survivors));
    let mut wall_seconds = sweep_a.wall_seconds;
    let mut sim_summary: Vec<Option<ProfileSummary>> = vec![None; candidates.len()];
    for (c, cell) in survivors.iter().zip(&sweep_a.cells) {
        sim_summary[c.index] = Some(cell.summary.clone());
    }

    // Check every dominance certificate against the simulated truth: an
    // excluded candidate's optimistic corner must be strictly beaten, in
    // both dimensions, by some feasible simulated configuration — the
    // chain of witnesses that justified the exclusion terminates at one.
    // A certificate this check refutes means the artifact no longer
    // describes this code or budget; failing loudly beats a wrong
    // frontier.
    for (i, v) in verdicts.iter().enumerate() {
        if *v != Verdict::Dominated {
            continue;
        }
        let Pred::Certified {
            opt_peak, opt_time, ..
        } = &preds[i]
        else {
            unreachable!("only certified candidates are dominance-excluded");
        };
        let witnessed = sim_summary.iter().flatten().any(|s| {
            !s.oom
                && s.peak_reserved <= budget.capacity
                && (s.peak_reserved as f64) < *opt_peak
                && s.total_time_us < *opt_time
        });
        if !witnessed {
            return Err(format!(
                "surrogate certificate refuted: '{}' was screened out as dominated but no \
                 simulated configuration beats its optimistic corner — the SURROGATE \
                 artifact is stale for this build or budget; re-run `rlhf-mem fit`",
                candidates[i].key()
            ));
        }
    }

    // Pass B: overhead baselines (policy `never`, default allocator,
    // same strategy/algo/sharing) of every simulated candidate that the
    // screen excluded. A certified-OOM baseline stays excluded — the
    // exhaustive search also reports `overhead_pct: null` against an
    // OOMed baseline.
    let baseline_pos = |of: &Candidate| -> Option<usize> {
        candidates.iter().position(|c| {
            c.strategy_label == of.strategy_label
                && c.algo == of.algo
                && c.sharing == of.sharing
                && c.policy == EmptyCachePolicy::Never
                && c.alloc_label == "default"
        })
    };
    let mut needed: Vec<usize> = survivors
        .iter()
        .filter_map(baseline_pos)
        .filter(|&i| sim_summary[i].is_none() && verdicts[i] != Verdict::Oom)
        .collect();
    needed.sort_unstable();
    needed.dedup();
    let refined = needed.len() as u64;
    if !needed.is_empty() {
        let extra: Vec<Candidate> = needed.iter().map(|&i| candidates[i].clone()).collect();
        let sweep_b = SweepRunner::new(jobs).run(space::to_cells(budget, &extra));
        wall_seconds += sweep_b.wall_seconds;
        for (c, cell) in extra.iter().zip(&sweep_b.cells) {
            sim_summary[c.index] = Some(cell.summary.clone());
        }
    }

    // Frontier + overheads over the simulated subset, enumeration order.
    // Membership is identical to the exhaustive frontier: every excluded
    // candidate is either truly infeasible (never on a frontier, never
    // dominates) or strictly dominated in both dimensions by a feasible
    // simulated point (which therefore also dominates anything it
    // dominated).
    let simulated_idx: Vec<usize> = (0..candidates.len())
        .filter(|&i| sim_summary[i].is_some())
        .collect();
    let points: Vec<frontier::Point> = simulated_idx
        .iter()
        .map(|&i| {
            let s = sim_summary[i].as_ref().unwrap();
            let ok = !s.oom && s.peak_reserved <= budget.capacity;
            (s.peak_reserved, s.total_time_us, ok)
        })
        .collect();
    let on_frontier = frontier::pareto_frontier(&points);

    let outcomes: Vec<SurrogateOutcome> = simulated_idx
        .iter()
        .zip(&on_frontier)
        .map(|(&i, &front)| {
            let summary = sim_summary[i].clone().unwrap();
            let overhead_pct = baseline_pos(&candidates[i])
                .and_then(|b| sim_summary[b].as_ref())
                .filter(|base| !base.oom)
                .map(|base| {
                    (summary.total_time_us - base.total_time_us) / base.total_time_us * 100.0
                });
            SurrogateOutcome {
                candidate: candidates[i].clone(),
                summary: summary.clone(),
                feasible: !summary.oom && summary.peak_reserved <= budget.capacity,
                overhead_pct,
                on_frontier: front,
            }
        })
        .collect();

    let simulated = outcomes.len() as u64;
    Ok(SurrogatePlanReport {
        budget: budget.clone(),
        screened: candidates.len() as u64,
        screened_out: candidates.len() as u64 - (simulated - refined),
        simulated,
        refined,
        fallback,
        outcomes,
        wall_seconds,
        jobs: sweep_a.jobs,
        max_rel_err: model.max_rel_err,
    })
}

impl SurrogatePlanReport {
    /// The memory-vs-time Pareto frontier, cheapest memory first — the
    /// same points, in the same order, as the exhaustive
    /// [`crate::planner::PlanReport::frontier`].
    pub fn frontier(&self) -> Vec<&SurrogateOutcome> {
        let mut v: Vec<&SurrogateOutcome> =
            self.outcomes.iter().filter(|o| o.on_frontier).collect();
        v.sort_by(|a, b| {
            a.summary
                .peak_reserved
                .cmp(&b.summary.peak_reserved)
                .then(a.summary.total_time_us.total_cmp(&b.summary.total_time_us))
                .then(a.candidate.index.cmp(&b.candidate.index))
        });
        v
    }

    /// The cheapest feasible frontier configuration within the budget's
    /// overhead tolerance (peak, then time, then index). This is the
    /// surrogate search's recommendation; it is *not* always the
    /// exhaustive search's `best()` — that rank is a global ordering
    /// over candidates this search deliberately never simulated — which
    /// is why the identity contract is [`Self::frontier_jsonl`], not the
    /// recommendation string.
    pub fn recommended_frontier(&self) -> Option<&SurrogateOutcome> {
        self.outcomes
            .iter()
            .filter(|o| {
                o.on_frontier
                    && o.feasible
                    && match o.overhead_pct {
                        Some(p) => p <= self.budget.max_overhead_pct,
                        None => true,
                    }
            })
            .min_by(|a, b| {
                a.summary
                    .peak_reserved
                    .cmp(&b.summary.peak_reserved)
                    .then(a.summary.total_time_us.total_cmp(&b.summary.total_time_us))
                    .then(a.candidate.index.cmp(&b.candidate.index))
            })
    }

    /// Deterministic JSON-lines dump of the frontier, enumeration order
    /// — byte-identical to the exhaustive search's
    /// [`crate::planner::PlanReport::frontier_jsonl`] for the same
    /// budget (both emit [`frontier_line_json`] lines; `rust/tests/
    /// surrogate_soundness.rs` pins the identity, CI `cmp`s the files).
    pub fn frontier_jsonl(&self) -> String {
        let mut out = schema::header_line("planner");
        out.push('\n');
        for o in self.outcomes.iter().filter(|o| o.on_frontier) {
            out.push_str(
                &frontier_line_json(&o.candidate, &o.summary, o.overhead_pct, o.feasible, true)
                    .to_string(),
            );
            out.push('\n');
        }
        out
    }

    /// [`Self::frontier_jsonl`] plus one trailing `{"telemetry":{...}}`
    /// footer line. Still byte-identical for any `--jobs`.
    pub fn jsonl_with_telemetry(&self) -> String {
        let mut out = self.frontier_jsonl();
        out.push_str(&self.telemetry().footer_line());
        out.push('\n');
        out
    }

    /// The run-telemetry ledger: screening counters first (the headline
    /// `sim_reduction_pct` is the integer percentage of candidates that
    /// never reached the simulator), then the same per-outcome allocator
    /// counters the exhaustive planner ledgers, over the simulated
    /// subset. Deterministic for any `--jobs`; wall-clock stays in the
    /// never-serialized wall list.
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::new();
        t.add("candidates", self.screened);
        t.add("screened_out", self.screened_out);
        t.add("simulated", self.simulated);
        t.add("refined", self.refined);
        t.add("surrogate_fallback", self.fallback);
        t.add(
            "feasible",
            self.outcomes.iter().filter(|o| o.feasible).count() as u64,
        );
        t.add(
            "frontier",
            self.outcomes.iter().filter(|o| o.on_frontier).count() as u64,
        );
        t.add(
            "oom_cells",
            self.outcomes.iter().filter(|o| o.summary.oom).count() as u64,
        );
        for o in &self.outcomes {
            t.add("num_allocs", o.summary.num_allocs);
            t.add("cache_hits", o.summary.num_cache_hits);
        }
        t.add(
            "sim_reduction_pct",
            (100 * (self.screened - self.simulated)) / self.screened.max(1),
        );
        t.add(
            "surrogate_max_rel_err_ppm",
            (self.max_rel_err * 1e6).round() as u64,
        );
        t.wall("plan_surrogate", self.wall_seconds);
        t
    }

    /// The frontier as a table. No Rank column: ranks order *every*
    /// feasible candidate and this search never simulates most of them.
    pub fn frontier_table(&self) -> TextTable {
        let mut t = TextTable::new(&[
            "Algo", "Sharing", "Strategy", "Policy", "Allocator", "Reserved", "Frag.",
            "Overhead", "Frontier",
        ]);
        for o in self.frontier() {
            t.row(vec![
                o.candidate.algo.name().to_string(),
                o.candidate.sharing.name().to_string(),
                o.candidate.strategy_label.clone(),
                o.candidate.policy.name().to_string(),
                o.candidate.alloc_label.clone(),
                fmt_gib_paper(o.summary.peak_reserved),
                fmt_gib_paper(o.summary.frag),
                match o.overhead_pct {
                    Some(p) => format!("{p:+.1}%"),
                    None => "n/a".to_string(),
                },
                if o.on_frontier { "*" } else { "" }.to_string(),
            ]);
        }
        t
    }

    /// One-line run summary for CLI output.
    pub fn summary_line(&self) -> String {
        format!(
            "{} candidates screened, {} simulated ({} survivors, {} baselines, {} fallbacks) \
             in {:.2}s on {} worker{}",
            self.screened,
            self.simulated,
            self.simulated - self.refined,
            self.refined,
            self.fallback,
            self.wall_seconds,
            self.jobs,
            if self.jobs == 1 { "" } else { "s" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::plan;
    use crate::surrogate::{fit, FitOptions};

    fn tiny_budget() -> Budget {
        let mut b = Budget::rtx3090_table1();
        b.steps = 1;
        b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
        b.allocators = Some(vec!["default".to_string(), "expandable".to_string()]);
        b
    }

    #[test]
    fn screened_frontier_matches_exhaustive_byte_for_byte() {
        let budget = tiny_budget();
        let model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
        let screened = plan_surrogate(&budget, 2, &model).unwrap();
        let exhaustive = plan(&budget, 2).unwrap();
        assert_eq!(screened.frontier_jsonl(), exhaustive.frontier_jsonl());
        assert!(
            screened.simulated < screened.screened,
            "screening must skip some simulations ({} of {})",
            screened.simulated,
            screened.screened
        );
        assert_eq!(screened.fallback, 0, "self-fit artifact certifies everything");
    }

    #[test]
    fn unknown_groups_fall_back_to_simulation() {
        // Fit on a narrower space than we plan: the zero3 groups are
        // unknown to the artifact and must be simulated, and the
        // frontier must still match the exhaustive search exactly.
        let mut narrow = tiny_budget();
        narrow.strategies = Some(vec!["none".to_string()]);
        let model = fit(&narrow, 2, &FitOptions::for_budget(&narrow)).unwrap();
        let wide = tiny_budget();
        let screened = plan_surrogate(&wide, 2, &model).unwrap();
        assert!(screened.fallback > 0, "unknown groups must fall back");
        assert_eq!(
            screened.frontier_jsonl(),
            plan(&wide, 2).unwrap().frontier_jsonl()
        );
    }

    #[test]
    fn mismatched_provenance_simulates_everything() {
        let budget = tiny_budget();
        let model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
        let mut other = budget.clone();
        other.seed = 0xBEEF;
        let screened = plan_surrogate(&other, 2, &model).unwrap();
        assert_eq!(screened.fallback, screened.screened);
        assert_eq!(screened.simulated - screened.refined, screened.screened);
        assert_eq!(
            screened.frontier_jsonl(),
            plan(&other, 2).unwrap().frontier_jsonl()
        );
    }
}
